"""Repo-root pytest config: make `compile.*` importable when tests run as
`pytest python/tests/` from the repository root (the Makefile runs them
from `python/`, where the package is already on sys.path)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "python"))
