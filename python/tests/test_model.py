"""L2 correctness: BNN forward graph (im2col, pooling, layer wiring)."""

import numpy as np
import pytest
import jax.numpy as jnp
from numpy.testing import assert_array_equal

from compile import model as model_lib
from compile.kernels import ref


@pytest.fixture
def rng():
    return np.random.default_rng(7)


def test_registry_contains_expected_models():
    assert {"tiny", "small", "vgg_small"} <= set(model_lib.MODELS)


def test_vgg_small_geometry_matches_paper():
    """VGG-small layer dims (LQ-Nets): 6 convs 128..512 + FC; the max conv
    vector size of the zoo stays below gamma=8503 (paper §IV-C)."""
    dims = model_lib.MODELS["vgg_small"].layer_dims()
    ks = [d["k"] for d in dims]
    assert ks == [128, 128, 256, 256, 512, 512, 10]
    ss = [d["s"] for d in dims]
    assert ss == [27, 1152, 1152, 2304, 2304, 4608, 8192]
    conv_ss = [d["s"] for d in dims if d["kind"] == "conv"]
    assert max(conv_ss) == 4608  # paper: max conv S across modern CNNs
    assert max(conv_ss) < 8503  # < gamma at DR=50


def test_param_shapes_consistent(rng):
    for name, spec in model_lib.MODELS.items():
        shapes = model_lib.param_shapes(spec)
        assert len(shapes) == len(spec.convs) + 1
        params = model_lib.init_params(rng, spec)
        for p, s in zip(params, shapes):
            assert p.shape == s
            assert set(np.unique(np.asarray(p))) <= {0.0, 1.0}


def im2col_naive(x, kernel, stride):
    """O(HWk^2C) loop oracle for the im2col layout convention."""
    _, h, w, c = x.shape
    pad = (kernel - 1) // 2
    xp = np.pad(np.asarray(x), ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    h_out = (h + 2 * pad - kernel) // stride + 1
    w_out = (w + 2 * pad - kernel) // stride + 1
    out = np.zeros((h_out * w_out, kernel * kernel * c), np.float32)
    for oi in range(h_out):
        for oj in range(w_out):
            row = oi * w_out + oj
            for ki in range(kernel):
                for kj in range(kernel):
                    for ch in range(c):
                        col = (ki * kernel + kj) * c + ch
                        out[row, col] = xp[0, oi * stride + ki, oj * stride + kj, ch]
    return out


def test_im2col_layout(rng):
    x = jnp.asarray(rng.integers(0, 2, size=(1, 6, 6, 3)), dtype=jnp.float32)
    got = np.asarray(model_lib.im2col(x, 3, 1))
    assert_array_equal(got, im2col_naive(x, 3, 1))


def test_im2col_stride2(rng):
    x = jnp.asarray(rng.integers(0, 2, size=(1, 8, 8, 2)), dtype=jnp.float32)
    got = np.asarray(model_lib.im2col(x, 3, 2))
    assert_array_equal(got, im2col_naive(x, 3, 2))


def test_maxpool_is_binary_or(rng):
    x = jnp.asarray(rng.integers(0, 2, size=(1, 4, 4, 2)), dtype=jnp.float32)
    got = np.asarray(model_lib.maxpool2(x))
    xn = np.asarray(x)
    for i in range(2):
        for j in range(2):
            for ch in range(2):
                window = xn[0, 2 * i : 2 * i + 2, 2 * j : 2 * j + 2, ch]
                assert got[0, i, j, ch] == window.max()


def forward_oracle(spec, params, x):
    """Layer-by-layer oracle using only ref.py primitives."""
    a = ref.binarize01(x)
    hw = spec.input_hw
    for i, conv in enumerate(spec.convs):
        patches = jnp.asarray(im2col_naive(a, conv.kernel, conv.stride))
        s = patches.shape[1]
        z = ref.xnor_popcount_ref(patches, params[i])
        act = ref.activation_ref(z, float(s))
        out_hw = hw // conv.stride
        a = act.reshape(1, out_hw, out_hw, conv.out_channels)
        if conv.pool:
            a = model_lib.maxpool2(a)
            out_hw //= 2
        hw = out_hw
    flat = a.reshape(1, -1)
    return ref.xnor_popcount_ref(flat, params[-1])


@pytest.mark.parametrize("name", ["tiny", "small"])
def test_forward_matches_oracle(name, rng):
    spec = model_lib.MODELS[name]
    params = model_lib.init_params(rng, spec)
    x = jnp.asarray(
        rng.normal(size=(1, spec.input_hw, spec.input_hw, spec.input_channels)),
        dtype=jnp.float32,
    )
    got = np.asarray(model_lib.forward(spec, params, x))
    want = np.asarray(forward_oracle(spec, params, x))
    assert_array_equal(got, want)


def test_forward_logits_shape_and_range(rng):
    spec = model_lib.MODELS["tiny"]
    params = model_lib.init_params(rng, spec)
    x = jnp.zeros((1, 8, 8, 3), jnp.float32)
    logits = np.asarray(model_lib.forward(spec, params, x))
    assert logits.shape == (1, 10)
    s_fc = model_lib.param_shapes(spec)[-1][0]
    assert logits.min() >= 0 and logits.max() <= s_fc


def test_forward_gamma_noop_when_large(rng):
    spec = model_lib.MODELS["tiny"]
    params = model_lib.init_params(rng, spec)
    x = jnp.asarray(rng.normal(size=(1, 8, 8, 3)), dtype=jnp.float32)
    a = np.asarray(model_lib.forward(spec, params, x))
    b = np.asarray(model_lib.forward(spec, params, x, gamma=8503.0))
    assert_array_equal(a, b)


def test_forward_wrong_param_count_raises(rng):
    spec = model_lib.MODELS["tiny"]
    params = model_lib.init_params(rng, spec)[:-1]
    with pytest.raises(ValueError):
        model_lib.forward(spec, params, jnp.zeros((1, 8, 8, 3)))
