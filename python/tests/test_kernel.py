"""L1 correctness: Pallas XNOR-popcount kernel vs pure-jnp oracle.

Counts are small integers carried in f32, so every comparison here is
*exact* (assert_array_equal), not allclose — any discrepancy is a real
kernel bug, not float noise.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp
from numpy.testing import assert_array_equal

from compile.kernels import ref
from compile.kernels.xnor_popcount import xnor_gemm, xnor_gemm_sliced


def rand_bits(rng, shape):
    return jnp.asarray(rng.integers(0, 2, size=shape), dtype=jnp.float32)


@pytest.fixture
def rng():
    return np.random.default_rng(0xB17C0117)


# ---------------------------------------------------------------------------
# Oracle self-consistency
# ---------------------------------------------------------------------------


def test_xnor_truth_table():
    a = jnp.array([0.0, 0.0, 1.0, 1.0])
    b = jnp.array([0.0, 1.0, 0.0, 1.0])
    assert_array_equal(np.asarray(ref.xnor_bit(a, b)), [1.0, 0.0, 0.0, 1.0])


def test_closed_form_matches_bitwise(rng):
    i = rand_bits(rng, (17, 53))
    w = rand_bits(rng, (53, 11))
    assert_array_equal(
        np.asarray(ref.xnor_popcount_ref(i, w)),
        np.asarray(ref.xnor_popcount_closed_form(i, w)),
    )


def test_popcount_bounds(rng):
    i = rand_bits(rng, (9, 40))
    w = rand_bits(rng, (40, 7))
    z = np.asarray(ref.xnor_popcount_ref(i, w))
    assert z.min() >= 0 and z.max() <= 40


def test_popcount_identical_vectors_is_s(rng):
    i = rand_bits(rng, (5, 33))
    z = np.asarray(ref.xnor_popcount_ref(i, i.T))
    assert_array_equal(np.diag(z), np.full(5, 33.0))


def test_popcount_complement_is_zero(rng):
    i = rand_bits(rng, (5, 33))
    z = np.asarray(ref.xnor_popcount_ref(i, (1.0 - i).T))
    assert_array_equal(np.diag(z), np.zeros(5))


# ---------------------------------------------------------------------------
# Kernel vs oracle
# ---------------------------------------------------------------------------


def test_kernel_matches_ref_aligned(rng):
    i = rand_bits(rng, (64, 128))
    w = rand_bits(rng, (128, 64))
    assert_array_equal(
        np.asarray(xnor_gemm(i, w)), np.asarray(ref.xnor_popcount_ref(i, w))
    )


def test_kernel_matches_ref_ragged(rng):
    # Shapes that force padding on every axis.
    i = rand_bits(rng, (37, 211))
    w = rand_bits(rng, (211, 19))
    assert_array_equal(
        np.asarray(xnor_gemm(i, w)), np.asarray(ref.xnor_popcount_ref(i, w))
    )


def test_kernel_activation_fused(rng):
    i = rand_bits(rng, (30, 90))
    w = rand_bits(rng, (90, 12))
    got = np.asarray(xnor_gemm(i, w, apply_activation=True))
    want = np.asarray(ref.xnor_gemm_act_ref(i, w))
    assert_array_equal(got, want)
    assert set(np.unique(got)) <= {0.0, 1.0}


def test_kernel_gamma_saturation(rng):
    i = rand_bits(rng, (16, 64))
    w = rand_bits(rng, (64, 16))
    gamma = 20.0
    got = np.asarray(xnor_gemm(i, w, gamma=gamma))
    want = np.minimum(np.asarray(ref.xnor_popcount_ref(i, w)), gamma)
    assert_array_equal(got, want)
    assert got.max() <= gamma


def test_kernel_gamma_unbinding_when_large(rng):
    # gamma above S never clips (paper §IV-C: max S=4608 < gamma=8503).
    i = rand_bits(rng, (8, 48))
    w = rand_bits(rng, (48, 8))
    assert_array_equal(
        np.asarray(xnor_gemm(i, w, gamma=8503.0)),
        np.asarray(ref.xnor_popcount_ref(i, w)),
    )


def test_sliced_kernel_pass_equivalence(rng):
    # block_s = N slice (one PASS per grid step) must not change results.
    i = rand_bits(rng, (12, 95))
    w = rand_bits(rng, (95, 10))
    for n in (19, 53, 66):  # paper Table II N values
        assert_array_equal(
            np.asarray(xnor_gemm_sliced(i, w, slice_n=n)),
            np.asarray(ref.xnor_popcount_ref(i, w)),
        )


def test_kernel_all_ones_all_zeros():
    i = jnp.ones((4, 32), jnp.float32)
    w = jnp.zeros((32, 4), jnp.float32)
    assert_array_equal(np.asarray(xnor_gemm(i, w)), np.zeros((4, 4)))
    assert_array_equal(
        np.asarray(xnor_gemm(i, jnp.ones((32, 4), jnp.float32))),
        np.full((4, 4), 32.0),
    )


def test_kernel_single_element():
    for a in (0.0, 1.0):
        for b in (0.0, 1.0):
            i = jnp.full((1, 1), a, jnp.float32)
            w = jnp.full((1, 1), b, jnp.float32)
            want = 1.0 if a == b else 0.0
            assert np.asarray(xnor_gemm(i, w))[0, 0] == want


def test_shape_mismatch_raises():
    with pytest.raises(ValueError):
        xnor_gemm(jnp.zeros((2, 3)), jnp.zeros((4, 2)))


# ---------------------------------------------------------------------------
# Hypothesis sweep: shapes / block sizes / gamma
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(1, 40),
    s=st.integers(1, 160),
    k=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
    block_s=st.sampled_from([16, 32, 128]),
)
def test_kernel_hypothesis_sweep(h, s, k, seed, block_s):
    rng = np.random.default_rng(seed)
    i = rand_bits(rng, (h, s))
    w = rand_bits(rng, (s, k))
    got = np.asarray(xnor_gemm(i, w, block_s=block_s))
    want = np.asarray(ref.xnor_popcount_ref(i, w))
    assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(
    s=st.integers(1, 120),
    gamma=st.integers(1, 140),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_hypothesis_gamma(s, gamma, seed):
    rng = np.random.default_rng(seed)
    i = rand_bits(rng, (6, s))
    w = rand_bits(rng, (s, 6))
    got = np.asarray(xnor_gemm(i, w, gamma=float(gamma)))
    want = np.minimum(np.asarray(ref.xnor_popcount_ref(i, w)), float(gamma))
    assert_array_equal(got, want)
