"""AOT exporter contract tests: manifest schema and HLO-text validity.

The rust runtime's only knowledge of the python layer is the manifest +
HLO text; these tests pin that contract from the python side (the rust
side pins it again in rust/tests/runtime_roundtrip.rs).
"""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model as model_lib


@pytest.fixture(scope="module")
def export_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    # Export the fast subset through the real CLI entry point.
    result = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--models",
            "tiny",
        ],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
    )
    assert result.returncode == 0, result.stderr
    return out


def test_manifest_schema(export_dir):
    with open(export_dir / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text"
    arts = manifest["artifacts"]
    assert {"xnor_gemm", "xnor_gemm_bench", "bnn_tiny"} <= set(arts)
    tiny = arts["bnn_tiny"]
    assert tiny["kind"] == "bnn_forward"
    assert tiny["model"] == "tiny"
    assert tiny["output"]["shape"] == [1, 10]
    # Arg list: input then one weight matrix per layer.
    spec = model_lib.MODELS["tiny"]
    assert len(tiny["args"]) == 1 + len(spec.convs) + 1
    assert tiny["args"][0]["shape"] == [1, 8, 8, 3]
    for arg, shape in zip(tiny["args"][1:], model_lib.param_shapes(spec)):
        assert tuple(arg["shape"]) == shape
    # Layer geometry matches the ModelSpec-derived table.
    assert tiny["layers"] == spec.layer_dims()


def test_hlo_files_exist_and_parse(export_dir):
    with open(export_dir / "manifest.json") as f:
        manifest = json.load(f)
    for name, art in manifest["artifacts"].items():
        path = export_dir / art["file"]
        assert path.exists(), name
        text = path.read_text()
        # HLO text structural sanity: an ENTRY computation with a ROOT.
        assert "ENTRY" in text, name
        assert "ROOT" in text, name
        # return_tuple=True → the entry computation returns a tuple.
        assert "tuple" in text.lower(), name


def test_manifest_merge_preserves_existing(export_dir):
    """Partial re-export must keep other artifacts in the manifest."""
    result = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(export_dir),
            "--models",
            "",
            "--skip-gemm",
        ],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
    )
    assert result.returncode == 0, result.stderr
    with open(export_dir / "manifest.json") as f:
        manifest = json.load(f)
    assert "bnn_tiny" in manifest["artifacts"]
    assert "xnor_gemm" in manifest["artifacts"]


def test_gemm_artifact_metadata():
    text, meta = aot.export_gemm((8, 16, 4), apply_activation=True)
    assert meta["kind"] == "xnor_gemm"
    assert meta["apply_activation"] is True
    assert meta["args"][0]["shape"] == [8, 16]
    assert meta["args"][1]["shape"] == [16, 4]
    assert meta["output"]["shape"] == [8, 4]
    assert "ENTRY" in text


def test_unknown_model_rejected(tmp_path):
    result = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(tmp_path),
            "--models",
            "not_a_model",
        ],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
    )
    assert result.returncode != 0
