"""Analog-noise tolerance study (PCA as a noisy thresholder).

The rust resolution analysis (analysis::pca_resolution) derives the PCA's
count noise: sigma ≈ 2.4 counts at γ = 8503 (DR = 50) and ≈ 11 counts at
γ = 39682 (DR = 3). These tests quantify the consequence for BNN
activations: flip probability of the comparator decision as a function of
analog sigma, and its concentration on near-threshold counts.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.kernels.ref import activation_ref, xnor_popcount_ref
from compile.kernels.xnor_popcount import xnor_gemm_noisy

# Analog count-noise operating points from the rust analysis.
SIGMA_DR50 = 2.4
SIGMA_DR3 = 11.0


def rand_bits(rng, shape):
    return jnp.asarray(rng.integers(0, 2, size=shape), dtype=jnp.float32)


@pytest.fixture
def data():
    rng = np.random.default_rng(0xA11A)
    i = rand_bits(rng, (64, 512))
    w = rand_bits(rng, (512, 32))
    return i, w


def flip_rate(i, w, sigma, seed=0):
    ideal = np.asarray(activation_ref(xnor_popcount_ref(i, w), float(i.shape[1])))
    noisy = np.asarray(
        xnor_gemm_noisy(i, w, sigma, jax.random.PRNGKey(seed))
    )
    return float(np.mean(ideal != noisy))


def test_zero_noise_is_exact(data):
    i, w = data
    assert flip_rate(i, w, 0.0) == 0.0


def test_flip_rate_monotone_in_sigma(data):
    i, w = data
    rates = [flip_rate(i, w, s) for s in (0.0, SIGMA_DR50, SIGMA_DR3, 40.0)]
    assert all(a <= b + 1e-9 for a, b in zip(rates, rates[1:])), rates


def test_operating_points_have_low_flip_rate(data):
    # At the paper's design points the comparator decision is robust:
    # random binarized data gives |z - S/2| ~ 0.5*sqrt(S) ≈ 11 counts at
    # S = 512, so sigma = 2.4 flips only a small fraction of activations.
    i, w = data
    r50 = flip_rate(i, w, SIGMA_DR50)
    assert r50 < 0.15, r50
    # The DR=3 point (sigma ~ 11 counts) is noticeably noisier at this
    # (small) S — large-S layers gain margin as sqrt(S).
    r3 = flip_rate(i, w, SIGMA_DR3)
    assert r50 < r3 < 0.5


def test_flips_concentrate_near_threshold(data):
    i, w = data
    s = i.shape[1]
    z = np.asarray(xnor_popcount_ref(i, w))
    ideal = np.asarray(activation_ref(jnp.asarray(z), float(s)))
    noisy = np.asarray(xnor_gemm_noisy(i, w, SIGMA_DR50, jax.random.PRNGKey(7)))
    flipped = ideal != noisy
    if flipped.any():
        margin_flipped = np.abs(z[flipped] - 0.5 * s)
        margin_all = np.abs(z - 0.5 * s)
        assert margin_flipped.mean() < margin_all.mean()
        # No flip should occur far from the threshold (> 5 sigma).
        assert margin_flipped.max() <= 5 * SIGMA_DR50


def test_noisy_counts_without_activation(data):
    i, w = data
    z_noisy = np.asarray(
        xnor_gemm_noisy(i, w, 1.0, jax.random.PRNGKey(1), apply_activation=False)
    )
    z = np.asarray(xnor_popcount_ref(i, w))
    # Noise is zero-mean and unit-ish sigma.
    resid = z_noisy - z
    assert abs(resid.mean()) < 0.1
    assert 0.8 < resid.std() < 1.2
