"""AOT exporter: lower the L2 BNN graphs to HLO **text** artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla_extension 0.5.1
linked by the rust `xla` crate rejects (`proto.id() <= INT_MAX`); the HLO
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Run once at build time (`make artifacts`); python is never on the request
path.  Emits:

  artifacts/xnor_gemm.hlo.txt        one-layer XPE pipeline (quickstart)
  artifacts/xnor_gemm_bench.hlo.txt  larger GEMM for the rust hot-path bench
  artifacts/bnn_tiny.hlo.txt         tiny BNN forward (serving hot path)
  artifacts/bnn_small.hlo.txt        small BNN forward (integration tests)
  artifacts/bnn_vgg_small.hlo.txt    VGG-small forward (end-to-end example)
  artifacts/manifest.json            arg shapes + layer geometry for rust
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_lib
from .kernels.xnor_popcount import xnor_gemm

# (H, S, K) for the standalone GEMM artifacts.
GEMM_SHAPE = (64, 288, 64)
GEMM_BENCH_SHAPE = (256, 1152, 128)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="float32"):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def export_gemm(shape, apply_activation: bool):
    """Standalone XPE pipeline: bitcount + comparator over (H,S)x(S,K)."""
    h, s, k = shape

    def fn(inputs, weights):
        return (
            xnor_gemm(inputs, weights, apply_activation=apply_activation),
        )

    lowered = jax.jit(fn).lower(_spec((h, s)), _spec((s, k)))
    return to_hlo_text(lowered), {
        "kind": "xnor_gemm",
        "apply_activation": apply_activation,
        "args": [
            {"name": "inputs", "shape": [h, s], "dtype": "f32"},
            {"name": "weights", "shape": [s, k], "dtype": "f32"},
        ],
        "output": {"shape": [h, k], "dtype": "f32"},
    }


def export_model(name: str):
    """Full BNN forward: f(x, w0, ..., wL) -> (1, classes) logits."""
    spec = model_lib.MODELS[name]
    fn = model_lib.make_forward_fn(spec)
    x_spec = _spec((1, spec.input_hw, spec.input_hw, spec.input_channels))
    w_specs = [_spec(s) for s in model_lib.param_shapes(spec)]
    lowered = jax.jit(fn).lower(x_spec, *w_specs)
    args = [
        {
            "name": "x",
            "shape": [1, spec.input_hw, spec.input_hw, spec.input_channels],
            "dtype": "f32",
        }
    ]
    for i, s in enumerate(model_lib.param_shapes(spec)):
        args.append({"name": f"w{i}", "shape": list(s), "dtype": "f32"})
    meta = {
        "kind": "bnn_forward",
        "model": name,
        "args": args,
        "output": {"shape": [1, spec.num_classes], "dtype": "f32"},
        "layers": spec.layer_dims(),
        "input_hw": spec.input_hw,
        "input_channels": spec.input_channels,
        "num_classes": spec.num_classes,
    }
    return to_hlo_text(lowered), meta


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--models",
        default="tiny,small,vgg_small",
        help="comma-separated model names to export",
    )
    parser.add_argument(
        "--skip-gemm", action="store_true", help="skip standalone GEMM artifacts"
    )
    args = parser.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "artifacts": {}}
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    if os.path.exists(manifest_path):
        # Merge: partial re-exports must not drop other artifacts' entries.
        with open(manifest_path) as f:
            manifest = json.load(f)

    def emit(stem: str, text: str, meta: dict):
        path = os.path.join(args.out_dir, f"{stem}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["file"] = f"{stem}.hlo.txt"
        manifest["artifacts"][stem] = meta
        print(f"[aot] wrote {path} ({len(text)} chars)", flush=True)

    if not args.skip_gemm:
        text, meta = export_gemm(GEMM_SHAPE, apply_activation=True)
        emit("xnor_gemm", text, meta)
        text, meta = export_gemm(GEMM_BENCH_SHAPE, apply_activation=False)
        emit("xnor_gemm_bench", text, meta)

    for name in [m for m in args.models.split(",") if m]:
        if name not in model_lib.MODELS:
            print(f"[aot] unknown model '{name}'", file=sys.stderr)
            return 1
        text, meta = export_model(name)
        emit(f"bnn_{name}", text, meta)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {os.path.join(args.out_dir, 'manifest.json')}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
