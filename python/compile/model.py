"""Layer-2 JAX model: binarized CNN forward pass built on the L1 kernel.

The OXBNN paper evaluates inference of four BNNs (VGG-small, ResNet18,
MobileNetV2, ShuffleNetV2) binarized with LQ-Nets into the {0,1} value set.
This module defines the *functional* BNN graph used for end-to-end
validation: every convolution is an im2col + XNOR-bitcount GEMM routed
through :func:`kernels.xnor_popcount.xnor_gemm` (the Pallas XPE kernel),
followed by the comparator activation and optional 2x2 max-pooling
(binary max == OR, matching the paper's pooling units in Fig. 6).

The graph is AOT-lowered once by :mod:`aot` to HLO text; the rust L3 then
executes it through PJRT with weights it generates itself and cross-checks
against its own integer functional engine (``rust/src/functional/``).

im2col layout convention (must match rust/src/functional/im2col.rs):
  patch feature index = (ki * KW + kj) * C + c
i.e. kernel-position-major, channel-minor.  Spatial padding uses binary 0
(which encodes -1 in the {-1,+1} view), as BNN hardware does.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.ref import activation_ref, binarize01
from .kernels.xnor_popcount import xnor_gemm


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One binarized conv layer: 3x3 (or kxk) stride-1 SAME convolution."""

    out_channels: int
    kernel: int = 3
    stride: int = 1
    pool: bool = False  # 2x2 max-pool after activation


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A binarized CNN: input geometry + conv stack + linear classifier."""

    name: str
    input_hw: int
    input_channels: int
    convs: Tuple[ConvSpec, ...]
    num_classes: int

    def layer_dims(self) -> List[dict]:
        """Geometry of every XNOR-GEMM layer: (H, S, K) plus feature map.

        This is the exact table the rust workload models are derived from;
        test_model.py pins it against rust/src/workloads expectations.
        """
        dims = []
        hw = self.input_hw
        c = self.input_channels
        for spec in self.convs:
            out_hw = hw // spec.stride
            s = spec.kernel * spec.kernel * c
            dims.append(
                dict(
                    kind="conv",
                    h=out_hw * out_hw,
                    s=s,
                    k=spec.out_channels,
                    fmap_hw=out_hw,
                )
            )
            hw = out_hw // 2 if spec.pool else out_hw
            c = spec.out_channels
        dims.append(
            dict(kind="fc", h=1, s=hw * hw * c, k=self.num_classes, fmap_hw=1)
        )
        return dims


# ---------------------------------------------------------------------------
# Model zoo (geometry mirrors rust/src/workloads/*.rs)
# ---------------------------------------------------------------------------

MODELS = {
    # Minimal graph for fast unit tests and the serving hot path.
    "tiny": ModelSpec(
        name="tiny",
        input_hw=8,
        input_channels=3,
        convs=(ConvSpec(8, pool=True), ConvSpec(16, pool=True)),
        num_classes=10,
    ),
    # Mid-size net for integration tests / examples.
    "small": ModelSpec(
        name="small",
        input_hw=16,
        input_channels=3,
        convs=(ConvSpec(32, pool=True), ConvSpec(64, pool=True)),
        num_classes=10,
    ),
    # VGG-small as used by LQ-Nets [9] and the paper's evaluation:
    # 6 convs (128,128,256,256,512,512) with pooling after every pair.
    "vgg_small": ModelSpec(
        name="vgg_small",
        input_hw=32,
        input_channels=3,
        convs=(
            ConvSpec(128),
            ConvSpec(128, pool=True),
            ConvSpec(256),
            ConvSpec(256, pool=True),
            ConvSpec(512),
            ConvSpec(512, pool=True),
        ),
        num_classes=10,
    ),
}


def param_shapes(spec: ModelSpec) -> List[Tuple[int, int]]:
    """Shapes of the flattened {0,1} weight matrices, layer order."""
    return [(d["s"], d["k"]) for d in spec.layer_dims()]


def init_params(rng: np.random.Generator, spec: ModelSpec) -> List[jnp.ndarray]:
    """Synthetic binarized weights (see DESIGN.md: FPS depends on geometry,
    not learned values; functional checks use the same synthetic weights on
    both the jax and rust sides)."""
    return [
        jnp.asarray(rng.integers(0, 2, size=shape), dtype=jnp.float32)
        for shape in param_shapes(spec)
    ]


def im2col(x: jnp.ndarray, kernel: int, stride: int) -> jnp.ndarray:
    """Flatten SAME-padded kxk patches of an NHWC=(1,H,W,C) {0,1} map.

    Returns (H_out * W_out, kernel*kernel*C) with the layout documented in
    the module docstring.
    """
    _, h, w, c = x.shape
    pad = (kernel - 1) // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    h_out = (h + 2 * pad - kernel) // stride + 1
    w_out = (w + 2 * pad - kernel) // stride + 1
    cols = []
    for ki in range(kernel):
        for kj in range(kernel):
            cols.append(
                xp[
                    :,
                    ki : ki + h_out * stride : stride,
                    kj : kj + w_out * stride : stride,
                    :,
                ]
            )
    patches = jnp.concatenate(cols, axis=-1)  # (1, H', W', k*k*C)
    return patches.reshape(h_out * w_out, kernel * kernel * c)


def maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 stride-2 max-pool of an NHWC {0,1} map (binary max == OR)."""
    _, h, w, c = x.shape
    x = x.reshape(1, h // 2, 2, w // 2, 2, c)
    return jnp.max(x, axis=(2, 4))


def forward(
    spec: ModelSpec,
    params: Sequence[jnp.ndarray],
    x: jnp.ndarray,
    *,
    gamma: Optional[float] = None,
) -> jnp.ndarray:
    """Full BNN forward pass.

    Args:
      spec: model geometry.
      params: list of (S, K) {0,1} weight matrices, conv layers then FC.
      x: (1, H, W, C) real-valued input; binarized on entry (paper Eq. 1).
      gamma: optional PCA accumulation capacity applied in every layer.

    Returns:
      (1, num_classes) f32 bitcount logits from the final linear layer.
    """
    if len(params) != len(spec.convs) + 1:
        raise ValueError(
            f"{spec.name}: expected {len(spec.convs) + 1} weight matrices, "
            f"got {len(params)}"
        )
    a = binarize01(x)
    hw = spec.input_hw
    for i, conv in enumerate(spec.convs):
        patches = im2col(a, conv.kernel, conv.stride)  # (H'W', S)
        s = patches.shape[1]
        z = xnor_gemm(patches, params[i], gamma=gamma)
        act = activation_ref(z, float(s))
        out_hw = hw // conv.stride
        a = act.reshape(1, out_hw, out_hw, conv.out_channels)
        if conv.pool:
            a = maxpool2(a)
            out_hw //= 2
        hw = out_hw
    flat = a.reshape(1, -1)
    logits = xnor_gemm(flat, params[-1], gamma=gamma)
    return logits


def make_forward_fn(spec: ModelSpec, gamma: Optional[float] = None):
    """Positional-arg wrapper for AOT lowering: f(x, w0, w1, ...)."""

    def fn(x, *weights):
        return (forward(spec, list(weights), x, gamma=gamma),)

    return fn
