"""Pure-jnp reference oracle for the XNOR-bitcount kernel.

This module is the CORE correctness signal for Layer 1: every Pallas kernel
in :mod:`xnor_popcount` must agree bit-exactly (counts are small integers
held in f32) with the functions below.

The paper (OXBNN, ISQED 2023) processes binarized vectors drawn from the
binary value set ``{0, 1}`` (Section II-A).  A vector-dot-product (VDP)
between a binarized input vector ``I`` and weight vector ``W`` of size S is

    z = sum_i xnor(I_i, W_i)                       (paper Eq. 2)

with ``xnor(a, b) = a*b + (1-a)*(1-b)`` over {0, 1}.  The activation for
the next layer is the comparator (paper Section II-A):

    act = 1 if z > 0.5 * z_max else 0,   z_max = S.
"""

from __future__ import annotations

import jax.numpy as jnp


def binarize01(x: jnp.ndarray) -> jnp.ndarray:
    """Binary quantization into the {0, 1} value set (paper Eq. 1 mapped
    onto the {0,1} encoding used by all optical BNN accelerators)."""
    return (x >= 0).astype(jnp.float32)


def xnor_bit(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Element-wise XNOR over {0,1}-valued float arrays."""
    return a * b + (1.0 - a) * (1.0 - b)


def xnor_popcount_ref(inputs: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Reference XNOR-bitcount GEMM.

    Args:
      inputs:  (H, S) array over {0, 1} — H flattened input vectors.
      weights: (S, K) array over {0, 1} — K flattened weight vectors.

    Returns:
      (H, K) float32 array of bitcounts; entry (h, k) is the number of bit
      positions where inputs[h] and weights[:, k] agree — i.e. the VDP of
      paper Eq. 2 computed with one XPE pass per N-slice.
    """
    a = inputs[:, :, None]  # (H, S, 1)
    b = weights[None, :, :]  # (1, S, K)
    return jnp.sum(xnor_bit(a, b), axis=1).astype(jnp.float32)


def xnor_popcount_closed_form(inputs: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Closed-form identity used by the Pallas kernel.

    sum_i [1 - a_i - b_i + 2 a_i b_i]
      = S - rowsum(a) - colsum(b) + 2 * (a @ b)

    This turns the bit-level XNOR into one MXU-friendly matmul plus an
    affine correction — the TPU adaptation of the paper's wavelength-
    parallel OXG array (DESIGN.md §Hardware-Adaptation).
    """
    h, s = inputs.shape
    s2, k = weights.shape
    assert s == s2
    matmul = inputs @ weights
    row = jnp.sum(inputs, axis=1, keepdims=True)  # (H, 1)
    col = jnp.sum(weights, axis=0, keepdims=True)  # (1, K)
    return (jnp.float32(s) - row - col + 2.0 * matmul).astype(jnp.float32)


def pca_saturate(z: jnp.ndarray, gamma: float) -> jnp.ndarray:
    """Photo-Charge Accumulator saturation.

    The PCA's TIR output saturates once gamma '1's have accumulated
    (paper Section III-B2 / Table II).  Accumulated partial counts are
    non-negative and monotone, so clamping the final count equals clamping
    continuously during accumulation.
    """
    return jnp.minimum(z, jnp.float32(gamma))


def activation_ref(z: jnp.ndarray, z_max: float) -> jnp.ndarray:
    """Comparator activation: compare(z, 0.5 * z_max) (paper Section II-A).

    Models the PCA comparator with V_REF at half the TIR dynamic range
    (paper Fig. 4: V_REF = 2.5 V of a 5 V range).
    """
    return (z > 0.5 * z_max).astype(jnp.float32)


def xnor_gemm_act_ref(
    inputs: jnp.ndarray,
    weights: jnp.ndarray,
    gamma: float | None = None,
) -> jnp.ndarray:
    """Full XPE pipeline reference: bitcount -> PCA saturation -> comparator."""
    z = xnor_popcount_ref(inputs, weights)
    s = inputs.shape[1]
    if gamma is not None:
        z = pca_saturate(z, gamma)
    return activation_ref(z, float(s))
