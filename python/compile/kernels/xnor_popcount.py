"""Layer-1 Pallas kernel: XNOR-bitcount GEMM with PCA semantics.

This is the compute hot-spot of the OXBNN paper mapped onto a TPU-style
kernel.  The paper's XPE performs, per PASS, an N-wide bit-parallel XNOR
(one OXG per wavelength) followed by an analog bitcount in the PCA, which
accumulates up to ``alpha = gamma / N`` slices without any psum-reduction
network (paper Fig. 5(b)).

TPU adaptation (DESIGN.md §Hardware-Adaptation):

* One *grid step along the S axis* of the kernel corresponds to one PASS:
  a ``block_s``-wide slice of the binarized vectors is staged HBM->VMEM via
  ``BlockSpec`` (the analog of the DWDM broadcast of a slice to the OXG
  array).
* The bit-level XNOR popcount is computed with the closed form
  ``bs - rowsum(a) - colsum(b) + 2*(a@b)`` so the inner product runs on the
  MXU systolic array instead of element-wise VPU ops.
* The f32 accumulator tile plays the PCA capacitor: it is monotone
  non-decreasing across S-steps and is clamped to ``gamma`` at the end
  (monotonicity makes the final clamp exact w.r.t. continuous saturation).
* The comparator activation (``z > 0.5 * S``) is fused into the last
  S-step, mirroring the PCA's comparator at V_REF = 2.5 V.

The kernel is always launched with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret mode lowers to plain HLO
(while-loops + dynamic slices) that both jax and the rust PJRT runtime can
run.  Real-TPU block sizes are documented in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block-size policy (EXPERIMENTS.md §Perf L1):
#
# * Real TPU: (128, 128, 512) tiles fill the 128x128 MXU with a 512-deep
#   S (PASS) pipeline and fit comfortably in VMEM
#   (128·512 + 512·128 + 128·128 f32 ≈ 580 KB of ~16 MB).
# * interpret=True on CPU (this repo's execution mode): every grid step
#   pays python-interpreter + while-loop overhead, so *fewer, larger*
#   steps win. The measured sweep on the 256x1152x128 bench GEMM:
#   (64,64,128) → 1.4 Gbitop/s; (128,128,576) → 14.9; (256,128,1152)
#   → 27.7. The auto policy below picks the largest tile that covers the
#   operand (capped to keep memory bounded), recovering ~20x.
DEFAULT_BLOCK_H = 64
DEFAULT_BLOCK_K = 64
DEFAULT_BLOCK_S = 128

# Caps for the auto policy (elements per axis).
AUTO_MAX_H = 256
AUTO_MAX_K = 128
AUTO_MAX_S = 2048


def _pow2_ceil(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def auto_blocks(h: int, s: int, k: int) -> tuple:
    """Pick (block_h, block_k, block_s) for interpret-mode execution."""
    return (
        min(AUTO_MAX_H, _pow2_ceil(h)),
        min(AUTO_MAX_K, _pow2_ceil(k)),
        min(AUTO_MAX_S, _pow2_ceil(s)),
    )


def _xnor_gemm_kernel(i_ref, w_ref, o_ref, *, block_s: int, n_steps: int,
                      s_actual: int, gamma: Optional[float],
                      apply_activation: bool):
    """Pallas kernel body.

    Grid layout: (H/bh, K/bk, S/bs); the S axis is the PASS axis and is the
    innermost (sequential accumulation) dimension.
    """
    step = pl.program_id(2)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = i_ref[...].astype(jnp.float32)  # (bh, bs) slice of inputs
    b = w_ref[...].astype(jnp.float32)  # (bs, bk) slice of weights

    # Closed-form XNOR popcount partial for this slice (one PASS):
    #   sum_i [1 - a_i - b_i + 2 a_i b_i]
    # a@b runs on the MXU; row/col sums are cheap VPU reductions.
    matmul = jnp.dot(a, b, preferred_element_type=jnp.float32)
    row = jnp.sum(a, axis=1, keepdims=True)
    col = jnp.sum(b, axis=0, keepdims=True)
    partial = jnp.float32(block_s) - row - col + 2.0 * matmul

    o_ref[...] += partial

    @pl.when(step == n_steps - 1)
    def _finalize():
        z = o_ref[...]
        if gamma is not None:
            # PCA saturation: the TIR output rails at gamma accumulated '1's.
            z = jnp.minimum(z, jnp.float32(gamma))
        if apply_activation:
            # Comparator activation at V_REF = half dynamic range.
            z = (z > 0.5 * jnp.float32(s_actual)).astype(jnp.float32)
        o_ref[...] = z


def _pad_to(x: jnp.ndarray, axis: int, multiple: int, value: float) -> jnp.ndarray:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=(
        "gamma", "apply_activation", "block_h", "block_k", "block_s",
    ),
)
def xnor_gemm(
    inputs: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    gamma: Optional[float] = None,
    apply_activation: bool = False,
    block_h: Optional[int] = None,
    block_k: Optional[int] = None,
    block_s: Optional[int] = None,
) -> jnp.ndarray:
    """XNOR-bitcount GEMM via the Pallas XPE kernel.

    Args:
      inputs:  (H, S) {0,1}-valued array (flattened input vectors).
      weights: (S, K) {0,1}-valued array (flattened weight vectors).
      gamma: PCA accumulation capacity; counts are clamped to this value
        (``None`` models an ideal, unbounded accumulator).
      apply_activation: fuse the comparator ``z > 0.5*S`` into the kernel,
        returning {0,1} activations instead of raw bitcounts.
      block_h/block_k/block_s: tile sizes; S is padded with the identity
        pair (input=1, weight=0) whose XNOR contribution is zero.

    Returns:
      (H, K) f32 array of bitcounts (or activations).
    """
    h, s = inputs.shape
    s2, k = weights.shape
    if s != s2:
        raise ValueError(f"shape mismatch: inputs S={s} vs weights S={s2}")

    # Auto block policy unless the caller pinned tile sizes.
    auto = auto_blocks(h, s, k)
    block_h = block_h if block_h is not None else auto[0]
    block_k = block_k if block_k is not None else auto[1]
    block_s = block_s if block_s is not None else auto[2]

    # Pad S with (input=1, weight=0): xnor(1, 0) = 0, so padded positions
    # contribute nothing to the bitcount.  Padding H/K with anything is
    # fine — those rows/cols are sliced away below.
    ip = _pad_to(_pad_to(inputs, 1, block_s, 1.0), 0, block_h, 1.0)
    wp = _pad_to(_pad_to(weights, 0, block_s, 0.0), 1, block_k, 0.0)
    hp, sp = ip.shape
    _, kp = wp.shape
    n_steps = sp // block_s

    kernel = functools.partial(
        _xnor_gemm_kernel,
        block_s=block_s,
        n_steps=n_steps,
        s_actual=s,
        gamma=gamma,
        apply_activation=apply_activation,
    )

    out = pl.pallas_call(
        kernel,
        grid=(hp // block_h, kp // block_k, n_steps),
        in_specs=[
            pl.BlockSpec((block_h, block_s), lambda i, j, t: (i, t)),
            pl.BlockSpec((block_s, block_k), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((block_h, block_k), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((hp, kp), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(ip, wp)
    return out[:h, :k]


def xnor_gemm_sliced(
    inputs: jnp.ndarray,
    weights: jnp.ndarray,
    slice_n: int,
    *,
    gamma: Optional[float] = None,
) -> jnp.ndarray:
    """XNOR GEMM with the paper's explicit per-PASS slicing semantics.

    Uses ``block_s = slice_n`` so every grid step along S is exactly one
    XPE PASS over an N-element vector slice — the structure simulated at
    transaction level by the rust L3 (``rust/src/arch/xpe.rs``).  Produces
    identical results to :func:`xnor_gemm`; exists so tests can pin the
    PASS-for-PASS equivalence of kernel and simulator.
    """
    return xnor_gemm(
        inputs,
        weights,
        gamma=gamma,
        apply_activation=False,
        block_h=min(DEFAULT_BLOCK_H, _ceil_pow2(inputs.shape[0])),
        block_k=min(DEFAULT_BLOCK_K, _ceil_pow2(weights.shape[1])),
        block_s=slice_n,
    )


def _ceil_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def xnor_gemm_noisy(
    inputs: jnp.ndarray,
    weights: jnp.ndarray,
    count_sigma: float,
    key,
    *,
    apply_activation: bool = True,
) -> jnp.ndarray:
    """XNOR GEMM with the PCA's *analog* count noise injected.

    The rust-side resolution analysis (``analysis::pca_resolution``) shows
    the TIR chain adds sigma ≈ 2.4 counts of Gaussian noise at γ = 8503
    (≈ 11 at γ = 39682): the PCA is a thresholder, not an exact counter.
    This wrapper models that by perturbing the ideal bitcount (from the
    Pallas kernel) before the comparator, so accuracy-vs-noise studies can
    quantify how much analog imprecision a BNN tolerates
    (``python/tests/test_noise.py``).
    """
    z = xnor_gemm(inputs, weights)
    noise = count_sigma * jax.random.normal(key, z.shape, dtype=jnp.float32)
    z_noisy = z + noise
    if apply_activation:
        s = inputs.shape[1]
        return (z_noisy > 0.5 * jnp.float32(s)).astype(jnp.float32)
    return z_noisy
