//! End-to-end driver (DESIGN.md E9): full BNN inference through every
//! layer of the stack on a real (synthetic-weight) workload.
//!
//! Pipeline exercised per frame:
//!   L1 Pallas XNOR-popcount kernel → L2 JAX BNN graph → AOT HLO text →
//!   L3 rust PJRT runtime → coordinator serving loop, cross-checked
//!   bit-exactly against the independent rust functional engine, with the
//!   simulated photonic frame latency of OXBNN_50 and OXBNN_5 attached.
//!
//! Results from this run are recorded in EXPERIMENTS.md §E9.
//!
//! Run: `cargo run --release --example bnn_inference -- [frames] [model]`

use std::time::Instant;

use oxbnn::api::{BackendKind, Session};
use oxbnn::arch::accelerator::AcceleratorConfig;
use oxbnn::coordinator::{
    synthetic_weights, workload_from_artifact, InferenceRequest, Server, ServerConfig,
};
use oxbnn::functional::bnn;
use oxbnn::runtime::Manifest;
use oxbnn::util::rng::Rng;
use oxbnn::util::units::fmt_time;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let frames: usize = args.first().map(|a| a.parse().unwrap_or(16)).unwrap_or(16);
    let model = args.get(1).cloned().unwrap_or_else(|| "small".to_string());

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }
    let manifest = Manifest::load(&dir)?;
    let artifact = manifest.get(&format!("bnn_{}", model))?.clone();
    println!(
        "model {}: {} layers, input {}x{}x{}, {} weight tensors",
        model,
        artifact.layers.len(),
        artifact.input_hw.unwrap(),
        artifact.input_hw.unwrap(),
        artifact.input_channels.unwrap(),
        artifact.args.len() - 1
    );

    // Simulated photonic performance of this exact geometry, through the
    // unified Session facade.
    let workload = workload_from_artifact(&artifact);
    for acc in [AcceleratorConfig::oxbnn_50(), AcceleratorConfig::oxbnn_5()] {
        let report = Session::builder()
            .accelerator(acc)
            .workload(workload.clone())
            .backend(BackendKind::Analytic)
            .build()?
            .run();
        println!(
            "  simulated {}: frame {} → {:.0} FPS, {:.2} FPS/W",
            report.accelerator,
            fmt_time(report.frame_latency_s),
            report.fps,
            report.fps_per_w
        );
    }

    // Serve frames through the coordinator (PJRT workers).
    let cfg = ServerConfig::new(&dir, &[model.as_str()]);
    let seed = cfg.weight_seed;
    let server = Server::start(cfg)?;
    let input_len = server.input_len(&model).unwrap();
    let weights = synthetic_weights(&artifact, seed);

    let mut rng = Rng::new(0xE2E);
    let mut mismatches = 0usize;
    let mut agreement_checked = 0usize;
    let t0 = Instant::now();
    for frame in 0..frames {
        let input: Vec<f32> = (0..input_len).map(|_| rng.f64() as f32 - 0.5).collect();
        let resp = server.infer_blocking(InferenceRequest {
            model: model.clone(),
            input: input.clone(),
        })?;
        // Cross-validate a subset (functional engine is O(HSK) per layer).
        if frame < 4 {
            let want = bnn::forward(&artifact, &input, &weights);
            agreement_checked += 1;
            if resp.logits != want {
                mismatches += 1;
                eprintln!("frame {}: MISMATCH {:?} vs {:?}", frame, resp.logits, want);
            }
        }
        if frame == 0 {
            let top = resp
                .logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            println!(
                "  frame 0: class {} (bitcount {}), queue {}, exec {}, photonic(sim) {}",
                top.0,
                top.1,
                fmt_time(resp.queue_s),
                fmt_time(resp.execute_s),
                fmt_time(resp.simulated_photonic_s)
            );
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    println!(
        "served {} frames in {:.3}s → {:.1} frames/s on CPU-PJRT",
        frames,
        elapsed,
        frames as f64 / elapsed
    );
    println!(
        "functional cross-check: {}/{} frames bit-exact",
        agreement_checked - mismatches,
        agreement_checked
    );
    println!("{}", server.metrics.lock().unwrap().report());
    server.shutdown();
    assert_eq!(mismatches, 0, "functional mismatch — see log");
    Ok(())
}
