//! Quickstart: the 60-second tour of the OXBNN library.
//!
//! 1. Regenerate one row of the paper's scalability analysis (Table II).
//! 2. Load the AOT-compiled XNOR-GEMM artifact and run it through PJRT.
//! 3. Compare a conv layer on OXBNN_50 vs a psum-reduction baseline
//!    through the unified `api::Session` facade.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use oxbnn::analysis::scalability::ScalabilitySolver;
use oxbnn::api::analytic_report;
use oxbnn::arch::accelerator::AcceleratorConfig;
use oxbnn::baselines::robin::robin_po;
use oxbnn::mapping::layer::GemmLayer;
use oxbnn::runtime::{HostTensor, Manifest, Runtime};
use oxbnn::util::rng::Rng;
use oxbnn::util::units::fmt_time;
use oxbnn::workloads::Workload;

fn main() -> anyhow::Result<()> {
    // --- 1. Scalability analysis (paper Table II, DR = 50 GS/s row) ------
    let solver = ScalabilitySolver::default();
    let row = solver.solve(50.0);
    println!(
        "Table II @ 50 GS/s: P_PD-opt = {:.2} dBm, N = {}, γ = {}, α = {}",
        row.p_pd_opt_dbm, row.n, row.gamma, row.alpha
    );

    // --- 2. Run the AOT Pallas kernel through PJRT -----------------------
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts/ missing — run `make artifacts` first");
    } else {
        let manifest = Manifest::load(&dir)?;
        let art = manifest.get("xnor_gemm")?;
        let (h, s) = (art.args[0].shape[0], art.args[0].shape[1]);
        let k = art.args[1].shape[1];
        let rt = Runtime::cpu()?;
        let exe = rt.load_artifact(art)?;
        let mut rng = Rng::new(1);
        let out = exe.run(&[
            HostTensor::new(vec![h, s], rng.bits(h * s))?,
            HostTensor::new(vec![s, k], rng.bits(s * k))?,
        ])?;
        let ones: f32 = out.data.iter().sum();
        println!(
            "PJRT xnor_gemm ({}x{} · {}x{}): {} activations high of {}",
            h, s, s, k, ones, out.data.len()
        );
    }

    // --- 3. OXBNN vs baseline on one conv layer (api facade) -------------
    let layer = GemmLayer::new("conv3x3_256", 1024, 1152, 128);
    let probe = Workload::new("conv_probe", vec![layer.clone()]);
    let ox = analytic_report(&AcceleratorConfig::oxbnn_50(), &probe);
    let po = analytic_report(&robin_po(), &probe);
    println!(
        "layer {}: OXBNN_50 {} vs ROBIN_PO {} ({:.1}x faster, psums {} vs {})",
        layer.name,
        fmt_time(ox.frame_latency_s),
        fmt_time(po.frame_latency_s),
        po.frame_latency_s / ox.frame_latency_s,
        ox.psums,
        po.psums
    );
    Ok(())
}
