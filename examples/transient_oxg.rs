//! Regenerates paper Fig. 3 (DESIGN.md E1/E2): the OXG device study.
//!
//! * `--spectra`: Fig. 3(b) — through-port passband positions for each
//!   operand combination (ASCII spectrum around λ_in).
//! * default: Fig. 3(c) — transient XNOR of two 8-bit operand streams at
//!   10 GS/s (ASCII trace), plus a data-rate sweep to the error-free
//!   limit (paper: 50 GS/s).
//!
//! Run: `cargo run --release --example transient_oxg [-- --spectra]`

use oxbnn::devices::oxg::Oxg;
use oxbnn::util::rng::Rng;

fn main() {
    let spectra = std::env::args().any(|a| a == "--spectra");
    let gate = Oxg::new(1550.0);
    if spectra {
        print_spectra(&gate);
    } else {
        print_transient(&gate);
        dr_sweep(&gate);
    }
}

fn print_spectra(gate: &Oxg) {
    println!("Fig. 3(b) — OXG through-port spectra (λ_in = 1550 nm marked '|')\n");
    for (label, i, w) in [
        ("(i,w)=(0,0)  κ     ", false, false),
        ("(i,w)=(0,1)/(1,0)  ", false, true),
        ("(i,w)=(1,1)        ", true, true),
    ] {
        let mut line = String::new();
        for step in -30..=30 {
            let lambda = 1550.0 + step as f64 * 0.05;
            let t = {
                let junctions = i as u32 + w as u32;
                gate.mrr.through_transmission(lambda, junctions)
            };
            line.push(if step == 0 {
                '|'
            } else if t < 0.2 {
                '_' // deep notch
            } else if t < 0.6 {
                '.'
            } else {
                '-'
            });
        }
        let t_in = gate.transmission(i, w);
        println!("{} {}  T(λ_in)={:.2} → {}", label, line, t_in, (t_in > gate.threshold) as u8);
    }
    println!("\nnotch at λ_in only for mixed operands → through-port computes XNOR");
}

fn print_transient(gate: &Oxg) {
    println!("Fig. 3(c) — OXG transient at 10 GS/s (8-bit streams)\n");
    let mut rng = Rng::new(42);
    let bits_i: Vec<bool> = (0..8).map(|_| rng.bool()).collect();
    let bits_w: Vec<bool> = (0..8).map(|_| rng.bool()).collect();
    let spb = 12;
    let trace = gate.transient(&bits_i, &bits_w, 10.0, spb, 3.0);
    let rows = 8;
    for r in (0..rows).rev() {
        let lo = r as f64 / rows as f64;
        let mut line = String::new();
        for v in &trace {
            line.push(if *v >= lo { '#' } else { ' ' });
        }
        println!("T={:.2} {}", lo, line);
    }
    let fmt = |bits: &[bool]| {
        bits.iter()
            .map(|b| format!("{:^width$}", *b as u8, width = spb))
            .collect::<String>()
    };
    println!("  I    {}", fmt(&bits_i));
    println!("  W    {}", fmt(&bits_w));
    let decoded = gate.decode_trace(&trace, spb);
    println!("  XNOR {}", fmt(&decoded));
    let want: Vec<bool> = bits_i.iter().zip(&bits_w).map(|(a, b)| a == b).collect();
    println!("\ndecode {}", if decoded == want { "OK" } else { "FAILED" });
}

fn dr_sweep(gate: &Oxg) {
    println!("\nData-rate sweep (device τ = 3 ps, 256-bit PRBS):");
    let max = gate.max_error_free_dr(3.0, 0xD12);
    for dr in [3.0, 5.0, 10.0, 20.0, 30.0, 40.0, 50.0, 64.0, 80.0] {
        let ok = dr <= max;
        println!("  {:>4} GS/s: {}", dr, if ok { "error-free" } else { "eye closed" });
    }
    println!("max error-free DR = {} GS/s (paper claims 50 GS/s)", max);
}
