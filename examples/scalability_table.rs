//! Regenerates paper Table II (DESIGN.md E3): the XPC scalability
//! analysis — receiver sensitivity (Eqs. 3–4), feasible XPE size N
//! (Eq. 5), and PCA capacity (γ, α) across the paper's data-rate sweep —
//! side by side with the published values.
//!
//! Run: `cargo run --release --example scalability_table`

use oxbnn::analysis::pca_capacity::{gamma_analytic, PAPER_TABLE2};
use oxbnn::analysis::scalability::ScalabilitySolver;
use oxbnn::devices::pca::PcaParams;
use oxbnn::devices::photodetector::Photodetector;
use oxbnn::util::bench::Table;

fn main() {
    let solver = ScalabilitySolver::default();
    let pd = Photodetector::default();
    let pca = PcaParams::default();

    let mut t = Table::new(&[
        "DR (GS/s)",
        "P_PD-opt (dBm)",
        "paper",
        "N",
        "paper",
        "gamma",
        "paper",
        "alpha",
        "paper",
        "gamma(analytic)",
    ]);
    let mut n_exact = 0;
    for (row, paper) in solver.table2().iter().zip(PAPER_TABLE2.iter()) {
        let (_, p_paper, n_paper, g_paper, a_paper) = *paper;
        if row.n == n_paper {
            n_exact += 1;
        }
        // First-principles γ estimate at the PD-received power.
        let g_analytic = gamma_analytic(
            &pca,
            &pd,
            row.p_pd_opt_dbm - solver.budget.il_penalty_db,
            row.dr_gsps,
        );
        t.row(&[
            format!("{}", row.dr_gsps),
            format!("{:.2}", row.p_pd_opt_dbm),
            format!("{:.2}", p_paper),
            format!("{}", row.n),
            format!("{}", n_paper),
            format!("{}", row.gamma),
            format!("{}", g_paper),
            format!("{}", row.alpha),
            format!("{}", a_paper),
            format!("{}", g_analytic),
        ]);
    }
    println!("Paper Table II — reproduced vs published\n");
    t.print();
    println!(
        "\nN exact on {}/7 rows (P_PD-opt within 0.15 dB on all rows).",
        n_exact
    );
    println!(
        "gamma column uses the MultiSim-extracted calibration (see DESIGN.md);\n\
         gamma(analytic) is the first-principles charge-model estimate."
    );
    println!(
        "\n§IV-C check: max modern-CNN conv vector S = 4608 < γ(50 GS/s) = {} →\n\
         OXBNN needs no psum reduction network.",
        solver.solve(50.0).gamma
    );
}
