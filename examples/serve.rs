//! Serving demo (DESIGN.md E10): drive the coordinator with a Poisson
//! open-loop request stream from multiple client threads and report
//! latency percentiles, batching behaviour, and the simulated photonic
//! frame latency.
//!
//! Run: `cargo run --release --example serve -- [requests] [rate_hz]`

use std::sync::Arc;
use std::time::{Duration, Instant};

use oxbnn::coordinator::{InferenceRequest, Server, ServerConfig};
use oxbnn::util::rng::Rng;
use oxbnn::util::units::fmt_time;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let total: usize = args.first().map(|a| a.parse().unwrap_or(64)).unwrap_or(64);
    let rate: f64 = args.get(1).map(|a| a.parse().unwrap_or(500.0)).unwrap_or(500.0);

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }
    let mut cfg = ServerConfig::new(&dir, &["tiny"]);
    cfg.max_batch = 16;
    cfg.max_wait = Duration::from_millis(1);
    let server = Arc::new(Server::start(cfg)?);
    let input_len = server.input_len("tiny").unwrap();
    println!(
        "open-loop Poisson load: {} requests at {} req/s target on model 'tiny'",
        total, rate
    );

    let clients = 4usize;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let server = Arc::clone(&server);
        let n = total / clients + usize::from(c < total % clients);
        handles.push(std::thread::spawn(move || -> (usize, f64) {
            let mut rng = Rng::new(0xC0FFEE + c as u64);
            let mut ok = 0usize;
            let mut photonic = 0.0;
            for _ in 0..n {
                // Poisson inter-arrival per client (rate split evenly).
                let wait = rng.exp(rate / clients as f64);
                std::thread::sleep(Duration::from_secs_f64(wait.min(0.05)));
                let input: Vec<f32> =
                    (0..input_len).map(|_| rng.f64() as f32 - 0.5).collect();
                match server.infer_blocking(InferenceRequest {
                    model: "tiny".into(),
                    input,
                }) {
                    Ok(resp) => {
                        ok += 1;
                        photonic = resp.simulated_photonic_s;
                    }
                    Err(e) => eprintln!("client {}: {:#}", c, e),
                }
            }
            (ok, photonic)
        }));
    }
    let mut ok = 0usize;
    let mut photonic = 0.0;
    for h in handles {
        let (o, p) = h.join().expect("client thread");
        ok += o;
        photonic = p;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    println!(
        "\ncompleted {}/{} in {:.3}s → measured {:.1} req/s (CPU-PJRT functional path)",
        ok,
        total,
        elapsed,
        ok as f64 / elapsed
    );
    println!(
        "simulated OXBNN_50 photonic frame latency for this geometry: {}",
        fmt_time(photonic)
    );
    println!("\n{}", server.metrics.lock().unwrap().report());
    match Arc::try_unwrap(server) {
        Ok(s) => s.shutdown(),
        Err(_) => unreachable!("clients joined"),
    }
    Ok(())
}
