//! HTTP front-end metrics: per-endpoint/status request counters, shed and
//! retry totals, and the lazy-parse timing the `serve-bench --http` report
//! reads back. Rendered as a plain-text exposition (Prometheus-style
//! `name{labels} value` lines) by `GET /metrics`.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::sync::lock_unpoisoned;

/// Counters shared by every connection handler. One `Mutex` around a
/// small map keeps this dependency-free; the critical sections are a few
/// integer bumps, far off the request critical path compared to the
/// engine round-trip.
#[derive(Debug, Default)]
pub struct HttpMetrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    /// (endpoint label, status code) → count.
    requests: BTreeMap<(String, u16), u64>,
    /// Requests shed with 429 after the retry budget ran dry.
    shed: u64,
    /// Individual retry attempts performed by the shard router.
    retries: u64,
    /// Nanoseconds spent in the lazy request parser, and requests parsed.
    parse_ns: u64,
    parse_count: u64,
    /// Whether the server is draining (new requests get 503).
    draining: bool,
}

impl HttpMetrics {
    /// Record one finished request.
    pub fn record(&self, endpoint: &str, status: u16) {
        let mut m = lock_unpoisoned(&self.inner);
        *m.requests.entry((endpoint.to_string(), status)).or_insert(0) += 1;
        if status == 429 {
            m.shed += 1;
        }
    }

    /// Record `n` retry attempts made on behalf of one request.
    pub fn record_retries(&self, n: u64) {
        if n > 0 {
            lock_unpoisoned(&self.inner).retries += n;
        }
    }

    /// Record one lazy-parsed request body.
    pub fn record_parse_ns(&self, ns: u64) {
        let mut m = lock_unpoisoned(&self.inner);
        m.parse_ns += ns;
        m.parse_count += 1;
    }

    pub fn set_draining(&self, draining: bool) {
        lock_unpoisoned(&self.inner).draining = draining;
    }

    /// Count for one (endpoint, status) cell.
    pub fn count(&self, endpoint: &str, status: u16) -> u64 {
        lock_unpoisoned(&self.inner)
            .requests
            .get(&(endpoint.to_string(), status))
            .copied()
            .unwrap_or(0)
    }

    /// Total requests shed with 429.
    pub fn shed(&self) -> u64 {
        lock_unpoisoned(&self.inner).shed
    }

    /// Total retry attempts.
    pub fn retries(&self) -> u64 {
        lock_unpoisoned(&self.inner).retries
    }

    /// Mean lazy-parse nanoseconds per request (0 before any parse).
    pub fn mean_parse_ns(&self) -> f64 {
        let m = lock_unpoisoned(&self.inner);
        if m.parse_count == 0 {
            0.0
        } else {
            m.parse_ns as f64 / m.parse_count as f64
        }
    }

    /// Plain-text exposition. `extra` lines (e.g. per-model coordinator
    /// counters) are appended verbatim by the caller.
    pub fn render(&self, extra: &str) -> String {
        let m = lock_unpoisoned(&self.inner);
        let mut out = String::new();
        for ((endpoint, status), count) in &m.requests {
            out.push_str(&format!(
                "oxbnn_http_requests_total{{endpoint=\"{}\",status=\"{}\"}} {}\n",
                endpoint, status, count
            ));
        }
        out.push_str(&format!("oxbnn_http_shed_total {}\n", m.shed));
        out.push_str(&format!("oxbnn_http_retries_total {}\n", m.retries));
        out.push_str(&format!("oxbnn_http_parse_ns_total {}\n", m.parse_ns));
        out.push_str(&format!("oxbnn_http_parse_requests_total {}\n", m.parse_count));
        out.push_str(&format!("oxbnn_http_draining {}\n", u8::from(m.draining)));
        out.push_str(extra);
        out
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests may unwrap
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_render() {
        let m = HttpMetrics::default();
        m.record("/v1/infer", 200);
        m.record("/v1/infer", 200);
        m.record("/v1/infer", 429);
        m.record("/healthz", 200);
        m.record_retries(3);
        m.record_parse_ns(500);
        m.record_parse_ns(1500);
        assert_eq!(m.count("/v1/infer", 200), 2);
        assert_eq!(m.count("/v1/infer", 429), 1);
        assert_eq!(m.count("/v1/infer", 500), 0);
        assert_eq!(m.shed(), 1);
        assert_eq!(m.retries(), 3);
        assert!((m.mean_parse_ns() - 1000.0).abs() < 1e-9);
        let text = m.render("oxbnn_model_replicas{model=\"tiny\"} 2\n");
        assert!(text.contains(
            "oxbnn_http_requests_total{endpoint=\"/v1/infer\",status=\"200\"} 2"
        ));
        assert!(text.contains("oxbnn_http_shed_total 1"));
        assert!(text.contains("oxbnn_http_retries_total 3"));
        assert!(text.contains("oxbnn_http_draining 0"));
        assert!(text.contains("oxbnn_model_replicas{model=\"tiny\"} 2"));
        m.set_draining(true);
        assert!(m.render("").contains("oxbnn_http_draining 1"));
    }

    #[test]
    fn empty_metrics_render_safely() {
        let m = HttpMetrics::default();
        assert_eq!(m.mean_parse_ns(), 0.0);
        let text = m.render("");
        assert!(text.contains("oxbnn_http_shed_total 0"));
    }
}
