//! Hand-rolled HTTP/1.1 over `std::net` (no hyper/tokio offline): a
//! buffered server-side connection ([`Conn`]) that parses pipelined
//! keep-alive requests with `Content-Length` bodies, a response writer,
//! and a small keep-alive client ([`ClientConn`]) shared by the smoke
//! suite, the integration tests and `serve-bench --http`.
//!
//! Scope is deliberately the serving front-end's needs: request-line +
//! headers + fixed-length body. No chunked transfer encoding, no
//! multi-line headers, no HTTP/2 — a request using those is answered
//! with a clean protocol error, never undefined behavior.

use std::io::{Read, Write};
use std::net::TcpStream;

/// Largest accepted header block; protects the server from unbounded
/// buffering on garbage input.
const MAX_HEAD: usize = 16 * 1024;
/// Largest accepted request body (a vgg_small frame is ~3072 floats ≈
/// 40 KiB of JSON; 16 MiB leaves generous headroom).
const MAX_BODY: usize = 16 * 1024 * 1024;
const READ_CHUNK: usize = 8 * 1024;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// Whether the connection should be kept open after the response.
    pub keep_alive: bool,
}

/// Connection-level errors.
#[derive(Debug, thiserror::Error)]
pub enum HttpError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("malformed request: {0}")]
    Malformed(String),
}

fn malformed(msg: impl Into<String>) -> HttpError {
    HttpError::Malformed(msg.into())
}

/// Standard reason phrase for the status codes this server emits.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Find the first `\r\n\r\n` in `buf`, returning the index just past it.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|i| i + 4)
}

/// Case-insensitive ASCII equality (header names).
fn eq_ignore_case(a: &str, b: &str) -> bool {
    a.eq_ignore_ascii_case(b)
}

/// Server side of one TCP connection: owns the stream plus a carry-over
/// buffer so pipelined keep-alive requests parse without re-reads.
pub struct Conn {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Conn {
    pub fn new(stream: TcpStream) -> Conn {
        Conn { stream, buf: Vec::new() }
    }

    /// Read one full request. `Ok(None)` means the peer closed cleanly
    /// between requests; truncation mid-request is an error.
    pub fn read_request(&mut self) -> Result<Option<Request>, HttpError> {
        // Accumulate until the header block is complete.
        let head_end = loop {
            if let Some(end) = find_head_end(&self.buf) {
                break end;
            }
            if self.buf.len() > MAX_HEAD {
                return Err(malformed("header block exceeds 16 KiB"));
            }
            let mut chunk = [0u8; READ_CHUNK];
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                if self.buf.is_empty() {
                    return Ok(None); // clean close between requests
                }
                return Err(malformed("connection closed mid-headers"));
            }
            self.buf.extend_from_slice(&chunk[..n]);
        };

        let head = std::str::from_utf8(&self.buf[..head_end - 4])
            .map_err(|_| malformed("non-UTF-8 header block"))?;
        let mut lines = head.split("\r\n");
        let request_line = lines.next().unwrap_or("");
        let mut parts = request_line.split(' ');
        let method = parts.next().unwrap_or("").to_string();
        let path = parts.next().unwrap_or("").to_string();
        let version = parts.next().unwrap_or("");
        if method.is_empty() || path.is_empty() {
            return Err(malformed(format!("bad request line '{}'", request_line)));
        }
        if version != "HTTP/1.1" && version != "HTTP/1.0" {
            return Err(malformed(format!("unsupported version '{}'", version)));
        }

        let mut content_length: usize = 0;
        // HTTP/1.1 defaults to keep-alive; 1.0 to close.
        let mut keep_alive = version == "HTTP/1.1";
        for line in lines {
            let (name, value) = match line.split_once(':') {
                Some((n, v)) => (n.trim(), v.trim()),
                None => continue, // tolerate stray lines
            };
            if eq_ignore_case(name, "content-length") {
                content_length = value
                    .parse()
                    .map_err(|_| malformed(format!("bad content-length '{}'", value)))?;
            } else if eq_ignore_case(name, "connection") {
                if value.eq_ignore_ascii_case("close") {
                    keep_alive = false;
                } else if value.eq_ignore_ascii_case("keep-alive") {
                    keep_alive = true;
                }
            } else if eq_ignore_case(name, "transfer-encoding") {
                return Err(malformed("transfer-encoding is not supported"));
            }
        }
        if content_length > MAX_BODY {
            return Err(malformed(format!("body of {} bytes exceeds cap", content_length)));
        }

        // Accumulate the body.
        let total = head_end + content_length;
        while self.buf.len() < total {
            let mut chunk = [0u8; READ_CHUNK];
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                return Err(malformed("connection closed mid-body"));
            }
            self.buf.extend_from_slice(&chunk[..n]);
        }
        let body = self.buf[head_end..total].to_vec();
        // Keep any pipelined bytes beyond this request.
        self.buf.drain(..total);
        Ok(Some(Request { method, path, body, keep_alive }))
    }

    /// Write a complete response. `keep_alive` decides the `Connection`
    /// header; the caller closes the connection when it is false.
    pub fn write_response(
        &mut self,
        status: u16,
        headers: &[(&str, &str)],
        body: &[u8],
        keep_alive: bool,
    ) -> std::io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
            status,
            status_reason(status),
            body.len(),
            if keep_alive { "keep-alive" } else { "close" }
        );
        for (name, value) in headers {
            head.push_str(name);
            head.push_str(": ");
            head.push_str(value);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(body)?;
        self.stream.flush()
    }
}

/// Minimal keep-alive HTTP/1.1 client over one connection. Responses
/// must carry `Content-Length` (everything this repo's server sends
/// does).
pub struct ClientConn {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl ClientConn {
    pub fn connect(addr: &str) -> std::io::Result<ClientConn> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(ClientConn { stream, buf: Vec::new() })
    }

    /// Issue one request; returns `(status, body)`.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> Result<(u16, Vec<u8>), HttpError> {
        let head = format!(
            "{} {} HTTP/1.1\r\nHost: oxbnn\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n",
            method,
            path,
            body.len()
        );
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(body)?;
        self.stream.flush()?;

        // Read the response head.
        let head_end = loop {
            if let Some(end) = find_head_end(&self.buf) {
                break end;
            }
            if self.buf.len() > MAX_HEAD {
                return Err(malformed("response header block exceeds cap"));
            }
            let mut chunk = [0u8; READ_CHUNK];
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                return Err(malformed("connection closed before response"));
            }
            self.buf.extend_from_slice(&chunk[..n]);
        };
        let head = std::str::from_utf8(&self.buf[..head_end - 4])
            .map_err(|_| malformed("non-UTF-8 response head"))?;
        let mut lines = head.split("\r\n");
        let status_line = lines.next().unwrap_or("");
        let status: u16 = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| malformed(format!("bad status line '{}'", status_line)))?;
        let mut content_length: usize = 0;
        for line in lines {
            if let Some((name, value)) = line.split_once(':') {
                if eq_ignore_case(name.trim(), "content-length") {
                    content_length = value
                        .trim()
                        .parse()
                        .map_err(|_| malformed("bad response content-length"))?;
                }
            }
        }
        if content_length > MAX_BODY {
            return Err(malformed("response body exceeds cap"));
        }
        let total = head_end + content_length;
        while self.buf.len() < total {
            let mut chunk = [0u8; READ_CHUNK];
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                return Err(malformed("connection closed mid-response"));
            }
            self.buf.extend_from_slice(&chunk[..n]);
        }
        let body = self.buf[head_end..total].to_vec();
        self.buf.drain(..total);
        Ok((status, body))
    }
}

/// One-shot convenience: connect, issue a single request, disconnect.
pub fn request_once(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
) -> Result<(u16, Vec<u8>), HttpError> {
    let mut conn = ClientConn::connect(addr)?;
    conn.request(method, path, body)
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests may unwrap
mod tests {
    use super::*;
    use std::net::TcpListener;
    use std::thread;

    /// Loopback round-trip: the server-side Conn parses what the
    /// client-side ClientConn sends, and vice versa.
    #[test]
    fn request_roundtrip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut conn = Conn::new(stream);
            // Two pipeline-friendly requests on one connection.
            let r1 = conn.read_request().unwrap().unwrap();
            assert_eq!(r1.method, "POST");
            assert_eq!(r1.path, "/v1/infer");
            assert_eq!(r1.body, b"{\"model\":\"tiny\"}");
            assert!(r1.keep_alive);
            conn.write_response(200, &[("X-Test", "1")], b"ok-1", true).unwrap();
            let r2 = conn.read_request().unwrap().unwrap();
            assert_eq!(r2.method, "GET");
            assert_eq!(r2.path, "/metrics");
            assert!(r2.body.is_empty());
            conn.write_response(404, &[], b"gone", false).unwrap();
            // Peer closes; next read reports a clean end.
            assert!(matches!(conn.read_request(), Ok(None) | Err(_)));
        });
        let mut client = ClientConn::connect(&addr.to_string()).unwrap();
        let (status, body) = client
            .request("POST", "/v1/infer", b"{\"model\":\"tiny\"}")
            .unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"ok-1");
        let (status, body) = client.request("GET", "/metrics", b"").unwrap();
        assert_eq!(status, 404);
        assert_eq!(body, b"gone");
        drop(client);
        server.join().unwrap();
    }

    #[test]
    fn malformed_requests_rejected() {
        let cases: &[&[u8]] = &[
            b"NONSENSE\r\n\r\n",
            b"GET /x SPDY/3\r\n\r\n",
            b"POST /x HTTP/1.1\r\nContent-Length: banana\r\n\r\n",
            b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
        ];
        for raw in cases {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let raw = raw.to_vec();
            let client = thread::spawn(move || {
                let mut s = TcpStream::connect(addr).unwrap();
                s.write_all(&raw).unwrap();
            });
            let (stream, _) = listener.accept().unwrap();
            let mut conn = Conn::new(stream);
            let got = conn.read_request();
            assert!(
                matches!(got, Err(HttpError::Malformed(_))),
                "{:?} must be rejected, got {:?}",
                String::from_utf8_lossy(&raw),
                got.map(|r| r.map(|q| q.path))
            );
            client.join().unwrap();
        }
    }

    #[test]
    fn truncated_body_is_an_error_not_a_hang() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"POST /x HTTP/1.1\r\nContent-Length: 100\r\n\r\nshort").unwrap();
            // Close with 95 bytes still owed.
        });
        let (stream, _) = listener.accept().unwrap();
        let mut conn = Conn::new(stream);
        assert!(conn.read_request().is_err());
        client.join().unwrap();
    }

    #[test]
    fn http_1_0_defaults_to_close() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GET / HTTP/1.0\r\n\r\n").unwrap();
            s
        });
        let (stream, _) = listener.accept().unwrap();
        let mut conn = Conn::new(stream);
        let r = conn.read_request().unwrap().unwrap();
        assert!(!r.keep_alive);
        drop(client.join().unwrap());
    }

    #[test]
    fn reason_phrases_cover_emitted_codes() {
        for code in [200u16, 202, 400, 404, 405, 413, 429, 500, 503] {
            assert_ne!(status_reason(code), "Unknown", "{}", code);
        }
        assert_eq!(status_reason(418), "Unknown");
    }
}
