//! Shard router: spreads requests across the replicas of many models
//! with production resilience — retry-with-backoff gated by a per-model
//! retry budget, consistent-hash session affinity, and failover across
//! epochs (a request that lands on a server mid-drain re-looks the model
//! up and retries on the fresh entry).
//!
//! Retry budget: a token bucket fed by request volume (`budget_ratio`
//! tokens per request, capped). Each retry withdraws one token; when the
//! bucket is dry the request is shed instead of retried, which bounds
//! retry amplification under sustained overload (a retry storm can at
//! most multiply offered load by `1 + budget_ratio`).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::{InferenceRequest, InferenceResponse, SubmitError};
use crate::util::sync::lock_unpoisoned;

use super::registry::ModelRegistry;

/// Resilience knobs for [`ShardRouter`].
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Retry attempts per request (0 = fail fast).
    pub max_retries: usize,
    /// First backoff sleep; doubles per attempt (50µs → 100µs → ...).
    pub backoff: Duration,
    /// Retry tokens deposited per incoming request.
    pub budget_ratio: f64,
    /// Token-bucket cap per model.
    pub budget_cap: f64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_retries: 2,
            backoff: Duration::from_micros(50),
            budget_ratio: 0.1,
            budget_cap: 16.0,
        }
    }
}

/// Per-model token bucket.
struct RetryBudget {
    tokens: Mutex<f64>,
}

impl RetryBudget {
    fn new(cap: f64) -> RetryBudget {
        // Start full so cold-start blips (first requests racing a
        // reload) can retry immediately.
        RetryBudget { tokens: Mutex::new(cap) }
    }

    fn deposit(&self, ratio: f64, cap: f64) {
        let mut t = lock_unpoisoned(&self.tokens);
        *t = (*t + ratio).min(cap);
    }

    fn withdraw(&self) -> bool {
        let mut t = lock_unpoisoned(&self.tokens);
        if *t >= 1.0 {
            *t -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Routing-level failures, mapped to HTTP statuses by the front-end.
#[derive(Debug, thiserror::Error)]
pub enum InferError {
    #[error("unknown model '{0}'")]
    UnknownModel(String),
    #[error("model '{model}' expects {expect} input values, got {got}")]
    InvalidInput { model: String, expect: usize, got: usize },
    /// Back-pressure after the retry budget ran dry → 429.
    #[error("model '{0}' is overloaded — retry later")]
    Overloaded(String),
    /// Execution kept failing past the retry budget → 500.
    #[error("inference failed: {0}")]
    Failed(String),
}

/// A successful routed inference plus the resilience telemetry the HTTP
/// layer reports.
pub struct InferReply {
    pub response: InferenceResponse,
    /// Registry epoch of the entry that served the request.
    pub epoch: u64,
    /// Retries spent before success.
    pub retries: u64,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut hash: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x0000_0100_0000_01B3);
    }
    hash
}

/// What went wrong on one attempt — decides retry vs fail-fast.
enum Attempt {
    Done(InferenceResponse, u64),
    /// Queue full: retryable while budget lasts, sheds as Overloaded.
    Full,
    /// Worker gone / reply dropped / execution error: retryable,
    /// sheds as Failed.
    Broken(String),
}

/// Model-level router over the registry. The routing unit is the
/// registry ENTRY: a model staged onto a K-chip shard group
/// ([`ModelRegistry::load_with`]) is one entry and therefore ONE
/// high-throughput replica set here — the router never addresses
/// individual chips, group health is the whole entry's live-replica
/// state, and an unload/swap drains the group atomically.
pub struct ShardRouter {
    registry: Arc<ModelRegistry>,
    policy: RetryPolicy,
    budgets: Mutex<std::collections::BTreeMap<String, Arc<RetryBudget>>>,
}

impl ShardRouter {
    pub fn new(registry: Arc<ModelRegistry>, policy: RetryPolicy) -> ShardRouter {
        ShardRouter { registry, policy, budgets: Mutex::new(Default::default()) }
    }

    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    fn budget(&self, model: &str) -> Arc<RetryBudget> {
        Arc::clone(
            lock_unpoisoned(&self.budgets)
                .entry(model.to_string())
                .or_insert_with(|| Arc::new(RetryBudget::new(self.policy.budget_cap))),
        )
    }

    /// One submit + reply round-trip against the CURRENT registry entry.
    fn attempt(&self, model: &str, input: &[f32], session: Option<&str>) -> Result<Attempt, InferError> {
        let entry = self
            .registry
            .get(model)
            .ok_or_else(|| InferError::UnknownModel(model.to_string()))?;
        let req = InferenceRequest { model: model.to_string(), input: input.to_vec() };
        let submitted = match session {
            // Consistent-hash affinity: the same session key maps to the
            // same live replica (mod the live set, so quarantines only
            // remap the sessions that lost their replica).
            Some(key) => {
                let replicas = entry.server.replicas(model);
                if replicas.is_empty() {
                    Err(SubmitError::WorkerGone(model.to_string()))
                } else {
                    let pick = replicas[(fnv1a(key.as_bytes()) % replicas.len() as u64) as usize];
                    entry.server.submit_to(req, pick)
                }
            }
            None => entry.server.submit(req).map(|(_replica, rx)| rx),
        };
        let rx = match submitted {
            Ok(rx) => rx,
            Err(SubmitError::QueueFull { .. }) => return Ok(Attempt::Full),
            Err(SubmitError::WorkerGone(m)) => {
                return Ok(Attempt::Broken(format!("worker for '{}' is gone", m)))
            }
            Err(SubmitError::UnknownModel(m)) => return Err(InferError::UnknownModel(m)),
            Err(SubmitError::InvalidInput { model, expect, got }) => {
                return Err(InferError::InvalidInput { model, expect, got })
            }
        };
        match rx.recv() {
            Ok(Ok(resp)) => Ok(Attempt::Done(resp, entry.epoch)),
            Ok(Err(e)) => Ok(Attempt::Broken(format!("{:#}", e))),
            Err(_) => Ok(Attempt::Broken("reply channel dropped".to_string())),
        }
    }

    /// Route one inference with retry/backoff resilience. `session`
    /// pins the request to a consistent replica when provided.
    pub fn infer(
        &self,
        model: &str,
        input: &[f32],
        session: Option<&str>,
    ) -> Result<InferReply, InferError> {
        let budget = self.budget(model);
        budget.deposit(self.policy.budget_ratio, self.policy.budget_cap);
        let mut retries: u64 = 0;
        let mut last = Attempt::Broken("no attempt made".to_string());
        loop {
            match self.attempt(model, input, session)? {
                Attempt::Done(response, epoch) => {
                    return Ok(InferReply { response, epoch, retries })
                }
                other => last = other,
            }
            // Retry iff both the per-request cap and the per-model
            // budget allow another attempt.
            if retries as usize >= self.policy.max_retries || !budget.withdraw() {
                return Err(match last {
                    Attempt::Full => InferError::Overloaded(model.to_string()),
                    Attempt::Broken(why) => InferError::Failed(why),
                    Attempt::Done(..) => unreachable!("done returns above"),
                });
            }
            let backoff = self.policy.backoff.saturating_mul(1u32 << retries.min(16) as u32);
            retries += 1;
            std::thread::sleep(backoff);
        }
    }

    /// Fire-and-forget submit for `POST /v1/submit` (202 semantics): one
    /// routed attempt, reply receiver detached — the coordinator's router
    /// accounting is released on the worker's reply path regardless.
    pub fn submit_detached(&self, model: &str, input: &[f32]) -> Result<(), InferError> {
        let entry = self
            .registry
            .get(model)
            .ok_or_else(|| InferError::UnknownModel(model.to_string()))?;
        let req = InferenceRequest { model: model.to_string(), input: input.to_vec() };
        match entry.server.submit(req) {
            Ok((_replica, _rx)) => Ok(()), // receiver dropped deliberately
            Err(SubmitError::QueueFull { .. }) => {
                Err(InferError::Overloaded(model.to_string()))
            }
            Err(SubmitError::WorkerGone(m)) => {
                Err(InferError::Failed(format!("worker for '{}' is gone", m)))
            }
            Err(SubmitError::UnknownModel(m)) => Err(InferError::UnknownModel(m)),
            Err(SubmitError::InvalidInput { model, expect, got }) => {
                Err(InferError::InvalidInput { model, expect, got })
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests may unwrap
mod tests {
    use super::*;
    use crate::coordinator::ServerConfig;
    use crate::serving::registry::ModelRegistry;

    fn router_with(mutate: impl FnOnce(&mut ServerConfig), policy: RetryPolicy) -> ShardRouter {
        let mut cfg = ServerConfig::synthetic(&[]);
        cfg.max_batch = 4;
        cfg.queue_depth = 64;
        mutate(&mut cfg);
        let reg = Arc::new(ModelRegistry::synthetic(cfg));
        ShardRouter::new(reg, policy)
    }

    #[test]
    fn routes_and_reports_epoch() {
        let router = router_with(|_| {}, RetryPolicy::default());
        router.registry().load("tiny", 2).unwrap();
        let reply = router.infer("tiny", &vec![0.25; 192], None).unwrap();
        assert_eq!(reply.response.logits.len(), 10);
        assert_eq!(reply.epoch, 1);
        assert_eq!(reply.retries, 0);
        assert!(matches!(
            router.infer("ghost", &[0.0; 192], None),
            Err(InferError::UnknownModel(_))
        ));
        assert!(matches!(
            router.infer("tiny", &[0.0; 3], None),
            Err(InferError::InvalidInput { expect: 192, got: 3, .. })
        ));
        router.registry().drain_all();
    }

    #[test]
    fn session_affinity_is_consistent_and_survives_quarantine() {
        let router = router_with(|_| {}, RetryPolicy::default());
        router.registry().load("m", 3).unwrap();
        let entry = router.registry().get("m").unwrap();
        // Same key → same replica: with affinity the router must pin,
        // so run several and check determinism via replica_ids math.
        let replicas = entry.server.replicas("m");
        let key = "session-42";
        let expect = replicas[(fnv1a(key.as_bytes()) % replicas.len() as u64) as usize];
        for _ in 0..3 {
            let reply = router.infer("m", &vec![0.5; 192], Some(key)).unwrap();
            assert_eq!(reply.response.logits.len(), 10);
        }
        // Quarantine the pinned replica: the key remaps to a live one
        // and requests still succeed (failover, not an error).
        assert!(entry.server.quarantine("m", expect));
        let reply = router.infer("m", &vec![0.5; 192], Some(key)).unwrap();
        assert_eq!(reply.response.logits.len(), 10);
        router.registry().drain_all();
    }

    #[test]
    fn chip_group_routes_as_one_replica() {
        let router = router_with(|_| {}, RetryPolicy::default());
        router.registry().load_with("m", 2, 2).unwrap();
        let entry = router.registry().get("m").unwrap();
        assert_eq!(entry.chips, 2, "group width is recorded on the entry");
        // The group is ONE routing target: session affinity and plain
        // routing both resolve through the single entry.
        let reply = router.infer("m", &vec![0.25; 192], None).unwrap();
        assert_eq!(reply.response.logits.len(), 10);
        let pinned = router.infer("m", &vec![0.25; 192], Some("sess")).unwrap();
        assert_eq!(pinned.response.logits.len(), 10);
        // Atomic group drain: after unload the whole group refuses.
        assert!(router.registry().unload("m"));
        assert!(matches!(
            router.infer("m", &vec![0.25; 192], None),
            Err(InferError::UnknownModel(_))
        ));
        router.registry().drain_all();
    }

    #[test]
    fn overload_sheds_after_budget() {
        // One slow replica, queue depth 1, no retries: floods shed.
        let router = router_with(
            |cfg| {
                cfg.queue_depth = 1;
                cfg.max_batch = 1;
                cfg.execute_delay = std::time::Duration::from_millis(30);
            },
            RetryPolicy { max_retries: 0, ..RetryPolicy::default() },
        );
        router.registry().load("m", 1).unwrap();
        let input = vec![0.1_f32; 192];
        let mut shed = 0;
        let mut ok = 0;
        std::thread::scope(|scope| {
            let results: Vec<_> = (0..8)
                .map(|_| {
                    let router = &router;
                    let input = &input;
                    scope.spawn(move || router.infer("m", input, None))
                })
                .collect();
            for h in results {
                match h.join().unwrap() {
                    Ok(_) => ok += 1,
                    Err(InferError::Overloaded(_)) => shed += 1,
                    Err(e) => panic!("unexpected error {}", e),
                }
            }
        });
        assert!(shed > 0, "queue depth 1 must shed some of 8 concurrent requests");
        assert!(ok > 0, "some requests must land");
        assert_eq!(ok + shed, 8, "every request is either served or shed");
        router.registry().drain_all();
    }

    #[test]
    fn retry_masks_a_mid_flight_reload() {
        // Wide backoff so the retry window comfortably covers the reload.
        let policy = RetryPolicy {
            max_retries: 6,
            backoff: std::time::Duration::from_millis(20),
            ..RetryPolicy::default()
        };
        let router = router_with(|_| {}, policy);
        router.registry().load("m", 1).unwrap();
        let v1 = router.registry().get("m").unwrap();
        // Drain the live server out from under the router, as a crash
        // would; the registry still lists the dead entry, so the first
        // attempt fails with WorkerGone. A reload racing the retries
        // restores service; each attempt re-resolves the entry, so a
        // retry must land on the new epoch.
        v1.server.drain();
        std::thread::scope(|scope| {
            let registry = Arc::clone(router.registry());
            scope.spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(5));
                registry.reload("m").unwrap();
            });
            // First attempt runs now, at least 5ms before the reload, so
            // it must hit the drained epoch-1 server and take the retry
            // path; success can only come from the reloaded entry.
            let reply = router
                .infer("m", &vec![0.2; 192], None)
                .expect("retry must mask the reload");
            assert_eq!(reply.response.logits.len(), 10);
            assert_eq!(reply.epoch, 2, "success must come from the reloaded entry");
            assert!(reply.retries >= 1, "the dead epoch-1 attempt must have retried");
        });
        router.registry().drain_all();
    }

    #[test]
    fn detached_submit_is_accounted() {
        let router = router_with(|_| {}, RetryPolicy::default());
        router.registry().load("m", 1).unwrap();
        let entry = router.registry().get("m").unwrap();
        router.submit_detached("m", &vec![0.3; 192]).unwrap();
        assert!(matches!(
            router.submit_detached("ghost", &[0.0; 1]),
            Err(InferError::UnknownModel(_))
        ));
        // The worker completes the dropped-receiver job and releases
        // router accounting; drain flushes it deterministically.
        entry.server.drain();
        assert_eq!(entry.server.outstanding("m"), 0);
        assert_eq!(entry.server.metrics.lock().unwrap().completed, 1);
        router.registry().drain_all();
    }
}
