//! Health checking that exercises a real replica round-trip.
//!
//! A probe is a zero-input inference submitted through the model's live
//! coordinator server — the same queue, batcher, and engine a user
//! request crosses — so "healthy" means the serving path works, not just
//! that a thread is parked somewhere. Probe outcomes map to three
//! states: `Live` (round-trip completed), `Degraded` (back-pressured or
//! slow: queue full, or no reply within the probe timeout), `Dead`
//! (submission refused or execution failed). Reports are TTL-cached per
//! model so `GET /healthz` polling never becomes its own load source.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::coordinator::{InferenceRequest, SubmitError};
use crate::util::sync::lock_unpoisoned;

use super::registry::ModelEntry;

/// Probe verdict for one model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    /// Probe round-trip completed.
    Live,
    /// Serving but back-pressured: probe shed with queue-full, or the
    /// reply missed the probe timeout.
    Degraded,
    /// Probe refused or failed — the model cannot serve.
    Dead,
}

impl HealthState {
    pub fn as_str(&self) -> &'static str {
        match self {
            HealthState::Live => "live",
            HealthState::Degraded => "degraded",
            HealthState::Dead => "dead",
        }
    }
}

/// One model's health verdict plus the evidence.
#[derive(Debug, Clone)]
pub struct HealthReport {
    pub model: String,
    pub state: HealthState,
    pub detail: String,
}

/// TTL-cached prober.
pub struct HealthChecker {
    cache: Mutex<BTreeMap<String, (Instant, HealthReport)>>,
    ttl: Duration,
    probe_timeout: Duration,
}

impl HealthChecker {
    pub fn new(ttl: Duration, probe_timeout: Duration) -> HealthChecker {
        HealthChecker { cache: Mutex::new(BTreeMap::new()), ttl, probe_timeout }
    }

    /// Probe `entry`, serving a cached report when fresher than the TTL.
    pub fn check(&self, entry: &ModelEntry) -> HealthReport {
        {
            let cache = lock_unpoisoned(&self.cache);
            if let Some((at, report)) = cache.get(&entry.name) {
                if at.elapsed() < self.ttl {
                    return report.clone();
                }
            }
        }
        let report = probe(entry, self.probe_timeout);
        lock_unpoisoned(&self.cache)
            .insert(entry.name.clone(), (Instant::now(), report.clone()));
        report
    }

    /// Drop the cached report for `model` (after quarantine/reload, the
    /// next check must re-probe).
    pub fn invalidate(&self, model: &str) {
        lock_unpoisoned(&self.cache).remove(model);
    }
}

/// One uncached probe round-trip.
pub fn probe(entry: &ModelEntry, timeout: Duration) -> HealthReport {
    let req = InferenceRequest {
        model: entry.name.clone(),
        input: vec![0.0; entry.input_len],
    };
    let report = |state: HealthState, detail: String| HealthReport {
        model: entry.name.clone(),
        state,
        detail,
    };
    let rx = match entry.server.submit(req) {
        Ok((_replica, rx)) => rx,
        Err(SubmitError::QueueFull { depth, .. }) => {
            return report(
                HealthState::Degraded,
                format!("probe shed: queue full at depth {}", depth),
            )
        }
        Err(e) => return report(HealthState::Dead, format!("probe refused: {}", e)),
    };
    match rx.recv_timeout(timeout) {
        Ok(Ok(resp)) => report(
            HealthState::Live,
            format!("probe round-trip in {:.3}ms", resp.total_s * 1e3),
        ),
        Ok(Err(e)) => report(HealthState::Dead, format!("probe execution failed: {:#}", e)),
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => report(
            HealthState::Degraded,
            format!("probe reply missed {:?} timeout", timeout),
        ),
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
            report(HealthState::Dead, "probe reply channel dropped".to_string())
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests may unwrap
mod tests {
    use super::*;
    use crate::coordinator::ServerConfig;
    use crate::serving::registry::ModelRegistry;
    use std::sync::Arc;

    fn registry() -> Arc<ModelRegistry> {
        let mut cfg = ServerConfig::synthetic(&[]);
        cfg.max_batch = 4;
        cfg.queue_depth = 64;
        Arc::new(ModelRegistry::synthetic(cfg))
    }

    #[test]
    fn live_model_probes_live() {
        let reg = registry();
        let entry = reg.load("m", 1).unwrap();
        let r = probe(&entry, Duration::from_secs(5));
        assert_eq!(r.state, HealthState::Live, "detail: {}", r.detail);
        assert_eq!(r.model, "m");
        reg.drain_all();
    }

    #[test]
    fn drained_model_probes_dead() {
        let reg = registry();
        let entry = reg.load("m", 1).unwrap();
        entry.server.drain();
        let r = probe(&entry, Duration::from_secs(1));
        assert_eq!(r.state, HealthState::Dead, "detail: {}", r.detail);
        reg.drain_all();
    }

    #[test]
    fn slow_model_probes_degraded() {
        let mut cfg = ServerConfig::synthetic(&[]);
        cfg.max_batch = 1;
        cfg.queue_depth = 64;
        cfg.execute_delay = Duration::from_millis(200);
        let reg = Arc::new(ModelRegistry::synthetic(cfg));
        let entry = reg.load("m", 1).unwrap();
        let r = probe(&entry, Duration::from_millis(5));
        assert_eq!(r.state, HealthState::Degraded, "detail: {}", r.detail);
        reg.drain_all();
    }

    #[test]
    fn checker_caches_within_ttl_and_invalidates() {
        let reg = registry();
        let entry = reg.load("m", 1).unwrap();
        let checker = HealthChecker::new(Duration::from_secs(60), Duration::from_secs(5));
        assert_eq!(checker.check(&entry).state, HealthState::Live);
        // Kill the model; the cached verdict still reads live until
        // invalidated — then the re-probe sees it dead.
        entry.server.drain();
        assert_eq!(checker.check(&entry).state, HealthState::Live, "TTL-cached");
        checker.invalidate("m");
        assert_eq!(checker.check(&entry).state, HealthState::Dead);
        reg.drain_all();
    }
}
