//! HTTP serving front-end: a multi-model sharded router with
//! production resilience over the batched coordinator.
//!
//! Request-path code in this subtree may not `unwrap()`/`expect()` (the
//! `disallowed_methods` deny below + `clippy.toml`): a panic must cost
//! one request, never the process. Locks go through
//! [`crate::util::sync`]; everything else is matched or surfaced as a
//! protocol error. Test modules opt back out locally.
//!
//! The layer cake, top to bottom:
//!
//! * [`http`] — hand-rolled HTTP/1.1 over `std::net` (no external
//!   dependencies): pipelined keep-alive parsing with `Content-Length`
//!   bodies, plus the small client the tests and bench harness use.
//! * [`server`] — accept loop + connection handlers on the shared
//!   [`ThreadPool`]; dispatches `/v1/infer`, `/v1/submit`,
//!   `/v1/models`, `/metrics` and `/healthz`. The infer hot path uses
//!   the lazy JSON field scanner ([`crate::util::json::path_f32_slice`])
//!   so a request parse costs no tree allocation.
//! * [`shard`] — [`ShardRouter`]: least-outstanding replica spread (via
//!   the coordinator's router), consistent-hash session affinity,
//!   retry-with-backoff gated by a per-model retry budget, failover
//!   across hot reloads.
//! * [`registry`] — [`ModelRegistry`]: one coordinator [`Server`] per
//!   model over a shared plan cache, epoch-guarded hot load / unload /
//!   reload, background drains.
//! * [`health`] — real replica round-trip probes (live / degraded /
//!   dead), TTL-cached.
//! * [`metrics`] — front-end counters rendered by `GET /metrics`.
//!
//! [`ThreadPool`]: crate::util::threadpool::ThreadPool
//! [`Server`]: crate::coordinator::Server

#![deny(clippy::disallowed_methods)]

pub mod health;
pub mod http;
pub mod metrics;
pub mod registry;
pub mod server;
pub mod shard;

pub use health::{probe, HealthChecker, HealthReport, HealthState};
pub use http::{request_once, ClientConn, Conn, HttpError, Request};
pub use metrics::HttpMetrics;
pub use registry::{ModelEntry, ModelRegistry};
pub use server::{serve, HttpConfig, ServingHandle};
pub use shard::{InferError, InferReply, RetryPolicy, ShardRouter};
