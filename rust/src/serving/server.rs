//! The HTTP front-end: accept loop + connection handlers over the
//! existing [`ThreadPool`], dispatching to the shard router, registry,
//! health checker and metrics.
//!
//! Endpoints:
//!
//! | route              | method | purpose                                        |
//! |--------------------|--------|------------------------------------------------|
//! | `/v1/infer`        | POST   | synchronous inference (lazy-parsed hot path)   |
//! | `/v1/submit`       | POST   | fire-and-forget inference → 202                |
//! | `/v1/models`       | GET    | live models: epoch, replicas, photonic FPS     |
//! | `/v1/models`       | PUT    | desired-state hot load / unload / reload       |
//! | `/metrics`         | GET    | plain-text counters (front-end + per-model)    |
//! | `/healthz`         | GET    | real replica round-trip probes, TTL-cached     |
//!
//! The infer hot path never builds a JSON tree: the three fields it
//! needs (`model`, `session`, `input`) are pulled straight off the raw
//! body by the lazy scanner in [`crate::util::json`], with the input
//! vector reused across every request of a keep-alive connection.
//!
//! Graceful drain: `ServingHandle::shutdown` flips the draining flag,
//! wakes the accept loop, joins it (the connection pool drains — every
//! in-flight request finishes and is answered; queued connections get a
//! clean 503), then drains every model server so accepted inferences
//! complete. Nothing accepted is ever dropped on the floor.
//!
//! [`ThreadPool`]: crate::util::threadpool::ThreadPool

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Context as _;

use crate::check::planlint::LintRejection;
use crate::util::json::{path_f32_slice, path_str, Json};
use crate::util::sync::lock_unpoisoned;
use crate::util::threadpool::{host_threads, ThreadPool};

use super::health::{HealthChecker, HealthState};
use super::http::{Conn, HttpError, Request};
use super::metrics::HttpMetrics;
use super::registry::ModelRegistry;
use super::shard::{InferError, RetryPolicy, ShardRouter};

/// Front-end knobs.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Bind address; use port 0 to let the OS pick (tests, smoke).
    pub addr: String,
    /// Connection-handler threads (0 = one per host core).
    pub threads: usize,
    pub retry: RetryPolicy,
    /// How long a health verdict stays cached.
    pub health_ttl: Duration,
    /// How long a health probe waits for its round-trip.
    pub probe_timeout: Duration,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig {
            addr: "127.0.0.1:8080".to_string(),
            threads: 0,
            retry: RetryPolicy::default(),
            health_ttl: Duration::from_millis(500),
            probe_timeout: Duration::from_secs(2),
        }
    }
}

/// State shared by every connection handler.
struct Ctx {
    registry: Arc<ModelRegistry>,
    router: ShardRouter,
    metrics: Arc<HttpMetrics>,
    health: HealthChecker,
    draining: Arc<AtomicBool>,
}

/// A running front-end. Dropping the handle shuts the server down
/// gracefully (prefer calling [`ServingHandle::shutdown`] explicitly).
pub struct ServingHandle {
    addr: SocketAddr,
    draining: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
    registry: Arc<ModelRegistry>,
    metrics: Arc<HttpMetrics>,
}

impl ServingHandle {
    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    pub fn metrics(&self) -> &Arc<HttpMetrics> {
        &self.metrics
    }

    /// Graceful drain: stop accepting, finish every in-flight request,
    /// drain every model server, then return.
    pub fn shutdown(mut self) {
        self.shutdown_in_place();
    }

    fn shutdown_in_place(&mut self) {
        if self.accept.is_none() {
            return;
        }
        self.draining.store(true, Ordering::SeqCst);
        self.metrics.set_draining(true);
        // Wake the accept loop so it observes the flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
        self.registry.drain_all();
    }
}

impl Drop for ServingHandle {
    fn drop(&mut self) {
        self.shutdown_in_place();
    }
}

/// Bind `cfg.addr` and serve `registry` until the handle is shut down.
pub fn serve(cfg: HttpConfig, registry: Arc<ModelRegistry>) -> anyhow::Result<ServingHandle> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding HTTP front-end to {}", cfg.addr))?;
    let addr = listener.local_addr().context("resolving bound address")?;
    let draining = Arc::new(AtomicBool::new(false));
    let metrics = Arc::new(HttpMetrics::default());
    let ctx = Arc::new(Ctx {
        registry: Arc::clone(&registry),
        router: ShardRouter::new(Arc::clone(&registry), cfg.retry.clone()),
        metrics: Arc::clone(&metrics),
        health: HealthChecker::new(cfg.health_ttl, cfg.probe_timeout),
        draining: Arc::clone(&draining),
    });
    let threads = if cfg.threads > 0 { cfg.threads } else { host_threads() };
    let accept = thread::Builder::new()
        .name("oxbnn-http-accept".to_string())
        .spawn(move || {
            // The accept loop owns the handler pool: when it breaks, the
            // pool drops, which drains queued connections (they answer
            // 503 under the draining flag) and joins in-flight handlers.
            let pool = ThreadPool::new(threads);
            for stream in listener.incoming() {
                if ctx.draining.load(Ordering::SeqCst) {
                    break;
                }
                let stream = match stream {
                    Ok(s) => s,
                    Err(_) => continue, // transient accept error
                };
                let ctx = Arc::clone(&ctx);
                pool.execute(move || handle_conn(stream, ctx));
            }
        })
        .context("spawning HTTP accept thread")?;
    Ok(ServingHandle { addr, draining, accept: Some(accept), registry, metrics })
}

const CT_JSON: &str = "application/json";
const CT_TEXT: &str = "text/plain; version=0.0.4";

/// One dispatched response.
struct Reply {
    endpoint: &'static str,
    status: u16,
    content_type: &'static str,
    /// Adds `Retry-After: 1` (set on 429).
    retry_after: bool,
    body: String,
}

impl Reply {
    fn json(endpoint: &'static str, status: u16, body: String) -> Reply {
        Reply { endpoint, status, content_type: CT_JSON, retry_after: false, body }
    }
}

fn error_body(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).to_string()
}

/// Serve one connection: pipelined keep-alive requests until close,
/// error, or a non-keep-alive exchange. The f32 input buffer is reused
/// across all requests on the connection (zero steady-state allocation
/// in the input parse).
fn handle_conn(stream: TcpStream, ctx: Arc<Ctx>) {
    let _ = stream.set_nodelay(true);
    // Bounds a handler blocked on an idle or stalled peer, so drains
    // can't be held hostage by a silent connection.
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let mut conn = Conn::new(stream);
    let mut input_buf: Vec<f32> = Vec::new();
    loop {
        let req = match conn.read_request() {
            Ok(Some(req)) => req,
            Ok(None) => return, // clean close
            Err(HttpError::Malformed(why)) => {
                ctx.metrics.record("other", 400);
                let _ = conn.write_response(
                    400,
                    &[("Content-Type", CT_JSON)],
                    error_body(&why).as_bytes(),
                    false,
                );
                return;
            }
            Err(HttpError::Io(_)) => return, // peer gone or idle timeout
        };
        if ctx.draining.load(Ordering::SeqCst) {
            ctx.metrics.record("other", 503);
            let _ = conn.write_response(
                503,
                &[("Content-Type", CT_JSON)],
                error_body("draining").as_bytes(),
                false,
            );
            return;
        }
        let keep = req.keep_alive;
        let reply = dispatch(&req, &ctx, &mut input_buf);
        ctx.metrics.record(reply.endpoint, reply.status);
        let mut headers: Vec<(&str, &str)> = vec![("Content-Type", reply.content_type)];
        if reply.retry_after {
            headers.push(("Retry-After", "1"));
        }
        if conn
            .write_response(reply.status, &headers, reply.body.as_bytes(), keep)
            .is_err()
        {
            return;
        }
        if !keep {
            return;
        }
    }
}

fn dispatch(req: &Request, ctx: &Ctx, input_buf: &mut Vec<f32>) -> Reply {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/infer") => infer(req, ctx, input_buf, false),
        ("POST", "/v1/submit") => infer(req, ctx, input_buf, true),
        ("GET", "/metrics") => metrics_page(ctx),
        ("GET", "/healthz") => healthz(ctx),
        ("GET", "/v1/models") => Reply::json("/v1/models", 200, models_listing(ctx)),
        ("PUT", "/v1/models") => put_models(req, ctx),
        (_, "/v1/infer") | (_, "/v1/submit") | (_, "/metrics") | (_, "/healthz") => Reply::json(
            endpoint_label(&req.path),
            405,
            error_body(&format!("method {} not allowed", req.method)),
        ),
        (_, "/v1/models") => {
            Reply::json("/v1/models", 405, error_body(&format!("method {} not allowed", req.method)))
        }
        _ => Reply::json("other", 404, error_body(&format!("no such endpoint {}", req.path))),
    }
}

fn endpoint_label(path: &str) -> &'static str {
    match path {
        "/v1/infer" => "/v1/infer",
        "/v1/submit" => "/v1/submit",
        "/v1/models" => "/v1/models",
        "/metrics" => "/metrics",
        "/healthz" => "/healthz",
        _ => "other",
    }
}

/// The hot path. `detached` selects `/v1/submit` 202 semantics.
fn infer(req: &Request, ctx: &Ctx, input_buf: &mut Vec<f32>, detached: bool) -> Reply {
    let endpoint: &'static str = if detached { "/v1/submit" } else { "/v1/infer" };
    // Lazy scan — three targeted passes over the raw bytes, no tree.
    let parse_start = Instant::now();
    let model = match path_str(&req.body, &["model"]) {
        Ok(Some(m)) => m,
        Ok(None) => return Reply::json(endpoint, 400, error_body("missing 'model'")),
        Err(e) => return Reply::json(endpoint, 400, error_body(&format!("bad JSON: {}", e))),
    };
    let session = match path_str(&req.body, &["session"]) {
        Ok(s) => s,
        Err(e) => return Reply::json(endpoint, 400, error_body(&format!("bad JSON: {}", e))),
    };
    match path_f32_slice(&req.body, &["input"], input_buf) {
        Ok(true) => {}
        Ok(false) => return Reply::json(endpoint, 400, error_body("missing 'input'")),
        Err(e) => return Reply::json(endpoint, 400, error_body(&format!("bad JSON: {}", e))),
    }
    ctx.metrics.record_parse_ns(parse_start.elapsed().as_nanos() as u64);

    if detached {
        return match ctx.router.submit_detached(&model, input_buf) {
            Ok(()) => Reply::json(
                "/v1/submit",
                202,
                Json::obj(vec![("accepted", Json::Bool(true))]).to_string(),
            ),
            Err(e) => infer_error_reply("/v1/submit", e),
        };
    }
    match ctx.router.infer(&model, input_buf, session.as_deref()) {
        Ok(reply) => {
            ctx.metrics.record_retries(reply.retries);
            let logits: Vec<f64> = reply.response.logits.iter().map(|&x| x as f64).collect();
            let body = Json::obj(vec![
                ("model", Json::Str(model)),
                ("epoch", Json::Num(reply.epoch as f64)),
                ("retries", Json::Num(reply.retries as f64)),
                ("logits", Json::arr_f64(&logits)),
                (
                    "latency",
                    Json::obj(vec![
                        ("queue_s", Json::Num(reply.response.queue_s)),
                        ("execute_s", Json::Num(reply.response.execute_s)),
                        ("total_s", Json::Num(reply.response.total_s)),
                        (
                            "simulated_photonic_s",
                            Json::Num(reply.response.simulated_photonic_s),
                        ),
                    ]),
                ),
            ]);
            Reply::json("/v1/infer", 200, body.to_string())
        }
        Err(e) => infer_error_reply("/v1/infer", e),
    }
}

fn infer_error_reply(endpoint: &'static str, err: InferError) -> Reply {
    let (status, retry_after) = match &err {
        InferError::UnknownModel(_) => (404, false),
        InferError::InvalidInput { .. } => (400, false),
        InferError::Overloaded(_) => (429, true),
        InferError::Failed(_) => (500, false),
    };
    Reply {
        endpoint,
        status,
        content_type: CT_JSON,
        retry_after,
        body: error_body(&err.to_string()),
    }
}

fn metrics_page(ctx: &Ctx) -> Reply {
    let mut extra = String::new();
    for entry in ctx.registry.list() {
        let live = entry.server.replicas(&entry.name).len();
        let m = lock_unpoisoned(&entry.server.metrics);
        extra.push_str(&format!(
            "oxbnn_model_replicas{{model=\"{name}\"}} {live}\n\
             oxbnn_model_epoch{{model=\"{name}\"}} {epoch}\n\
             oxbnn_model_outstanding{{model=\"{name}\"}} {out}\n\
             oxbnn_model_completed{{model=\"{name}\"}} {done}\n\
             oxbnn_model_failed{{model=\"{name}\"}} {failed}\n\
             oxbnn_model_rejected{{model=\"{name}\"}} {rej}\n",
            name = entry.name,
            live = live,
            epoch = entry.epoch,
            out = entry.server.outstanding(&entry.name),
            done = m.completed,
            failed = m.failed,
            rej = m.rejected,
        ));
    }
    Reply {
        endpoint: "/metrics",
        status: 200,
        content_type: CT_TEXT,
        retry_after: false,
        body: ctx.metrics.render(&extra),
    }
}

fn healthz(ctx: &Ctx) -> Reply {
    let mut all_live = true;
    let mut states = std::collections::BTreeMap::new();
    for entry in ctx.registry.list() {
        let report = ctx.health.check(&entry);
        if report.state != HealthState::Live {
            all_live = false;
        }
        states.insert(
            entry.name.clone(),
            Json::obj(vec![
                ("state", Json::Str(report.state.as_str().to_string())),
                ("detail", Json::Str(report.detail)),
            ]),
        );
    }
    let body = Json::obj(vec![
        (
            "status",
            Json::Str(if all_live { "ok" } else { "unhealthy" }.to_string()),
        ),
        ("models", Json::Obj(states)),
    ]);
    Reply::json("/healthz", if all_live { 200 } else { 503 }, body.to_string())
}

fn models_listing(ctx: &Ctx) -> String {
    let models: Vec<Json> = ctx
        .registry
        .list()
        .iter()
        .map(|entry| {
            let live: Vec<f64> = entry
                .server
                .replicas(&entry.name)
                .iter()
                .map(|&r| r as f64)
                .collect();
            Json::obj(vec![
                ("name", Json::Str(entry.name.clone())),
                ("epoch", Json::Num(entry.epoch as f64)),
                ("replicas", Json::arr_f64(&live)),
                ("configured_replicas", Json::Num(entry.replicas as f64)),
                ("chips", Json::Num(entry.chips as f64)),
                ("input_len", Json::Num(entry.input_len as f64)),
                ("photonic_fps", Json::Num(entry.photonic_fps)),
            ])
        })
        .collect();
    Json::obj(vec![("models", Json::Arr(models))]).to_string()
}

/// `PUT /v1/models` — desired-state reconcile. Body shape:
/// `{"models": [{"name": "a", "replicas": 2, "chips": 4}, ...],
/// "reload": ["b"]}`. When `models` is present, listed models are
/// loaded (or resized) and unlisted ones unloaded; an optional `chips`
/// stages the model onto a K-accelerator shard group that serves as ONE
/// high-throughput replica; `reload` hot-reloads by name (epoch bump).
/// A model whose compiled plan fails the static lint gate
/// ([`LintRejection`] in the load error chain) is refused with
/// `422 Unprocessable Entity` — the request was well-formed, the plan
/// is provably unservable. Other load failures stay 400.
/// This is the cold path, so the full tree parser is fine here.
fn put_models(req: &Request, ctx: &Ctx) -> Reply {
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => return Reply::json("/v1/models", 400, error_body("body is not UTF-8")),
    };
    let j = match Json::parse(text) {
        Ok(j) => j,
        Err(e) => {
            return Reply::json("/v1/models", 400, error_body(&format!("bad JSON: {}", e)))
        }
    };
    if let Some(models) = j.get("models").and_then(Json::as_arr) {
        let mut desired: Vec<(String, usize, usize)> = Vec::new();
        for m in models {
            let name = match m.get("name").and_then(Json::as_str) {
                Some(n) => n.to_string(),
                None => {
                    return Reply::json(
                        "/v1/models",
                        400,
                        error_body("each model needs a 'name'"),
                    )
                }
            };
            let replicas = m.get("replicas").and_then(Json::as_usize).unwrap_or(0);
            let chips = m.get("chips").and_then(Json::as_usize).unwrap_or(1).max(1);
            desired.push((name, replicas, chips));
        }
        for name in ctx.registry.names() {
            if !desired.iter().any(|(n, _, _)| *n == name) {
                ctx.registry.unload(&name);
                ctx.health.invalidate(&name);
            }
        }
        for (name, replicas, chips) in &desired {
            let needs_load = match ctx.registry.get(name) {
                None => true,
                Some(entry) => {
                    entry.chips != *chips || (*replicas > 0 && entry.replicas != *replicas)
                }
            };
            if needs_load {
                if let Err(e) = ctx.registry.load_with(name, *replicas, *chips) {
                    return Reply::json(
                        "/v1/models",
                        load_error_status(&e),
                        error_body(&format!("loading '{}': {:#}", name, e)),
                    );
                }
                ctx.health.invalidate(name);
            }
        }
    }
    if let Some(reloads) = j.get("reload").and_then(Json::as_arr) {
        for r in reloads {
            let name = match r.as_str() {
                Some(n) => n,
                None => {
                    return Reply::json(
                        "/v1/models",
                        400,
                        error_body("'reload' entries must be model names"),
                    )
                }
            };
            if let Err(e) = ctx.registry.reload(name) {
                return Reply::json(
                    "/v1/models",
                    load_error_status(&e),
                    error_body(&format!("reloading '{}': {:#}", name, e)),
                );
            }
            ctx.health.invalidate(name);
        }
    }
    Reply::json("/v1/models", 200, models_listing(ctx))
}

/// 422 when the load was refused by the static plan lint (anywhere in
/// the error chain), 400 for everything else.
fn load_error_status(e: &anyhow::Error) -> u16 {
    if e.downcast_ref::<LintRejection>().is_some() {
        422
    } else {
        400
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests may unwrap
mod tests {
    use super::*;
    use crate::coordinator::ServerConfig;
    use crate::serving::http::request_once;
    use crate::util::json::path_f64;

    fn boot(models: &[(&str, usize)]) -> ServingHandle {
        let mut cfg = ServerConfig::synthetic(&[]);
        cfg.max_batch = 4;
        cfg.queue_depth = 64;
        let registry = Arc::new(ModelRegistry::synthetic(cfg));
        for (name, replicas) in models {
            registry.load(name, *replicas).unwrap();
        }
        let http = HttpConfig { addr: "127.0.0.1:0".to_string(), threads: 2, ..Default::default() };
        serve(http, registry).unwrap()
    }

    fn infer_body(model: &str) -> String {
        let input: Vec<f64> = (0..192).map(|i| (i % 7) as f64 * 0.125).collect();
        Json::obj(vec![
            ("model", Json::Str(model.to_string())),
            ("input", Json::arr_f64(&input)),
        ])
        .to_string()
    }

    #[test]
    fn infer_round_trip_and_unknowns() {
        let handle = boot(&[("tiny", 1)]);
        let addr = handle.addr().to_string();
        let (status, body) =
            request_once(&addr, "POST", "/v1/infer", infer_body("tiny").as_bytes()).unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(j.get("logits").and_then(Json::as_arr).unwrap().len(), 10);
        assert_eq!(j.get("epoch").and_then(Json::as_usize), Some(1));
        assert!(path_f64(&body, &["latency", "total_s"]).unwrap().unwrap() > 0.0);

        let (status, _) =
            request_once(&addr, "POST", "/v1/infer", infer_body("ghost").as_bytes()).unwrap();
        assert_eq!(status, 404);
        let (status, _) = request_once(&addr, "POST", "/v1/infer", b"{not json").unwrap();
        assert_eq!(status, 400);
        let (status, _) = request_once(&addr, "GET", "/nope", b"").unwrap();
        assert_eq!(status, 404);
        let (status, _) = request_once(&addr, "GET", "/v1/infer", b"").unwrap();
        assert_eq!(status, 405);
        assert_eq!(handle.metrics().count("/v1/infer", 200), 1);
        handle.shutdown();
    }

    #[test]
    fn submit_is_fire_and_forget() {
        let handle = boot(&[("tiny", 1)]);
        let addr = handle.addr().to_string();
        let (status, body) =
            request_once(&addr, "POST", "/v1/submit", infer_body("tiny").as_bytes()).unwrap();
        assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
        handle.shutdown();
    }

    #[test]
    fn health_metrics_and_models_pages() {
        let handle = boot(&[("alpha", 1), ("beta", 2)]);
        let addr = handle.addr().to_string();
        let (status, body) = request_once(&addr, "GET", "/healthz", b"").unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(j.get("status").and_then(Json::as_str), Some("ok"));

        let (status, body) = request_once(&addr, "GET", "/v1/models", b"").unwrap();
        assert_eq!(status, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let models = j.get("models").and_then(Json::as_arr).unwrap();
        assert_eq!(models.len(), 2);
        assert_eq!(models[0].get("name").and_then(Json::as_str), Some("alpha"));
        assert_eq!(
            models[1].get("replicas").and_then(Json::as_arr).map(|a| a.len()),
            Some(2)
        );

        let (status, body) = request_once(&addr, "GET", "/metrics", b"").unwrap();
        assert_eq!(status, 200);
        let text = String::from_utf8(body).unwrap();
        assert!(text.contains("oxbnn_model_replicas{model=\"beta\"} 2"), "{}", text);
        assert!(text.contains("oxbnn_http_draining 0"));
        handle.shutdown();
    }

    #[test]
    fn put_models_reconciles_desired_state() {
        let handle = boot(&[("alpha", 1), ("beta", 1)]);
        let addr = handle.addr().to_string();
        // Desired state: keep alpha, drop beta, add gamma with 2 replicas.
        let body = br#"{"models": [{"name": "alpha"}, {"name": "gamma", "replicas": 2}]}"#;
        let (status, listing) = request_once(&addr, "PUT", "/v1/models", body).unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&listing));
        assert_eq!(handle.registry().names(), vec!["alpha".to_string(), "gamma".to_string()]);
        assert_eq!(handle.registry().get("alpha").unwrap().epoch, 1, "untouched");
        assert_eq!(handle.registry().get("gamma").unwrap().replicas, 2);

        // Reload alpha: epoch bumps, serving continues.
        let (status, _) =
            request_once(&addr, "PUT", "/v1/models", br#"{"reload": ["alpha"]}"#).unwrap();
        assert_eq!(status, 200);
        assert_eq!(handle.registry().get("alpha").unwrap().epoch, 4);
        let (status, _) =
            request_once(&addr, "POST", "/v1/infer", infer_body("alpha").as_bytes()).unwrap();
        assert_eq!(status, 200);

        // Dropped model now 404s; bad reload 400s.
        let (status, _) =
            request_once(&addr, "POST", "/v1/infer", infer_body("beta").as_bytes()).unwrap();
        assert_eq!(status, 404);
        let (status, _) =
            request_once(&addr, "PUT", "/v1/models", br#"{"reload": ["ghost"]}"#).unwrap();
        assert_eq!(status, 400);
        handle.shutdown();
    }

    #[test]
    fn overcap_model_is_refused_with_422() {
        let handle = boot(&[("alpha", 1)]);
        let addr = handle.addr().to_string();
        // `*-overcap` names synthesize an FC stage whose accumulation
        // exceeds B_PCA, so the plan lints with PL301 and the load is
        // refused before any worker spawns.
        let body = br#"{"models": [{"name": "alpha"}, {"name": "bad-overcap"}]}"#;
        let (status, reply) = request_once(&addr, "PUT", "/v1/models", body).unwrap();
        let text = String::from_utf8_lossy(&reply).to_string();
        assert_eq!(status, 422, "{}", text);
        assert!(text.contains("PL301"), "{}", text);
        // The refused model was never published; existing models serve on.
        assert_eq!(handle.registry().names(), vec!["alpha".to_string()]);
        let (status, _) =
            request_once(&addr, "POST", "/v1/infer", infer_body("alpha").as_bytes()).unwrap();
        assert_eq!(status, 200);
        handle.shutdown();
    }

    #[test]
    fn shutdown_is_graceful_and_idempotent_via_drop() {
        let handle = boot(&[("tiny", 1)]);
        let addr = handle.addr().to_string();
        let (status, _) =
            request_once(&addr, "POST", "/v1/infer", infer_body("tiny").as_bytes()).unwrap();
        assert_eq!(status, 200);
        drop(handle); // Drop path must shut down cleanly too
        assert!(
            request_once(&addr, "GET", "/healthz", b"").is_err(),
            "server must be gone after drop"
        );
    }
}
