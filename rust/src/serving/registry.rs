//! Multi-model registry: artifact manifest + one coordinator
//! [`Server`] per model, with hot load/unload/reload.
//!
//! Swap discipline (epoch-guarded): a load of an already-served model
//! builds the NEW server first — workers spawned, plan compiled, weights
//! staged — and only then swaps the registry entry (epoch + 1). The swap
//! itself is guarded under the write lock: an entry only replaces one
//! with a LOWER epoch, so two loads racing on the same name can never
//! publish the older build last (the loser drains itself instead). The
//! interleaving model checker exercises exactly this protocol
//! ([`crate::check::protocols`], `RegistryBug::UnguardedSwap` shows the
//! regression the guard prevents). Requests racing the swap either land
//! on the old entry (drained in the background, so every accepted
//! request still gets its reply) or the new one; there is never a window
//! with no server behind the name.
//!
//! Loads are also statically vetted: the compiled [`ExecutionPlan`] runs
//! through [`crate::check::planlint::gate`] before the server is built,
//! and a plan with an `Error`-severity finding refuses to load
//! ([`crate::check::planlint::LintRejection`] in the error chain — the
//! HTTP surface maps it to `422 Unprocessable Entity`).
//! All per-model servers share the base config's [`PlanCache`], so N
//! models with the same geometry on the same accelerator compile one
//! mapping.
//!
//! [`ExecutionPlan`]: crate::plan::ExecutionPlan
//!
//! [`PlanCache`]: crate::plan::PlanCache

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::{synthetic_manifest, workload_from_artifact, Server, ServerConfig};
use crate::runtime::manifest::Manifest;
use crate::util::sync::{lock_unpoisoned, read_unpoisoned, write_unpoisoned};

/// Where per-model manifests come from.
enum Source {
    /// Every model name materializes the in-memory synthetic artifact —
    /// the bare-checkout serving path (and the hot-load path for smoke
    /// tests, where any name is loadable).
    Synthetic,
    /// A real artifacts manifest; loads slice it per model
    /// ([`Manifest::subset`]), so one broken sibling artifact never
    /// blocks a hot load.
    Artifacts(Manifest),
}

/// One live model: its coordinator server plus the metadata the HTTP
/// surface reports.
pub struct ModelEntry {
    pub name: String,
    /// Bumped on every (re)load of this name; `GET /v1/models` exposes it
    /// so clients can observe hot reloads.
    pub epoch: u64,
    pub server: Arc<Server>,
    pub input_len: usize,
    /// Replicas the entry was configured with (live count may be lower
    /// after quarantines — see [`Server::replicas`]).
    pub replicas: usize,
    /// Accelerators in the entry's shard group (1 = single-chip). A
    /// K-chip group is ONE registry entry — the router sees one
    /// high-throughput replica set, health is the whole group's, and
    /// unload/drain retires the group atomically.
    pub chips: usize,
    /// Simulated photonic FPS of this geometry on the configured
    /// accelerator group (the paper-model reference the front-end
    /// reports; for `chips > 1` this is the sharded group's batched FPS).
    pub photonic_fps: f64,
}

/// Registry of live models. Cheap to share (`Arc<ModelRegistry>`).
pub struct ModelRegistry {
    base: ServerConfig,
    source: Source,
    epoch: AtomicU64,
    models: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
    /// Background drains of replaced/unloaded servers; joined by
    /// [`ModelRegistry::drain_all`] so shutdown observes them complete.
    drains: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl ModelRegistry {
    /// A registry serving synthetic in-memory models: any name is
    /// loadable. `base` supplies the serving knobs (batching, queue
    /// depth, replicas, accelerator, shared plan cache); its `models`
    /// and `manifest` fields are ignored — call [`ModelRegistry::load`]
    /// per model instead.
    pub fn synthetic(base: ServerConfig) -> ModelRegistry {
        ModelRegistry {
            base,
            source: Source::Synthetic,
            epoch: AtomicU64::new(0),
            models: RwLock::new(BTreeMap::new()),
            drains: Mutex::new(Vec::new()),
        }
    }

    /// A registry over a parsed artifacts manifest.
    pub fn with_manifest(base: ServerConfig, manifest: Manifest) -> ModelRegistry {
        ModelRegistry {
            base,
            source: Source::Artifacts(manifest),
            epoch: AtomicU64::new(0),
            models: RwLock::new(BTreeMap::new()),
            drains: Mutex::new(Vec::new()),
        }
    }

    /// A registry loading `<base.artifacts_dir>/manifest.json`.
    pub fn from_artifacts(base: ServerConfig) -> Result<ModelRegistry> {
        let manifest =
            Manifest::load(&base.artifacts_dir).context("loading artifacts manifest")?;
        Ok(ModelRegistry::with_manifest(base, manifest))
    }

    /// Load (or hot-reload) `name` with `replicas` workers (0 = the base
    /// config's replica count). Builds the new server fully before
    /// swapping it in; a replaced server drains in the background.
    pub fn load(&self, name: &str, replicas: usize) -> Result<Arc<ModelEntry>> {
        self.load_with(name, replicas, 1)
    }

    /// [`ModelRegistry::load`] onto a `chips`-accelerator shard group
    /// (VdpSplit). The group is staged as ONE entry: the compiled
    /// [`crate::plan::ShardPlan`] runs through
    /// [`crate::check::planlint::gate_shard`] — exactly the same refusal
    /// surface as single-chip loads through `gate` (`LintRejection` →
    /// HTTP 422) — and the published photonic reference is the sharded
    /// group's batched FPS. `chips = 0` or `1` is the plain single-chip
    /// load.
    pub fn load_with(&self, name: &str, replicas: usize, chips: usize) -> Result<Arc<ModelEntry>> {
        let chips = chips.max(1);
        let replicas = if replicas > 0 { replicas } else { self.base.replicas.max(1) };
        let mut cfg = self.base.clone();
        cfg.models = vec![name.to_string()];
        cfg.replicas = replicas;
        let manifest = match &self.source {
            Source::Synthetic => synthetic_manifest(&[name]),
            Source::Artifacts(m) => m
                .subset(&[name])
                .with_context(|| format!("slicing manifest for model '{}'", name))?,
        };
        let artifact = manifest.get(&format!("bnn_{}", name))?.clone();
        cfg.manifest = Some(manifest);
        let workload = workload_from_artifact(&artifact);
        // Static admission: lint the compiled plan BEFORE spawning any
        // worker. An Error-severity finding (capacity overflow, threshold
        // deadlock, conservation breach) means the geometry cannot serve
        // correctly; surface it as a typed rejection instead of letting
        // workers fail at runtime.
        let policy = crate::api::default_policy(&cfg.accelerator);
        let photonic_fps = if chips > 1 {
            let shard = crate::plan::ShardPlan::compile(
                &cfg.accelerator,
                &workload,
                policy,
                chips,
                crate::plan::ShardPolicy::VdpSplit,
            );
            crate::check::planlint::gate_shard(name, &shard)
                .with_context(|| format!("refusing to load model '{}'", name))?;
            let batch = if cfg.sim_pipeline { cfg.max_batch.max(1) } else { 1 };
            crate::api::Session::builder()
                .accelerator(cfg.accelerator.clone())
                .workload(workload.clone())
                .backend(cfg.sim_backend)
                .batch(batch)
                .pipeline(cfg.sim_pipeline)
                .chips(chips)
                .plan_cache(Arc::clone(&cfg.plan_cache))
                .build()
                .map_err(|e| anyhow!("building sharded session for '{}': {}", name, e))?
                .run()
                .batched_fps()
        } else {
            let plan = cfg.plan_cache.get_or_compile(&cfg.accelerator, &workload, policy);
            crate::check::planlint::gate(name, &plan)
                .with_context(|| format!("refusing to load model '{}'", name))?;
            crate::api::simulated_photonic_fps_cached(
                &cfg.plan_cache,
                &cfg.accelerator,
                &workload,
                cfg.sim_backend,
                if cfg.sim_pipeline { cfg.max_batch } else { 1 },
                cfg.sim_pipeline,
            )
            .map_err(|e| anyhow!("simulating photonic reference for '{}': {}", name, e))?
        };
        let server = Arc::new(Server::start(cfg)?);
        let input_len = server
            .input_len(name)
            .ok_or_else(|| anyhow!("server started without model '{}'", name))?;
        let epoch = self.epoch.fetch_add(1, Ordering::SeqCst) + 1;
        let entry = Arc::new(ModelEntry {
            name: name.to_string(),
            epoch,
            server,
            input_len,
            replicas,
            chips,
            photonic_fps,
        });
        // Epoch-guarded swap: only replace an entry with a LOWER epoch.
        // Epoch allocation (fetch_add above) and publication happen under
        // different synchronization, so two loads racing on one name can
        // reach this point in either order; without the guard the older
        // build could be published last (the regression
        // `check::protocols::RegistryBug::UnguardedSwap` demonstrates).
        // The losing build drains itself; the caller gets the winner.
        let (published, superseded) = {
            let mut models = write_unpoisoned(&self.models);
            match models.get(name) {
                Some(existing) if existing.epoch >= epoch => {
                    (Arc::clone(existing), Some(Arc::clone(&entry)))
                }
                _ => {
                    let old = models.insert(name.to_string(), Arc::clone(&entry));
                    (entry, old)
                }
            }
        };
        if let Some(stale) = superseded {
            self.background_drain(stale);
        }
        Ok(published)
    }

    /// Hot-reload `name` at its current replica count and shard-group
    /// width (epoch bump).
    pub fn reload(&self, name: &str) -> Result<Arc<ModelEntry>> {
        let (replicas, chips) = self
            .get(name)
            .map(|e| (e.replicas, e.chips))
            .ok_or_else(|| anyhow!("model '{}' is not loaded", name))?;
        self.load_with(name, replicas, chips)
    }

    /// Unload `name`; its server drains in the background (accepted
    /// requests still complete). Returns `false` when not loaded.
    pub fn unload(&self, name: &str) -> bool {
        match write_unpoisoned(&self.models).remove(name) {
            Some(entry) => {
                self.background_drain(entry);
                true
            }
            None => false,
        }
    }

    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        read_unpoisoned(&self.models).get(name).cloned()
    }

    /// Live entries, name-sorted.
    pub fn list(&self) -> Vec<Arc<ModelEntry>> {
        read_unpoisoned(&self.models).values().cloned().collect()
    }

    pub fn names(&self) -> Vec<String> {
        read_unpoisoned(&self.models).keys().cloned().collect()
    }

    fn background_drain(&self, entry: Arc<ModelEntry>) {
        let spawned = thread::Builder::new()
            .name(format!("oxbnn-drain-{}", entry.name))
            .spawn({
                let entry = Arc::clone(&entry);
                move || entry.server.drain()
            });
        match spawned {
            Ok(handle) => lock_unpoisoned(&self.drains).push(handle),
            // Thread exhaustion: drain inline rather than leaking the
            // replaced server's accepted requests.
            Err(_) => entry.server.drain(),
        }
    }

    /// Drain every live model and join all background drains. Idempotent.
    pub fn drain_all(&self) {
        let entries = std::mem::take(&mut *write_unpoisoned(&self.models));
        for entry in entries.values() {
            entry.server.drain();
        }
        let handles: Vec<thread::JoinHandle<()>> =
            lock_unpoisoned(&self.drains).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests may unwrap
mod tests {
    use super::*;
    use crate::coordinator::{InferenceRequest, SubmitError};

    fn base() -> ServerConfig {
        let mut cfg = ServerConfig::synthetic(&[]);
        cfg.max_batch = 4;
        cfg.queue_depth = 64;
        cfg
    }

    #[test]
    fn load_infer_unload_lifecycle() {
        let reg = ModelRegistry::synthetic(base());
        let a = reg.load("alpha", 1).unwrap();
        assert_eq!(a.epoch, 1);
        assert_eq!(a.input_len, 8 * 8 * 3);
        assert!(a.photonic_fps > 0.0);
        let resp = a
            .server
            .infer_blocking(InferenceRequest {
                model: "alpha".into(),
                input: vec![0.25; a.input_len],
            })
            .unwrap();
        assert_eq!(resp.logits.len(), 10);
        assert_eq!(reg.names(), vec!["alpha".to_string()]);
        assert!(reg.unload("alpha"));
        assert!(!reg.unload("alpha"), "second unload is a no-op");
        assert!(reg.get("alpha").is_none());
        reg.drain_all();
    }

    #[test]
    fn hot_reload_bumps_epoch_and_keeps_serving() {
        let reg = ModelRegistry::synthetic(base());
        let v1 = reg.load("m", 1).unwrap();
        assert_eq!(v1.epoch, 1);
        let v2 = reg.reload("m").unwrap();
        assert_eq!(v2.epoch, 2);
        assert_eq!(reg.get("m").unwrap().epoch, 2);
        // The new entry serves; the replaced server drains in the
        // background and rejects new submissions once drained.
        let resp = v2
            .server
            .infer_blocking(InferenceRequest {
                model: "m".into(),
                input: vec![0.1; v2.input_len],
            })
            .unwrap();
        assert_eq!(resp.logits.len(), 10);
        reg.drain_all();
        match v1.server.submit(InferenceRequest { model: "m".into(), input: vec![0.1; v1.input_len] }) {
            Err(SubmitError::WorkerGone(_)) => {}
            other => panic!("drained server must refuse, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn models_share_one_plan_compile() {
        let reg = ModelRegistry::synthetic(base());
        let cache = Arc::clone(&reg.base.plan_cache);
        reg.load("a", 1).unwrap();
        reg.load("b", 1).unwrap();
        // Same geometry + accelerator → one compiled plan across models
        // (registry photonic-FPS computation AND both servers' workers).
        assert_eq!(cache.len(), 1, "synthetic models must share one plan");
        reg.drain_all();
    }

    #[test]
    fn group_load_stages_k_chip_entry() {
        let reg = ModelRegistry::synthetic(base());
        let solo = reg.load("alpha", 1).unwrap();
        assert_eq!(solo.chips, 1);
        let group = reg.load_with("alpha", 1, 2).unwrap();
        assert_eq!(group.chips, 2);
        assert_eq!(group.epoch, 2, "group load is an epoch-bumping swap");
        assert!(
            group.photonic_fps > 0.0 && group.photonic_fps.is_finite(),
            "group photonic FPS must be a positive reference, got {}",
            group.photonic_fps
        );
        // Reload preserves the group width.
        let again = reg.reload("alpha").unwrap();
        assert_eq!(again.chips, 2);
        // The group still serves as one replica set.
        let resp = again
            .server
            .infer_blocking(InferenceRequest {
                model: "alpha".into(),
                input: vec![0.5; again.input_len],
            })
            .unwrap();
        assert_eq!(resp.logits.len(), 10);
        reg.drain_all();
    }

    #[test]
    fn artifact_registry_rejects_unknown_models() {
        let manifest = synthetic_manifest(&["real"]);
        let reg = ModelRegistry::with_manifest(base(), manifest);
        assert!(reg.load("real", 1).is_ok());
        assert!(reg.load("ghost", 1).is_err(), "no artifact, no load");
        // The failed load never disturbed the live entry.
        assert_eq!(reg.names(), vec!["real".to_string()]);
        reg.drain_all();
    }
}
