//! Analytic (closed-form) performance model — the fast path used for the
//! full Fig. 7 benchmark sweeps. Validated against the event-driven
//! simulator on small layers (`rust/tests/sim_vs_analytic.rs`).
//!
//! Latency model per GEMM layer (batch = 1, layers sequential):
//!
//! ```text
//! compute = ceil(VDPs·slices / XPE_total) · τ            (PASS pipeline)
//! memory  = (operand_bits + psum_traffic_bits) / BW      (eDRAM + H-tree)
//! reduce  = VDPs·slices / (XPC·M) · t_red                (baselines only)
//! layer   = max(compute, memory, reduce) + fixed          (+ pipeline fill)
//! ```
//!
//! The PCA eliminates both the psum traffic term and the reduce term —
//! exactly the mechanism the paper credits for OXBNN's latency win
//! (Section IV-C); everything else is identical across accelerators.

use super::accelerator::{AcceleratorConfig, BitcountMode};
use super::reduction::ReductionNetwork;
use crate::mapping::layer::GemmLayer;
use crate::workloads::Workload;

/// Per-layer results.
#[derive(Debug, Clone)]
pub struct LayerPerf {
    pub name: String,
    pub latency_s: f64,
    pub compute_s: f64,
    pub memory_s: f64,
    pub reduce_s: f64,
    pub fixed_s: f64,
    pub dynamic_energy_j: f64,
    pub passes: u64,
    pub psums: u64,
}

/// Whole-workload (one frame) results.
#[derive(Debug, Clone)]
pub struct WorkloadPerf {
    pub accelerator: String,
    pub workload: String,
    pub frame_latency_s: f64,
    pub fps: f64,
    pub dynamic_energy_per_frame_j: f64,
    pub static_power_w: f64,
    pub avg_power_w: f64,
    pub fps_per_w: f64,
    pub layers: Vec<LayerPerf>,
}

/// Evaluate one layer on one accelerator.
pub fn layer_perf(cfg: &AcceleratorConfig, layer: &GemmLayer) -> LayerPerf {
    let tau = cfg.tau_s();
    let vdp = layer.vdp_count() as u64;
    let slices = layer.slices(cfg.n) as u64;
    let passes = vdp * slices;
    let p = &cfg.peripherals;

    // --- latency -----------------------------------------------------------
    let compute_s = (passes.div_ceil(cfg.xpe_total as u64)) as f64 * tau;

    let (psums, psum_traffic_bits, reduce_s) = match &cfg.bitcount {
        BitcountMode::Pca { .. } => (0u64, 0u64, 0.0),
        BitcountMode::Reduction { latency_s, psum_bits } => {
            let psums = passes;
            // Each psum is written to the psum buffer and read back by the
            // reduction network.
            let traffic = psums * (*psum_bits as u64) * 2;
            let net = ReductionNetwork::new(cfg.m(), *latency_s);
            // One network per XPC, all operating in parallel.
            let reduce = net.drain_time_s(psums as usize) / cfg.xpc_count() as f64;
            (psums, traffic, reduce)
        }
    };

    let memory_s =
        (layer.operand_bits() + psum_traffic_bits) as f64 / cfg.mem_bw_bits_per_s;

    // Fixed per-layer overhead: operand staging + NoC + final activation
    // drain (+ pooling + final psum-tree drain for baselines).
    let mut fixed_s = p.edram.latency_s
        + p.bus.latency_s
        + p.router.latency_s
        + p.activation_unit.latency_s;
    if layer.pool {
        fixed_s += p.pooling_unit.latency_s;
    }
    if let BitcountMode::Reduction { latency_s, .. } = &cfg.bitcount {
        fixed_s += ReductionNetwork::new(cfg.m(), *latency_s)
            .combine_latency_s(slices as usize);
    }

    let latency_s = compute_s.max(memory_s).max(reduce_s) + fixed_s;

    // --- dynamic energy ----------------------------------------------------
    let e = &cfg.energy;
    let bitops = layer.bitops() as f64;
    let mut energy = bitops * e.xnor_j_per_bit // OXG modulation
        + passes as f64 * e.receiver_j_per_pass
        + layer.operand_bits() as f64 * e.sram_j_per_bit;
    match &cfg.bitcount {
        BitcountMode::Pca { .. } => {
            energy += vdp as f64 * e.pca_readout_j;
        }
        BitcountMode::Reduction { .. } => {
            energy += psums as f64 * (e.adc_j_per_psum + e.reduction_j_per_psum)
                + psum_traffic_bits as f64 * e.sram_j_per_bit;
        }
    }

    LayerPerf {
        name: layer.name.clone(),
        latency_s,
        compute_s,
        memory_s,
        reduce_s,
        fixed_s,
        dynamic_energy_j: energy,
        passes,
        psums,
    }
}

/// Evaluate a whole workload (one inference frame, batch = 1).
pub fn workload_perf(cfg: &AcceleratorConfig, workload: &Workload) -> WorkloadPerf {
    let layers: Vec<LayerPerf> =
        workload.layers.iter().map(|l| layer_perf(cfg, l)).collect();
    let frame_latency_s: f64 = layers.iter().map(|l| l.latency_s).sum();
    let dynamic: f64 = layers.iter().map(|l| l.dynamic_energy_j).sum();
    let fps = 1.0 / frame_latency_s;
    let static_w = cfg.static_power_w();
    let frame_energy = static_w * frame_latency_s + dynamic;
    WorkloadPerf {
        accelerator: cfg.name.clone(),
        workload: workload.name.clone(),
        frame_latency_s,
        fps,
        dynamic_energy_per_frame_j: dynamic,
        static_power_w: static_w,
        avg_power_w: frame_energy / frame_latency_s,
        fps_per_w: 1.0 / frame_energy,
        layers,
    }
}

/// Geometric mean helper for the Fig. 7 gmean rows.
pub fn gmean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::accelerator::AcceleratorConfig;
    use crate::baselines::{lightbulb::lightbulb, robin::robin_eo};

    fn test_layer() -> GemmLayer {
        GemmLayer::new("conv", 1024, 1152, 128)
    }

    #[test]
    fn pca_has_no_reduce_or_psum_terms() {
        let perf = layer_perf(&AcceleratorConfig::oxbnn_50(), &test_layer());
        assert_eq!(perf.psums, 0);
        assert_eq!(perf.reduce_s, 0.0);
        assert!(perf.latency_s > 0.0);
    }

    #[test]
    fn baseline_pays_for_psums() {
        let perf = layer_perf(&robin_eo(), &test_layer());
        assert!(perf.psums > 0);
        assert!(perf.reduce_s > 0.0);
        let ox = layer_perf(&AcceleratorConfig::oxbnn_5(), &test_layer());
        assert!(perf.latency_s > ox.latency_s, "ROBIN_EO must be slower");
        assert!(perf.dynamic_energy_j > ox.dynamic_energy_j);
    }

    #[test]
    fn compute_term_matches_hand_calc() {
        let cfg = AcceleratorConfig::oxbnn_50();
        let layer = test_layer();
        let perf = layer_perf(&cfg, &layer);
        // slices = ceil(1152/19) = 61; passes = 1024·128·61.
        assert_eq!(perf.passes, 1024 * 128 * 61);
        let expect = ((1024u64 * 128 * 61).div_ceil(1123)) as f64 * 20e-12;
        assert!((perf.compute_s - expect).abs() < 1e-15);
    }

    #[test]
    fn oxbnn_beats_all_baselines_on_fig7_metrics() {
        // The paper's headline orderings must hold for a representative
        // conv layer: OXBNN wins FPS and consumes less dynamic energy.
        let layer = test_layer();
        let ox5 = layer_perf(&AcceleratorConfig::oxbnn_5(), &layer);
        let ox50 = layer_perf(&AcceleratorConfig::oxbnn_50(), &layer);
        for base in [robin_eo(), crate::baselines::robin::robin_po(), lightbulb()] {
            let b = layer_perf(&base, &layer);
            assert!(b.latency_s > ox50.latency_s, "{} vs OXBNN_50", base.name);
            assert!(b.latency_s > ox5.latency_s, "{} vs OXBNN_5", base.name);
        }
    }

    #[test]
    fn gmean_basics() {
        assert!((gmean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert!((gmean(&[5.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn fixed_overhead_includes_pooling_when_present() {
        let cfg = AcceleratorConfig::oxbnn_5();
        let plain = layer_perf(&cfg, &GemmLayer::new("a", 8, 64, 8));
        let pooled = layer_perf(&cfg, &GemmLayer::new("a", 8, 64, 8).with_pool());
        assert!(pooled.fixed_s > plain.fixed_s);
    }
}
