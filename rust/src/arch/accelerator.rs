//! Accelerator configuration: XPE/XPC/tile organization plus device and
//! energy parameters. One `AcceleratorConfig` fully describes an
//! accelerator instance (OXBNN variant or baseline) for both the analytic
//! performance model and the event-driven simulator.
//!
//! System organization (paper Fig. 6): a mesh of tiles; each tile has 4
//! XPCs sharing an output buffer and pooling units via an H-tree; an XPC
//! has M = N XPEs fed by N DWDM wavelengths.

use crate::devices::laser::LossBudget;
use crate::energy::power::{EnergyModel, Peripherals};

/// How the accelerator counts bits / combines psums.
#[derive(Debug, Clone, PartialEq)]
pub enum BitcountMode {
    /// OXBNN's Photo-Charge Accumulator: psums accumulate in the analog
    /// domain, capacity γ '1's (paper Section III-B2).
    Pca { gamma: u64 },
    /// Prior-work bitcount: one psum per PASS, converted (ADC) and
    /// combined by a psum reduction network (paper Section II-C).
    Reduction {
        /// Reduction-network latency per (pipelined) combine step.
        latency_s: f64,
        /// Bits per stored psum (storage + traffic width).
        psum_bits: u32,
    },
}

/// Full accelerator description.
#[derive(Debug, Clone)]
pub struct AcceleratorConfig {
    pub name: String,
    /// OXG/bitcount data rate (GS/s); PASS latency τ = 1/DR.
    pub dr_gsps: f64,
    /// XPE size N (OXGs per XPE = wavelengths per XPC).
    pub n: usize,
    /// Total XPEs across the accelerator (area-proportionate scaling of
    /// paper Section V-B).
    pub xpe_total: usize,
    pub bitcount: BitcountMode,
    pub energy: EnergyModel,
    pub peripherals: Peripherals,
    pub loss_budget: LossBudget,
    /// Shared operand/psum memory bandwidth (bits/s) between eDRAM and the
    /// XPC arrays. Same value for every accelerator (fair comparison).
    pub mem_bw_bits_per_s: f64,
}

/// Default shared memory bandwidth: 1 TB/s aggregate eDRAM + H-tree.
pub const DEFAULT_MEM_BW: f64 = 8e12;

impl AcceleratorConfig {
    /// PASS latency τ (paper Section III-B: as low as 20 ps at 50 GS/s).
    pub fn tau_s(&self) -> f64 {
        1.0 / (self.dr_gsps * 1e9)
    }

    /// XPEs per XPC (paper assumes M = N).
    pub fn m(&self) -> usize {
        self.n
    }

    /// XPC count to host all XPEs.
    pub fn xpc_count(&self) -> usize {
        self.xpe_total.div_ceil(self.m())
    }

    /// Tiles (4 XPCs per tile, paper Fig. 6).
    pub fn tile_count(&self) -> usize {
        self.xpc_count().div_ceil(4)
    }

    /// Total resonators (MRRs / microdisks) across all XNOR gates.
    pub fn resonator_count(&self) -> f64 {
        self.xpe_total as f64 * self.n as f64 * self.energy.mrrs_per_gate
    }

    /// Laser diodes: N wavelengths per XPC.
    pub fn laser_count(&self) -> usize {
        self.xpc_count() * self.n
    }

    /// Static (time-independent) electrical power draw (W):
    /// lasers (wall-plug), resonator thermal locking, and the Table III
    /// peripherals (per-tile eDRAM/bus/router/activation/pooling, one IO
    /// interface, reduction networks per XPC for baseline designs).
    pub fn static_power_w(&self) -> f64 {
        let p = &self.peripherals;
        let lasers = self.laser_count() as f64 * self.loss_budget.laser_electrical_w();
        let tuning = self.resonator_count() * self.energy.tuning_w_per_mrr;
        let tiles = self.tile_count() as f64;
        let per_tile = p.edram.power_w
            + p.bus.power_w
            + p.router.power_w
            + p.activation_unit.power_w
            + p.pooling_unit.power_w;
        let reduction = match self.bitcount {
            BitcountMode::Pca { .. } => 0.0,
            BitcountMode::Reduction { .. } => {
                self.xpc_count() as f64 * p.reduction_network.power_w
            }
        };
        lasers + tuning + tiles * per_tile + p.io_interface.power_w + reduction
    }

    /// Photonic area estimate (mm²): OXG footprints + peripherals.
    pub fn area_mm2(&self) -> f64 {
        let p = &self.peripherals;
        let gates = self.xpe_total as f64
            * self.n as f64
            * crate::devices::oxg::OXG_AREA_MM2
            * self.energy.mrrs_per_gate;
        let tiles = self.tile_count() as f64;
        gates
            + tiles
                * (p.edram.area_mm2
                    + p.bus.area_mm2
                    + p.router.area_mm2
                    + p.activation_unit.area_mm2
                    + p.pooling_unit.area_mm2)
            + p.io_interface.area_mm2
            + self.xpc_count() as f64 * p.reduction_network.area_mm2
    }

    // -- Reference configurations (paper Section V-B) ----------------------

    /// OXBNN_5: DR = 5 GS/s (matching ROBIN), N = 53, 100 XPEs — the
    /// area-normalization anchor.
    pub fn oxbnn_5() -> AcceleratorConfig {
        AcceleratorConfig {
            name: "OXBNN_5".into(),
            dr_gsps: 5.0,
            n: 53,
            xpe_total: 100,
            bitcount: BitcountMode::Pca {
                gamma: crate::analysis::pca_capacity::gamma_calibrated(5.0),
            },
            energy: EnergyModel::oxbnn(),
            peripherals: Peripherals::default(),
            loss_budget: LossBudget::default(),
            mem_bw_bits_per_s: DEFAULT_MEM_BW,
        }
    }

    /// OXBNN_50: DR = 50 GS/s (matching LIGHTBULB), N = 19, 1123 XPEs.
    pub fn oxbnn_50() -> AcceleratorConfig {
        AcceleratorConfig {
            name: "OXBNN_50".into(),
            dr_gsps: 50.0,
            n: 19,
            xpe_total: 1123,
            bitcount: BitcountMode::Pca {
                gamma: crate::analysis::pca_capacity::gamma_calibrated(50.0),
            },
            energy: EnergyModel::oxbnn(),
            peripherals: Peripherals::default(),
            loss_budget: LossBudget::default(),
            mem_bw_bits_per_s: DEFAULT_MEM_BW,
        }
    }

    /// All five accelerators of the paper's evaluation, in figure order.
    pub fn evaluation_set() -> Vec<AcceleratorConfig> {
        vec![
            AcceleratorConfig::oxbnn_5(),
            AcceleratorConfig::oxbnn_50(),
            crate::baselines::robin::robin_eo(),
            crate::baselines::robin::robin_po(),
            crate::baselines::lightbulb::lightbulb(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oxbnn_variants_match_paper_section5() {
        let a = AcceleratorConfig::oxbnn_5();
        assert_eq!((a.dr_gsps, a.n, a.xpe_total), (5.0, 53, 100));
        let b = AcceleratorConfig::oxbnn_50();
        assert_eq!((b.dr_gsps, b.n, b.xpe_total), (50.0, 19, 1123));
        // N values come from Table II at the matching DR.
        assert!(matches!(b.bitcount, BitcountMode::Pca { gamma: 8503 }));
        assert!(matches!(a.bitcount, BitcountMode::Pca { gamma: 29761 }));
    }

    #[test]
    fn tau_matches_paper() {
        assert!((AcceleratorConfig::oxbnn_50().tau_s() - 20e-12).abs() < 1e-18);
        assert!((AcceleratorConfig::oxbnn_5().tau_s() - 200e-12).abs() < 1e-18);
    }

    #[test]
    fn organization_counts() {
        let b = AcceleratorConfig::oxbnn_50();
        assert_eq!(b.m(), 19);
        assert_eq!(b.xpc_count(), 1123usize.div_ceil(19)); // 60
        assert_eq!(b.tile_count(), 15);
        assert_eq!(b.laser_count(), 60 * 19);
        assert_eq!(b.resonator_count(), 1123.0 * 19.0);
    }

    #[test]
    fn static_power_positive_and_laser_dominated() {
        let b = AcceleratorConfig::oxbnn_50();
        let p = b.static_power_w();
        let lasers = b.laser_count() as f64 * b.loss_budget.laser_electrical_w();
        assert!(p > lasers);
        assert!(lasers / p > 0.5, "lasers {} of {}", lasers, p);
    }

    #[test]
    fn area_scales_with_gates() {
        let small = AcceleratorConfig::oxbnn_5();
        let big = AcceleratorConfig::oxbnn_50();
        assert!(big.area_mm2() > small.area_mm2());
    }
}
