//! psum reduction network (needed by the ROBIN/LIGHTBULB baselines).
//!
//! Modeled as an M-input pipelined adder tree per XPC, clocked at the
//! Table III reduction latency (3.125 ns per initiation). A group of up to
//! M psums enters per initiation; a VDP's final value is ready after the
//! tree depth drains. OXBNN eliminates this block entirely (paper §IV-C).

/// Adder-tree reduction network model.
#[derive(Debug, Clone)]
pub struct ReductionNetwork {
    /// Tree fan-in (psums absorbed per initiation) — M of the host XPC.
    pub width: usize,
    /// Initiation interval / stage latency (s); Table III: 3.125 ns.
    pub latency_s: f64,
}

impl ReductionNetwork {
    pub fn new(width: usize, latency_s: f64) -> ReductionNetwork {
        assert!(width >= 1);
        ReductionNetwork { width, latency_s }
    }

    /// Pipeline depth for combining `count` psums (tree levels).
    pub fn depth(&self, count: usize) -> usize {
        if count <= 1 {
            return 0;
        }
        // ceil(log2(count)) levels of pairwise combine.
        (usize::BITS - (count - 1).leading_zeros()) as usize
    }

    /// Latency for one VDP whose psums arrive together: depth × stage.
    pub fn combine_latency_s(&self, psum_count: usize) -> f64 {
        self.depth(psum_count) as f64 * self.latency_s
    }

    /// Throughput-limited time to push `total_psums` through the network:
    /// one `width`-wide group per initiation interval.
    pub fn drain_time_s(&self, total_psums: usize) -> f64 {
        (total_psums.div_ceil(self.width)) as f64 * self.latency_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_is_ceil_log2() {
        let r = ReductionNetwork::new(8, 3.125e-9);
        assert_eq!(r.depth(1), 0);
        assert_eq!(r.depth(2), 1);
        assert_eq!(r.depth(3), 2);
        assert_eq!(r.depth(8), 3);
        assert_eq!(r.depth(9), 4);
        assert_eq!(r.depth(116), 7); // ROBIN_EO on an S=1152 layer
    }

    #[test]
    fn combine_latency_scales_with_depth() {
        let r = ReductionNetwork::new(8, 3.125e-9);
        assert_eq!(r.combine_latency_s(1), 0.0);
        assert!((r.combine_latency_s(8) - 3.0 * 3.125e-9).abs() < 1e-18);
    }

    #[test]
    fn drain_time_groups_by_width() {
        let r = ReductionNetwork::new(10, 3.125e-9);
        assert!((r.drain_time_s(10) - 3.125e-9).abs() < 1e-18);
        assert!((r.drain_time_s(11) - 6.25e-9).abs() < 1e-18);
        assert_eq!(r.drain_time_s(0), 0.0);
    }
}
