//! Full-workload event-driven simulation: runs every layer of a BNN
//! through the transaction-level engine with inter-layer dependencies and
//! eDRAM prefetch overlap — the detailed counterpart of
//! [`super::perf::workload_perf`] for whole frames.
//!
//! Layer l+1's operand fetch (eDRAM → tile buffers, Table III latency +
//! shared bandwidth) is issued as soon as layer l starts computing
//! (double-buffered staging), so the frame-level critical path is
//! `max(compute_l, fetch_{l+1})` chained — the same structure the analytic
//! model uses, but with the event engine's exact PASS/psum/PCA dynamics
//! per layer.

use super::accelerator::AcceleratorConfig;
use super::event_sim::simulate_layer_planned;
use crate::mapping::scheduler::MappingPolicy;
use crate::plan::ExecutionPlan;
use crate::sim::stats::SimStats;
use crate::workloads::Workload;

/// Per-layer record of a full-frame event simulation.
#[derive(Debug, Clone)]
pub struct LayerTrace {
    pub name: String,
    pub start_s: f64,
    pub compute_s: f64,
    pub fetch_s: f64,
    pub events: u64,
}

/// Whole-frame result.
#[derive(Debug, Clone)]
pub struct FrameTrace {
    pub accelerator: String,
    pub workload: String,
    pub frame_latency_s: f64,
    pub stats: SimStats,
    pub layers: Vec<LayerTrace>,
}

impl FrameTrace {
    pub fn fps(&self) -> f64 {
        1.0 / self.frame_latency_s
    }
}

/// Double-buffered fetch/compute overlap chaining for one frame: layer
/// l+1's operand fetch starts when layer l starts computing. This is the
/// single home of the frame-latency recurrence, shared by
/// [`simulate_frame`] and the api facade's event backend
/// (`api::EventSimBackend::run_workload`) so the two cannot drift.
pub struct OverlapChain<'a> {
    cfg: &'a AcceleratorConfig,
    workload: &'a Workload,
    now: f64,
    pending_fetch_done: f64,
    idx: usize,
}

impl<'a> OverlapChain<'a> {
    pub fn new(cfg: &'a AcceleratorConfig, workload: &'a Workload) -> OverlapChain<'a> {
        OverlapChain {
            cfg,
            workload,
            now: 0.0,
            // First layer cannot overlap its fetch with anything.
            pending_fetch_done: first_fetch_time(cfg, workload),
            idx: 0,
        }
    }

    /// Advance past the next layer given its compute (event end) time.
    /// Returns `(start_s, next_fetch_s)` for trace recording.
    pub fn step(&mut self, compute_s: f64) -> (f64, f64) {
        let start = self.now.max(self.pending_fetch_done);
        // Next layer's operands prefetch while this layer computes.
        let next_fetch = self
            .workload
            .layers
            .get(self.idx + 1)
            .map(|l| l.operand_bits() as f64 / self.cfg.mem_bw_bits_per_s)
            .unwrap_or(0.0);
        self.pending_fetch_done =
            start + next_fetch + self.cfg.peripherals.edram.latency_s;
        self.now = start + compute_s + self.cfg.peripherals.bus.latency_s;
        self.idx += 1;
        (start, next_fetch)
    }

    /// Frame latency after the layers stepped so far.
    pub fn frame_latency_s(&self) -> f64 {
        self.now
    }
}

/// Event-simulate one frame of `workload` on `cfg`, compiling a
/// throwaway [`ExecutionPlan`]. Callers with a plan in hand (the api
/// facade, sweeps) use [`simulate_frame_planned`] and skip recompiling.
pub fn simulate_frame(
    cfg: &AcceleratorConfig,
    workload: &Workload,
    policy: MappingPolicy,
) -> FrameTrace {
    simulate_frame_planned(&ExecutionPlan::compile(cfg, workload, policy))
}

/// Event-simulate one frame from a compiled [`ExecutionPlan`].
///
/// Each layer runs in its own event space (layers are strictly dependent,
/// so no cross-layer event interleaving is lost); fetch/compute overlap is
/// applied when chaining. Counters and the energy ledger accumulate across
/// layers into one `SimStats`. A layer whose event budget truncates panics
/// (via [`simulate_layer_planned`]) instead of contributing a bogus
/// shorter latency to the frame.
pub fn simulate_frame_planned(plan: &ExecutionPlan) -> FrameTrace {
    let cfg = &plan.accelerator;
    let workload = &plan.workload;
    let mut total = SimStats::default();
    let mut layers = Vec::with_capacity(plan.layers.len());
    let mut chain = OverlapChain::new(cfg, workload);
    for layer_plan in plan.layers.iter() {
        let stats = simulate_layer_planned(cfg, layer_plan);
        let (start, next_fetch) = chain.step(stats.end_time_s);
        layers.push(LayerTrace {
            name: layer_plan.layer.name.clone(),
            start_s: start,
            compute_s: stats.end_time_s,
            fetch_s: next_fetch,
            events: stats.events_processed,
        });
        merge(&mut total, &stats);
    }
    let now = chain.frame_latency_s();
    total.end_time_s = now;
    FrameTrace {
        accelerator: cfg.name.clone(),
        workload: workload.name.clone(),
        frame_latency_s: now,
        stats: total,
        layers,
    }
}

fn first_fetch_time(cfg: &AcceleratorConfig, workload: &Workload) -> f64 {
    workload.layers[0].operand_bits() as f64 / cfg.mem_bw_bits_per_s
        + cfg.peripherals.edram.latency_s
}

fn merge(total: &mut SimStats, part: &SimStats) {
    total.events_processed += part.events_processed;
    for (k, v) in part.counters() {
        total.count(k, *v);
    }
    for (k, v) in part.energy_breakdown() {
        total.energy(k, *v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{BackendKind, Session};
    use crate::arch::accelerator::{AcceleratorConfig, BitcountMode};
    use crate::mapping::layer::GemmLayer;

    /// Layers with >= 26 slices/VDP at N=9 so that VDP readouts arrive
    /// slower than the 5 ns TIR discharge — the regime real BNN layers
    /// occupy (ceil(S/N)·τ >> discharge). Shorter vectors make the event
    /// sim *correctly* report discharge stalls the analytic model folds
    /// away; `readout_rate_limit_visible_on_short_vectors` pins that.
    fn tiny_workload() -> Workload {
        Workload::new(
            "tiny_wl",
            vec![
                GemmLayer::new("c1", 16, 243, 8),
                GemmLayer::new("c2", 16, 288, 8).with_pool(),
                GemmLayer::fc("fc", 512, 10),
            ],
        )
    }

    fn small_cfg() -> AcceleratorConfig {
        let mut cfg = AcceleratorConfig::oxbnn_5();
        cfg.n = 9;
        cfg.xpe_total = 8;
        cfg
    }

    #[test]
    fn frame_runs_all_layers() {
        let trace = simulate_frame(&small_cfg(), &tiny_workload(), MappingPolicy::PcaLocal);
        assert_eq!(trace.layers.len(), 3);
        assert!(trace.frame_latency_s > 0.0);
        // Every layer's VDPs completed.
        let wl = tiny_workload();
        let vdps: u64 = wl.layers.iter().map(|l| l.vdp_count() as u64).sum();
        assert_eq!(trace.stats.counter("activations"), vdps);
    }

    #[test]
    fn layers_are_sequential_and_monotone() {
        let trace = simulate_frame(&small_cfg(), &tiny_workload(), MappingPolicy::PcaLocal);
        let mut prev_end = 0.0;
        for l in &trace.layers {
            assert!(l.start_s >= prev_end - 1e-15, "{} starts early", l.name);
            prev_end = l.start_s + l.compute_s;
        }
        assert!(trace.frame_latency_s >= prev_end);
    }

    #[test]
    fn event_frame_close_to_analytic() {
        // The event-driven frame must land near the closed-form model on a
        // compute-bound config (within 40%: the analytic model folds
        // pipeline fill differently).
        let cfg = small_cfg();
        let wl = tiny_workload();
        let event = simulate_frame(&cfg, &wl, MappingPolicy::PcaLocal);
        let analytic = Session::builder()
            .accelerator(cfg)
            .workload(wl)
            .backend(BackendKind::Analytic)
            .build()
            .unwrap()
            .run();
        let rel = (event.frame_latency_s - analytic.frame_latency_s).abs()
            / analytic.frame_latency_s;
        assert!(
            rel < 0.4,
            "event {} vs analytic {} (rel {:.2})",
            event.frame_latency_s,
            analytic.frame_latency_s,
            rel
        );
    }

    #[test]
    fn readout_rate_limit_visible_on_short_vectors() {
        // With few slices per VDP, consecutive readouts on one XPE arrive
        // faster than the TIR discharge — the event sim reports the stalls
        // the analytic model does not model. (Real BNN layers sit well
        // above this threshold: ceil(S/N)·τ ≥ 26·0.2 ns > 5 ns.)
        let wl = Workload::new(
            "short",
            vec![GemmLayer::new("c", 16, 27, 8)], // 3 slices/VDP → 0.6 ns
        );
        let trace = simulate_frame(&small_cfg(), &wl, MappingPolicy::PcaLocal);
        assert!(trace.stats.counter("pca_discharge_stalls") > 0);
        let long = simulate_frame(&small_cfg(), &tiny_workload(), MappingPolicy::PcaLocal);
        assert_eq!(long.stats.counter("pca_discharge_stalls"), 0);
    }

    #[test]
    fn planned_frame_matches_adhoc_frame() {
        // simulate_frame is just "compile + simulate_frame_planned"; a
        // cached plan must produce bit-identical results.
        let cfg = small_cfg();
        let wl = tiny_workload();
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
        let a = simulate_frame_planned(&plan);
        let b = simulate_frame(&cfg, &wl, MappingPolicy::PcaLocal);
        assert_eq!(a.frame_latency_s, b.frame_latency_s);
        assert_eq!(a.stats.events_processed, b.stats.events_processed);
        assert_eq!(a.stats.counters(), b.stats.counters());
    }

    #[test]
    fn pca_frame_beats_reduction_frame() {
        let wl = tiny_workload();
        let pca = simulate_frame(&small_cfg(), &wl, MappingPolicy::PcaLocal);
        let mut red_cfg = small_cfg();
        red_cfg.bitcount = BitcountMode::Reduction { latency_s: 3.125e-9, psum_bits: 16 };
        red_cfg.energy = crate::energy::power::EnergyModel::robin();
        let red = simulate_frame(&red_cfg, &wl, MappingPolicy::SlicedSpread);
        assert!(pca.frame_latency_s < red.frame_latency_s);
        assert!(pca.stats.total_energy_j() < red.stats.total_energy_j());
    }
}
