//! Full-workload event-driven simulation: runs every layer of a BNN
//! through the transaction-level engine with inter-layer dependencies and
//! eDRAM prefetch overlap — the detailed counterpart of
//! [`super::perf::workload_perf`] for whole frames.
//!
//! Layer l+1's operand fetch (eDRAM → tile buffers, Table III latency +
//! shared bandwidth) is issued as soon as layer l starts computing
//! (double-buffered staging), so the frame-level critical path is
//! `max(compute_l, fetch_{l+1})` chained — the same structure the analytic
//! model uses, but with the event engine's exact PASS/psum/PCA dynamics
//! per layer.

use super::accelerator::AcceleratorConfig;
use super::event_sim::{simulate_layer_planned, FrameWorld};
use crate::mapping::scheduler::MappingPolicy;
use crate::plan::{AdmissionMode, ExecutionPlan, FramePlan, ShardPlan};
use crate::sim::stats::SimStats;
use crate::workloads::Workload;

/// Per-layer record of a full-frame event simulation.
#[derive(Debug, Clone)]
pub struct LayerTrace {
    pub name: String,
    pub start_s: f64,
    pub compute_s: f64,
    pub fetch_s: f64,
    pub events: u64,
}

/// Whole-frame result.
#[derive(Debug, Clone)]
pub struct FrameTrace {
    pub accelerator: String,
    pub workload: String,
    pub frame_latency_s: f64,
    pub stats: SimStats,
    pub layers: Vec<LayerTrace>,
}

impl FrameTrace {
    pub fn fps(&self) -> f64 {
        1.0 / self.frame_latency_s
    }
}

/// Double-buffered fetch/compute overlap chaining for one frame: layer
/// l+1's operand fetch starts when layer l starts computing. This is the
/// single home of the frame-latency recurrence, shared by
/// [`simulate_frame`] and the api facade's event backend
/// (`api::EventSimBackend::run_workload`) so the two cannot drift.
pub struct OverlapChain<'a> {
    cfg: &'a AcceleratorConfig,
    workload: &'a Workload,
    now: f64,
    pending_fetch_done: f64,
    idx: usize,
}

impl<'a> OverlapChain<'a> {
    pub fn new(cfg: &'a AcceleratorConfig, workload: &'a Workload) -> OverlapChain<'a> {
        OverlapChain {
            cfg,
            workload,
            now: 0.0,
            // First layer cannot overlap its fetch with anything.
            pending_fetch_done: first_fetch_time(cfg, workload),
            idx: 0,
        }
    }

    /// Advance past the next layer given its compute (event end) time.
    /// Returns `(start_s, next_fetch_s)` for trace recording.
    pub fn step(&mut self, compute_s: f64) -> (f64, f64) {
        let start = self.now.max(self.pending_fetch_done);
        // Next layer's operands prefetch while this layer computes.
        let next_fetch = self
            .workload
            .layers
            .get(self.idx + 1)
            .map(|l| l.operand_bits() as f64 / self.cfg.mem_bw_bits_per_s)
            .unwrap_or(0.0);
        self.pending_fetch_done =
            start + next_fetch + self.cfg.peripherals.edram.latency_s;
        self.now = start + compute_s + self.cfg.peripherals.bus.latency_s;
        self.idx += 1;
        (start, next_fetch)
    }

    /// Frame latency after the layers stepped so far.
    pub fn frame_latency_s(&self) -> f64 {
        self.now
    }
}

/// Event-simulate one frame of `workload` on `cfg`, compiling a
/// throwaway [`ExecutionPlan`]. Callers with a plan in hand (the api
/// facade, sweeps) use [`simulate_frame_planned`] and skip recompiling.
pub fn simulate_frame(
    cfg: &AcceleratorConfig,
    workload: &Workload,
    policy: MappingPolicy,
) -> FrameTrace {
    simulate_frame_planned(&ExecutionPlan::compile(cfg, workload, policy))
}

/// Event-simulate one frame from a compiled [`ExecutionPlan`].
///
/// Each layer runs in its own event space (layers are strictly dependent,
/// so no cross-layer event interleaving is lost); fetch/compute overlap is
/// applied when chaining. Counters and the energy ledger accumulate across
/// layers into one `SimStats`. A layer whose event budget truncates panics
/// (via [`simulate_layer_planned`]) instead of contributing a bogus
/// shorter latency to the frame.
pub fn simulate_frame_planned(plan: &ExecutionPlan) -> FrameTrace {
    let cfg = &plan.accelerator;
    let workload = &plan.workload;
    let mut total = SimStats::default();
    let mut layers = Vec::with_capacity(plan.layers.len());
    let mut chain = OverlapChain::new(cfg, workload);
    for layer_plan in plan.layers.iter() {
        let stats = simulate_layer_planned(cfg, layer_plan);
        let (start, next_fetch) = chain.step(stats.end_time_s);
        layers.push(LayerTrace {
            name: layer_plan.layer.name.clone(),
            start_s: start,
            compute_s: stats.end_time_s,
            fetch_s: next_fetch,
            events: stats.events_processed,
        });
        merge(&mut total, &stats);
    }
    let now = chain.frame_latency_s();
    total.end_time_s = now;
    FrameTrace {
        accelerator: cfg.name.clone(),
        workload: workload.name.clone(),
        frame_latency_s: now,
        stats: total,
        layers,
    }
}

/// Per-layer record of the frame-0 units of a pipelined batch.
#[derive(Debug, Clone)]
pub struct PipelinedLayerTrace {
    pub name: String,
    /// Time the unit's first pass was issued.
    pub start_s: f64,
    /// Time the unit's last activation drained.
    pub done_s: f64,
    pub passes: u64,
    pub psums: u64,
    pub pca_readouts: u64,
    pub mid_vdp_readouts: u64,
    pub activations: u64,
}

/// Result of a whole-frame pipelined batch: every layer of every frame in
/// ONE event space (see [`FrameWorld`]), so cross-layer and cross-frame
/// overlap are simulated rather than multiplied.
#[derive(Debug, Clone)]
pub struct PipelineTrace {
    pub accelerator: String,
    pub workload: String,
    /// Frames simulated back-to-back through the shared event space.
    pub frames: usize,
    /// Completion time of the first frame (the pipelined frame latency).
    pub frame_latency_s: f64,
    /// Completion time of the last frame — the batch makespan.
    pub batch_latency_s: f64,
    /// Per-frame completion times (monotone: frame-major XPE priority).
    pub frame_done_s: Vec<f64>,
    /// Whole-batch stats (counters/energy cover all frames).
    pub stats: SimStats,
    /// Per-XPE accumulated PASS occupancy (s).
    pub busy_s: Vec<f64>,
    /// Per-XPE time spent parked on an admission threshold (registered
    /// in the wake index with no steal available). Disjoint from both
    /// `busy_s` and plain idle time.
    pub parked_s: Vec<f64>,
    /// XPEs per member chip — the correct per-chip denominator even
    /// when the flat grid does not divide evenly by `chips`.
    pub per_chip_xpes: usize,
    /// Frame-0 unit records, in layer order (per-frame counts/energy come
    /// from these — every frame runs the identical compiled plan).
    pub layers: Vec<PipelinedLayerTrace>,
    /// Chips in the shard group (1 = ordinary single-chip batch).
    pub chips: usize,
    /// PASS occupancy summed per chip (one entry when unsharded).
    pub chip_busy_s: Vec<f64>,
    /// Serialized occupancy of the inter-chip activation link (0 when
    /// unsharded).
    pub link_busy_s: f64,
    /// Activation flits that crossed the inter-chip link.
    pub link_transfers: u64,
}

impl PipelineTrace {
    /// Pipelined throughput: frames per batch makespan.
    pub fn fps(&self) -> f64 {
        self.frames as f64 / self.batch_latency_s
    }

    /// Mean fraction of the makespan each XPE spent running a PASS.
    pub fn xpe_busy_fraction(&self) -> f64 {
        self.mean_fraction(&self.busy_s)
    }

    /// Mean fraction of the makespan each XPE spent parked on an
    /// admission threshold — blocked with work in hand, waiting on a
    /// producer's drains. This is the time bounded work-stealing eats
    /// into; it is NOT idle capacity a bigger batch could fill.
    pub fn xpe_parked_fraction(&self) -> f64 {
        self.mean_fraction(&self.parked_s)
    }

    /// Mean fraction of the makespan each XPE spent genuinely idle:
    /// neither running a PASS nor parked on an admission threshold —
    /// the quantity multi-frame pipelining exists to shrink. (Earlier
    /// revisions folded parked time in here, overstating idleness on
    /// dependency-stalled batches.)
    pub fn xpe_idle_fraction(&self) -> f64 {
        if self.busy_s.is_empty() || self.batch_latency_s <= 0.0 {
            return 0.0;
        }
        (1.0 - self.xpe_busy_fraction() - self.xpe_parked_fraction()).clamp(0.0, 1.0)
    }

    fn mean_fraction(&self, per_xpe_s: &[f64]) -> f64 {
        if per_xpe_s.is_empty() || self.batch_latency_s <= 0.0 {
            return 0.0;
        }
        let total: f64 = per_xpe_s.iter().sum();
        (total / (per_xpe_s.len() as f64 * self.batch_latency_s)).clamp(0.0, 1.0)
    }

    /// Per-chip idle fraction over the batch makespan (one entry per
    /// member chip; a single entry when unsharded). The denominator is
    /// the plan's own per-chip XPE count — dividing the flat grid by
    /// `chips` misattributes capacity whenever K does not divide it.
    pub fn chip_idle_fraction(&self) -> Vec<f64> {
        if self.batch_latency_s <= 0.0 || self.chips == 0 {
            return vec![0.0; self.chips.max(1)];
        }
        let per_chip = self.per_chip_xpes.max(1) as f64;
        self.chip_busy_s
            .iter()
            .map(|b| (1.0 - b / (per_chip * self.batch_latency_s)).clamp(0.0, 1.0))
            .collect()
    }

    /// Fraction of the makespan the inter-chip link was occupied.
    pub fn link_occupancy_fraction(&self) -> f64 {
        if self.batch_latency_s <= 0.0 {
            0.0
        } else {
            (self.link_busy_s / self.batch_latency_s).clamp(0.0, 1.0)
        }
    }
}

/// Event-simulate `frames` back-to-back frames of a compiled plan through
/// one whole-frame pipelined event space. Layer `l+1`'s passes start as
/// soon as the exact receptive-field prefix of their input activations has
/// drained ([`crate::plan::AdmissionMode::Exact`]); frame `f+1`'s early
/// layers fill XPEs idled by frame `f`'s tail. Panics if the (generous)
/// event budget truncates the run.
pub fn simulate_frames_pipelined(plan: &ExecutionPlan, frames: usize) -> PipelineTrace {
    simulate_frames_pipelined_admission(plan, frames, AdmissionMode::Exact)
}

/// [`simulate_frames_pipelined`] under an explicit
/// [`crate::plan::AdmissionMode`] — the halo mode exists for the
/// exact-vs-halo differential tests and `bench_pipeline`.
pub fn simulate_frames_pipelined_admission(
    plan: &ExecutionPlan,
    frames: usize,
    admission: AdmissionMode,
) -> PipelineTrace {
    simulate_frames_pipelined_opts(plan, frames, admission, true)
}

/// [`simulate_frames_pipelined`] with every scheduler knob explicit:
/// admission mode and bounded work-stealing (`steal = false` reproduces
/// the strict frame-major frontier; the differential is property-tested
/// and benched by `bench_steal`).
pub fn simulate_frames_pipelined_opts(
    plan: &ExecutionPlan,
    frames: usize,
    admission: AdmissionMode,
    steal: bool,
) -> PipelineTrace {
    let fp = FramePlan::with_admission(plan, frames, admission);
    run_frame_world(&plan.accelerator, &fp, steal)
}

/// Event-simulate `frames` back-to-back frames of a K-chip [`ShardPlan`]
/// through one shared event space: the unit table spans the whole
/// group's XPEs, cross-chip activation edges are serialized onto the
/// shared inter-chip link, and the consumer chip's admission counts
/// *arrived* activations against the same exact receptive-field
/// thresholds. A `K = 1` shard is event-identical to
/// [`simulate_frames_pipelined`] on the inner plan (pinned by
/// `rust/tests/scaleout.rs`).
pub fn simulate_frames_sharded(shard: &ShardPlan, frames: usize) -> PipelineTrace {
    simulate_frames_sharded_admission(shard, frames, AdmissionMode::Exact)
}

/// [`simulate_frames_sharded`] under an explicit [`AdmissionMode`].
pub fn simulate_frames_sharded_admission(
    shard: &ShardPlan,
    frames: usize,
    admission: AdmissionMode,
) -> PipelineTrace {
    simulate_frames_sharded_opts(shard, frames, admission, true)
}

/// [`simulate_frames_sharded`] with admission and work-stealing explicit.
pub fn simulate_frames_sharded_opts(
    shard: &ShardPlan,
    frames: usize,
    admission: AdmissionMode,
    steal: bool,
) -> PipelineTrace {
    let fp = FramePlan::for_shard(shard, frames, admission);
    // The world runs against the per-chip accelerator: a VdpSplit plan's
    // own `accelerator` is the scaled group grid, not a member chip.
    run_frame_world(&shard.base, &fp, steal)
}

/// The single home of "run a [`FrameWorld`] and package a
/// [`PipelineTrace`]", shared by the unsharded and sharded entry points
/// so the two cannot drift.
fn run_frame_world(cfg: &AcceleratorConfig, fp: &FramePlan<'_>, steal: bool) -> PipelineTrace {
    let plan = fp.plan();
    let frames = fp.frames();
    let mut world = FrameWorld::new(cfg, fp);
    world.set_steal(steal);
    let outcome = crate::sim::engine::run(&mut world, fp.event_budget());
    let mut stats = outcome.expect_complete(&format!(
        "pipelined batch of {} frame(s) of '{}'",
        frames, plan.workload.name
    ));
    let frame_done_s = world.frame_done_s().to_vec();
    let batch_latency_s =
        frame_done_s.iter().cloned().fold(0.0_f64, f64::max);
    stats.end_time_s = batch_latency_s;
    let layers = world.units()[..plan.layers.len()]
        .iter()
        .zip(&plan.layers)
        .map(|(u, lp)| PipelinedLayerTrace {
            name: lp.layer.name.clone(),
            start_s: u.start_s,
            done_s: u.done_s,
            passes: u.passes,
            psums: u.psums,
            pca_readouts: u.pca_readouts,
            mid_vdp_readouts: u.mid_vdp_readouts,
            activations: u.activations,
        })
        .collect();
    PipelineTrace {
        accelerator: cfg.name.clone(),
        workload: plan.workload.name.clone(),
        frames,
        frame_latency_s: frame_done_s[0],
        batch_latency_s,
        frame_done_s,
        busy_s: world.busy_s().to_vec(),
        parked_s: world.parked_s().to_vec(),
        stats,
        layers,
        chips: fp.chips(),
        per_chip_xpes: fp.per_chip_xpes(),
        chip_busy_s: world.per_chip_busy_s(),
        link_busy_s: world.link_busy_s(),
        link_transfers: world.link_transfers(),
    }
}

fn first_fetch_time(cfg: &AcceleratorConfig, workload: &Workload) -> f64 {
    workload.layers[0].operand_bits() as f64 / cfg.mem_bw_bits_per_s
        + cfg.peripherals.edram.latency_s
}

fn merge(total: &mut SimStats, part: &SimStats) {
    total.events_processed += part.events_processed;
    for (k, v) in part.counters() {
        // Peak stats don't add across layers run in separate event spaces
        // — the frame-level live-queue footprint is the largest layer's.
        if k == "peak_pending_events" {
            total.set_counter_max(k, *v);
        } else {
            total.count(k, *v);
        }
    }
    for (k, v) in part.energy_breakdown() {
        total.energy(k, *v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{BackendKind, Session};
    use crate::arch::accelerator::{AcceleratorConfig, BitcountMode};
    use crate::mapping::layer::{ConvGeom, GemmLayer};

    /// Layers with >= 26 slices/VDP at N=9 so that VDP readouts arrive
    /// slower than the 5 ns TIR discharge — the regime real BNN layers
    /// occupy (ceil(S/N)·τ >> discharge). Shorter vectors make the event
    /// sim *correctly* report discharge stalls the analytic model folds
    /// away; `readout_rate_limit_visible_on_short_vectors` pins that.
    /// The convs are 3×3 same-convs on a 4×4 map, so exact receptive-field
    /// admission lets c2 start after c1's first two activation rows.
    fn tiny_workload() -> Workload {
        Workload::new(
            "tiny_wl",
            vec![
                GemmLayer::new("c1", 16, 243, 8).with_geom(ConvGeom::new(3, 1, 1, 4)),
                GemmLayer::new("c2", 16, 288, 8)
                    .with_geom(ConvGeom::new(3, 1, 1, 4))
                    .with_pool(),
                GemmLayer::fc("fc", 512, 10),
            ],
        )
    }

    fn small_cfg() -> AcceleratorConfig {
        let mut cfg = AcceleratorConfig::oxbnn_5();
        cfg.n = 9;
        cfg.xpe_total = 8;
        cfg
    }

    #[test]
    fn frame_runs_all_layers() {
        let trace = simulate_frame(&small_cfg(), &tiny_workload(), MappingPolicy::PcaLocal);
        assert_eq!(trace.layers.len(), 3);
        assert!(trace.frame_latency_s > 0.0);
        // Every layer's VDPs completed.
        let wl = tiny_workload();
        let vdps: u64 = wl.layers.iter().map(|l| l.vdp_count() as u64).sum();
        assert_eq!(trace.stats.counter("activations"), vdps);
    }

    #[test]
    fn layers_are_sequential_and_monotone() {
        let trace = simulate_frame(&small_cfg(), &tiny_workload(), MappingPolicy::PcaLocal);
        let mut prev_end = 0.0;
        for l in &trace.layers {
            assert!(l.start_s >= prev_end - 1e-15, "{} starts early", l.name);
            prev_end = l.start_s + l.compute_s;
        }
        assert!(trace.frame_latency_s >= prev_end);
    }

    #[test]
    fn event_frame_close_to_analytic() {
        // The event-driven frame must land near the closed-form model on a
        // compute-bound config (within 40%: the analytic model folds
        // pipeline fill differently).
        let cfg = small_cfg();
        let wl = tiny_workload();
        let event = simulate_frame(&cfg, &wl, MappingPolicy::PcaLocal);
        let analytic = Session::builder()
            .accelerator(cfg)
            .workload(wl)
            .backend(BackendKind::Analytic)
            .build()
            .unwrap()
            .run();
        let rel = (event.frame_latency_s - analytic.frame_latency_s).abs()
            / analytic.frame_latency_s;
        assert!(
            rel < 0.4,
            "event {} vs analytic {} (rel {:.2})",
            event.frame_latency_s,
            analytic.frame_latency_s,
            rel
        );
    }

    #[test]
    fn readout_rate_limit_visible_on_short_vectors() {
        // With few slices per VDP, consecutive readouts on one XPE arrive
        // faster than the TIR discharge — the event sim reports the stalls
        // the analytic model does not model. (Real BNN layers sit well
        // above this threshold: ceil(S/N)·τ ≥ 26·0.2 ns > 5 ns.)
        let wl = Workload::new(
            "short",
            vec![GemmLayer::new("c", 16, 27, 8)], // 3 slices/VDP → 0.6 ns
        );
        let trace = simulate_frame(&small_cfg(), &wl, MappingPolicy::PcaLocal);
        assert!(trace.stats.counter("pca_discharge_stalls") > 0);
        let long = simulate_frame(&small_cfg(), &tiny_workload(), MappingPolicy::PcaLocal);
        assert_eq!(long.stats.counter("pca_discharge_stalls"), 0);
    }

    #[test]
    fn planned_frame_matches_adhoc_frame() {
        // simulate_frame is just "compile + simulate_frame_planned"; a
        // cached plan must produce bit-identical results.
        let cfg = small_cfg();
        let wl = tiny_workload();
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
        let a = simulate_frame_planned(&plan);
        let b = simulate_frame(&cfg, &wl, MappingPolicy::PcaLocal);
        assert_eq!(a.frame_latency_s, b.frame_latency_s);
        assert_eq!(a.stats.events_processed, b.stats.events_processed);
        assert_eq!(a.stats.counters(), b.stats.counters());
    }

    #[test]
    fn pipelined_single_frame_conserves_and_is_no_slower() {
        let cfg = small_cfg();
        let wl = tiny_workload();
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
        let seq = simulate_frame_planned(&plan);
        let pipe = simulate_frames_pipelined(&plan, 1);
        // Same compiled plan streamed either way: the transaction multiset
        // is conserved exactly.
        for key in ["passes", "pca_readouts", "activations", "psums"] {
            assert_eq!(
                pipe.stats.counter(key),
                seq.stats.counter(key),
                "counter '{}' diverged",
                key
            );
        }
        assert_eq!(pipe.stats.counter("clamped_events"), 0);
        // Cross-layer overlap can only help a frame, never hurt it.
        assert!(
            pipe.frame_latency_s <= seq.frame_latency_s * (1.0 + 1e-9),
            "pipelined {} vs sequential {}",
            pipe.frame_latency_s,
            seq.frame_latency_s
        );
        assert!(pipe.frame_latency_s > 0.0);
        assert_eq!(pipe.layers.len(), wl.layers.len());
        for (lt, l) in pipe.layers.iter().zip(&wl.layers) {
            assert_eq!(lt.passes, l.total_passes(cfg.n) as u64, "layer {}", lt.name);
            assert_eq!(lt.activations, l.vdp_count() as u64);
            assert!(lt.done_s >= lt.start_s);
        }
    }

    #[test]
    fn pipelined_layers_overlap_within_a_frame() {
        // The tentpole behavior: layer l+1's first passes start before
        // layer l's last activation drains (sequential chaining forbids
        // exactly this).
        let cfg = small_cfg();
        let wl = tiny_workload();
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
        let pipe = simulate_frames_pipelined(&plan, 1);
        let overlap = pipe
            .layers
            .windows(2)
            .any(|w| w[1].start_s < w[0].done_s);
        assert!(overlap, "no cross-layer overlap observed: {:?}", pipe.layers);
    }

    #[test]
    fn pipelined_batch_beats_sequential_multiply() {
        let cfg = small_cfg();
        let wl = tiny_workload();
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
        let seq = simulate_frame_planned(&plan);
        let n = 4;
        let pipe = simulate_frames_pipelined(&plan, n);
        assert_eq!(
            pipe.stats.counter("passes"),
            n as u64 * seq.stats.counter("passes"),
            "batch must run every frame's every pass"
        );
        assert_eq!(pipe.stats.counter("clamped_events"), 0);
        // Frames complete in order (frame-major XPE priority).
        for w in pipe.frame_done_s.windows(2) {
            assert!(
                w[1] >= w[0] - 1e-12,
                "frame completions out of order: {:?}",
                pipe.frame_done_s
            );
        }
        // Multi-frame overlap strictly beats the with_batch multiply.
        let sequential_batch = n as f64 * seq.frame_latency_s;
        assert!(
            pipe.batch_latency_s < sequential_batch,
            "pipelined batch {} vs sequential {}",
            pipe.batch_latency_s,
            sequential_batch
        );
        assert!(pipe.fps() > 1.0 / seq.frame_latency_s);
        let idle = pipe.xpe_idle_fraction();
        assert!((0.0..1.0).contains(&idle), "idle fraction {}", idle);
    }

    #[test]
    fn steal_off_conserves_and_never_beats_steal_on() {
        // The bounded-steal differential at module scope: the same
        // compiled plan with stealing disabled runs the identical
        // transaction multiset, never faster, and reports zero steal
        // counters (the prop suite fuzzes this across geometries).
        let cfg = small_cfg();
        let wl = tiny_workload();
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
        let n = 4;
        let on = simulate_frames_pipelined_opts(&plan, n, AdmissionMode::Exact, true);
        let off = simulate_frames_pipelined_opts(&plan, n, AdmissionMode::Exact, false);
        for key in ["passes", "pca_readouts", "activations", "psums"] {
            assert_eq!(on.stats.counter(key), off.stats.counter(key), "counter '{}'", key);
        }
        assert_eq!(on.stats.counter("clamped_events"), 0);
        assert_eq!(off.stats.counter("clamped_events"), 0);
        assert_eq!(off.stats.counter("steal_dispatches"), 0);
        assert_eq!(off.stats.counter("stolen_passes"), 0);
        assert!(
            on.batch_latency_s <= off.batch_latency_s * (1.0 + 1e-9),
            "steal-on {} vs steal-off {}",
            on.batch_latency_s,
            off.batch_latency_s
        );
        // Busy + parked + idle fractions tile the makespan.
        for t in [&on, &off] {
            let total = t.xpe_busy_fraction() + t.xpe_parked_fraction() + t.xpe_idle_fraction();
            assert!((total - 1.0).abs() < 1e-9, "fractions sum to {}", total);
        }
    }

    #[test]
    fn sharded_chip_fractions_use_stage_map_k3_on_64_xpes() {
        // K = 3 chips of 64 XPEs each under LayerPipeline: the per-chip
        // denominator must come from the ShardPlan's own per-chip slot
        // count, never from dividing the flat grid by `chips`, and
        // chip attribution must land each stage's work on its stage
        // chip with nothing lost.
        use crate::plan::{ShardPlan, ShardPolicy};
        let mut cfg = AcceleratorConfig::oxbnn_5();
        cfg.n = 8;
        cfg.xpe_total = 64;
        let wl = tiny_workload();
        let shard =
            ShardPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal, 3, ShardPolicy::LayerPipeline);
        assert_eq!(shard.per_chip_xpes(), 64);
        let trace = simulate_frames_sharded(&shard, 2);
        assert_eq!(trace.stats.counter("clamped_events"), 0);
        assert_eq!(trace.chips, 3);
        assert_eq!(trace.per_chip_xpes, 64);
        assert_eq!(trace.chip_busy_s.len(), 3);
        // Attribution conserves occupancy exactly.
        let flat: f64 = trace.busy_s.iter().sum();
        let chips: f64 = trace.chip_busy_s.iter().sum();
        assert!((flat - chips).abs() < 1e-9, "busy {} vs per-chip {}", flat, chips);
        // Occupancy lands exactly on the chips the stage map names.
        let stages: std::collections::HashSet<usize> =
            shard.chip_of_layer.iter().copied().collect();
        for (c, b) in trace.chip_busy_s.iter().enumerate() {
            assert_eq!(
                *b > 0.0,
                stages.contains(&c),
                "chip {} occupancy {} disagrees with stage map {:?}",
                c,
                b,
                shard.chip_of_layer
            );
        }
        for (c, f) in trace.chip_idle_fraction().iter().enumerate() {
            assert!((0.0..=1.0).contains(f), "chip {} idle fraction {}", c, f);
        }
    }

    #[test]
    fn pipelined_reduction_mode_conserves_psums() {
        let wl = tiny_workload();
        let mut cfg = small_cfg();
        cfg.bitcount = BitcountMode::Reduction { latency_s: 3.125e-9, psum_bits: 16 };
        cfg.energy = crate::energy::power::EnergyModel::robin();
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::SlicedSpread);
        let seq = simulate_frame_planned(&plan);
        let pipe = simulate_frames_pipelined(&plan, 2);
        assert_eq!(pipe.stats.counter("psums"), 2 * seq.stats.counter("psums"));
        assert_eq!(pipe.stats.counter("activations"), 2 * seq.stats.counter("activations"));
        assert_eq!(pipe.stats.counter("clamped_events"), 0);
        assert!(pipe.batch_latency_s < 2.0 * seq.frame_latency_s);
    }

    #[test]
    fn pca_frame_beats_reduction_frame() {
        let wl = tiny_workload();
        let pca = simulate_frame(&small_cfg(), &wl, MappingPolicy::PcaLocal);
        let mut red_cfg = small_cfg();
        red_cfg.bitcount = BitcountMode::Reduction { latency_s: 3.125e-9, psum_bits: 16 };
        red_cfg.energy = crate::energy::power::EnergyModel::robin();
        let red = simulate_frame(&red_cfg, &wl, MappingPolicy::SlicedSpread);
        assert!(pca.frame_latency_s < red.frame_latency_s);
        assert!(pca.stats.total_energy_j() < red.stats.total_energy_j());
    }
}
