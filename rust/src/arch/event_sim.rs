//! Event-driven (transaction-level) simulation of one GEMM layer on an
//! accelerator — the detailed counterpart of the closed-form model in
//! [`super::perf`]. Every PASS, PCA readout, psum, reduction initiation
//! and activation is an explicit event; PCA saturation/discharge dynamics
//! come from the real [`crate::devices::pca::Pca`] state machine.
//!
//! Used for the Fig. 5 mapping comparison, PCA-dynamics studies (including
//! forced mid-VDP readouts when γ is too small for the vector — paper
//! Section III-B2: "once the TIR saturates, the ongoing accumulation phase
//! ends"), and to validate the analytic model (exact transaction counts,
//! close latency).
//!
//! Hot-loop structure (EXPERIMENTS.md §Perf L3-sim): the schedule is a
//! compiled [`LayerPlan`] streamed through a [`PassStream`] — each XPE's
//! next pass is computed in O(1), so the world's live state is O(#XPEs)
//! cursors + O(#VDPs) completion counters instead of one heap struct per
//! pass (a VGG conv layer has millions). Counters/energy accumulate in
//! plain fields flushed once via `World::finalize` — no per-event
//! string-keyed map traffic.

use super::accelerator::{AcceleratorConfig, BitcountMode};
use crate::devices::pca::{Pca, PcaParams};
use crate::mapping::layer::GemmLayer;
use crate::mapping::scheduler::MappingPolicy;
use crate::plan::{FramePlan, FrameStream, LayerPlan, PassStream};
use crate::sim::engine::{RunOutcome, Scheduler, World};
use crate::sim::event::{EventKind, VdpId, XpeId};
use crate::sim::stats::SimStats;

/// One-layer event-driven world, driven by a compiled [`LayerPlan`].
pub struct LayerWorld<'a> {
    cfg: &'a AcceleratorConfig,
    plan: &'a LayerPlan,
    /// O(#XPEs) streaming cursor over the plan — replaces the old
    /// materialized (and cloned) per-XPE pass queues.
    stream: PassStream,
    slices: usize,
    m: usize,
    /// Per-XPE PCA state (None in reduction mode), indexed flat.
    pcas: Vec<Option<Pca>>,
    /// Remaining slices per VDP (reduction-mode completion tracking).
    vdp_remaining: Vec<usize>,
    vdps_done: usize,
    vdp_total: usize,
    /// Per-XPC pending psum count and next-free time of its reduction net.
    red_pending: Vec<usize>,
    red_free_at: Vec<f64>,
    /// Ones per slice bit (density of synthetic activations).
    ones_density: f64,
    // --- locally accumulated metrics (flushed in finalize) --------------
    n_passes: u64,
    n_pca_readouts: u64,
    n_mid_vdp_readouts: u64,
    n_saturations: u64,
    n_discharge_stalls: u64,
    n_psums: u64,
    n_reduction_inits: u64,
    n_reductions_done: u64,
    n_activations: u64,
    e_oxg: f64,
    e_receiver: f64,
    e_pca: f64,
    e_adc_red: f64,
}

impl<'a> LayerWorld<'a> {
    /// Build the world over a plan compiled for exactly this accelerator
    /// geometry.
    pub fn new(cfg: &'a AcceleratorConfig, plan: &'a LayerPlan) -> LayerWorld<'a> {
        assert!(
            plan.n == cfg.n && plan.m == cfg.m() && plan.xpc_count == cfg.xpc_count(),
            "plan geometry (N={}, M={}, XPCs={}) does not match accelerator '{}' \
             (N={}, M={}, XPCs={})",
            plan.n,
            plan.m,
            plan.xpc_count,
            cfg.name,
            cfg.n,
            cfg.m(),
            cfg.xpc_count()
        );
        let gamma = match cfg.bitcount {
            BitcountMode::Pca { gamma } => gamma,
            _ => 0,
        };
        let m = cfg.m();
        let total = plan.total_xpes();
        let pcas: Vec<Option<Pca>> = (0..total)
            .map(|_| match cfg.bitcount {
                BitcountMode::Pca { .. } => Some(Pca::new(PcaParams::default(), gamma)),
                _ => None,
            })
            .collect();
        let vdp_total = plan.vdp_count();
        let slices = plan.slices();
        let xpcs = cfg.xpc_count();
        LayerWorld {
            cfg,
            plan,
            stream: PassStream::new(plan),
            slices,
            m,
            pcas,
            vdp_remaining: vec![slices; vdp_total],
            vdps_done: 0,
            vdp_total,
            red_pending: vec![0; xpcs],
            red_free_at: vec![0.0; xpcs],
            ones_density: 0.5,
            n_passes: 0,
            n_pca_readouts: 0,
            n_mid_vdp_readouts: 0,
            n_saturations: 0,
            n_discharge_stalls: 0,
            n_psums: 0,
            n_reduction_inits: 0,
            n_reductions_done: 0,
            n_activations: 0,
            e_oxg: 0.0,
            e_receiver: 0.0,
            e_pca: 0.0,
            e_adc_red: 0.0,
        }
    }

    fn flat(&self, id: XpeId) -> usize {
        id.xpc * self.m + id.xpe
    }

    /// Stream the next planned pass on `id` and issue it after
    /// `extra_delay` — O(1), no queue lookup.
    fn start_next_pass(&mut self, id: XpeId, extra_delay: f64, sched: &mut Scheduler) {
        let flat = self.flat(id);
        let Some(pass) = self.stream.next_for(self.plan, flat) else {
            return;
        };
        let tau = self.cfg.tau_s();
        let ones = (pass.slice_len as f64 * self.ones_density).round() as u64;
        sched.after(
            extra_delay + tau,
            EventKind::PassComplete { xpe: id, vdp: pass.vdp, slice_idx: pass.slice_idx, ones },
        );
    }

    fn all_passes_issued(&self) -> bool {
        self.stream.all_issued()
    }
}

impl World for LayerWorld<'_> {
    fn init(&mut self, sched: &mut Scheduler, _stats: &mut SimStats) {
        for xpc in 0..self.red_pending.len() {
            for xpe in 0..self.m {
                self.start_next_pass(XpeId { xpc, xpe }, 0.0, sched);
            }
        }
    }

    fn handle(&mut self, event: &EventKind, sched: &mut Scheduler, _stats: &mut SimStats) {
        match event {
            EventKind::PassComplete { xpe, vdp, slice_idx, ones } => {
                self.n_passes += 1;
                self.e_oxg += self.cfg.n as f64 * self.cfg.energy.xnor_j_per_bit;
                self.e_receiver += self.cfg.energy.receiver_j_per_pass;
                let is_pca = matches!(self.cfg.bitcount, BitcountMode::Pca { .. });
                if is_pca {
                    let last = *slice_idx == self.slices - 1;
                    let flat = self.flat(*xpe);
                    let pca = self.pcas[flat].as_mut().expect("pca mode");
                    let saturated = pca.accumulate(*ones);
                    if saturated {
                        self.n_saturations += 1;
                    }
                    if last {
                        sched.after(0.0, EventKind::PcaReadout { xpe: *xpe, vdp: *vdp });
                    } else if saturated {
                        // Paper Section III-B2: a railed TIR ends the
                        // accumulation phase. Read out mid-VDP (losing the
                        // clamped excess), swap capacitors, and continue
                        // the same VDP on the fresh TIR — stalling only if
                        // the redundant capacitor is still discharging.
                        self.n_mid_vdp_readouts += 1;
                        self.e_pca += self.cfg.energy.pca_readout_j;
                        let now = sched.now();
                        let pca = self.pcas[flat].as_mut().expect("pca mode");
                        let (_r, stall) = pca.readout(now);
                        if stall > 0.0 {
                            self.n_discharge_stalls += 1;
                        }
                        self.start_next_pass(*xpe, stall, sched);
                    } else {
                        self.start_next_pass(*xpe, 0.0, sched);
                    }
                } else {
                    sched.after(0.0, EventKind::PsumReady {
                        xpe: *xpe,
                        vdp: *vdp,
                        slice_idx: *slice_idx,
                    });
                    self.start_next_pass(*xpe, 0.0, sched);
                }
            }
            EventKind::PcaReadout { xpe, vdp } => {
                self.n_pca_readouts += 1;
                self.e_pca += self.cfg.energy.pca_readout_j;
                let now = sched.now();
                let flat = self.flat(*xpe);
                let pca = self.pcas[flat].as_mut().expect("pca mode");
                let (_result, stall) = pca.readout(now);
                if stall > 0.0 {
                    self.n_discharge_stalls += 1;
                }
                // Comparator/activation latency, then this VDP is done.
                let act = self.cfg.peripherals.activation_unit.latency_s;
                sched.after(stall + act, EventKind::ActivationDone { vdp: *vdp });
                // The XPE continues with its next queued VDP after the
                // (possibly stalled) swap.
                self.start_next_pass(*xpe, stall, sched);
            }
            EventKind::PsumReady { xpe, vdp, .. } => {
                self.n_psums += 1;
                self.e_adc_red +=
                    self.cfg.energy.adc_j_per_psum + self.cfg.energy.reduction_j_per_psum;
                let xpc = xpe.xpc;
                self.red_pending[xpc] += 1;
                // Group psums M-wide per initiation of the XPC's network.
                let (lat, width) = match self.cfg.bitcount {
                    BitcountMode::Reduction { latency_s, .. } => (latency_s, self.m),
                    _ => unreachable!("psum in PCA mode"),
                };
                if self.red_pending[xpc] >= width || self.all_passes_issued() {
                    let start = sched.now().max(self.red_free_at[xpc]);
                    self.red_free_at[xpc] = start + lat;
                    self.red_pending[xpc] = 0;
                    self.n_reduction_inits += 1;
                    sched.at(start + lat, EventKind::ReductionDone { vdp: *vdp });
                }
                // VDP completion bookkeeping (all slices produced).
                let v = vdp.0;
                self.vdp_remaining[v] -= 1;
                if self.vdp_remaining[v] == 0 {
                    let act = self.cfg.peripherals.activation_unit.latency_s;
                    let lat_now = sched.now();
                    let done_at = self.red_free_at[xpc].max(lat_now) + lat + act;
                    sched.at(done_at, EventKind::ActivationDone { vdp: *vdp });
                }
            }
            EventKind::ReductionDone { .. } => {
                self.n_reductions_done += 1;
            }
            EventKind::ActivationDone { .. } => {
                self.n_activations += 1;
                self.vdps_done += 1;
            }
            _ => {}
        }
    }

    fn done(&self) -> bool {
        self.vdps_done >= self.vdp_total
    }

    fn finalize(&mut self, stats: &mut SimStats) {
        stats.count("passes", self.n_passes);
        stats.count("pca_readouts", self.n_pca_readouts);
        stats.count("mid_vdp_readouts", self.n_mid_vdp_readouts);
        stats.count("pca_saturations", self.n_saturations);
        stats.count("pca_discharge_stalls", self.n_discharge_stalls);
        stats.count("psums", self.n_psums);
        stats.count("reduction_inits", self.n_reduction_inits);
        stats.count("reductions_done", self.n_reductions_done);
        stats.count("activations", self.n_activations);
        stats.energy("oxg", self.e_oxg);
        stats.energy("receiver", self.e_receiver);
        stats.energy("pca", self.e_pca);
        stats.energy("adc+reduction", self.e_adc_red);
    }
}

/// Run one pre-compiled layer plan to completion on `cfg`, without
/// panicking on truncation — the caller inspects `completed`.
pub fn simulate_layer_outcome(cfg: &AcceleratorConfig, plan: &LayerPlan) -> RunOutcome {
    let mut world = LayerWorld::new(cfg, plan);
    crate::sim::engine::run(&mut world, plan.event_budget())
}

/// Run one pre-compiled layer plan to completion, returning stats.
/// Panics if the event budget truncated the run (a truncated latency is
/// bogus; the generous budget means truncation is a scheduling bug).
pub fn simulate_layer_planned(cfg: &AcceleratorConfig, plan: &LayerPlan) -> SimStats {
    simulate_layer_outcome(cfg, plan)
        .expect_complete(&format!("layer '{}'", plan.layer.name))
}

/// Convenience: compile a single-layer plan and run it to completion.
pub fn simulate_layer(
    cfg: &AcceleratorConfig,
    layer: &GemmLayer,
    policy: MappingPolicy,
) -> SimStats {
    let plan = LayerPlan::compile(layer, policy, cfg.n, cfg.m(), cfg.xpc_count());
    simulate_layer_planned(cfg, &plan)
}

// ---------------------------------------------------------------------------
// Whole-frame pipelined event space
// ---------------------------------------------------------------------------

/// Dynamic-energy ledger implied by a set of transaction counts on `cfg`
/// — the single home of the per-event energy formulas shared by
/// [`FrameWorld`]'s finalize and the pipelined report path (the two must
/// not drift).
pub fn energy_ledger(
    cfg: &AcceleratorConfig,
    passes: u64,
    pca_readouts: u64,
    mid_vdp_readouts: u64,
    psums: u64,
) -> [(&'static str, f64); 4] {
    let e = &cfg.energy;
    [
        ("oxg", passes as f64 * cfg.n as f64 * e.xnor_j_per_bit),
        ("receiver", passes as f64 * e.receiver_j_per_pass),
        ("pca", (pca_readouts + mid_vdp_readouts) as f64 * e.pca_readout_j),
        (
            "adc+reduction",
            psums as f64 * (e.adc_j_per_psum + e.reduction_j_per_psum),
        ),
    ]
}

/// Live state of one `(frame, layer)` unit inside a [`FrameWorld`].
#[derive(Debug, Clone, Default)]
pub struct UnitState {
    /// First pass issued (triggers the successor's double-buffered fetch).
    pub started: bool,
    fetch_requested: bool,
    fetch_done: bool,
    fetch_ready_s: f64,
    /// Activations drained so far — the quantity successor admission
    /// ([`FramePlan::need_acts`]) gates on.
    pub acts_done: usize,
    /// Remaining psum slices per local VDP (reduction mode only).
    vdp_remaining: Vec<usize>,
    /// Time of this unit's first issued pass.
    pub start_s: f64,
    /// Time of this unit's last drained activation.
    pub done_s: f64,
    pub passes: u64,
    pub pca_readouts: u64,
    pub mid_vdp_readouts: u64,
    pub psums: u64,
    pub activations: u64,
}

/// Whole-frame (and multi-frame) pipelined event world: every layer of
/// every frame in the batch shares ONE event space, replacing the
/// per-layer spaces chained by [`crate::arch::workload_sim::OverlapChain`].
///
/// * **Cross-layer interleaving** — layer `l+1`'s first PASSes are
///   admitted as soon as the raster prefix of layer `l`'s activations they
///   read has drained ([`FramePlan::need_acts`]), rather than after layer
///   `l` fully completes.
/// * **Multi-frame pipelining** — the [`FrameStream`] cursors carry a
///   frame index, so frame `f+1`'s early layers stream into XPEs idled by
///   frame `f`'s tail. XPEs prefer work in frame-major unit order, so an
///   older frame is never starved by a newer one.
/// * **O(woken) wake-ups** — an XPE blocked on admission parks itself in
///   the stream's wake index under its head-pass threshold; each
///   activation drain pops exactly the waiters it admits instead of
///   re-dispatching every idle XPE.
///
/// Shared hardware stays shared: one memory channel serializes operand
/// fetches (double-buffered: a unit's fetch is requested when its
/// predecessor starts computing), the per-XPC reduction networks and the
/// per-XPE PCAs service whichever unit's work reaches them. PCA state is
/// re-armed when an XPE switches units — the operand re-staging gap covers
/// the TIR discharge — so a unit's analog accumulation never mixes frames
/// or layers.
pub struct FrameWorld<'a> {
    cfg: &'a AcceleratorConfig,
    fp: &'a FramePlan<'a>,
    stream: FrameStream,
    m: usize,
    pca_mode: bool,
    gamma: u64,
    pcas: Vec<Option<Pca>>,
    /// Unit whose operands are staged on each XPE (usize::MAX = none yet).
    staged_unit: Vec<usize>,
    idle: Vec<bool>,
    busy_s: Vec<f64>,
    units: Vec<UnitState>,
    red_pending: Vec<usize>,
    red_free_at: Vec<f64>,
    /// Next-free time of each chip's eDRAM fetch channel (one entry for
    /// an unsharded run).
    mem_free_at: Vec<f64>,
    /// Next-free time of the shared inter-chip activation link.
    link_free_at: f64,
    /// Per producer unit: activations that have ARRIVED over the
    /// inter-chip link — what cross-chip consumer admission gates on
    /// (same-chip edges gate on `UnitState::acts_done` as before).
    acts_arrived: Vec<usize>,
    n_link_transfers: u64,
    link_busy_s: f64,
    ones_density: f64,
    frames_done: usize,
    frame_done_s: Vec<f64>,
    /// Activations drained across all units, against the batch total:
    /// under exact admission a consumer whose strided window never reads
    /// the producer's last rows (e.g. 1×1 stride 2) can finish BEFORE its
    /// producer fully drains, so frame completion alone must not stop the
    /// event space while drain events are still pending — that would
    /// silently drop them from the conservation counters.
    acts_done_total: usize,
    vdps_total: usize,
    n_reduction_inits: u64,
    n_reductions_done: u64,
    n_discharge_stalls: u64,
    n_saturations: u64,
    /// Dispatches performed through the activation-drain wake index (one
    /// per woken XPE — the satellite regression gate: an activation drain
    /// must wake O(woken) XPEs, not re-dispatch every idle one).
    n_wake_dispatches: u64,
    /// Bounded work-stealing past admission-blocked units: a parked XPE
    /// may run already-admitted VDPs from later units when their
    /// closed-form remaining cost undercuts a floor on its stall. On by
    /// default; [`FrameWorld::set_steal`] restores strict frame-major
    /// order.
    steal: bool,
    /// Steal claims issued (one per stolen VDP under PcaLocal, one per
    /// stolen slice under SlicedSpread).
    n_steal_dispatches: u64,
    /// Passes executed through steal claims.
    n_stolen_passes: u64,
    /// `FetchDone` sweep dispatches that hit the one unit the idle XPE
    /// was actually waiting on, vs idle XPEs swept but skipped (the old
    /// sweep re-dispatched every idle unparked XPE on every fetch).
    n_fetch_wake_dispatches: u64,
    n_fetch_sweep_skips: u64,
    /// Seconds each XPE spent parked on an admission threshold —
    /// reported separately from idle (a parked XPE is waiting on a
    /// dependency, not lacking work).
    parked_s: Vec<f64>,
    /// Open park-interval start per XPE (INFINITY = not parked-idle).
    park_since: Vec<f64>,
    /// PASS occupancy accumulated per owning chip at issue time.
    chip_busy_s: Vec<f64>,
    /// When set, every admitted pass with a producer records `(unit, local
    /// vdp, producer activations drained at issue)` — raw facts the
    /// admission-oracle suite replays against an independent sliding-window
    /// reference model. Off by default (one entry per pass).
    record_admissions: bool,
    admission_log: Vec<(u32, u32, u32)>,
}

impl<'a> FrameWorld<'a> {
    pub fn new(cfg: &'a AcceleratorConfig, fp: &'a FramePlan<'a>) -> FrameWorld<'a> {
        let first = fp.layer_plan(0);
        // A VDP-split shard compiles its layer grid over the whole K-chip
        // group; a layer-pipeline shard (and the unsharded case) keeps the
        // single-chip grid. Either way each chip must match `cfg`.
        let grid_chips = if fp.chips() > 1 && fp.fetch_split() > 1 { fp.chips() } else { 1 };
        assert!(
            first.n == cfg.n
                && first.m == cfg.m()
                && first.xpc_count == cfg.xpc_count() * grid_chips,
            "frame plan geometry (N={}, M={}, XPCs={}) does not match accelerator '{}' \
             (N={}, M={}, XPCs={} x {} chip(s))",
            first.n,
            first.m,
            first.xpc_count,
            cfg.name,
            cfg.n,
            cfg.m(),
            cfg.xpc_count(),
            grid_chips
        );
        let pca_mode = matches!(cfg.bitcount, BitcountMode::Pca { .. });
        let gamma = match cfg.bitcount {
            BitcountMode::Pca { gamma } => gamma,
            _ => 0,
        };
        let total = fp.total_xpes();
        // Reduction networks are per-XPC of the whole (possibly multi-chip)
        // grid — each chip brings its own set.
        let xpcs = total.div_ceil(cfg.m());
        let units: Vec<UnitState> = (0..fp.units())
            .map(|u| {
                let mut s = UnitState::default();
                if !pca_mode {
                    let lp = fp.layer_plan(u);
                    s.vdp_remaining = vec![lp.slices(); lp.vdp_count()];
                }
                s
            })
            .collect();
        FrameWorld {
            cfg,
            fp,
            stream: FrameStream::new(fp),
            m: cfg.m(),
            pca_mode,
            gamma,
            pcas: vec![None; total],
            staged_unit: vec![usize::MAX; total],
            idle: vec![true; total],
            busy_s: vec![0.0; total],
            units,
            red_pending: vec![0; xpcs],
            red_free_at: vec![0.0; xpcs],
            mem_free_at: vec![0.0; fp.chips()],
            link_free_at: 0.0,
            acts_arrived: vec![0; fp.units()],
            n_link_transfers: 0,
            link_busy_s: 0.0,
            ones_density: 0.5,
            frames_done: 0,
            frame_done_s: vec![0.0; fp.frames()],
            acts_done_total: 0,
            vdps_total: (0..fp.units()).map(|u| fp.layer_plan(u).vdp_count()).sum(),
            n_reduction_inits: 0,
            n_reductions_done: 0,
            n_discharge_stalls: 0,
            n_saturations: 0,
            n_wake_dispatches: 0,
            steal: true,
            n_steal_dispatches: 0,
            n_stolen_passes: 0,
            n_fetch_wake_dispatches: 0,
            n_fetch_sweep_skips: 0,
            parked_s: vec![0.0; total],
            park_since: vec![f64::INFINITY; total],
            chip_busy_s: vec![0.0; fp.chips()],
            record_admissions: false,
            admission_log: Vec::new(),
        }
    }

    fn flat(&self, id: XpeId) -> usize {
        id.xpc * self.m + id.xpe
    }

    fn xpe_id(&self, flat: usize) -> XpeId {
        XpeId { xpc: flat / self.m, xpe: flat % self.m }
    }

    /// Completion times of each frame (last activation + output bus hop).
    pub fn frame_done_s(&self) -> &[f64] {
        &self.frame_done_s
    }

    /// Per-XPE accumulated PASS occupancy (seconds of photonic work).
    pub fn busy_s(&self) -> &[f64] {
        &self.busy_s
    }

    /// Per-unit state snapshot (frame-major order).
    pub fn units(&self) -> &[UnitState] {
        &self.units
    }

    /// Dispatches performed through the activation-drain wake index (one
    /// per woken XPE).
    pub fn wake_dispatches(&self) -> u64 {
        self.n_wake_dispatches
    }

    /// Enable/disable bounded work-stealing past admission-blocked units
    /// (on by default; off restores strict frame-major dispatch order).
    pub fn set_steal(&mut self, on: bool) {
        self.steal = on;
    }

    /// Steal claims issued by parked XPEs.
    pub fn steal_dispatches(&self) -> u64 {
        self.n_steal_dispatches
    }

    /// Passes executed through steal claims.
    pub fn stolen_passes(&self) -> u64 {
        self.n_stolen_passes
    }

    /// `FetchDone` sweep dispatches that hit the unit the idle XPE was
    /// waiting on (the O(woken) part of the sweep).
    pub fn fetch_wake_dispatches(&self) -> u64 {
        self.n_fetch_wake_dispatches
    }

    /// Idle XPEs a `FetchDone` sweep examined but did NOT dispatch
    /// (their frontier was elsewhere — the old sweep dispatched them).
    pub fn fetch_sweep_skips(&self) -> u64 {
        self.n_fetch_sweep_skips
    }

    /// Per-XPE accumulated admission-parked time (seconds).
    pub fn parked_s(&self) -> &[f64] {
        &self.parked_s
    }

    /// Record `(unit, local vdp, producer acts drained)` for every issued
    /// pass with a producer — the admission-oracle replay hook.
    pub fn record_admissions(&mut self, on: bool) {
        self.record_admissions = on;
    }

    /// The recorded admission log (empty unless
    /// [`FrameWorld::record_admissions`] was enabled before the run).
    pub fn admission_log(&self) -> &[(u32, u32, u32)] {
        &self.admission_log
    }

    /// Activations that ARRIVED over the inter-chip link, per producer
    /// unit (all zero on an unsharded run — nothing crosses a link).
    pub fn acts_arrived(&self) -> &[usize] {
        &self.acts_arrived
    }

    /// Activation transfers serialized onto the inter-chip link.
    pub fn link_transfers(&self) -> u64 {
        self.n_link_transfers
    }

    /// Total occupancy of the shared inter-chip link (seconds).
    pub fn link_busy_s(&self) -> f64 {
        self.link_busy_s
    }

    /// Accumulated PASS occupancy summed per chip (length = group size;
    /// a single-element vec on an unsharded run). Accumulated at issue
    /// time against the owning chip rather than re-derived from a flat
    /// division, so a grid that does not divide evenly by K cannot
    /// misattribute work.
    pub fn per_chip_busy_s(&self) -> Vec<f64> {
        self.chip_busy_s.clone()
    }

    /// Activations available from producer `p` for admitting work on
    /// consumer unit `next`: arrivals over the inter-chip link when the
    /// edge crosses chips, the producer's own drains otherwise.
    fn avail_acts(&self, p: usize, next: usize) -> usize {
        if self.fp.edge_crosses(next) {
            self.acts_arrived[p]
        } else {
            self.units[p].acts_done
        }
    }

    /// Serialize a unit's operand fetch onto the shared memory channel and
    /// schedule its readiness event. Requested once, when the predecessor
    /// unit starts computing (double-buffered staging).
    fn request_fetch(&mut self, u: usize, sched: &mut Scheduler) {
        if self.units[u].fetch_requested {
            return;
        }
        self.units[u].fetch_requested = true;
        let bits = self.fp.layer_plan(u).layer.operand_bits() as f64;
        let now = sched.now();
        let split = self.fp.fetch_split();
        let done = if split > 1 {
            // VDP-split: every chip holds 1/K of the layer's slices, so all
            // K eDRAM channels stage their shares in parallel.
            let share = bits / split as f64;
            let mut done = now;
            for free in self.mem_free_at.iter_mut() {
                let start = now.max(*free);
                *free = start + share / self.cfg.mem_bw_bits_per_s;
                done = done.max(*free);
            }
            done
        } else {
            // Unsharded or layer-pipeline: the unit lives wholly on one
            // chip and serializes on that chip's channel.
            let chip = self.fp.unit_chip(u);
            let start = now.max(self.mem_free_at[chip]);
            let done = start + bits / self.cfg.mem_bw_bits_per_s;
            self.mem_free_at[chip] = done;
            done
        };
        let ready = done + self.cfg.peripherals.edram.latency_s;
        self.units[u].fetch_ready_s = ready;
        sched.at(ready, EventKind::FetchDone { unit: u });
    }

    /// Find and issue the next pass for XPE `flat`: the locked (mid-VDP)
    /// unit if any, else the earliest unit in frame-major order that still
    /// has passes for this XPE — **if** its operands are staged and the
    /// producer has drained the activation prefix the head pass reads.
    ///
    /// An XPE skips permanently *exhausted* units (that is what lets it
    /// stream into a later frame when it holds none of this frame's tail)
    /// but never *advances its frontier* past a unit that is merely
    /// blocked on admission: its schedule stays a concatenation of its
    /// unit queues in frame-major order, which is what makes "pipelined
    /// is never slower than sequential" provable (and property-tested).
    ///
    /// What a blocked XPE MAY do (the ISSUE-10 tentpole, with the
    /// steal/park/wake handshake model-checked in `check::protocols`
    /// first) is **steal, boundedly**: run one already-admitted VDP from
    /// a later unit of its own queue, provided its closed-form cost
    /// (read off the compiled pass maps) fits inside a lower bound on
    /// the stall it is parked for — see [`Self::steal_candidate`]. The
    /// registration in the wake index survives the detour (a stolen unit
    /// must not orphan the wake-heap entry); the XPE re-checks admission
    /// itself when the stolen VDP completes, so a wake arriving mid-steal
    /// is never lost and never double-dispatches.
    ///
    /// A blocked XPE does not spin: one blocked on admission parks itself
    /// in the stream's wake index under its head-pass threshold (the
    /// matching activation drain pops it — O(woken)); one blocked on
    /// operand staging is woken by the unit's `FetchDone`.
    fn dispatch(&mut self, flat: usize, extra_delay: f64, sched: &mut Scheduler) {
        if let Some(u) = self.stream.locked(flat) {
            self.issue(u, flat, extra_delay, sched);
            return;
        }
        self.stream.advance_first_open(self.fp, flat);
        let next = self.stream.first_open(flat);
        if next >= self.fp.units() {
            self.idle[flat] = true; // everything drained: idle for good
            return;
        }
        if !self.units[next].fetch_done {
            self.idle[flat] = true; // FetchDone { next } wakes us
            return;
        }
        match self.fp.producer(next) {
            None => self.issue(next, flat, extra_delay, sched),
            Some(p) => {
                let pass = self
                    .stream
                    .peek_for(self.fp, next, flat)
                    .expect("first_open units have passes for this XPE");
                let need = self.fp.need_acts(next, pass.vdp.0);
                if self.avail_acts(p, next) >= need {
                    self.issue(next, flat, extra_delay, sched);
                    return;
                }
                // Park under the head-pass threshold. The XPE may pass
                // through here again mid-park (after a stolen VDP
                // completes), so the registration is guarded: the heap
                // entry from the first park is still live and must not
                // be duplicated.
                if self.stream.waiting_on(flat).is_none() {
                    self.stream.register_waiter(next, need, flat);
                }
                if self.steal {
                    if let Some(v) = self.steal_candidate(flat, next, need) {
                        let cost = self.steal_cost(v, flat);
                        self.n_steal_dispatches += 1;
                        self.n_stolen_passes += cost as u64;
                        self.issue(v, flat, extra_delay, sched);
                        return;
                    }
                }
                self.park(flat, sched.now());
            }
        }
    }

    /// Open XPE `flat`'s parked interval (idle while registered in the
    /// wake index). Closed by the next [`Self::issue`].
    fn park(&mut self, flat: usize, now: f64) {
        self.idle[flat] = true;
        if self.park_since[flat].is_infinite() {
            self.park_since[flat] = now;
        }
    }

    /// The first later unit whose already-admitted head VDP the parked
    /// XPE may run without risking the "pipelined ≤ sequential"
    /// guarantee or in-order frame completion. A candidate must be
    ///
    /// * eligible on this XPE with passes left, operands staged, and its
    ///   own admission threshold met (a steal never front-runs an
    ///   admission oracle);
    /// * not a last-layer unit — last-layer work per XPE stays in frame
    ///   order, which keeps `frame_done_s` monotone under stealing;
    /// * not feeding a cross-chip edge — a stolen drain must not reorder
    ///   the serialized inter-chip link against in-order transfers;
    /// * cheap enough: its closed-form cost ([`Self::steal_cost`]) must
    ///   fit inside the stall floor ([`Self::stall_floor_passes`]), so
    ///   the XPE is back — and never mid-VDP — before the earliest
    ///   moment its blocked unit can possibly be admitted.
    fn steal_candidate(&self, flat: usize, next: usize, need: usize) -> Option<usize> {
        if !self.pca_mode {
            // Reduction-network bitcount serializes psums per XPC; a
            // steal could contend with in-order reductions there.
            return None;
        }
        let floor = self.stall_floor_passes(next, need);
        if floor == 0 {
            return None;
        }
        for v in next + 1..self.fp.units() {
            if self.fp.unit_layer(v) + 1 == self.fp.layers() {
                continue;
            }
            if self.fp.unit_layer(v) + 1 < self.fp.layers() && self.fp.edge_crosses(v + 1) {
                continue;
            }
            if !self.fp.eligible(v, flat)
                || !self.units[v].fetch_done
                || self.stream.exhausted_for(self.fp, v, flat)
            {
                continue;
            }
            let Some(pass) = self.stream.peek_for(self.fp, v, flat) else {
                continue;
            };
            if let Some(p) = self.fp.producer(v) {
                if self.avail_acts(p, v) < self.fp.need_acts(v, pass.vdp.0) {
                    continue;
                }
            }
            if self.steal_cost(v, flat) <= floor {
                return Some(v);
            }
        }
        None
    }

    /// Closed-form cost, in PASS counts on this XPE, of stealing unit
    /// `v`'s head work: a whole VDP under PcaLocal (the analog PCA
    /// accumulation locks the XPE until the VDP's last slice), one slice
    /// under SlicedSpread.
    fn steal_cost(&self, v: usize, flat: usize) -> usize {
        let lp = self.fp.layer_plan(v);
        match lp.policy {
            MappingPolicy::PcaLocal => {
                lp.slices().min(self.stream.remaining_for(self.fp, v, flat))
            }
            MappingPolicy::SlicedSpread => 1,
        }
    }

    /// A LOWER bound, in PASS counts, on how long XPE `flat` stays
    /// parked on consumer `next`'s threshold `need`. The producer must
    /// still drain `need − acts_done` activations; drains obtainable
    /// from VDPs already issued (or mid-issue — up to one partial VDP
    /// per producer XPE) are generously assumed free, and the rest need
    /// whole new VDPs whose slice chains run serially per XPE. Only a
    /// PcaLocal producer has this closed form (one VDP = one XPE's
    /// back-to-back slices); any other shape returns 0 — no steal.
    /// Underestimating the stall only makes stealing rarer, never
    /// unsafe.
    fn stall_floor_passes(&self, next: usize, need: usize) -> usize {
        let Some(p) = self.fp.producer(next) else {
            return 0;
        };
        let lp = self.fp.layer_plan(p);
        if lp.policy != MappingPolicy::PcaLocal {
            return 0;
        }
        let drained = self.units[p].acts_done;
        let deficit = need.saturating_sub(drained);
        if deficit == 0 {
            return 0; // waiting on in-flight latency (or the link) only
        }
        let slices = lp.slices().max(1);
        let t = lp.total_xpes().max(1);
        // VDPs with at least one slice issued: every fully-issued chain
        // plus at most one partial per producer XPE.
        let touched = self.stream.issued(p) / slices + t;
        let in_flight = touched.saturating_sub(drained);
        let new_vdps = deficit.saturating_sub(in_flight);
        if new_vdps == 0 {
            return 0;
        }
        new_vdps.div_ceil(t) * slices
    }

    fn issue(&mut self, u: usize, flat: usize, extra_delay: f64, sched: &mut Scheduler) {
        let lp = self.fp.layer_plan(u);
        let pass = self
            .stream
            .next_for(self.fp, u, flat)
            .expect("dispatch only picks units with passes left");
        if self.record_admissions {
            if let Some(p) = self.fp.producer(u) {
                // Log the quantity admission actually gated on: link
                // arrivals for a cross-chip edge, drains otherwise.
                self.admission_log.push((
                    u as u32,
                    pass.vdp.0 as u32,
                    self.avail_acts(p, u) as u32,
                ));
            }
        }
        if self.pca_mode && self.staged_unit[flat] != u {
            // Unit switch re-stages operands; the staging gap covers the
            // TIR discharge, so the XPE starts the unit on a fresh PCA.
            self.pcas[flat] = Some(Pca::new(PcaParams::default(), self.gamma));
        }
        self.staged_unit[flat] = u;
        // Under PcaLocal all slices of a VDP run back-to-back on this XPE
        // (analog accumulation) — lock the XPE to the unit mid-VDP.
        let mid_vdp =
            lp.policy == MappingPolicy::PcaLocal && pass.slice_idx + 1 < lp.slices();
        self.stream.set_locked(flat, mid_vdp.then_some(u));
        if !self.units[u].started {
            self.units[u].started = true;
            self.units[u].start_s = sched.now();
            // Double-buffered staging: fetch the successor layer's operands
            // (and the next frame's first layer) while this unit computes.
            if self.fp.unit_layer(u) + 1 < self.fp.layers() {
                self.request_fetch(u + 1, sched);
            }
            if self.fp.unit_layer(u) == 0 && self.fp.unit_frame(u) + 1 < self.fp.frames()
            {
                self.request_fetch(u + self.fp.layers(), sched);
            }
        }
        let tau = self.cfg.tau_s();
        let ones = (pass.slice_len as f64 * self.ones_density).round() as u64;
        self.idle[flat] = false;
        self.busy_s[flat] += tau;
        // Attribute the pass to the owning chip directly: deriving chip
        // totals from a flat division downstream misattributes work when
        // the grid does not divide evenly by K.
        let chip = self.fp.xpe_chip(flat).min(self.chip_busy_s.len() - 1);
        self.chip_busy_s[chip] += tau;
        if self.park_since[flat].is_finite() {
            self.parked_s[flat] += sched.now() - self.park_since[flat];
            self.park_since[flat] = f64::INFINITY;
        }
        sched.after(
            extra_delay + tau,
            EventKind::PassComplete {
                xpe: self.xpe_id(flat),
                vdp: VdpId(self.fp.global_vdp(u, pass.vdp.0)),
                slice_idx: pass.slice_idx,
                ones,
            },
        );
    }

    /// Re-dispatch idle XPEs that are NOT parked on an admission
    /// threshold (a fetch completion cannot advance a producer's
    /// activation count, so parked waiters stay parked) and whose
    /// frontier is the unit whose operands just landed. An idle,
    /// unparked XPE waits on exactly one thing — `first_open`'s fetch
    /// (the frontier is stable while the XPE is idle: only its own
    /// issues advance it) — so dispatching for any other unit's
    /// `FetchDone` is a redundant sweep. Those sweep touches used to be
    /// full `dispatch` calls; now they are counted but skipped, pinning
    /// the per-event work to O(woken) like the activation path.
    fn wake_unparked(&mut self, unit: usize, sched: &mut Scheduler) {
        for flat in 0..self.idle.len() {
            if !self.idle[flat] || self.stream.waiting_on(flat).is_some() {
                continue;
            }
            if self.stream.first_open(flat) == unit {
                self.n_fetch_wake_dispatches += 1;
                self.dispatch(flat, 0.0, sched);
            } else {
                self.n_fetch_sweep_skips += 1;
            }
        }
    }
}

impl World for FrameWorld<'_> {
    fn init(&mut self, sched: &mut Scheduler, _stats: &mut SimStats) {
        // Everything is gated on the first unit's operand staging; XPEs
        // wake on its FetchDone.
        self.request_fetch(0, sched);
    }

    fn handle(&mut self, event: &EventKind, sched: &mut Scheduler, _stats: &mut SimStats) {
        match event {
            EventKind::FetchDone { unit } => {
                self.units[*unit].fetch_done = true;
                self.wake_unparked(*unit, sched);
            }
            EventKind::PassComplete { xpe, vdp, slice_idx, ones } => {
                let (u, _local) = self.fp.unit_of_vdp(vdp.0);
                self.units[u].passes += 1;
                let flat = self.flat(*xpe);
                if self.pca_mode {
                    let slices = self.fp.layer_plan(u).slices();
                    let last = *slice_idx == slices - 1;
                    let pca = self.pcas[flat].as_mut().expect("pca mode");
                    let saturated = pca.accumulate(*ones);
                    if saturated {
                        self.n_saturations += 1;
                    }
                    if last {
                        sched.after(0.0, EventKind::PcaReadout { xpe: *xpe, vdp: *vdp });
                    } else if saturated {
                        // Paper Section III-B2: a railed TIR ends the
                        // accumulation phase — read out mid-VDP and continue
                        // on the swapped capacitor.
                        self.units[u].mid_vdp_readouts += 1;
                        let now = sched.now();
                        let pca = self.pcas[flat].as_mut().expect("pca mode");
                        let (_r, stall) = pca.readout(now);
                        if stall > 0.0 {
                            self.n_discharge_stalls += 1;
                        }
                        self.dispatch(flat, stall, sched);
                    } else {
                        self.dispatch(flat, 0.0, sched);
                    }
                } else {
                    sched.after(0.0, EventKind::PsumReady {
                        xpe: *xpe,
                        vdp: *vdp,
                        slice_idx: *slice_idx,
                    });
                    self.dispatch(flat, 0.0, sched);
                }
            }
            EventKind::PcaReadout { xpe, vdp } => {
                let (u, _local) = self.fp.unit_of_vdp(vdp.0);
                self.units[u].pca_readouts += 1;
                let flat = self.flat(*xpe);
                let now = sched.now();
                let pca = self.pcas[flat].as_mut().expect("pca mode");
                let (_result, stall) = pca.readout(now);
                if stall > 0.0 {
                    self.n_discharge_stalls += 1;
                }
                let act = self.cfg.peripherals.activation_unit.latency_s;
                sched.after(stall + act, EventKind::ActivationDone { vdp: *vdp });
                self.dispatch(flat, stall, sched);
            }
            EventKind::PsumReady { xpe, vdp, .. } => {
                let (u, local) = self.fp.unit_of_vdp(vdp.0);
                self.units[u].psums += 1;
                let xpc = xpe.xpc;
                self.red_pending[xpc] += 1;
                let (lat, width) = match self.cfg.bitcount {
                    BitcountMode::Reduction { latency_s, .. } => (latency_s, self.m),
                    _ => unreachable!("psum in PCA mode"),
                };
                // Group psums M-wide per initiation of the XPC's network; a
                // unit that has issued its last pass flushes the remainder.
                if self.red_pending[xpc] >= width || self.stream.all_issued(u) {
                    let start = sched.now().max(self.red_free_at[xpc]);
                    self.red_free_at[xpc] = start + lat;
                    self.red_pending[xpc] = 0;
                    self.n_reduction_inits += 1;
                    sched.at(start + lat, EventKind::ReductionDone { vdp: *vdp });
                }
                self.units[u].vdp_remaining[local] -= 1;
                if self.units[u].vdp_remaining[local] == 0 {
                    let act = self.cfg.peripherals.activation_unit.latency_s;
                    let done_at = self.red_free_at[xpc].max(sched.now()) + lat + act;
                    sched.at(done_at, EventKind::ActivationDone { vdp: *vdp });
                }
            }
            EventKind::ReductionDone { .. } => {
                self.n_reductions_done += 1;
            }
            EventKind::ActivationDone { vdp } => {
                let (u, _local) = self.fp.unit_of_vdp(vdp.0);
                self.units[u].activations += 1;
                self.units[u].acts_done += 1;
                self.acts_done_total += 1;
                let vdps = self.fp.layer_plan(u).vdp_count();
                if self.units[u].acts_done == vdps {
                    self.units[u].done_s = sched.now();
                    if self.fp.unit_layer(u) + 1 == self.fp.layers() {
                        let frame = self.fp.unit_frame(u);
                        self.frame_done_s[frame] =
                            sched.now() + self.cfg.peripherals.bus.latency_s;
                        self.frames_done += 1;
                    }
                }
                // A drained activation can only admit the same-frame
                // successor's waiters: pop exactly the XPEs whose head-pass
                // threshold is now met — O(woken), where the old path
                // re-dispatched every idle XPE. The bus hop carries the
                // activation to the consumer's tile buffers; when the
                // successor runs on another chip the activation first
                // crosses the serialized inter-chip link, and the consumer
                // is admitted by `LinkArrived` (on *arrival*, not drain).
                if self.fp.unit_layer(u) + 1 < self.fp.layers() {
                    if self.fp.edge_crosses(u + 1) {
                        let link = self.fp.link().expect("cross-chip edge implies a link");
                        let occ = link.occupancy_s();
                        let arrive_lat = link.latency_s;
                        let start = sched.now().max(self.link_free_at);
                        self.link_free_at = start + occ;
                        self.link_busy_s += occ;
                        self.n_link_transfers += 1;
                        sched.at(start + occ + arrive_lat, EventKind::LinkArrived { unit: u });
                    } else {
                        let acts = self.units[u].acts_done;
                        let bus = self.cfg.peripherals.bus.latency_s;
                        for flat in self.stream.pop_admitted(u + 1, acts) {
                            // A waiter woken mid-steal is busy, not
                            // parked: its own PassComplete re-enters
                            // dispatch, which re-checks admission
                            // directly. Dispatching it here would run
                            // two passes on one XPE at once.
                            if !self.idle[flat] {
                                continue;
                            }
                            self.n_wake_dispatches += 1;
                            self.dispatch(flat, bus, sched);
                        }
                    }
                }
            }
            EventKind::LinkArrived { unit } => {
                // The link is FIFO (serialized occupancy + constant
                // latency), so arrivals land in drain order and this count
                // is exactly the arrived raster prefix.
                let u = *unit;
                self.acts_arrived[u] += 1;
                let acts = self.acts_arrived[u];
                for flat in self.stream.pop_admitted(u + 1, acts) {
                    // Same mid-steal guard as the local-drain wake path.
                    if !self.idle[flat] {
                        continue;
                    }
                    self.n_wake_dispatches += 1;
                    // The transfer itself already charged link occupancy +
                    // latency; no extra bus hop on top.
                    self.dispatch(flat, 0.0, sched);
                }
            }
            _ => {}
        }
    }

    fn done(&self) -> bool {
        // Frame completions drive the latency numbers, but the event space
        // only closes once every unit's activations have drained — exact
        // admission lets a consumer finish ahead of its producer's tail,
        // and stopping there would truncate the conservation counters.
        self.frames_done >= self.fp.frames() && self.acts_done_total >= self.vdps_total
    }

    fn finalize(&mut self, stats: &mut SimStats) {
        let (mut passes, mut readouts, mut mid, mut psums, mut acts) = (0, 0, 0, 0, 0);
        for s in &self.units {
            passes += s.passes;
            readouts += s.pca_readouts;
            mid += s.mid_vdp_readouts;
            psums += s.psums;
            acts += s.activations;
        }
        stats.count("passes", passes);
        stats.count("pca_readouts", readouts);
        stats.count("mid_vdp_readouts", mid);
        stats.count("pca_saturations", self.n_saturations);
        stats.count("pca_discharge_stalls", self.n_discharge_stalls);
        stats.count("psums", psums);
        stats.count("reduction_inits", self.n_reduction_inits);
        stats.count("reductions_done", self.n_reductions_done);
        stats.count("activations", acts);
        stats.count("wake_dispatches", self.n_wake_dispatches);
        stats.count("steal_dispatches", self.n_steal_dispatches);
        stats.count("stolen_passes", self.n_stolen_passes);
        stats.count("fetch_wake_dispatches", self.n_fetch_wake_dispatches);
        stats.count("fetch_sweep_skips", self.n_fetch_sweep_skips);
        stats.count("link_transfers", self.n_link_transfers);
        for (category, joules) in energy_ledger(self.cfg, passes, readouts, mid, psums)
        {
            stats.energy(category, joules);
        }
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::accelerator::AcceleratorConfig;

    fn small_cfg(pca: bool) -> AcceleratorConfig {
        let mut cfg = AcceleratorConfig::oxbnn_5();
        cfg.n = 9;
        cfg.xpe_total = 4;
        if !pca {
            cfg.bitcount = BitcountMode::Reduction { latency_s: 3.125e-9, psum_bits: 16 };
            cfg.energy = crate::energy::power::EnergyModel::robin();
        }
        cfg
    }

    #[test]
    fn pca_mode_processes_all_vdps() {
        let layer = GemmLayer::new("t", 8, 30, 4); // 32 VDPs, 4 slices each
        let stats = simulate_layer(&small_cfg(true), &layer, MappingPolicy::PcaLocal);
        assert_eq!(stats.counter("passes"), 32 * 4);
        assert_eq!(stats.counter("pca_readouts"), 32);
        assert_eq!(stats.counter("activations"), 32);
        assert_eq!(stats.counter("psums"), 0);
        assert!(stats.end_time_s > 0.0);
    }

    #[test]
    fn reduction_mode_emits_psums() {
        let layer = GemmLayer::new("t", 8, 30, 4);
        let stats =
            simulate_layer(&small_cfg(false), &layer, MappingPolicy::SlicedSpread);
        assert_eq!(stats.counter("passes"), 32 * 4);
        assert_eq!(stats.counter("psums"), 32 * 4);
        assert!(stats.counter("reduction_inits") > 0);
        assert_eq!(stats.counter("activations"), 32);
    }

    #[test]
    fn fig5_pca_faster_than_reduction() {
        // The Fig. 5 comparison: same layer, same photonic resources; the
        // PCA mapping avoids all reduction-network serialization.
        let layer = GemmLayer::new("fig5", 32, 45, 8);
        let pca = simulate_layer(&small_cfg(true), &layer, MappingPolicy::PcaLocal);
        let red =
            simulate_layer(&small_cfg(false), &layer, MappingPolicy::SlicedSpread);
        assert!(
            pca.end_time_s < red.end_time_s,
            "PCA {} s vs reduction {} s",
            pca.end_time_s,
            red.end_time_s
        );
    }

    #[test]
    fn pca_energy_cheaper_per_layer() {
        let layer = GemmLayer::new("e", 16, 60, 4);
        let pca = simulate_layer(&small_cfg(true), &layer, MappingPolicy::PcaLocal);
        let red =
            simulate_layer(&small_cfg(false), &layer, MappingPolicy::SlicedSpread);
        assert!(pca.total_energy_j() < red.total_energy_j());
        assert_eq!(red.energy_of("pca"), 0.0);
        assert!(red.energy_of("adc+reduction") > 0.0);
    }

    #[test]
    fn saturation_forces_mid_vdp_readouts_when_gamma_tiny() {
        let mut cfg = small_cfg(true);
        cfg.bitcount = BitcountMode::Pca { gamma: 4 }; // absurdly small
        let layer = GemmLayer::new("sat", 4, 40, 1);
        let stats = simulate_layer(&cfg, &layer, MappingPolicy::PcaLocal);
        assert!(stats.counter("pca_saturations") > 0);
        assert!(stats.counter("mid_vdp_readouts") > 0);
        // A healthy gamma produces none.
        let healthy = simulate_layer(&small_cfg(true), &layer, MappingPolicy::PcaLocal);
        assert_eq!(healthy.counter("mid_vdp_readouts"), 0);
    }

    #[test]
    fn tiny_gamma_costs_latency_via_discharge_stalls() {
        // With gamma below a single slice's ones, every pass saturates and
        // the dual-TIR swap eventually stalls on discharge — latency must
        // exceed the healthy-gamma run.
        let layer = GemmLayer::new("sat", 8, 120, 2);
        let mut tiny = small_cfg(true);
        tiny.bitcount = BitcountMode::Pca { gamma: 2 };
        let slow = simulate_layer(&tiny, &layer, MappingPolicy::PcaLocal);
        let fast = simulate_layer(&small_cfg(true), &layer, MappingPolicy::PcaLocal);
        assert!(slow.counter("pca_discharge_stalls") > 0);
        assert!(
            slow.end_time_s > fast.end_time_s,
            "tiny gamma {} vs healthy {}",
            slow.end_time_s,
            fast.end_time_s
        );
    }

    #[test]
    fn planned_and_convenience_paths_agree() {
        // simulate_layer compiles the same plan simulate_layer_planned
        // receives — identical stats either way.
        let cfg = small_cfg(true);
        let layer = GemmLayer::new("t", 8, 30, 4);
        let plan =
            LayerPlan::compile(&layer, MappingPolicy::PcaLocal, cfg.n, cfg.m(), cfg.xpc_count());
        let a = simulate_layer_planned(&cfg, &plan);
        let b = simulate_layer(&cfg, &layer, MappingPolicy::PcaLocal);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.end_time_s, b.end_time_s);
        assert_eq!(a.counters(), b.counters());
        assert_eq!(a.counter("clamped_events"), 0, "no past-time scheduling");
    }

    #[test]
    #[should_panic(expected = "does not match accelerator")]
    fn mismatched_plan_geometry_rejected() {
        let cfg = small_cfg(true);
        let layer = GemmLayer::new("t", 8, 30, 4);
        // Compiled for a different N than the accelerator's.
        let plan = LayerPlan::compile(&layer, MappingPolicy::PcaLocal, 7, 7, 1);
        let _ = LayerWorld::new(&cfg, &plan);
    }
}
