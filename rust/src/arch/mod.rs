//! Accelerator architecture: configuration, psum reduction network,
//! closed-form performance model, and the event-driven world.

pub mod accelerator;
pub mod event_sim;
pub mod perf;
pub mod reduction;
pub mod workload_sim;

pub use accelerator::{AcceleratorConfig, BitcountMode, DEFAULT_MEM_BW};
pub use event_sim::{
    simulate_layer, simulate_layer_outcome, simulate_layer_planned, LayerWorld,
};
pub use perf::{gmean, layer_perf, workload_perf, LayerPerf, WorkloadPerf};
pub use reduction::ReductionNetwork;
pub use workload_sim::{
    simulate_frame, simulate_frame_planned, FrameTrace, LayerTrace, OverlapChain,
};
