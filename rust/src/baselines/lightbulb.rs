//! LIGHTBULB baseline (Zokaee et al., DATE 2020): an all-optical
//! XNOR-bitcount accelerator using microdisk pairs per XNOR gate, optical
//! ADCs, and PCM-based racetrack memory, running at a very high data rate
//! (paper Section II-C).
//!
//! Modeled with the paper's area-proportionate scaling: N = 16, 1139 XPEs,
//! DR = 50 GS/s (OXBNN_50 matches this rate). Like ROBIN it evaluates one
//! psum per pass and needs the psum reduction path; its optical ADCs keep
//! up with the 50 GS/s pass rate but cost energy per conversion.

use crate::arch::accelerator::{AcceleratorConfig, BitcountMode, DEFAULT_MEM_BW};
use crate::devices::laser::LossBudget;
use crate::energy::power::{EnergyModel, Peripherals};

/// LIGHTBULB psum width: 4-bit optical ADC output per pass (N = 16 →
/// counts fit in 5 bits; the design quantizes to 4-bit PCM counters, we
/// grant the full 5 to avoid penalizing accuracy).
pub const LIGHTBULB_PSUM_BITS: u32 = 5;

/// LIGHTBULB configuration (paper Section V-B scaling).
pub fn lightbulb() -> AcceleratorConfig {
    let peripherals = Peripherals::default();
    let red_latency = peripherals.reduction_network.latency_s;
    AcceleratorConfig {
        name: "LIGHTBULB".into(),
        dr_gsps: 50.0,
        n: 16,
        xpe_total: 1139,
        bitcount: BitcountMode::Reduction {
            latency_s: red_latency,
            psum_bits: LIGHTBULB_PSUM_BITS,
        },
        energy: EnergyModel::lightbulb(),
        peripherals,
        loss_budget: LossBudget::default(),
        mem_bw_bits_per_s: DEFAULT_MEM_BW,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scaled_counts() {
        let lb = lightbulb();
        assert_eq!((lb.n, lb.xpe_total, lb.dr_gsps), (16, 1139, 50.0));
    }

    #[test]
    fn same_pass_latency_as_oxbnn_50() {
        let lb = lightbulb();
        let ox = crate::arch::accelerator::AcceleratorConfig::oxbnn_50();
        assert!((lb.tau_s() - ox.tau_s()).abs() < 1e-18);
    }

    #[test]
    fn pays_adc_energy_per_psum() {
        let lb = lightbulb();
        assert!(lb.energy.adc_j_per_psum > EnergyModel::robin().adc_j_per_psum);
    }

    #[test]
    fn fig7_ordering_holds_through_session_facade() {
        // Same facade, same backend, same workload: OXBNN_50 (the matched
        // 50 GS/s variant) must beat LIGHTBULB on FPS and FPS/W.
        use crate::api::analytic_report;
        let vgg = crate::workloads::Workload::evaluation_set().remove(0);
        let ox = analytic_report(&AcceleratorConfig::oxbnn_50(), &vgg);
        let lb = analytic_report(&lightbulb(), &vgg);
        assert!(ox.fps > lb.fps);
        assert!(ox.fps_per_w > lb.fps_per_w);
        assert!(lb.psums > 0 && ox.psums == 0);
    }

    #[test]
    fn pcm_weights_reduce_tuning_power() {
        // Non-volatile PCM weight cells need no static hold power; modeled
        // as half the tuning population of an all-MRR design.
        let lb = lightbulb();
        assert!(lb.energy.tuning_w_per_mrr < EnergyModel::robin().tuning_w_per_mrr);
    }
}
