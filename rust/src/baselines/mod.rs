//! Baseline photonic BNN accelerators the paper compares against
//! (Section V-B): ROBIN (EO/PO) and LIGHTBULB.
//!
//! Baselines are plain [`AcceleratorConfig`]s, so every [`crate::api`]
//! backend (analytic, event-driven, functional) runs them through the same
//! [`crate::api::Session`] facade as the OXBNN variants — the Fig. 7
//! comparison is apples-to-apples by construction. Each baseline module
//! pins that property with a facade-level test.

use crate::arch::accelerator::AcceleratorConfig;

pub mod lightbulb;
pub mod robin;

pub use lightbulb::lightbulb;
pub use robin::{robin_eo, robin_po};

/// The three baseline configurations, in the paper's figure order.
pub fn baseline_set() -> Vec<AcceleratorConfig> {
    vec![robin_eo(), robin_po(), lightbulb()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_set_matches_evaluation_set_tail() {
        let names: Vec<String> =
            baseline_set().into_iter().map(|c| c.name).collect();
        assert_eq!(names, vec!["ROBIN_EO", "ROBIN_PO", "LIGHTBULB"]);
        // The evaluation set is exactly [OXBNN_5, OXBNN_50] + baselines.
        let eval: Vec<String> = AcceleratorConfig::evaluation_set()
            .into_iter()
            .map(|c| c.name)
            .collect();
        assert_eq!(&eval[2..], names.as_slice());
    }
}
