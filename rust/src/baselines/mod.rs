//! Baseline photonic BNN accelerators the paper compares against
//! (Section V-B): ROBIN (EO/PO) and LIGHTBULB.

pub mod lightbulb;
pub mod robin;

pub use lightbulb::lightbulb;
pub use robin::{robin_eo, robin_po};
