//! ROBIN baseline (Sunny et al., ACM TECS 2021): a robust optical BNN
//! accelerator using broadcast-and-weight style XNOR circuits with *two*
//! heterogeneous MRRs per 1-bit gate and a conventional bitcount whose
//! psums traverse a reduction network (paper Section II-C).
//!
//! Two published variants are modeled with the paper's area-proportionate
//! scaling (Section V-B, normalized to OXBNN_5's 100-XPE area):
//! * ROBIN_EO (energy-optimized): N = 10 → 916 XPEs.
//! * ROBIN_PO (performance-optimized): N = 50 → 183 XPEs.
//! Both operate at DR = 5 GS/s (OXBNN_5 matches this rate for fairness).

use crate::arch::accelerator::{AcceleratorConfig, BitcountMode, DEFAULT_MEM_BW};
use crate::devices::laser::LossBudget;
use crate::energy::power::{EnergyModel, Peripherals};

/// Stored-psum width: bitcounts of N ≤ 50 need 6 bits, but ROBIN stores
/// psums at 16-bit fixed point in its buffers (conservative, matches the
/// reduction-network datapath).
pub const ROBIN_PSUM_BITS: u32 = 16;

fn robin(name: &str, n: usize, xpe_total: usize) -> AcceleratorConfig {
    let peripherals = Peripherals::default();
    let red_latency = peripherals.reduction_network.latency_s;
    AcceleratorConfig {
        name: name.into(),
        dr_gsps: 5.0,
        n,
        xpe_total,
        bitcount: BitcountMode::Reduction {
            latency_s: red_latency,
            psum_bits: ROBIN_PSUM_BITS,
        },
        energy: EnergyModel::robin(),
        peripherals,
        loss_budget: LossBudget::default(),
        mem_bw_bits_per_s: DEFAULT_MEM_BW,
    }
}

/// ROBIN energy-optimized variant (paper Section V-B: N = 10, 916 XPEs).
pub fn robin_eo() -> AcceleratorConfig {
    robin("ROBIN_EO", 10, 916)
}

/// ROBIN performance-optimized variant (N = 50, 183 XPEs).
pub fn robin_po() -> AcceleratorConfig {
    robin("ROBIN_PO", 50, 183)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scaled_counts() {
        let eo = robin_eo();
        assert_eq!((eo.n, eo.xpe_total, eo.dr_gsps), (10, 916, 5.0));
        let po = robin_po();
        assert_eq!((po.n, po.xpe_total, po.dr_gsps), (50, 183, 5.0));
    }

    #[test]
    fn uses_reduction_bitcount() {
        assert!(matches!(robin_eo().bitcount, BitcountMode::Reduction { .. }));
    }

    #[test]
    fn two_mrrs_per_gate() {
        assert_eq!(robin_po().energy.mrrs_per_gate, 2.0);
    }

    #[test]
    fn fig7_ordering_holds_through_session_facade() {
        // Apples-to-apples: the same api facade that evaluates OXBNN
        // evaluates ROBIN; on the Fig. 7 metrics OXBNN_5 (same 5 GS/s data
        // rate) must win both FPS and FPS/W against both variants.
        use crate::api::analytic_report;
        let vgg = crate::workloads::Workload::evaluation_set().remove(0);
        let ox = analytic_report(&AcceleratorConfig::oxbnn_5(), &vgg);
        for baseline in [robin_eo(), robin_po()] {
            let name = baseline.name.clone();
            let b = analytic_report(&baseline, &vgg);
            assert!(ox.fps > b.fps, "OXBNN_5 FPS vs {}", name);
            assert!(ox.fps_per_w > b.fps_per_w, "OXBNN_5 FPS/W vs {}", name);
            assert!(b.psums > 0, "{} must pay the psum path", name);
            assert_eq!(ox.psums, 0, "PCA emits no electrical psums");
        }
    }

    #[test]
    fn eo_variant_draws_less_power_than_po() {
        // EO's rings are smaller/slower; with identical per-device tuning
        // power its win comes from fewer lasers per XPC (N=10 vs N=50
        // splits) — check the static-power ordering the name implies, per
        // unit of raw throughput.
        let eo = robin_eo();
        let po = robin_po();
        let eo_rate = eo.xpe_total as f64 * eo.n as f64 * eo.dr_gsps;
        let po_rate = po.xpe_total as f64 * po.n as f64 * po.dr_gsps;
        assert!((eo_rate - po_rate).abs() / po_rate < 0.02, "area-normalized equal raw rate");
    }
}
