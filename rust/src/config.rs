//! Config system: JSON serialization of accelerator configurations.
//!
//! Lets users define custom accelerators (`oxbnn simulate
//! --config my_accel.json`), dump the built-in evaluation set, and keep
//! sweep results reproducible. Built on the in-repo JSON substrate.
//!
//! Schema (all fields optional except the ones shown in `to_json`;
//! omitted fields take the named base config's values):
//!
//! ```json
//! {
//!   "name": "MyAccel",
//!   "base": "OXBNN_50",
//!   "dr_gsps": 50.0,
//!   "n": 19,
//!   "xpe_total": 1123,
//!   "bitcount": {"mode": "pca", "gamma": 8503},
//!   "mem_bw_bits_per_s": 8e12,
//!   "energy": {"xnor_j_per_bit": 5e-14, ...}
//! }
//! ```

use crate::arch::accelerator::{AcceleratorConfig, BitcountMode};
use crate::util::json::Json;

/// Config errors.
#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("{0}")]
    Json(#[from] crate::util::json::JsonError),
    #[error("config schema: {0}")]
    Schema(String),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

fn schema(msg: impl Into<String>) -> ConfigError {
    ConfigError::Schema(msg.into())
}

/// Serialize an accelerator config to JSON.
pub fn to_json(cfg: &AcceleratorConfig) -> Json {
    let bitcount = match &cfg.bitcount {
        BitcountMode::Pca { gamma } => Json::obj(vec![
            ("mode", Json::Str("pca".into())),
            ("gamma", Json::Num(*gamma as f64)),
        ]),
        BitcountMode::Reduction { latency_s, psum_bits } => Json::obj(vec![
            ("mode", Json::Str("reduction".into())),
            ("latency_s", Json::Num(*latency_s)),
            ("psum_bits", Json::Num(*psum_bits as f64)),
        ]),
    };
    let e = &cfg.energy;
    let energy = Json::obj(vec![
        ("xnor_j_per_bit", Json::Num(e.xnor_j_per_bit)),
        ("receiver_j_per_pass", Json::Num(e.receiver_j_per_pass)),
        ("pca_readout_j", Json::Num(e.pca_readout_j)),
        ("adc_j_per_psum", Json::Num(e.adc_j_per_psum)),
        ("reduction_j_per_psum", Json::Num(e.reduction_j_per_psum)),
        ("sram_j_per_bit", Json::Num(e.sram_j_per_bit)),
        ("tuning_w_per_mrr", Json::Num(e.tuning_w_per_mrr)),
        ("mrrs_per_gate", Json::Num(e.mrrs_per_gate)),
    ]);
    Json::obj(vec![
        ("name", Json::Str(cfg.name.clone())),
        ("dr_gsps", Json::Num(cfg.dr_gsps)),
        ("n", Json::Num(cfg.n as f64)),
        ("xpe_total", Json::Num(cfg.xpe_total as f64)),
        ("bitcount", bitcount),
        ("mem_bw_bits_per_s", Json::Num(cfg.mem_bw_bits_per_s)),
        ("energy", energy),
    ])
}

/// Resolve a named built-in config.
pub fn builtin(name: &str) -> Option<AcceleratorConfig> {
    AcceleratorConfig::evaluation_set()
        .into_iter()
        .find(|a| a.name == name)
}

/// Parse an accelerator config from JSON text. Unspecified fields default
/// to the `base` config (default base: OXBNN_50).
pub fn from_json_text(text: &str) -> Result<AcceleratorConfig, ConfigError> {
    from_json(&Json::parse(text)?)
}

/// Parse an accelerator config from an already-parsed JSON value — the
/// inverse of [`to_json`] (round-trip identity is pinned by
/// `rust/tests/config_roundtrip.rs`).
pub fn from_json(j: &Json) -> Result<AcceleratorConfig, ConfigError> {
    let base_name = j.get("base").and_then(Json::as_str).unwrap_or("OXBNN_50");
    let mut cfg =
        builtin(base_name).ok_or_else(|| schema(format!("unknown base '{}'", base_name)))?;
    if let Some(name) = j.get("name").and_then(Json::as_str) {
        cfg.name = name.to_string();
    }
    if let Some(dr) = j.get("dr_gsps").and_then(Json::as_f64) {
        if dr <= 0.0 {
            return Err(schema("dr_gsps must be positive"));
        }
        cfg.dr_gsps = dr;
    }
    if let Some(n) = j.get("n").and_then(Json::as_usize) {
        if n == 0 {
            return Err(schema("n must be >= 1"));
        }
        cfg.n = n;
    }
    if let Some(x) = j.get("xpe_total").and_then(Json::as_usize) {
        if x == 0 {
            return Err(schema("xpe_total must be >= 1"));
        }
        cfg.xpe_total = x;
    }
    if let Some(bw) = j.get("mem_bw_bits_per_s").and_then(Json::as_f64) {
        cfg.mem_bw_bits_per_s = bw;
    }
    if let Some(b) = j.get("bitcount") {
        let mode = b
            .get("mode")
            .and_then(Json::as_str)
            .ok_or_else(|| schema("bitcount.mode required"))?;
        cfg.bitcount = match mode {
            "pca" => BitcountMode::Pca {
                gamma: b
                    .get("gamma")
                    .and_then(Json::as_usize)
                    .map(|g| g as u64)
                    .unwrap_or_else(|| {
                        crate::analysis::pca_capacity::gamma_calibrated(cfg.dr_gsps)
                    }),
            },
            "reduction" => BitcountMode::Reduction {
                latency_s: b.get("latency_s").and_then(Json::as_f64).unwrap_or(3.125e-9),
                psum_bits: b
                    .get("psum_bits")
                    .and_then(Json::as_usize)
                    .unwrap_or(16) as u32,
            },
            other => return Err(schema(format!("unknown bitcount mode '{}'", other))),
        };
    }
    if let Some(e) = j.get("energy") {
        let f = |k: &str, cur: f64| e.get(k).and_then(Json::as_f64).unwrap_or(cur);
        cfg.energy.xnor_j_per_bit = f("xnor_j_per_bit", cfg.energy.xnor_j_per_bit);
        cfg.energy.receiver_j_per_pass =
            f("receiver_j_per_pass", cfg.energy.receiver_j_per_pass);
        cfg.energy.pca_readout_j = f("pca_readout_j", cfg.energy.pca_readout_j);
        cfg.energy.adc_j_per_psum = f("adc_j_per_psum", cfg.energy.adc_j_per_psum);
        cfg.energy.reduction_j_per_psum =
            f("reduction_j_per_psum", cfg.energy.reduction_j_per_psum);
        cfg.energy.sram_j_per_bit = f("sram_j_per_bit", cfg.energy.sram_j_per_bit);
        cfg.energy.tuning_w_per_mrr = f("tuning_w_per_mrr", cfg.energy.tuning_w_per_mrr);
        cfg.energy.mrrs_per_gate = f("mrrs_per_gate", cfg.energy.mrrs_per_gate);
    }
    Ok(cfg)
}

/// Load a config from a file path.
pub fn load(path: impl AsRef<std::path::Path>) -> Result<AcceleratorConfig, ConfigError> {
    from_json_text(&std::fs::read_to_string(path)?)
}

/// Save a config to a file path (pretty JSON).
pub fn save(
    cfg: &AcceleratorConfig,
    path: impl AsRef<std::path::Path>,
) -> Result<(), ConfigError> {
    std::fs::write(path, to_json(cfg).to_string_pretty())?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Workload configs: custom BNN geometry from JSON
// ---------------------------------------------------------------------------

/// Parse a workload (BNN layer geometry) from JSON text:
///
/// ```json
/// {
///   "name": "my_bnn",
///   "layers": [
///     {"kind": "conv", "out_hw": 32, "in_channels": 3, "kernel": 3,
///      "out_channels": 64, "pool": true},
///     {"kind": "depthwise", "out_hw": 16, "channels": 64, "kernel": 3,
///      "in_hw": 16},
///     {"kind": "gemm", "h": 256, "s": 576, "k": 64,
///      "kernel": 3, "stride": 1, "padding": 1, "in_hw": 16},
///     {"kind": "fc", "inputs": 1024, "outputs": 10}
///   ]
/// }
/// ```
///
/// Any non-FC layer may carry an explicit im2col window
/// (`kernel`/`stride`/`padding`/`in_hw`, defaults 3/1/kernel⁄2/—) for
/// receptive-field-exact pipelined admission; `conv` layers with odd
/// kernels get the same-convolution window automatically. Layers without
/// one take the conservative whole-map admission wait.
pub fn workload_from_json_text(
    text: &str,
) -> Result<crate::workloads::Workload, ConfigError> {
    use crate::mapping::layer::GemmLayer;
    let j = Json::parse(text)?;
    let name = j
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| schema("workload needs a name"))?;
    let layers_j = j
        .get("layers")
        .and_then(Json::as_arr)
        .ok_or_else(|| schema("workload needs a layers array"))?;
    if layers_j.is_empty() {
        return Err(schema("workload needs at least one layer"));
    }
    let mut layers = Vec::with_capacity(layers_j.len());
    for (i, l) in layers_j.iter().enumerate() {
        let kind = l
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| schema(format!("layer {}: missing kind", i)))?;
        let field = |k: &str| {
            l.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| schema(format!("layer {} ({}): missing '{}'", i, kind, k)))
        };
        let lname = l
            .get("name")
            .and_then(Json::as_str)
            .map(String::from)
            .unwrap_or_else(|| format!("layer{}", i));
        let mut layer = match kind {
            "conv" => GemmLayer::conv(
                lname,
                field("out_hw")?,
                field("in_channels")?,
                l.get("kernel").and_then(Json::as_usize).unwrap_or(3),
                field("out_channels")?,
            ),
            "depthwise" => GemmLayer::depthwise(
                lname,
                field("out_hw")?,
                field("channels")?,
                l.get("kernel").and_then(Json::as_usize).unwrap_or(3),
            ),
            "gemm" => GemmLayer::new(lname, field("h")?, field("s")?, field("k")?),
            "fc" => GemmLayer::fc(lname, field("inputs")?, field("outputs")?),
            other => return Err(schema(format!("layer {}: unknown kind '{}'", i, other))),
        };
        // Optional explicit im2col window for exact pipelined admission
        // (overrides the same-conv window `conv` attaches automatically).
        // Validated here so malformed user JSON reports ConfigError like
        // every other field instead of tripping the library asserts.
        if let Some(in_hw) = l.get("in_hw").and_then(Json::as_usize) {
            let kernel = l.get("kernel").and_then(Json::as_usize).unwrap_or(3);
            let stride = l.get("stride").and_then(Json::as_usize).unwrap_or(1);
            let padding =
                l.get("padding").and_then(Json::as_usize).unwrap_or(kernel / 2);
            if layer.h == 1 {
                return Err(schema(format!(
                    "layer {} ({}): FC layers take no conv window (in_hw given)",
                    i, kind
                )));
            }
            if kernel == 0 || stride == 0 || in_hw == 0 || padding >= kernel {
                return Err(schema(format!(
                    "layer {} ({}): bad window (kernel {}, stride {}, padding {}, \
                     in_hw {}) — need kernel/stride/in_hw > 0 and padding < kernel",
                    i, kind, kernel, stride, padding, in_hw
                )));
            }
            if in_hw + 2 * padding < kernel {
                return Err(schema(format!(
                    "layer {} ({}): kernel {} larger than the padded {}-map",
                    i, kind, kernel, in_hw
                )));
            }
            let geom = crate::mapping::layer::ConvGeom::new(kernel, stride, padding, in_hw);
            let out = geom.out_hw();
            // Regular convs declare their output map as H = out_hw²; the
            // window must imply exactly that map (divisibility alone would
            // let a stride typo silently reinterpret the raster).
            if kind == "conv" && layer.h != out * out {
                return Err(schema(format!(
                    "layer {} (conv): window implies a {}×{} output map but the \
                     layer has H = {}",
                    i, out, out, layer.h
                )));
            }
            if layer.vdp_count() % (out * out) != 0 {
                return Err(schema(format!(
                    "layer {} ({}): {} VDPs cannot raster the {}×{} output map \
                     this window implies",
                    i,
                    kind,
                    layer.vdp_count(),
                    out,
                    out
                )));
            }
            layer = layer.with_geom(geom);
        }
        if l.get("pool").and_then(Json::as_bool).unwrap_or(false) {
            layer = layer.with_pool();
        }
        layers.push(layer);
    }
    Ok(crate::workloads::Workload::new(name, layers))
}

/// Load a workload definition from a file.
pub fn load_workload(
    path: impl AsRef<std::path::Path>,
) -> Result<crate::workloads::Workload, ConfigError> {
    workload_from_json_text(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_builtins() {
        for cfg in AcceleratorConfig::evaluation_set() {
            let text = to_json(&cfg).to_string_pretty();
            let back = from_json_text(&text).unwrap();
            assert_eq!(back.name, cfg.name);
            assert_eq!(back.dr_gsps, cfg.dr_gsps);
            assert_eq!(back.n, cfg.n);
            assert_eq!(back.xpe_total, cfg.xpe_total);
            assert_eq!(back.bitcount, cfg.bitcount);
            assert_eq!(back.energy.xnor_j_per_bit, cfg.energy.xnor_j_per_bit);
            assert_eq!(back.energy.mrrs_per_gate, cfg.energy.mrrs_per_gate);
        }
    }

    #[test]
    fn partial_override_inherits_base() {
        let cfg = from_json_text(
            r#"{"name": "Custom", "base": "OXBNN_5", "xpe_total": 250}"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "Custom");
        assert_eq!(cfg.xpe_total, 250);
        assert_eq!(cfg.n, 53); // inherited from OXBNN_5
        assert_eq!(cfg.dr_gsps, 5.0);
    }

    #[test]
    fn pca_gamma_defaults_to_calibration() {
        let cfg = from_json_text(
            r#"{"dr_gsps": 10.0, "bitcount": {"mode": "pca"}}"#,
        )
        .unwrap();
        assert_eq!(cfg.bitcount, BitcountMode::Pca { gamma: 19841 });
    }

    #[test]
    fn reduction_mode_parses() {
        let cfg = from_json_text(
            r#"{"bitcount": {"mode": "reduction", "psum_bits": 8}}"#,
        )
        .unwrap();
        assert_eq!(
            cfg.bitcount,
            BitcountMode::Reduction { latency_s: 3.125e-9, psum_bits: 8 }
        );
    }

    #[test]
    fn errors_on_bad_values() {
        assert!(from_json_text(r#"{"base": "NOPE"}"#).is_err());
        assert!(from_json_text(r#"{"n": 0}"#).is_err());
        assert!(from_json_text(r#"{"dr_gsps": -5}"#).is_err());
        assert!(from_json_text(r#"{"bitcount": {"mode": "magic"}}"#).is_err());
        assert!(from_json_text("{nope").is_err());
    }

    #[test]
    fn workload_from_json_all_kinds() {
        let w = workload_from_json_text(
            r#"{
              "name": "custom",
              "layers": [
                {"kind": "conv", "out_hw": 8, "in_channels": 3,
                 "out_channels": 16, "pool": true},
                {"kind": "depthwise", "out_hw": 4, "channels": 16},
                {"kind": "gemm", "h": 16, "s": 144, "k": 32, "name": "pw"},
                {"kind": "fc", "inputs": 512, "outputs": 10}
              ]
            }"#,
        )
        .unwrap();
        assert_eq!(w.name, "custom");
        assert_eq!(w.layers.len(), 4);
        assert_eq!((w.layers[0].h, w.layers[0].s, w.layers[0].k), (64, 27, 16));
        assert!(w.layers[0].pool);
        assert_eq!((w.layers[1].h, w.layers[1].s, w.layers[1].k), (16 * 16, 9, 1));
        assert_eq!(w.layers[2].name, "pw");
        assert_eq!((w.layers[3].h, w.layers[3].s, w.layers[3].k), (1, 512, 10));
    }

    #[test]
    fn workload_json_carries_conv_windows() {
        let w = workload_from_json_text(
            r#"{
              "name": "geom",
              "layers": [
                {"kind": "conv", "out_hw": 8, "in_channels": 3, "out_channels": 4},
                {"kind": "gemm", "h": 16, "s": 36, "k": 2,
                 "kernel": 3, "stride": 2, "padding": 1, "in_hw": 8},
                {"kind": "fc", "inputs": 32, "outputs": 10}
              ]
            }"#,
        )
        .unwrap();
        // conv: automatic same-conv window.
        let g0 = w.layers[0].geom.expect("conv auto-window");
        assert_eq!((g0.kernel, g0.stride, g0.padding, g0.in_hw), (3, 1, 1, 8));
        // gemm: explicit strided window.
        let g1 = w.layers[1].geom.expect("explicit window");
        assert_eq!((g1.kernel, g1.stride, g1.padding, g1.in_hw), (3, 2, 1, 8));
        assert_eq!(g1.out_hw(), 4);
        // fc: none.
        assert!(w.layers[2].geom.is_none());
    }

    #[test]
    fn workload_json_rejects_bad_windows_as_errors_not_panics() {
        // padding >= kernel
        assert!(workload_from_json_text(
            r#"{"name": "x", "layers": [{"kind": "gemm", "h": 16, "s": 9, "k": 1,
                "kernel": 3, "padding": 3, "in_hw": 8}]}"#
        )
        .is_err());
        // VDPs don't raster the implied output map
        assert!(workload_from_json_text(
            r#"{"name": "x", "layers": [{"kind": "gemm", "h": 16, "s": 9, "k": 1,
                "kernel": 3, "padding": 1, "in_hw": 12}]}"#
        )
        .is_err());
        // FC layers take no window
        assert!(workload_from_json_text(
            r#"{"name": "x", "layers": [{"kind": "fc", "inputs": 64, "outputs": 10,
                "in_hw": 8}]}"#
        )
        .is_err());
        // conv: an explicit window must imply the layer's own output map
        // (stride typo would otherwise silently reinterpret the raster)
        assert!(workload_from_json_text(
            r#"{"name": "x", "layers": [{"kind": "conv", "out_hw": 8,
                "in_channels": 2, "out_channels": 4, "kernel": 3, "stride": 2,
                "padding": 1, "in_hw": 8}]}"#
        )
        .is_err());
    }

    #[test]
    fn workload_json_errors() {
        assert!(workload_from_json_text(r#"{"layers": []}"#).is_err());
        assert!(workload_from_json_text(r#"{"name": "x", "layers": []}"#).is_err());
        assert!(workload_from_json_text(
            r#"{"name": "x", "layers": [{"kind": "warp", "h": 1}]}"#
        )
        .is_err());
        assert!(workload_from_json_text(
            r#"{"name": "x", "layers": [{"kind": "conv", "out_hw": 8}]}"#
        )
        .is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("oxbnn_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.json");
        let cfg = AcceleratorConfig::oxbnn_50();
        save(&cfg, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.name, cfg.name);
        std::fs::remove_file(&path).ok();
    }
}
