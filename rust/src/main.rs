//! `oxbnn` — CLI front-end for the OXBNN reproduction.
//!
//! Subcommands:
//!   table2      regenerate paper Table II (scalability analysis)
//!   fps         regenerate paper Fig. 7(a)/(b) (FPS and FPS/W sweep)
//!   simulate    run one accelerator × workload through the Session facade
//!   oxg         OXG device study (truth table / transient, paper Fig. 3)
//!   serve       start the inference server on AOT artifacts
//!   serve-http  HTTP front-end: multi-model sharded serving over real sockets
//!   lint        static plan verification over the model zoo (CI gate)
//!   info        dump accelerator configurations
//!
//! `simulate`, `fps` and `sweep` accept `--backend analytic|event|functional`
//! and all route through [`oxbnn::api::Session`], so every execution model
//! produces the same unified report shape.

use oxbnn::analysis::scalability::ScalabilitySolver;
use oxbnn::api::{BackendKind, Session};
use oxbnn::arch::accelerator::AcceleratorConfig;
use oxbnn::arch::perf::gmean;
use oxbnn::coordinator::{
    BatchPolicy, InferenceRequest, Server, ServerConfig, SubmitError,
};
use oxbnn::devices::oxg::Oxg;
use oxbnn::plan::ShardPolicy;
use oxbnn::util::bench::Table;
use oxbnn::util::cli::{CliError, Command};
use oxbnn::util::logging;
use oxbnn::util::threadpool::{host_threads, parallel_map};
use oxbnn::util::rng::Rng;
use oxbnn::util::units::fmt_time;
use oxbnn::workloads::Workload;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    logging::set_level(logging::Level::from_env());
    let code = match args.first().map(|s| s.as_str()) {
        Some("table2") => cmd_table2(),
        Some("fps") => cmd_fps(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("oxg") => cmd_oxg(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("serve-http") => cmd_serve_http(&args[1..]),
        Some("serve-bench") => cmd_serve_bench(&args[1..]),
        Some("lint") => cmd_lint(&args[1..]),
        Some("info") => cmd_info(),
        Some("dump-config") => cmd_dump_config(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("--help") | Some("-h") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand '{}'\n", other);
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "oxbnn — Optical XNOR-Bitcount BNN Accelerator (ISQED 2023 reproduction)\n\n\
         USAGE: oxbnn <subcommand> [options]\n\n\
         SUBCOMMANDS:\n\
           table2     regenerate paper Table II (N, P_PD-opt, gamma, alpha per DR)\n\
           fps        regenerate paper Fig. 7 FPS / FPS-per-W comparison (--backend)\n\
           simulate   one accelerator x workload run (--backend analytic|event|functional)\n\
           oxg        OXG device study (paper Fig. 3 truth table + transient)\n\
           serve      run the inference server over AOT artifacts\n\
           serve-http  HTTP front-end: multi-model sharded serving (--smoke self-test)\n\
           serve-bench closed/open-loop load benchmark of the serving path (--http)\n\
           lint        statically verify compiled plans over the model zoo (CI gate)\n\
           info        dump the five evaluation accelerator configurations\n\
           dump-config emit a built-in accelerator config as editable JSON\n\
           sweep       CSV sweep of FPS over the Table II DR points x XPE counts\n\n\
         Run any subcommand with --help for its options."
    );
}

fn handle_cli(err: CliError) -> i32 {
    match err {
        CliError::Help(usage) => {
            println!("{}", usage);
            0
        }
        other => {
            eprintln!("error: {}", other);
            2
        }
    }
}

/// Parse a `--backend` value, reporting api errors CLI-style.
fn parse_backend(s: &str) -> Result<BackendKind, i32> {
    s.parse().map_err(|e| {
        eprintln!("error: {}", e);
        2
    })
}

/// Parse the shared `--pipeline auto|true|false` option. `auto` (the
/// default) leaves the session's own rule in charge: batches run the
/// whole-frame pipelined event space, single frames stay sequential;
/// `false` is the opt-out back to the `with_batch` multiply.
fn parse_pipeline(s: &str) -> Result<Option<bool>, i32> {
    match s {
        "auto" | "" => Ok(None),
        "true" | "on" | "1" => Ok(Some(true)),
        "false" | "off" | "0" => Ok(Some(false)),
        other => {
            eprintln!("error: --pipeline must be auto|true|false, got '{}'", other);
            Err(2)
        }
    }
}

/// Parse the shared `--steal auto|on|off` option. `auto` (the default)
/// leaves the session's own rule in charge (stealing on, or whatever
/// `OXBNN_STEAL` pins); `off` is the opt-out back to the strict
/// frame-major scheduler frontier.
fn parse_steal(s: &str) -> Result<Option<bool>, i32> {
    match s {
        "auto" | "" => Ok(None),
        "true" | "on" | "1" => Ok(Some(true)),
        "false" | "off" | "0" => Ok(Some(false)),
        other => {
            eprintln!("error: --steal must be auto|on|off, got '{}'", other);
            Err(2)
        }
    }
}

/// Parse the shared `--chips K` / `--shard layer|vdp` scale-out options.
fn parse_shard(parsed: &oxbnn::util::cli::Parsed) -> Result<(usize, ShardPolicy), i32> {
    let chips = match parsed.get_usize("chips") {
        Ok(k) => k.max(1),
        Err(e) => return Err(handle_cli(e)),
    };
    let shard: ShardPolicy = match parsed.get("shard").parse() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {}", e);
            return Err(2);
        }
    };
    Ok((chips, shard))
}

fn cmd_table2() -> i32 {
    let solver = ScalabilitySolver::default();
    let mut table = Table::new(&[
        "DR (GS/s)",
        "P_PD-opt (dBm)",
        "N",
        "gamma",
        "alpha",
        "paper N",
        "paper gamma",
    ]);
    for (row, paper) in solver
        .table2()
        .iter()
        .zip(oxbnn::analysis::PAPER_TABLE2.iter())
    {
        table.row(&[
            format!("{}", row.dr_gsps),
            format!("{:.2}", row.p_pd_opt_dbm),
            format!("{}", row.n),
            format!("{}", row.gamma),
            format!("{}", row.alpha),
            format!("{}", paper.2),
            format!("{}", paper.3),
        ]);
    }
    println!("Paper Table II — XPC size N and PCA capacity per data rate\n");
    table.print();
    0
}

fn cmd_fps(args: &[String]) -> i32 {
    let cmd = Command::new("oxbnn fps", "Fig. 7 FPS and FPS/W sweep")
        .opt(
            "backend",
            "analytic",
            "analytic|event|functional (event is detailed but much slower)",
        )
        .opt("batch", "1", "frames per cell (pipelined batches report batched FPS)")
        .opt(
            "pipeline",
            "auto",
            "auto|true|false — whole-frame pipelined batches (auto: on when batch > 1)",
        )
        .opt(
            "steal",
            "auto",
            "auto|on|off — bounded work-stealing past admission-blocked units",
        )
        .opt("chips", "1", "accelerators per model (K-chip scale-out group)")
        .opt("shard", "vdp", "layer|vdp — shard policy when --chips > 1")
        .flag("json", "emit JSON instead of tables");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_cli(e),
    };
    let backend = match parse_backend(parsed.get("backend")) {
        Ok(b) => b,
        Err(code) => return code,
    };
    let batch = match parsed.get_usize("batch") {
        Ok(b) => b.max(1),
        Err(e) => return handle_cli(e),
    };
    let pipeline = match parse_pipeline(parsed.get("pipeline")) {
        Ok(p) => p,
        Err(code) => return code,
    };
    let steal = match parse_steal(parsed.get("steal")) {
        Ok(s) => s,
        Err(code) => return code,
    };
    let (chips, shard) = match parse_shard(&parsed) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let accels = AcceleratorConfig::evaluation_set();
    let workloads = Workload::evaluation_set();

    // Fan every (accelerator × workload) cell across the host's cores.
    // Cells are independent simulations (each a distinct plan-cache key),
    // so the grid scales with threads — which is what lets the event
    // backend complete the full Fig. 7 grid. `OXBNN_THREADS` overrides.
    let jobs: Vec<(AcceleratorConfig, Workload)> = accels
        .iter()
        .flat_map(|a| workloads.iter().map(move |w| (a.clone(), w.clone())))
        .collect();
    let cell_reports: Vec<oxbnn::api::Report> =
        parallel_map(jobs, host_threads(), move |(a, w)| {
            let mut builder = Session::builder()
                .accelerator(a)
                .workload(w)
                .backend(backend)
                .batch(batch)
                .chips(chips)
                .shard_policy(shard);
            if let Some(p) = pipeline {
                builder = builder.pipeline(p);
            }
            if let Some(s) = steal {
                builder = builder.steal(s);
            }
            builder.build().expect("session over built-in configs").run()
        });

    let mut fps_table = Table::new(&[
        "accelerator",
        "vgg_small",
        "resnet18",
        "mobilenet_v2",
        "shufflenet_v2",
        "gmean FPS",
    ]);
    let mut fpsw_table = fps_table_clone_headers();
    let mut results = Vec::new();
    for (i, acc) in accels.iter().enumerate() {
        let reports = &cell_reports[i * workloads.len()..(i + 1) * workloads.len()];
        let fps: Vec<f64> = reports.iter().map(|r| r.fps).collect();
        let fpsw: Vec<f64> = reports.iter().map(|r| r.fps_per_w).collect();
        fps_table.row(&[
            acc.name.clone(),
            format!("{:.1}", fps[0]),
            format!("{:.1}", fps[1]),
            format!("{:.1}", fps[2]),
            format!("{:.1}", fps[3]),
            format!("{:.1}", gmean(&fps)),
        ]);
        fpsw_table.row(&[
            acc.name.clone(),
            format!("{:.2}", fpsw[0]),
            format!("{:.2}", fpsw[1]),
            format!("{:.2}", fpsw[2]),
            format!("{:.2}", fpsw[3]),
            format!("{:.2}", gmean(&fpsw)),
        ]);
        results.push((acc.name.clone(), fps, fpsw));
    }
    if parsed.has_flag("json") {
        use oxbnn::util::json::Json;
        let accelerators = Json::Obj(
            results
                .into_iter()
                .map(|(name, fps, fpsw)| {
                    (
                        name,
                        Json::obj(vec![
                            ("fps", Json::arr_f64(&fps)),
                            ("fps_per_w", Json::arr_f64(&fpsw)),
                        ]),
                    )
                })
                .collect(),
        );
        let obj = Json::obj(vec![
            ("backend", Json::Str(backend.as_str().to_string())),
            ("chips", Json::Num(chips as f64)),
            ("shard", Json::Str(shard.as_str().to_string())),
            ("accelerators", accelerators),
        ]);
        println!("{}", obj.to_string_pretty());
    } else {
        let group = if chips > 1 {
            format!(", {}-chip {} shard", chips, shard.as_str())
        } else {
            String::new()
        };
        println!("Fig. 7(a) — FPS (higher is better, {} backend{})\n", backend, group);
        fps_table.print();
        println!("\nFig. 7(b) — FPS/W (higher is better, {} backend{})\n", backend, group);
        fpsw_table.print();
    }
    0
}

fn fps_table_clone_headers() -> Table {
    Table::new(&[
        "accelerator",
        "vgg_small",
        "resnet18",
        "mobilenet_v2",
        "shufflenet_v2",
        "gmean FPS/W",
    ])
}

fn cmd_simulate(args: &[String]) -> i32 {
    let cmd = Command::new(
        "oxbnn simulate",
        "run one accelerator x workload through the Session facade",
    )
    .opt("accelerator", "OXBNN_50", "OXBNN_5|OXBNN_50|ROBIN_EO|ROBIN_PO|LIGHTBULB")
    .opt("workload", "vgg_small", "vgg_small|resnet18|mobilenet_v2|shufflenet_v2")
    .opt("config", "", "JSON accelerator config file (overrides --accelerator)")
    .opt("workload-file", "", "JSON workload geometry file (overrides --workload)")
    .opt(
        "backend",
        "analytic",
        "analytic|event|functional (event simulates every PASS — slow on full BNNs)",
    )
    .opt("batch", "1", "frames to evaluate back-to-back")
    .opt(
        "pipeline",
        "auto",
        "auto|true|false — whole-frame pipelined batches: cross-layer + multi-frame \
         overlap with receptive-field-exact admission (auto: on when batch > 1)",
    )
    .opt(
        "steal",
        "auto",
        "auto|on|off — bounded work-stealing past admission-blocked units in the \
         pipelined event space (auto: on)",
    )
    .opt("chips", "1", "accelerators sharing the model (K-chip scale-out group)")
    .opt("shard", "vdp", "layer|vdp — shard policy when --chips > 1")
    .flag("json", "emit the unified report as JSON")
    .flag("layers", "print per-layer breakdown");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_cli(e),
    };
    let acc = if !parsed.get("config").is_empty() {
        match oxbnn::config::load(parsed.get("config")) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("config error: {}", e);
                return 2;
            }
        }
    } else {
        match AcceleratorConfig::evaluation_set()
            .into_iter()
            .find(|a| a.name == parsed.get("accelerator"))
        {
            Some(a) => a,
            None => {
                eprintln!("unknown accelerator '{}'", parsed.get("accelerator"));
                return 2;
            }
        }
    };
    let workload = if !parsed.get("workload-file").is_empty() {
        match oxbnn::config::load_workload(parsed.get("workload-file")) {
            Ok(w) => w,
            Err(e) => {
                eprintln!("workload config error: {}", e);
                return 2;
            }
        }
    } else {
        match Workload::evaluation_set()
            .into_iter()
            .find(|w| w.name == parsed.get("workload"))
        {
            Some(w) => w,
            None => {
                eprintln!("unknown workload '{}'", parsed.get("workload"));
                return 2;
            }
        }
    };
    let backend = match parse_backend(parsed.get("backend")) {
        Ok(b) => b,
        Err(code) => return code,
    };
    let batch = match parsed.get_usize("batch") {
        Ok(b) => b,
        Err(e) => return handle_cli(e),
    };
    let pipeline = match parse_pipeline(parsed.get("pipeline")) {
        Ok(p) => p,
        Err(code) => return code,
    };
    let steal = match parse_steal(parsed.get("steal")) {
        Ok(s) => s,
        Err(code) => return code,
    };
    let (chips, shard) = match parse_shard(&parsed) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let mut builder = Session::builder()
        .accelerator(acc)
        .workload(workload)
        .backend(backend)
        .batch(batch)
        .chips(chips)
        .shard_policy(shard);
    if let Some(p) = pipeline {
        builder = builder.pipeline(p);
    }
    if let Some(s) = steal {
        builder = builder.steal(s);
    }
    let mut session = match builder.build() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {}", e);
            return 2;
        }
    };
    let report = session.run();
    if parsed.has_flag("json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        println!(
            "[{}] {} on {}: frame latency {} → {:.1} FPS, avg power {:.2} W, {:.2} FPS/W",
            report.backend,
            report.accelerator,
            report.workload,
            fmt_time(report.frame_latency_s),
            report.fps,
            report.avg_power_w,
            report.fps_per_w
        );
        println!(
            "  passes {}, psums {}, dynamic energy {:.3e} J/frame",
            report.passes, report.psums, report.dynamic_energy_per_frame_j
        );
        if report.batch > 1 {
            println!(
                "  batch of {} frames{}: {} → {:.1} FPS batched",
                report.batch,
                if report.pipelined { " (pipelined)" } else { "" },
                fmt_time(report.batch_latency_s),
                report.batched_fps()
            );
        }
        if !report.energy_breakdown.is_empty() {
            let parts: Vec<String> = report
                .energy_breakdown
                .iter()
                .map(|(k, v)| format!("{} {:.3e} J", k, v))
                .collect();
            println!("  energy ledger: {}", parts.join(", "));
        }
        if let Some(c) = &report.correctness {
            println!(
                "  functional check: {} VDPs recomputed, {} mismatches, {} PCA clamps",
                c.vdps_checked, c.mismatches, c.pca_clamped
            );
        }
        if let Some(s) = &report.shard {
            let idle: Vec<String> = s
                .chip_idle_fraction
                .iter()
                .map(|f| format!("{:.0}%", f * 100.0))
                .collect();
            println!(
                "  scale-out: {} chips ({} shard), chip idle [{}], link busy {} over {} transfers",
                s.chips,
                s.policy,
                idle.join(", "),
                fmt_time(s.link_busy_s),
                s.link_transfers
            );
        }
        if parsed.has_flag("layers") {
            let t = |m: &std::collections::BTreeMap<String, f64>, k: &str| {
                m.get(k).map(|v| fmt_time(*v)).unwrap_or_else(|| "-".into())
            };
            let mut tbl = Table::new(&[
                "layer", "latency", "compute", "memory", "reduce", "passes", "psums",
            ]);
            for l in &report.layers {
                tbl.row(&[
                    l.name.clone(),
                    fmt_time(l.latency_s),
                    t(&l.timing, "compute_s"),
                    t(&l.timing, "memory_s"),
                    t(&l.timing, "reduce_s"),
                    format!("{}", l.passes),
                    format!("{}", l.psums),
                ]);
            }
            tbl.print();
        }
    }
    // A functional run that found arithmetic mismatches is a failure.
    match &report.correctness {
        Some(c) if !c.is_clean() => 1,
        _ => 0,
    }
}

fn cmd_oxg(args: &[String]) -> i32 {
    let cmd = Command::new("oxbnn oxg", "OXG device study (paper Fig. 3)")
        .opt("dr", "10", "data rate in GS/s for the transient")
        .opt("bits", "8", "bits per operand stream");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_cli(e),
    };
    let dr: f64 = match parsed.get_f64("dr") {
        Ok(v) => v,
        Err(e) => return handle_cli(e),
    };
    let nbits = parsed.get_usize("bits").unwrap_or(8);
    let gate = Oxg::new(1550.0);
    println!("OXG truth table (through-port transmission at λ_in):");
    for (i, w) in [(false, false), (false, true), (true, false), (true, true)] {
        println!(
            "  i={} w={} → T={:.3} → XNOR bit {}",
            i as u8,
            w as u8,
            gate.transmission(i, w),
            gate.xnor(i, w) as u8
        );
    }
    let mut rng = Rng::new(3);
    let bits_i: Vec<bool> = (0..nbits).map(|_| rng.bool()).collect();
    let bits_w: Vec<bool> = (0..nbits).map(|_| rng.bool()).collect();
    let trace = gate.transient(&bits_i, &bits_w, dr, 16, 3.0);
    let decoded = gate.decode_trace(&trace, 16);
    println!("\ntransient at {} GS/s:", dr);
    println!("  I      = {:?}", bits_i.iter().map(|b| *b as u8).collect::<Vec<_>>());
    println!("  W      = {:?}", bits_w.iter().map(|b| *b as u8).collect::<Vec<_>>());
    println!("  XNOR   = {:?}", decoded.iter().map(|b| *b as u8).collect::<Vec<_>>());
    let ok = decoded
        .iter()
        .zip(bits_i.iter().zip(&bits_w))
        .all(|(d, (a, b))| *d == (a == b));
    println!("  decode {}", if ok { "OK" } else { "FAILED" });
    (!ok) as i32
}

/// Build a ServerConfig from the shared serve/serve-bench options:
/// artifacts dir (synthetic stub model when the manifest is absent),
/// batching policy, bounded queue depth, replicas.
fn server_config_from_args(
    parsed: &oxbnn::util::cli::Parsed,
    model: &str,
) -> Result<ServerConfig, i32> {
    let dir = std::path::PathBuf::from(parsed.get("artifacts"));
    let mut cfg = if dir.join("manifest.json").exists() {
        ServerConfig::new(&dir, &[model])
    } else {
        println!(
            "artifacts manifest missing — serving the synthetic stub model '{}' \
             on the sim engine",
            model
        );
        ServerConfig::synthetic(&[model])
    };
    cfg.max_batch = parsed.get_usize("batch").map_err(handle_cli)?.max(1);
    cfg.policy = parsed.get("policy").parse::<BatchPolicy>().map_err(|e| {
        eprintln!("error: {}", e);
        2
    })?;
    let wait_ms = parsed.get_f64("max-wait-ms").map_err(handle_cli)?;
    cfg.max_wait = std::time::Duration::from_secs_f64((wait_ms / 1e3).max(0.0));
    cfg.queue_depth = parsed.get_usize("queue-depth").map_err(handle_cli)?.max(1);
    cfg.replicas = parsed.get_usize("replicas").map_err(handle_cli)?.max(1);
    // Photonic reference: pipelined batch of max_batch frames (the server
    // batches requests anyway). Default on with the analytic estimate;
    // `event` runs the transaction-level whole-frame event space instead;
    // `false` opts back out to the isolated-frame reference.
    match parsed.get("sim-pipeline") {
        "true" | "on" | "1" | "" => cfg.sim_pipeline = true,
        "false" | "off" | "0" => cfg.sim_pipeline = false,
        "event" => {
            cfg.sim_backend = BackendKind::Event;
            cfg.sim_pipeline = true;
        }
        other => {
            eprintln!("error: --sim-pipeline must be true|false|event, got '{}'", other);
            return Err(2);
        }
    }
    // Functional engine: packed XNOR+popcount by default; empty keeps the
    // environment-resolved default so OXBNN_FUNCTIONAL=f32 still works.
    let functional = parsed.get("functional");
    if !functional.is_empty() {
        cfg.functional_mode = functional.parse().map_err(|e| {
            eprintln!("error: {}", e);
            2
        })?;
    }
    Ok(cfg)
}

fn cmd_serve(args: &[String]) -> i32 {
    let cmd = Command::new("oxbnn serve", "inference server demo over AOT artifacts")
        .opt("artifacts", "artifacts", "artifacts directory (synthetic stub model if missing)")
        .opt("model", "tiny", "model to serve (tiny|small|vgg_small)")
        .opt("requests", "32", "number of requests to issue")
        .opt("batch", "8", "max dynamic batch size")
        .opt("policy", "immediate", "batch-cut policy: immediate|deadline")
        .opt("max-wait-ms", "2", "deadline policy: oldest-request max wait (ms)")
        .opt("queue-depth", "1024", "bounded per-replica queue depth (back-pressure)")
        .opt("replicas", "1", "worker replicas for the model")
        .opt(
            "sim-pipeline",
            "true",
            "true|false|event — pipelined-batch photonic reference (event: \
             transaction-level whole-frame event space)",
        )
        .opt(
            "functional",
            "",
            "packed|f32 — sim-engine functional implementation (default: \
             packed, or OXBNN_FUNCTIONAL)",
        );
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_cli(e),
    };
    let model = parsed.get("model").to_string();
    let cfg = match server_config_from_args(&parsed, &model) {
        Ok(c) => c,
        Err(code) => return code,
    };
    let n = parsed.get_usize("requests").unwrap_or(32);
    let server = match Server::start(cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("server start failed: {:#}", e);
            return 1;
        }
    };
    let input_len = server.input_len(&model).unwrap();
    let mut rng = Rng::new(0xF00D);
    let t0 = std::time::Instant::now();
    let mut ok = 0;
    for _ in 0..n {
        let input: Vec<f32> = (0..input_len).map(|_| rng.f64() as f32 - 0.5).collect();
        match server.infer_blocking(InferenceRequest { model: model.clone(), input }) {
            Ok(resp) => {
                ok += 1;
                oxbnn::log_debug!("logits[0..3]={:?}", &resp.logits[..3.min(resp.logits.len())]);
            }
            Err(e) => eprintln!("request failed: {:#}", e),
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    println!(
        "served {}/{} requests in {:.3}s ({:.1} req/s)",
        ok,
        n,
        elapsed,
        ok as f64 / elapsed
    );
    println!("{}", server.metrics.lock().unwrap().report());
    server.shutdown();
    (ok != n) as i32
}

/// Build a model registry for the HTTP front-end over the shared
/// serve/serve-bench options: real artifacts when the manifest exists,
/// the synthetic in-memory models otherwise.
fn registry_from_args(
    parsed: &oxbnn::util::cli::Parsed,
    first_model: &str,
) -> Result<std::sync::Arc<oxbnn::serving::ModelRegistry>, i32> {
    use oxbnn::serving::ModelRegistry;
    let cfg = server_config_from_args(parsed, first_model)?;
    let dir = std::path::PathBuf::from(parsed.get("artifacts"));
    let registry = if dir.join("manifest.json").exists() {
        match ModelRegistry::from_artifacts(cfg) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("error: {:#}", e);
                return Err(1);
            }
        }
    } else {
        ModelRegistry::synthetic(cfg)
    };
    Ok(std::sync::Arc::new(registry))
}

fn cmd_serve_http(args: &[String]) -> i32 {
    use oxbnn::serving::{serve, HttpConfig, RetryPolicy};
    let cmd = Command::new(
        "oxbnn serve-http",
        "HTTP front-end: multi-model sharded serving with hot reload and health checks",
    )
    .opt("addr", "127.0.0.1:8080", "bind address (port 0 = OS-assigned)")
    .opt("artifacts", "artifacts", "artifacts directory (synthetic models if missing)")
    .opt("models", "tiny", "comma-separated models to load at boot")
    .opt("batch", "8", "max dynamic batch size per model")
    .opt("policy", "immediate", "batch-cut policy: immediate|deadline")
    .opt("max-wait-ms", "2", "deadline policy: oldest-request max wait (ms)")
    .opt("queue-depth", "1024", "bounded per-replica queue depth (back-pressure)")
    .opt("replicas", "1", "worker replicas per model")
    .opt(
        "sim-pipeline",
        "true",
        "true|false|event — pipelined-batch photonic reference (event: \
         transaction-level whole-frame event space)",
    )
    .opt(
        "functional",
        "",
        "packed|f32 — sim-engine functional implementation (default: \
         packed, or OXBNN_FUNCTIONAL)",
    )
    .opt(
        "threads",
        "0",
        "connection-handler threads, one per open connection (0 = host cores); \
         size above the expected concurrent connection count",
    )
    .opt("retries", "2", "per-request retry cap (gated by the per-model retry budget)")
    .flag("smoke", "run the self-contained serving smoke suite on loopback and exit");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_cli(e),
    };
    if parsed.has_flag("smoke") {
        return run_http_smoke();
    }
    let models: Vec<String> = parsed
        .get("models")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect();
    if models.is_empty() {
        eprintln!("error: --models must list at least one model");
        return 2;
    }
    let registry = match registry_from_args(&parsed, &models[0]) {
        Ok(r) => r,
        Err(code) => return code,
    };
    for model in &models {
        if let Err(e) = registry.load(model, 0) {
            eprintln!("error loading model '{}': {:#}", model, e);
            return 1;
        }
    }
    let threads = parsed.get_usize("threads").unwrap_or(0);
    let retries = parsed.get_usize("retries").unwrap_or(2);
    let http = HttpConfig {
        addr: parsed.get("addr").to_string(),
        threads,
        retry: RetryPolicy { max_retries: retries, ..RetryPolicy::default() },
        ..HttpConfig::default()
    };
    let handle = match serve(http, registry) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("error: {:#}", e);
            return 1;
        }
    };
    println!(
        "oxbnn HTTP front-end listening on http://{} ({} models: {})",
        handle.addr(),
        models.len(),
        models.join(", ")
    );
    println!("  POST /v1/infer   {{\"model\":...,\"input\":[...],\"session\":...}}");
    println!("  POST /v1/submit  fire-and-forget (202)");
    println!("  GET  /v1/models  live models; PUT reconciles desired state");
    println!("  GET  /metrics    plain-text counters   GET /healthz  probe states");
    // Serve until the process is killed (no signal handling offline;
    // in-process embedders get graceful drain via ServingHandle).
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// The CI serving smoke: boots the full front-end on loopback with two
/// synthetic models and drives it over real sockets — concurrent infer
/// on both models, overload shedding, hot reload/unload under load,
/// health/metrics pages, and a graceful drain that must lose nothing.
fn run_http_smoke() -> i32 {
    use oxbnn::serving::{serve, HttpConfig, ModelRegistry, RetryPolicy};
    use oxbnn::serving::http::request_once;
    use oxbnn::util::json::Json;
    use std::sync::Arc;
    use std::time::Duration;

    macro_rules! check {
        ($cond:expr, $($msg:tt)*) => {
            if !$cond {
                eprintln!("serving-smoke FAILED: {}", format!($($msg)*));
                return 1;
            }
        };
    }

    let infer_body = |model: &str, seed: u64| -> String {
        let mut rng = Rng::new(0x517E + seed);
        let input: Vec<f64> = (0..192).map(|_| rng.f64() - 0.5).collect();
        Json::obj(vec![
            ("model", Json::Str(model.to_string())),
            ("input", Json::arr_f64(&input)),
        ])
        .to_string()
    };

    println!("serving-smoke: booting two synthetic models on loopback");
    let mut cfg = ServerConfig::synthetic(&[]);
    cfg.max_batch = 4;
    cfg.queue_depth = 4;
    cfg.replicas = 1;
    // Slow the engine down so overload and in-flight-drain states are
    // reliably observable over real sockets.
    cfg.execute_delay = Duration::from_millis(100);
    let registry = Arc::new(ModelRegistry::synthetic(cfg));
    for model in ["alpha", "beta"] {
        if let Err(e) = registry.load(model, 1) {
            eprintln!("serving-smoke FAILED: loading '{}': {:#}", model, e);
            return 1;
        }
    }
    let http = HttpConfig {
        addr: "127.0.0.1:0".to_string(),
        // More handlers than the flood below needs engine slots, so
        // shedding comes from the bounded engine queue, not the pool.
        threads: 32,
        retry: RetryPolicy {
            max_retries: 3,
            backoff: Duration::from_millis(10),
            ..RetryPolicy::default()
        },
        ..HttpConfig::default()
    };
    let handle = match serve(http, Arc::clone(&registry)) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("serving-smoke FAILED: {:#}", e);
            return 1;
        }
    };
    let addr = handle.addr().to_string();

    // -- step 1: concurrent inference on both models ----------------------
    println!("serving-smoke: [1/5] concurrent inference on two models");
    let mut workers = Vec::new();
    for i in 0..6u64 {
        let addr = addr.clone();
        let model = if i % 2 == 0 { "alpha" } else { "beta" };
        let body = infer_body(model, i);
        workers.push(std::thread::spawn(move || {
            request_once(&addr, "POST", "/v1/infer", body.as_bytes())
        }));
    }
    for w in workers {
        let result = w.join().expect("smoke client thread");
        match result {
            Ok((200, body)) => {
                let j = Json::parse(std::str::from_utf8(&body).unwrap_or("")).unwrap_or(Json::Null);
                let n = j.get("logits").and_then(Json::as_arr).map(|a| a.len());
                check!(n == Some(10), "expected 10 logits, got {:?}", n);
            }
            Ok((status, body)) => {
                check!(false, "infer returned {}: {}", status, String::from_utf8_lossy(&body));
            }
            Err(e) => check!(false, "infer transport error: {}", e),
        }
    }

    // -- step 2: overload sheds with 429, nothing hangs --------------------
    println!("serving-smoke: [2/5] overload: 64 concurrent vs queue depth 4");
    let mut workers = Vec::new();
    for i in 0..64u64 {
        let addr = addr.clone();
        let body = infer_body("alpha", 100 + i);
        workers.push(std::thread::spawn(move || {
            request_once(&addr, "POST", "/v1/infer", body.as_bytes())
        }));
    }
    let (mut ok, mut shed, mut other) = (0u64, 0u64, 0u64);
    for w in workers {
        match w.join().expect("smoke flood thread") {
            Ok((200, _)) => ok += 1,
            Ok((429, _)) => shed += 1,
            _ => other += 1,
        }
    }
    check!(other == 0, "flood produced {} non-200/429 outcomes", other);
    check!(ok > 0, "flood must land some requests");
    check!(shed > 0, "queue depth 4 must shed some of 64 concurrent requests");
    check!(ok + shed == 64, "every flood request must be answered");
    println!("serving-smoke:   {} served, {} shed with 429", ok, shed);

    // -- step 3: hot reload/unload under concurrent load -------------------
    println!("serving-smoke: [3/5] hot load gamma / unload beta / reload alpha under load");
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut loaders = Vec::new();
    for i in 0..2u64 {
        let addr = addr.clone();
        let stop = Arc::clone(&stop);
        let body = infer_body("alpha", 200 + i);
        loaders.push(std::thread::spawn(move || -> Result<u64, String> {
            let mut served = 0;
            while !stop.load(std::sync::atomic::Ordering::SeqCst) {
                match request_once(&addr, "POST", "/v1/infer", body.as_bytes()) {
                    Ok((200, _)) => served += 1,
                    Ok((status, body)) => {
                        return Err(format!(
                            "infer during reload returned {}: {}",
                            status,
                            String::from_utf8_lossy(&body)
                        ))
                    }
                    Err(e) => return Err(format!("transport error during reload: {}", e)),
                }
            }
            Ok(served)
        }));
    }
    // Let the load threads issue their first requests before reconfiguring.
    std::thread::sleep(Duration::from_millis(20));
    let put = br#"{"models": [{"name": "alpha"}, {"name": "gamma", "replicas": 2}]}"#;
    let (status, body) = match request_once(&addr, "PUT", "/v1/models", put) {
        Ok(r) => r,
        Err(e) => {
            check!(false, "PUT /v1/models transport error: {}", e);
            unreachable!()
        }
    };
    check!(status == 200, "PUT returned {}: {}", status, String::from_utf8_lossy(&body));
    let (status, _) = request_once(&addr, "PUT", "/v1/models", br#"{"reload": ["alpha"]}"#)
        .expect("reload request");
    check!(status == 200, "reload PUT returned {}", status);
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    for l in loaders {
        match l.join().expect("loader thread") {
            Ok(served) => check!(served > 0, "load thread served nothing"),
            Err(msg) => check!(false, "{}", msg),
        }
    }
    // Post-conditions: beta gone (404), gamma live, alpha epoch bumped.
    let (status, _) =
        request_once(&addr, "POST", "/v1/infer", infer_body("beta", 300).as_bytes())
            .expect("beta request");
    check!(status == 404, "unloaded beta must 404, got {}", status);
    let (status, _) =
        request_once(&addr, "POST", "/v1/infer", infer_body("gamma", 301).as_bytes())
            .expect("gamma request");
    check!(status == 200, "hot-loaded gamma must serve, got {}", status);
    let (_, listing) = request_once(&addr, "GET", "/v1/models", b"").expect("models listing");
    let j = Json::parse(std::str::from_utf8(&listing).unwrap_or("")).unwrap_or(Json::Null);
    let alpha_epoch = j
        .get("models")
        .and_then(Json::as_arr)
        .and_then(|ms| {
            ms.iter()
                .find(|m| m.get("name").and_then(Json::as_str) == Some("alpha"))
                .and_then(|m| m.get("epoch").and_then(Json::as_usize))
        })
        .unwrap_or(0);
    check!(alpha_epoch >= 3, "alpha reload must bump the epoch, got {}", alpha_epoch);

    // -- step 4: health and metrics pages ----------------------------------
    println!("serving-smoke: [4/5] health + metrics");
    let (status, body) = request_once(&addr, "GET", "/healthz", b"").expect("healthz");
    check!(status == 200, "healthz returned {}: {}", status, String::from_utf8_lossy(&body));
    let (status, body) = request_once(&addr, "GET", "/metrics", b"").expect("metrics");
    check!(status == 200, "metrics returned {}", status);
    let text = String::from_utf8_lossy(&body);
    check!(
        text.contains("oxbnn_http_requests_total{endpoint=\"/v1/infer\",status=\"200\"}"),
        "metrics missing infer counters: {}",
        text
    );
    check!(text.contains("oxbnn_http_shed_total"), "metrics missing shed counter");
    check!(
        text.contains("oxbnn_model_replicas{model=\"gamma\"} 2"),
        "metrics missing gamma replicas: {}",
        text
    );

    // -- step 5: graceful drain loses nothing in flight --------------------
    println!("serving-smoke: [5/5] graceful drain with requests in flight");
    let barrier = Arc::new(std::sync::Barrier::new(5));
    let mut drainers = Vec::new();
    for i in 0..4u64 {
        let addr = addr.clone();
        let barrier = Arc::clone(&barrier);
        let body = infer_body("alpha", 400 + i);
        drainers.push(std::thread::spawn(move || {
            barrier.wait();
            request_once(&addr, "POST", "/v1/infer", body.as_bytes())
        }));
    }
    barrier.wait();
    // Give the requests time to be accepted and submitted, then drain
    // while they are still executing (the engine holds each for 100ms).
    std::thread::sleep(Duration::from_millis(75));
    handle.shutdown();
    for d in drainers {
        match d.join().expect("drain client") {
            Ok((200, _)) => {}
            Ok((status, body)) => check!(
                false,
                "in-flight request lost to drain: {} {}",
                status,
                String::from_utf8_lossy(&body)
            ),
            Err(e) => check!(false, "in-flight request dropped: {}", e),
        }
    }
    check!(
        request_once(&addr, "GET", "/healthz", b"").is_err(),
        "server must be down after shutdown"
    );
    println!("serving-smoke PASSED");
    0
}

#[derive(Default)]
struct LoadStats {
    ok: u64,
    failed: u64,
    rejected: u64,
    photonic_s: f64,
}

impl LoadStats {
    fn absorb(&mut self, other: LoadStats) {
        self.ok += other.ok;
        self.failed += other.failed;
        self.rejected += other.rejected;
        if other.photonic_s > 0.0 {
            self.photonic_s = other.photonic_s;
        }
    }
}

fn is_queue_full(e: &anyhow::Error) -> bool {
    matches!(
        e.downcast_ref::<SubmitError>(),
        Some(SubmitError::QueueFull { .. })
    )
}

/// Closed/open-loop load benchmark of the serving coordinator: reports
/// p50/p95/p99 queue/execute/end-to-end latency plus achieved FPS next to
/// the Session-simulated photonic FPS, and verifies the router leaks no
/// outstanding accounting.
fn cmd_serve_bench(args: &[String]) -> i32 {
    let cmd = Command::new(
        "oxbnn serve-bench",
        "closed/open-loop load benchmark of the serving path",
    )
    .opt("artifacts", "artifacts", "artifacts directory (synthetic stub model if missing)")
    .opt("model", "tiny", "model to serve")
    .opt("mode", "closed", "closed (clients issue back-to-back) | open (Poisson arrivals)")
    .opt("concurrency", "32", "client threads")
    .opt("duration", "2", "seconds of load (when --requests is 0)")
    .opt("requests", "0", "total request budget (0 = run for --duration)")
    .opt("rate", "2000", "open mode: target total arrival rate (req/s)")
    .opt("batch", "8", "max dynamic batch size")
    .opt("policy", "immediate", "batch-cut policy: immediate|deadline")
    .opt("max-wait-ms", "2", "deadline policy: oldest-request max wait (ms)")
    .opt("queue-depth", "1024", "bounded per-replica queue depth (back-pressure)")
    .opt("replicas", "1", "worker replicas for the model")
    .opt(
        "sim-pipeline",
        "true",
        "true|false|event — pipelined-batch photonic reference (event: \
         transaction-level whole-frame event space)",
    )
    .opt(
        "functional",
        "",
        "packed|f32 — sim-engine functional implementation (default: \
         packed, or OXBNN_FUNCTIONAL)",
    )
    .opt(
        "http",
        "",
        "benchmark over HTTP instead of in-process: 'auto' boots a loopback \
         front-end, anything else is an external addr (host:port) — make sure \
         the target's --threads covers --concurrency; emits BENCH_http.json",
    );
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_cli(e),
    };
    if !parsed.get("http").is_empty() {
        return cmd_serve_bench_http(&parsed);
    }
    let model = parsed.get("model").to_string();
    let mode = parsed.get("mode").to_string();
    if mode != "closed" && mode != "open" {
        eprintln!("error: --mode must be closed|open, got '{}'", mode);
        return 2;
    }
    let concurrency = parsed.get_usize("concurrency").unwrap_or(32).max(1);
    let duration = parsed.get_f64("duration").unwrap_or(2.0).max(0.01);
    let total_requests = parsed.get_usize("requests").unwrap_or(0);
    let rate = parsed.get_f64("rate").unwrap_or(2000.0).max(1.0);
    let cfg = match server_config_from_args(&parsed, &model) {
        Ok(c) => c,
        Err(code) => return code,
    };
    let (max_batch, policy, queue_depth, replicas) =
        (cfg.max_batch, cfg.policy, cfg.queue_depth, cfg.replicas);
    let (accel_name, sim_backend) = (cfg.accelerator.name.clone(), cfg.sim_backend);
    let functional = cfg.functional_mode;
    let server = match Server::start(cfg) {
        Ok(s) => std::sync::Arc::new(s),
        Err(e) => {
            eprintln!("server start failed: {:#}", e);
            return 1;
        }
    };
    let input_len = server.input_len(&model).expect("model registered");
    println!(
        "serve-bench: model={} mode={} concurrency={} max_batch={} policy={} \
         queue_depth={} replicas={} functional={}",
        model, mode, concurrency, max_batch, policy, queue_depth, replicas, functional
    );

    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs_f64(duration);
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..concurrency {
        let server = std::sync::Arc::clone(&server);
        let model = model.clone();
        let mode = mode.clone();
        // Per-client request budget (None = run until the deadline). A
        // client whose share rounds to zero must issue nothing.
        let budget = if total_requests > 0 {
            Some(total_requests / concurrency + usize::from(c < total_requests % concurrency))
        } else {
            None
        };
        let client_rate = rate / concurrency as f64;
        handles.push(std::thread::spawn(move || -> LoadStats {
            let mut rng = Rng::new(0xBE7C4 + c as u64);
            let mut stats = LoadStats::default();
            let mut issued = 0usize;
            let mut pending = Vec::new();
            loop {
                match budget {
                    Some(b) if issued >= b => break,
                    Some(_) => {}
                    None if std::time::Instant::now() >= deadline => break,
                    None => {}
                }
                let input: Vec<f32> =
                    (0..input_len).map(|_| rng.f64() as f32 - 0.5).collect();
                let req = InferenceRequest { model: model.clone(), input };
                if mode == "closed" {
                    // Closed loop: at most one in-flight request per client.
                    match server.infer_blocking(req) {
                        Ok(resp) => {
                            issued += 1;
                            stats.ok += 1;
                            stats.photonic_s = resp.simulated_photonic_s;
                        }
                        Err(e) if is_queue_full(&e) => {
                            // Back-pressure: retry shortly WITHOUT consuming
                            // budget — the request was shed, not served.
                            stats.rejected += 1;
                            std::thread::sleep(std::time::Duration::from_micros(200));
                        }
                        Err(_) => {
                            issued += 1;
                            stats.failed += 1;
                        }
                    }
                } else {
                    // Open loop: fire-and-forget at Poisson arrivals,
                    // collect replies at the end. Every arrival — even a
                    // shed one — is one unit of offered load.
                    issued += 1;
                    match server.submit(req) {
                        Ok((_replica, rx)) => pending.push(rx),
                        Err(SubmitError::QueueFull { .. }) => stats.rejected += 1,
                        Err(_) => stats.failed += 1,
                    }
                    // Honest Poisson inter-arrival at the requested rate;
                    // in duration mode, never sleep past the deadline.
                    let mut wait =
                        std::time::Duration::from_secs_f64(rng.exp(client_rate));
                    if budget.is_none() {
                        let remaining = deadline
                            .saturating_duration_since(std::time::Instant::now());
                        wait = wait.min(remaining);
                    }
                    std::thread::sleep(wait);
                }
            }
            for rx in pending {
                match rx.recv() {
                    Ok(Ok(resp)) => {
                        stats.ok += 1;
                        stats.photonic_s = resp.simulated_photonic_s;
                    }
                    _ => stats.failed += 1,
                }
            }
            stats
        }));
    }
    let mut stats = LoadStats::default();
    for h in handles {
        match h.join() {
            Ok(s) => stats.absorb(s),
            Err(_) => eprintln!("client thread panicked"),
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let achieved_fps = stats.ok as f64 / elapsed;
    println!(
        "\ncompleted {} requests in {:.3}s → achieved {:.1} FPS ({} failed, \
         {} rejected by back-pressure)",
        stats.ok, elapsed, achieved_fps, stats.failed, stats.rejected
    );
    if stats.photonic_s > 0.0 {
        let photonic_fps = 1.0 / stats.photonic_s;
        println!(
            "simulated photonic frame ({} / {} backend): {} → {:.1} FPS; \
             serving achieves {:.2}% of photonic",
            accel_name,
            sim_backend,
            fmt_time(stats.photonic_s),
            photonic_fps,
            100.0 * achieved_fps / photonic_fps
        );
    }
    println!("\n{}", server.metrics.lock().unwrap().report());
    // Accounting invariant: every routed request must have completed.
    let mut leaked = 0usize;
    for m in server.models() {
        leaked += server.outstanding(&m);
    }
    println!("router outstanding after drain: {}", leaked);
    match std::sync::Arc::try_unwrap(server) {
        Ok(s) => s.shutdown(),
        Err(_) => unreachable!("clients joined"),
    }
    if leaked > 0 {
        eprintln!("error: router leaked {} outstanding slots", leaked);
        return 1;
    }
    (stats.ok == 0) as i32
}

/// Fetch `model`'s input length and photonic FPS from a front-end's
/// `GET /v1/models` listing (works for in-process and external targets).
fn fetch_model_info(addr: &str, model: &str) -> Result<(usize, f64), String> {
    use oxbnn::util::json::Json;
    let (status, body) = oxbnn::serving::request_once(addr, "GET", "/v1/models", b"")
        .map_err(|e| format!("GET /v1/models on {}: {}", addr, e))?;
    if status != 200 {
        return Err(format!("GET /v1/models returned {}", status));
    }
    let text =
        std::str::from_utf8(&body).map_err(|_| "non-UTF-8 models listing".to_string())?;
    let j = Json::parse(text).map_err(|e| format!("bad models listing JSON: {}", e))?;
    let entry = j
        .get("models")
        .and_then(Json::as_arr)
        .and_then(|ms| {
            ms.iter()
                .find(|m| m.get("name").and_then(Json::as_str) == Some(model))
                .cloned()
        })
        .ok_or_else(|| format!("model '{}' is not loaded on {}", model, addr))?;
    let input_len = entry
        .get("input_len")
        .and_then(Json::as_usize)
        .ok_or_else(|| "models listing missing input_len".to_string())?;
    let photonic_fps = entry.get("photonic_fps").and_then(Json::as_f64).unwrap_or(0.0);
    Ok((input_len, photonic_fps))
}

/// `serve-bench --http`: closed/open-loop load over real loopback (or
/// external) sockets against the HTTP front-end, then a lazy-vs-tree
/// request-parse micro-benchmark on the exact wire payload. Writes
/// `BENCH_http.json`; exits 1 if nothing was served or the lazy parser
/// falls below the 5x speedup floor.
fn cmd_serve_bench_http(parsed: &oxbnn::util::cli::Parsed) -> i32 {
    use oxbnn::coordinator::LatencyHistogram;
    use oxbnn::serving::{serve, ClientConn, HttpConfig, RetryPolicy};
    use oxbnn::util::json::{path_f32_slice, path_str, Json};
    use std::time::{Duration, Instant};

    let model = parsed.get("model").to_string();
    let mode = parsed.get("mode").to_string();
    if mode != "closed" && mode != "open" {
        eprintln!("error: --mode must be closed|open, got '{}'", mode);
        return 2;
    }
    let concurrency = parsed.get_usize("concurrency").unwrap_or(32).max(1);
    let duration = parsed.get_f64("duration").unwrap_or(2.0).max(0.01);
    let total_requests = parsed.get_usize("requests").unwrap_or(0);
    let rate = parsed.get_f64("rate").unwrap_or(2000.0).max(1.0);
    let target = parsed.get("http").to_string();

    let mut handle = None;
    let addr = if target == "auto" {
        let registry = match registry_from_args(parsed, &model) {
            Ok(r) => r,
            Err(code) => return code,
        };
        if let Err(e) = registry.load(&model, 0) {
            eprintln!("error loading model '{}': {:#}", model, e);
            return 1;
        }
        let http = HttpConfig {
            addr: "127.0.0.1:0".to_string(),
            // One handler per open benchmark connection, plus slack for
            // the info/metrics fetches.
            threads: concurrency + 2,
            retry: RetryPolicy::default(),
            ..HttpConfig::default()
        };
        match serve(http, registry) {
            Ok(h) => {
                let a = h.addr().to_string();
                handle = Some(h);
                a
            }
            Err(e) => {
                eprintln!("error: {:#}", e);
                return 1;
            }
        }
    } else {
        target.clone()
    };

    let (input_len, photonic_fps) = match fetch_model_info(&addr, &model) {
        Ok(v) => v,
        Err(msg) => {
            eprintln!("error: {}", msg);
            return 1; // a booted handle drains via Drop
        }
    };
    println!(
        "serve-bench --http: target={} model={} mode={} concurrency={} input_len={}",
        addr, model, mode, concurrency, input_len
    );

    let deadline = Instant::now() + Duration::from_secs_f64(duration);
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for c in 0..concurrency {
        let addr = addr.clone();
        let model = model.clone();
        let mode = mode.clone();
        let budget = if total_requests > 0 {
            Some(total_requests / concurrency + usize::from(c < total_requests % concurrency))
        } else {
            None
        };
        let client_rate = rate / concurrency as f64;
        clients.push(std::thread::spawn(move || -> (Vec<f64>, u64, u64, u64) {
            let mut rng = Rng::new(0xB17C + c as u64);
            let input: Vec<f64> = (0..input_len).map(|_| rng.f64() - 0.5).collect();
            let body = Json::obj(vec![
                ("model", Json::Str(model)),
                ("input", Json::arr_f64(&input)),
            ])
            .to_string();
            let mut conn = match ClientConn::connect(&addr) {
                Ok(conn) => conn,
                Err(_) => return (Vec::new(), 0, 0, 1),
            };
            let mut lat = Vec::new();
            let (mut ok, mut rejected, mut failed) = (0u64, 0u64, 0u64);
            let mut issued = 0usize;
            let mut next_arrival = Instant::now();
            loop {
                match budget {
                    Some(b) if issued >= b => break,
                    Some(_) => {}
                    None if Instant::now() >= deadline => break,
                    None => {}
                }
                if mode == "open" {
                    // Poisson arrival schedule; when the connection falls
                    // behind, arrivals burst back-to-back to catch up.
                    next_arrival += Duration::from_secs_f64(rng.exp(client_rate));
                    let now = Instant::now();
                    if next_arrival > now {
                        let mut wait = next_arrival - now;
                        if budget.is_none() {
                            wait = wait.min(deadline.saturating_duration_since(now));
                        }
                        std::thread::sleep(wait);
                    }
                }
                issued += 1;
                let t_req = Instant::now();
                match conn.request("POST", "/v1/infer", body.as_bytes()) {
                    Ok((200, _)) => {
                        ok += 1;
                        lat.push(t_req.elapsed().as_secs_f64());
                    }
                    Ok((429, _)) => rejected += 1,
                    Ok((_, _)) => failed += 1,
                    Err(_) => {
                        failed += 1;
                        match ClientConn::connect(&addr) {
                            Ok(fresh) => conn = fresh,
                            Err(_) => break,
                        }
                    }
                }
            }
            (lat, ok, rejected, failed)
        }));
    }
    let (mut ok, mut rejected, mut failed) = (0u64, 0u64, 0u64);
    let mut samples: Vec<f64> = Vec::new();
    for c in clients {
        match c.join() {
            Ok((lat, o, r, f)) => {
                samples.extend(lat);
                ok += o;
                rejected += r;
                failed += f;
            }
            Err(_) => eprintln!("bench client thread panicked"),
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let mut hist = LatencyHistogram::new(samples.len().max(1));
    for s in &samples {
        hist.record(*s);
    }
    let achieved_fps = ok as f64 / elapsed;
    println!(
        "\ncompleted {} requests in {:.3}s → {:.1} FPS end-to-end \
         ({} rejected with 429, {} failed)",
        ok, elapsed, achieved_fps, rejected, failed
    );
    println!(
        "e2e latency: p50 {} p95 {} p99 {}",
        fmt_time(hist.p50()),
        fmt_time(hist.p95()),
        fmt_time(hist.p99())
    );

    // Request-parse micro-benchmark on the exact wire shape the hot path
    // sees: lazy field scanner vs full tree parse + extraction.
    let parse_body = {
        let mut rng = Rng::new(0xFACE);
        let input: Vec<f64> = (0..input_len).map(|_| rng.f64() - 0.5).collect();
        Json::obj(vec![
            ("model", Json::Str(model.clone())),
            ("session", Json::Str("bench-session".to_string())),
            ("input", Json::arr_f64(&input)),
        ])
        .to_string()
    };
    let bytes = parse_body.as_bytes();
    let mut out: Vec<f32> = Vec::new();
    let lazy_pass = |out: &mut Vec<f32>| {
        let m = path_str(bytes, &["model"]).expect("lazy model").expect("model present");
        let s = path_str(bytes, &["session"]).expect("lazy session");
        let found = path_f32_slice(bytes, &["input"], out).expect("lazy input");
        std::hint::black_box((m.len(), s.is_some(), found, out.len()));
    };
    let full_pass = || {
        let j = Json::parse(&parse_body).expect("tree parse");
        let m = j.get("model").and_then(Json::as_str).map(String::from);
        let s = j.get("session").and_then(Json::as_str).map(String::from);
        let input: Vec<f32> = j
            .get("input")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_f64).map(|v| v as f32).collect())
            .unwrap_or_default();
        std::hint::black_box((m, s, input.len()));
    };
    let iters = 2000usize;
    for _ in 0..200 {
        lazy_pass(&mut out);
    }
    let t = Instant::now();
    for _ in 0..iters {
        lazy_pass(&mut out);
    }
    let lazy_ns = t.elapsed().as_nanos() as f64 / iters as f64;
    for _ in 0..50 {
        full_pass();
    }
    let t = Instant::now();
    for _ in 0..iters {
        full_pass();
    }
    let full_ns = t.elapsed().as_nanos() as f64 / iters as f64;
    let speedup = full_ns / lazy_ns.max(1e-9);
    println!(
        "request parse ({} floats): lazy {:.0} ns/req vs full tree {:.0} ns/req → {:.1}x",
        input_len, lazy_ns, full_ns, speedup
    );

    let report = Json::obj(vec![
        ("target", Json::Str(addr.clone())),
        ("model", Json::Str(model.clone())),
        ("mode", Json::Str(mode.clone())),
        ("concurrency", Json::Num(concurrency as f64)),
        ("input_len", Json::Num(input_len as f64)),
        ("requests_ok", Json::Num(ok as f64)),
        ("rejected", Json::Num(rejected as f64)),
        ("failed", Json::Num(failed as f64)),
        ("elapsed_s", Json::Num(elapsed)),
        ("achieved_fps", Json::Num(achieved_fps)),
        ("photonic_fps", Json::Num(photonic_fps)),
        ("e2e_p50_s", Json::Num(hist.p50())),
        ("e2e_p95_s", Json::Num(hist.p95())),
        ("e2e_p99_s", Json::Num(hist.p99())),
        ("parse_lazy_ns_per_req", Json::Num(lazy_ns)),
        ("parse_full_ns_per_req", Json::Num(full_ns)),
        ("parse_speedup", Json::Num(speedup)),
    ]);
    if let Err(e) = std::fs::write("BENCH_http.json", report.to_string_pretty()) {
        eprintln!("write BENCH_http.json failed: {}", e);
        return 1;
    }
    println!("wrote BENCH_http.json");
    if let Some(h) = handle {
        h.shutdown();
    }
    if ok == 0 {
        eprintln!("error: no requests served");
        return 1;
    }
    if speedup < 5.0 {
        eprintln!(
            "error: lazy parser speedup {:.1}x is below the 5x floor",
            speedup
        );
        return 1;
    }
    0
}

fn cmd_sweep(args: &[String]) -> i32 {
    let cmd = Command::new(
        "oxbnn sweep",
        "CSV sweep of FPS/FPS-per-W over DR and XPE count (for plotting)",
    )
    .opt("workload", "vgg_small", "workload name")
    .opt("xpes", "100,250,500,1000,2000", "comma-separated XPE counts")
    .opt(
        "backend",
        "analytic",
        "analytic|event|functional (analytic recommended for sweeps)",
    )
    .opt("batch", "1", "frames per cell (pipelined batches report batched FPS)")
    .opt(
        "pipeline",
        "auto",
        "auto|true|false — whole-frame pipelined batches (auto: on when batch > 1)",
    )
    .opt(
        "steal",
        "auto",
        "auto|on|off — bounded work-stealing past admission-blocked units",
    )
    .opt("chips", "1", "accelerators per cell (K-chip scale-out group)")
    .opt("shard", "vdp", "layer|vdp — shard policy when --chips > 1")
    .opt("out", "-", "output CSV path ('-' for stdout)");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_cli(e),
    };
    let Some(workload) = Workload::evaluation_set()
        .into_iter()
        .find(|w| w.name == parsed.get("workload"))
    else {
        eprintln!("unknown workload '{}'", parsed.get("workload"));
        return 2;
    };
    let backend = match parse_backend(parsed.get("backend")) {
        Ok(b) => b,
        Err(code) => return code,
    };
    let batch = match parsed.get_usize("batch") {
        Ok(b) => b.max(1),
        Err(e) => return handle_cli(e),
    };
    let pipeline = match parse_pipeline(parsed.get("pipeline")) {
        Ok(p) => p,
        Err(code) => return code,
    };
    let steal = match parse_steal(parsed.get("steal")) {
        Ok(s) => s,
        Err(code) => return code,
    };
    let (chips, shard) = match parse_shard(&parsed) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let xpes: Vec<usize> = parsed
        .get("xpes")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    if xpes.is_empty() {
        eprintln!("--xpes must list at least one integer");
        return 2;
    }
    let solver = ScalabilitySolver::default();
    // All (DR × XPE-count) cells run in parallel; each cell is an
    // independent simulation of a distinct accelerator config, so the
    // sweep scales with cores even on the event backend.
    let cells: Vec<(f64, usize, u64, usize)> = solver
        .table2()
        .iter()
        .flat_map(|row| xpes.iter().map(move |&x| (row.dr_gsps, row.n, row.gamma, x)))
        .collect();
    let lines: Vec<String> = parallel_map(cells, host_threads(), |(dr, n, gamma, x)| {
        let cfg = AcceleratorConfig {
            name: format!("OXBNN_{}x{}", dr, x),
            dr_gsps: dr,
            n,
            xpe_total: x,
            bitcount: oxbnn::arch::BitcountMode::Pca { gamma },
            ..AcceleratorConfig::oxbnn_50()
        };
        let mut builder = Session::builder()
            .accelerator(cfg)
            .workload(workload.clone())
            .backend(backend)
            .batch(batch)
            .chips(chips)
            .shard_policy(shard);
        if let Some(p) = pipeline {
            builder = builder.pipeline(p);
        }
        if let Some(s) = steal {
            builder = builder.steal(s);
        }
        let report = builder.build().expect("sweep session").run();
        format!(
            "{},{},{},{},{},{:.1},{:.2},{:.2}\n",
            dr, n, gamma, x, chips, report.fps, report.fps_per_w, report.static_power_w
        )
    });
    let mut csv = String::from("dr_gsps,n,gamma,xpe_total,chips,fps,fps_per_w,static_w\n");
    for line in &lines {
        csv.push_str(line);
    }
    if parsed.get("out") == "-" {
        print!("{}", csv);
    } else if let Err(e) = std::fs::write(parsed.get("out"), csv) {
        eprintln!("write failed: {}", e);
        return 1;
    }
    0
}

/// `oxbnn lint` — static verification of every compiled plan the repo
/// ships: the five zoo models × both mapping policies × both admission
/// modes × both OXBNN accelerators, through `check::planlint`. Exits
/// non-zero on any Error-severity finding, which is what makes it a CI
/// gate: a mapping or admission regression fails the build before any
/// simulator runs.
fn cmd_lint(args: &[String]) -> i32 {
    use oxbnn::check::planlint::{self, Severity};
    use oxbnn::mapping::scheduler::MappingPolicy;
    use oxbnn::plan::{AdmissionMode, ExecutionPlan};

    let cmd = Command::new(
        "oxbnn lint",
        "statically verify compiled plans over the model zoo (CI gate)",
    )
    .opt("halo", "0.125", "RasterHalo admission margin (fraction of producer acts)")
    .flag("verbose", "print info/warning findings too, not just errors");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_cli(e),
    };
    let halo = match parsed.get_f64("halo") {
        Ok(h) => h,
        Err(e) => return handle_cli(e),
    };
    let verbose = parsed.has_flag("verbose");

    let mut models = Workload::evaluation_set();
    models.push(oxbnn::workloads::zoo::resnet50());
    let accels = [AcceleratorConfig::oxbnn_5(), AcceleratorConfig::oxbnn_50()];
    let policies = [MappingPolicy::PcaLocal, MappingPolicy::SlicedSpread];
    let admissions = [AdmissionMode::Exact, AdmissionMode::RasterHalo(halo)];

    let (mut plans, mut errors, mut warnings, mut infos) = (0usize, 0usize, 0usize, 0usize);
    for acc in &accels {
        for model in &models {
            for policy in policies {
                let plan = ExecutionPlan::compile(acc, model, policy);
                for admission in admissions {
                    plans += 1;
                    let subject = format!(
                        "{} × {} [{:?}, {:?}]",
                        acc.name, model.name, policy, admission
                    );
                    for finding in planlint::verify_with(&plan, admission) {
                        match finding.severity {
                            Severity::Error => {
                                errors += 1;
                                eprintln!("{}: {}", subject, finding);
                            }
                            Severity::Warning => {
                                warnings += 1;
                                if verbose {
                                    println!("{}: {}", subject, finding);
                                }
                            }
                            Severity::Info => {
                                infos += 1;
                                if verbose {
                                    println!("{}: {}", subject, finding);
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    // Scale-out walk: the same zoo × policies grid again, sharded onto
    // K ∈ {1, 2, 4} chip groups under both shard policies, through the
    // PL4xx geometry lints (verify_shard re-lints the underlying
    // single-chip plan too, so a shard regression cannot hide one).
    let mut shard_plans = 0usize;
    for acc in &accels {
        for model in &models {
            for policy in policies {
                for chips in [1usize, 2, 4] {
                    for shard in ShardPolicy::all() {
                        shard_plans += 1;
                        let splan =
                            oxbnn::plan::ShardPlan::compile(acc, model, policy, chips, shard);
                        let subject = format!(
                            "{} × {} [{:?}, {} chips, {}]",
                            acc.name,
                            model.name,
                            policy,
                            chips,
                            shard.as_str()
                        );
                        for finding in planlint::verify_shard(&splan) {
                            match finding.severity {
                                Severity::Error => {
                                    errors += 1;
                                    eprintln!("{}: {}", subject, finding);
                                }
                                Severity::Warning => {
                                    warnings += 1;
                                    if verbose {
                                        println!("{}: {}", subject, finding);
                                    }
                                }
                                Severity::Info => {
                                    infos += 1;
                                    if verbose {
                                        println!("{}: {}", subject, finding);
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    println!(
        "lint: {} plans + {} shard plans checked ({} models × {} accelerators × {} \
         policies × {} admission modes; shards × K in {{1,2,4}} × both shard policies): \
         {} errors, {} warnings, {} info",
        plans,
        shard_plans,
        models.len(),
        accels.len(),
        policies.len(),
        admissions.len(),
        errors,
        warnings,
        infos
    );
    (errors > 0) as i32
}

fn cmd_dump_config(args: &[String]) -> i32 {
    let cmd = Command::new("oxbnn dump-config", "write a built-in accelerator config as JSON")
        .opt("accelerator", "OXBNN_50", "which built-in to dump")
        .opt("out", "-", "output path ('-' for stdout)");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_cli(e),
    };
    let Some(cfg) = oxbnn::config::builtin(parsed.get("accelerator")) else {
        eprintln!("unknown accelerator '{}'", parsed.get("accelerator"));
        return 2;
    };
    let text = oxbnn::config::to_json(&cfg).to_string_pretty();
    if parsed.get("out") == "-" {
        print!("{}", text);
    } else if let Err(e) = std::fs::write(parsed.get("out"), text) {
        eprintln!("write failed: {}", e);
        return 1;
    }
    0
}

fn cmd_info() -> i32 {
    let mut t = Table::new(&[
        "accelerator",
        "DR (GS/s)",
        "N",
        "XPEs",
        "XPCs",
        "tiles",
        "bitcount",
        "static W",
        "area mm^2",
    ]);
    for a in AcceleratorConfig::evaluation_set() {
        t.row(&[
            a.name.clone(),
            format!("{}", a.dr_gsps),
            format!("{}", a.n),
            format!("{}", a.xpe_total),
            format!("{}", a.xpc_count()),
            format!("{}", a.tile_count()),
            match a.bitcount {
                oxbnn::arch::BitcountMode::Pca { gamma } => format!("PCA(γ={})", gamma),
                oxbnn::arch::BitcountMode::Reduction { .. } => "psum-reduction".into(),
            },
            format!("{:.2}", a.static_power_w()),
            format!("{:.1}", a.area_mm2()),
        ]);
    }
    t.print();
    0
}
