//! `oxbnn` — CLI front-end for the OXBNN reproduction.
//!
//! Subcommands:
//!   table2      regenerate paper Table II (scalability analysis)
//!   fps         regenerate paper Fig. 7(a)/(b) (FPS and FPS/W sweep)
//!   simulate    run one accelerator × workload through the Session facade
//!   oxg         OXG device study (truth table / transient, paper Fig. 3)
//!   serve       start the inference server on AOT artifacts
//!   info        dump accelerator configurations
//!
//! `simulate`, `fps` and `sweep` accept `--backend analytic|event|functional`
//! and all route through [`oxbnn::api::Session`], so every execution model
//! produces the same unified report shape.

use oxbnn::analysis::scalability::ScalabilitySolver;
use oxbnn::api::{BackendKind, Session};
use oxbnn::arch::accelerator::AcceleratorConfig;
use oxbnn::arch::perf::gmean;
use oxbnn::coordinator::{
    BatchPolicy, InferenceRequest, Server, ServerConfig, SubmitError,
};
use oxbnn::devices::oxg::Oxg;
use oxbnn::util::bench::Table;
use oxbnn::util::cli::{CliError, Command};
use oxbnn::util::logging;
use oxbnn::util::threadpool::{host_threads, parallel_map};
use oxbnn::util::rng::Rng;
use oxbnn::util::units::fmt_time;
use oxbnn::workloads::Workload;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    logging::set_level(logging::Level::from_env());
    let code = match args.first().map(|s| s.as_str()) {
        Some("table2") => cmd_table2(),
        Some("fps") => cmd_fps(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("oxg") => cmd_oxg(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("serve-bench") => cmd_serve_bench(&args[1..]),
        Some("info") => cmd_info(),
        Some("dump-config") => cmd_dump_config(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("--help") | Some("-h") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand '{}'\n", other);
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "oxbnn — Optical XNOR-Bitcount BNN Accelerator (ISQED 2023 reproduction)\n\n\
         USAGE: oxbnn <subcommand> [options]\n\n\
         SUBCOMMANDS:\n\
           table2     regenerate paper Table II (N, P_PD-opt, gamma, alpha per DR)\n\
           fps        regenerate paper Fig. 7 FPS / FPS-per-W comparison (--backend)\n\
           simulate   one accelerator x workload run (--backend analytic|event|functional)\n\
           oxg        OXG device study (paper Fig. 3 truth table + transient)\n\
           serve      run the inference server over AOT artifacts\n\
           serve-bench closed/open-loop load benchmark of the serving path\n\
           info        dump the five evaluation accelerator configurations\n\
           dump-config emit a built-in accelerator config as editable JSON\n\
           sweep       CSV sweep of FPS over the Table II DR points x XPE counts\n\n\
         Run any subcommand with --help for its options."
    );
}

fn handle_cli(err: CliError) -> i32 {
    match err {
        CliError::Help(usage) => {
            println!("{}", usage);
            0
        }
        other => {
            eprintln!("error: {}", other);
            2
        }
    }
}

/// Parse a `--backend` value, reporting api errors CLI-style.
fn parse_backend(s: &str) -> Result<BackendKind, i32> {
    s.parse().map_err(|e| {
        eprintln!("error: {}", e);
        2
    })
}

/// Parse the shared `--pipeline auto|true|false` option. `auto` (the
/// default) leaves the session's own rule in charge: batches run the
/// whole-frame pipelined event space, single frames stay sequential;
/// `false` is the opt-out back to the `with_batch` multiply.
fn parse_pipeline(s: &str) -> Result<Option<bool>, i32> {
    match s {
        "auto" | "" => Ok(None),
        "true" | "on" | "1" => Ok(Some(true)),
        "false" | "off" | "0" => Ok(Some(false)),
        other => {
            eprintln!("error: --pipeline must be auto|true|false, got '{}'", other);
            Err(2)
        }
    }
}

fn cmd_table2() -> i32 {
    let solver = ScalabilitySolver::default();
    let mut table = Table::new(&[
        "DR (GS/s)",
        "P_PD-opt (dBm)",
        "N",
        "gamma",
        "alpha",
        "paper N",
        "paper gamma",
    ]);
    for (row, paper) in solver
        .table2()
        .iter()
        .zip(oxbnn::analysis::PAPER_TABLE2.iter())
    {
        table.row(&[
            format!("{}", row.dr_gsps),
            format!("{:.2}", row.p_pd_opt_dbm),
            format!("{}", row.n),
            format!("{}", row.gamma),
            format!("{}", row.alpha),
            format!("{}", paper.2),
            format!("{}", paper.3),
        ]);
    }
    println!("Paper Table II — XPC size N and PCA capacity per data rate\n");
    table.print();
    0
}

fn cmd_fps(args: &[String]) -> i32 {
    let cmd = Command::new("oxbnn fps", "Fig. 7 FPS and FPS/W sweep")
        .opt(
            "backend",
            "analytic",
            "analytic|event|functional (event is detailed but much slower)",
        )
        .opt("batch", "1", "frames per cell (pipelined batches report batched FPS)")
        .opt(
            "pipeline",
            "auto",
            "auto|true|false — whole-frame pipelined batches (auto: on when batch > 1)",
        )
        .flag("json", "emit JSON instead of tables");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_cli(e),
    };
    let backend = match parse_backend(parsed.get("backend")) {
        Ok(b) => b,
        Err(code) => return code,
    };
    let batch = match parsed.get_usize("batch") {
        Ok(b) => b.max(1),
        Err(e) => return handle_cli(e),
    };
    let pipeline = match parse_pipeline(parsed.get("pipeline")) {
        Ok(p) => p,
        Err(code) => return code,
    };
    let accels = AcceleratorConfig::evaluation_set();
    let workloads = Workload::evaluation_set();

    // Fan every (accelerator × workload) cell across the host's cores.
    // Cells are independent simulations (each a distinct plan-cache key),
    // so the grid scales with threads — which is what lets the event
    // backend complete the full Fig. 7 grid. `OXBNN_THREADS` overrides.
    let jobs: Vec<(AcceleratorConfig, Workload)> = accels
        .iter()
        .flat_map(|a| workloads.iter().map(move |w| (a.clone(), w.clone())))
        .collect();
    let cell_reports: Vec<oxbnn::api::Report> =
        parallel_map(jobs, host_threads(), move |(a, w)| {
            let mut builder = Session::builder()
                .accelerator(a)
                .workload(w)
                .backend(backend)
                .batch(batch);
            if let Some(p) = pipeline {
                builder = builder.pipeline(p);
            }
            builder.build().expect("session over built-in configs").run()
        });

    let mut fps_table = Table::new(&[
        "accelerator",
        "vgg_small",
        "resnet18",
        "mobilenet_v2",
        "shufflenet_v2",
        "gmean FPS",
    ]);
    let mut fpsw_table = fps_table_clone_headers();
    let mut results = Vec::new();
    for (i, acc) in accels.iter().enumerate() {
        let reports = &cell_reports[i * workloads.len()..(i + 1) * workloads.len()];
        let fps: Vec<f64> = reports.iter().map(|r| r.fps).collect();
        let fpsw: Vec<f64> = reports.iter().map(|r| r.fps_per_w).collect();
        fps_table.row(&[
            acc.name.clone(),
            format!("{:.1}", fps[0]),
            format!("{:.1}", fps[1]),
            format!("{:.1}", fps[2]),
            format!("{:.1}", fps[3]),
            format!("{:.1}", gmean(&fps)),
        ]);
        fpsw_table.row(&[
            acc.name.clone(),
            format!("{:.2}", fpsw[0]),
            format!("{:.2}", fpsw[1]),
            format!("{:.2}", fpsw[2]),
            format!("{:.2}", fpsw[3]),
            format!("{:.2}", gmean(&fpsw)),
        ]);
        results.push((acc.name.clone(), fps, fpsw));
    }
    if parsed.has_flag("json") {
        use oxbnn::util::json::Json;
        let accelerators = Json::Obj(
            results
                .into_iter()
                .map(|(name, fps, fpsw)| {
                    (
                        name,
                        Json::obj(vec![
                            ("fps", Json::arr_f64(&fps)),
                            ("fps_per_w", Json::arr_f64(&fpsw)),
                        ]),
                    )
                })
                .collect(),
        );
        let obj = Json::obj(vec![
            ("backend", Json::Str(backend.as_str().to_string())),
            ("accelerators", accelerators),
        ]);
        println!("{}", obj.to_string_pretty());
    } else {
        println!("Fig. 7(a) — FPS (higher is better, {} backend)\n", backend);
        fps_table.print();
        println!("\nFig. 7(b) — FPS/W (higher is better, {} backend)\n", backend);
        fpsw_table.print();
    }
    0
}

fn fps_table_clone_headers() -> Table {
    Table::new(&[
        "accelerator",
        "vgg_small",
        "resnet18",
        "mobilenet_v2",
        "shufflenet_v2",
        "gmean FPS/W",
    ])
}

fn cmd_simulate(args: &[String]) -> i32 {
    let cmd = Command::new(
        "oxbnn simulate",
        "run one accelerator x workload through the Session facade",
    )
    .opt("accelerator", "OXBNN_50", "OXBNN_5|OXBNN_50|ROBIN_EO|ROBIN_PO|LIGHTBULB")
    .opt("workload", "vgg_small", "vgg_small|resnet18|mobilenet_v2|shufflenet_v2")
    .opt("config", "", "JSON accelerator config file (overrides --accelerator)")
    .opt("workload-file", "", "JSON workload geometry file (overrides --workload)")
    .opt(
        "backend",
        "analytic",
        "analytic|event|functional (event simulates every PASS — slow on full BNNs)",
    )
    .opt("batch", "1", "frames to evaluate back-to-back")
    .opt(
        "pipeline",
        "auto",
        "auto|true|false — whole-frame pipelined batches: cross-layer + multi-frame \
         overlap with receptive-field-exact admission (auto: on when batch > 1)",
    )
    .flag("json", "emit the unified report as JSON")
    .flag("layers", "print per-layer breakdown");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_cli(e),
    };
    let acc = if !parsed.get("config").is_empty() {
        match oxbnn::config::load(parsed.get("config")) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("config error: {}", e);
                return 2;
            }
        }
    } else {
        match AcceleratorConfig::evaluation_set()
            .into_iter()
            .find(|a| a.name == parsed.get("accelerator"))
        {
            Some(a) => a,
            None => {
                eprintln!("unknown accelerator '{}'", parsed.get("accelerator"));
                return 2;
            }
        }
    };
    let workload = if !parsed.get("workload-file").is_empty() {
        match oxbnn::config::load_workload(parsed.get("workload-file")) {
            Ok(w) => w,
            Err(e) => {
                eprintln!("workload config error: {}", e);
                return 2;
            }
        }
    } else {
        match Workload::evaluation_set()
            .into_iter()
            .find(|w| w.name == parsed.get("workload"))
        {
            Some(w) => w,
            None => {
                eprintln!("unknown workload '{}'", parsed.get("workload"));
                return 2;
            }
        }
    };
    let backend = match parse_backend(parsed.get("backend")) {
        Ok(b) => b,
        Err(code) => return code,
    };
    let batch = match parsed.get_usize("batch") {
        Ok(b) => b,
        Err(e) => return handle_cli(e),
    };
    let pipeline = match parse_pipeline(parsed.get("pipeline")) {
        Ok(p) => p,
        Err(code) => return code,
    };
    let mut builder = Session::builder()
        .accelerator(acc)
        .workload(workload)
        .backend(backend)
        .batch(batch);
    if let Some(p) = pipeline {
        builder = builder.pipeline(p);
    }
    let mut session = match builder.build() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {}", e);
            return 2;
        }
    };
    let report = session.run();
    if parsed.has_flag("json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        println!(
            "[{}] {} on {}: frame latency {} → {:.1} FPS, avg power {:.2} W, {:.2} FPS/W",
            report.backend,
            report.accelerator,
            report.workload,
            fmt_time(report.frame_latency_s),
            report.fps,
            report.avg_power_w,
            report.fps_per_w
        );
        println!(
            "  passes {}, psums {}, dynamic energy {:.3e} J/frame",
            report.passes, report.psums, report.dynamic_energy_per_frame_j
        );
        if report.batch > 1 {
            println!(
                "  batch of {} frames{}: {} → {:.1} FPS batched",
                report.batch,
                if report.pipelined { " (pipelined)" } else { "" },
                fmt_time(report.batch_latency_s),
                report.batched_fps()
            );
        }
        if !report.energy_breakdown.is_empty() {
            let parts: Vec<String> = report
                .energy_breakdown
                .iter()
                .map(|(k, v)| format!("{} {:.3e} J", k, v))
                .collect();
            println!("  energy ledger: {}", parts.join(", "));
        }
        if let Some(c) = &report.correctness {
            println!(
                "  functional check: {} VDPs recomputed, {} mismatches, {} PCA clamps",
                c.vdps_checked, c.mismatches, c.pca_clamped
            );
        }
        if parsed.has_flag("layers") {
            let t = |m: &std::collections::BTreeMap<String, f64>, k: &str| {
                m.get(k).map(|v| fmt_time(*v)).unwrap_or_else(|| "-".into())
            };
            let mut tbl = Table::new(&[
                "layer", "latency", "compute", "memory", "reduce", "passes", "psums",
            ]);
            for l in &report.layers {
                tbl.row(&[
                    l.name.clone(),
                    fmt_time(l.latency_s),
                    t(&l.timing, "compute_s"),
                    t(&l.timing, "memory_s"),
                    t(&l.timing, "reduce_s"),
                    format!("{}", l.passes),
                    format!("{}", l.psums),
                ]);
            }
            tbl.print();
        }
    }
    // A functional run that found arithmetic mismatches is a failure.
    match &report.correctness {
        Some(c) if !c.is_clean() => 1,
        _ => 0,
    }
}

fn cmd_oxg(args: &[String]) -> i32 {
    let cmd = Command::new("oxbnn oxg", "OXG device study (paper Fig. 3)")
        .opt("dr", "10", "data rate in GS/s for the transient")
        .opt("bits", "8", "bits per operand stream");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_cli(e),
    };
    let dr: f64 = match parsed.get_f64("dr") {
        Ok(v) => v,
        Err(e) => return handle_cli(e),
    };
    let nbits = parsed.get_usize("bits").unwrap_or(8);
    let gate = Oxg::new(1550.0);
    println!("OXG truth table (through-port transmission at λ_in):");
    for (i, w) in [(false, false), (false, true), (true, false), (true, true)] {
        println!(
            "  i={} w={} → T={:.3} → XNOR bit {}",
            i as u8,
            w as u8,
            gate.transmission(i, w),
            gate.xnor(i, w) as u8
        );
    }
    let mut rng = Rng::new(3);
    let bits_i: Vec<bool> = (0..nbits).map(|_| rng.bool()).collect();
    let bits_w: Vec<bool> = (0..nbits).map(|_| rng.bool()).collect();
    let trace = gate.transient(&bits_i, &bits_w, dr, 16, 3.0);
    let decoded = gate.decode_trace(&trace, 16);
    println!("\ntransient at {} GS/s:", dr);
    println!("  I      = {:?}", bits_i.iter().map(|b| *b as u8).collect::<Vec<_>>());
    println!("  W      = {:?}", bits_w.iter().map(|b| *b as u8).collect::<Vec<_>>());
    println!("  XNOR   = {:?}", decoded.iter().map(|b| *b as u8).collect::<Vec<_>>());
    let ok = decoded
        .iter()
        .zip(bits_i.iter().zip(&bits_w))
        .all(|(d, (a, b))| *d == (a == b));
    println!("  decode {}", if ok { "OK" } else { "FAILED" });
    (!ok) as i32
}

/// Build a ServerConfig from the shared serve/serve-bench options:
/// artifacts dir (synthetic stub model when the manifest is absent),
/// batching policy, bounded queue depth, replicas.
fn server_config_from_args(
    parsed: &oxbnn::util::cli::Parsed,
    model: &str,
) -> Result<ServerConfig, i32> {
    let dir = std::path::PathBuf::from(parsed.get("artifacts"));
    let mut cfg = if dir.join("manifest.json").exists() {
        ServerConfig::new(&dir, &[model])
    } else {
        println!(
            "artifacts manifest missing — serving the synthetic stub model '{}' \
             on the sim engine",
            model
        );
        ServerConfig::synthetic(&[model])
    };
    cfg.max_batch = parsed.get_usize("batch").map_err(handle_cli)?.max(1);
    cfg.policy = parsed.get("policy").parse::<BatchPolicy>().map_err(|e| {
        eprintln!("error: {}", e);
        2
    })?;
    let wait_ms = parsed.get_f64("max-wait-ms").map_err(handle_cli)?;
    cfg.max_wait = std::time::Duration::from_secs_f64((wait_ms / 1e3).max(0.0));
    cfg.queue_depth = parsed.get_usize("queue-depth").map_err(handle_cli)?.max(1);
    cfg.replicas = parsed.get_usize("replicas").map_err(handle_cli)?.max(1);
    // Photonic reference: pipelined batch of max_batch frames (the server
    // batches requests anyway). Default on with the analytic estimate;
    // `event` runs the transaction-level whole-frame event space instead;
    // `false` opts back out to the isolated-frame reference.
    match parsed.get("sim-pipeline") {
        "true" | "on" | "1" | "" => cfg.sim_pipeline = true,
        "false" | "off" | "0" => cfg.sim_pipeline = false,
        "event" => {
            cfg.sim_backend = BackendKind::Event;
            cfg.sim_pipeline = true;
        }
        other => {
            eprintln!("error: --sim-pipeline must be true|false|event, got '{}'", other);
            return Err(2);
        }
    }
    Ok(cfg)
}

fn cmd_serve(args: &[String]) -> i32 {
    let cmd = Command::new("oxbnn serve", "inference server demo over AOT artifacts")
        .opt("artifacts", "artifacts", "artifacts directory (synthetic stub model if missing)")
        .opt("model", "tiny", "model to serve (tiny|small|vgg_small)")
        .opt("requests", "32", "number of requests to issue")
        .opt("batch", "8", "max dynamic batch size")
        .opt("policy", "immediate", "batch-cut policy: immediate|deadline")
        .opt("max-wait-ms", "2", "deadline policy: oldest-request max wait (ms)")
        .opt("queue-depth", "1024", "bounded per-replica queue depth (back-pressure)")
        .opt("replicas", "1", "worker replicas for the model")
        .opt(
            "sim-pipeline",
            "true",
            "true|false|event — pipelined-batch photonic reference (event: \
             transaction-level whole-frame event space)",
        );
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_cli(e),
    };
    let model = parsed.get("model").to_string();
    let cfg = match server_config_from_args(&parsed, &model) {
        Ok(c) => c,
        Err(code) => return code,
    };
    let n = parsed.get_usize("requests").unwrap_or(32);
    let server = match Server::start(cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("server start failed: {:#}", e);
            return 1;
        }
    };
    let input_len = server.input_len(&model).unwrap();
    let mut rng = Rng::new(0xF00D);
    let t0 = std::time::Instant::now();
    let mut ok = 0;
    for _ in 0..n {
        let input: Vec<f32> = (0..input_len).map(|_| rng.f64() as f32 - 0.5).collect();
        match server.infer_blocking(InferenceRequest { model: model.clone(), input }) {
            Ok(resp) => {
                ok += 1;
                oxbnn::log_debug!("logits[0..3]={:?}", &resp.logits[..3.min(resp.logits.len())]);
            }
            Err(e) => eprintln!("request failed: {:#}", e),
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    println!(
        "served {}/{} requests in {:.3}s ({:.1} req/s)",
        ok,
        n,
        elapsed,
        ok as f64 / elapsed
    );
    println!("{}", server.metrics.lock().unwrap().report());
    server.shutdown();
    (ok != n) as i32
}

#[derive(Default)]
struct LoadStats {
    ok: u64,
    failed: u64,
    rejected: u64,
    photonic_s: f64,
}

impl LoadStats {
    fn absorb(&mut self, other: LoadStats) {
        self.ok += other.ok;
        self.failed += other.failed;
        self.rejected += other.rejected;
        if other.photonic_s > 0.0 {
            self.photonic_s = other.photonic_s;
        }
    }
}

fn is_queue_full(e: &anyhow::Error) -> bool {
    matches!(
        e.downcast_ref::<SubmitError>(),
        Some(SubmitError::QueueFull { .. })
    )
}

/// Closed/open-loop load benchmark of the serving coordinator: reports
/// p50/p95/p99 queue/execute/end-to-end latency plus achieved FPS next to
/// the Session-simulated photonic FPS, and verifies the router leaks no
/// outstanding accounting.
fn cmd_serve_bench(args: &[String]) -> i32 {
    let cmd = Command::new(
        "oxbnn serve-bench",
        "closed/open-loop load benchmark of the serving path",
    )
    .opt("artifacts", "artifacts", "artifacts directory (synthetic stub model if missing)")
    .opt("model", "tiny", "model to serve")
    .opt("mode", "closed", "closed (clients issue back-to-back) | open (Poisson arrivals)")
    .opt("concurrency", "32", "client threads")
    .opt("duration", "2", "seconds of load (when --requests is 0)")
    .opt("requests", "0", "total request budget (0 = run for --duration)")
    .opt("rate", "2000", "open mode: target total arrival rate (req/s)")
    .opt("batch", "8", "max dynamic batch size")
    .opt("policy", "immediate", "batch-cut policy: immediate|deadline")
    .opt("max-wait-ms", "2", "deadline policy: oldest-request max wait (ms)")
    .opt("queue-depth", "1024", "bounded per-replica queue depth (back-pressure)")
    .opt("replicas", "1", "worker replicas for the model")
    .opt(
        "sim-pipeline",
        "true",
        "true|false|event — pipelined-batch photonic reference (event: \
         transaction-level whole-frame event space)",
    );
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_cli(e),
    };
    let model = parsed.get("model").to_string();
    let mode = parsed.get("mode").to_string();
    if mode != "closed" && mode != "open" {
        eprintln!("error: --mode must be closed|open, got '{}'", mode);
        return 2;
    }
    let concurrency = parsed.get_usize("concurrency").unwrap_or(32).max(1);
    let duration = parsed.get_f64("duration").unwrap_or(2.0).max(0.01);
    let total_requests = parsed.get_usize("requests").unwrap_or(0);
    let rate = parsed.get_f64("rate").unwrap_or(2000.0).max(1.0);
    let cfg = match server_config_from_args(&parsed, &model) {
        Ok(c) => c,
        Err(code) => return code,
    };
    let (max_batch, policy, queue_depth, replicas) =
        (cfg.max_batch, cfg.policy, cfg.queue_depth, cfg.replicas);
    let (accel_name, sim_backend) = (cfg.accelerator.name.clone(), cfg.sim_backend);
    let server = match Server::start(cfg) {
        Ok(s) => std::sync::Arc::new(s),
        Err(e) => {
            eprintln!("server start failed: {:#}", e);
            return 1;
        }
    };
    let input_len = server.input_len(&model).expect("model registered");
    println!(
        "serve-bench: model={} mode={} concurrency={} max_batch={} policy={} \
         queue_depth={} replicas={}",
        model, mode, concurrency, max_batch, policy, queue_depth, replicas
    );

    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs_f64(duration);
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..concurrency {
        let server = std::sync::Arc::clone(&server);
        let model = model.clone();
        let mode = mode.clone();
        // Per-client request budget (None = run until the deadline). A
        // client whose share rounds to zero must issue nothing.
        let budget = if total_requests > 0 {
            Some(total_requests / concurrency + usize::from(c < total_requests % concurrency))
        } else {
            None
        };
        let client_rate = rate / concurrency as f64;
        handles.push(std::thread::spawn(move || -> LoadStats {
            let mut rng = Rng::new(0xBE7C4 + c as u64);
            let mut stats = LoadStats::default();
            let mut issued = 0usize;
            let mut pending = Vec::new();
            loop {
                match budget {
                    Some(b) if issued >= b => break,
                    Some(_) => {}
                    None if std::time::Instant::now() >= deadline => break,
                    None => {}
                }
                let input: Vec<f32> =
                    (0..input_len).map(|_| rng.f64() as f32 - 0.5).collect();
                let req = InferenceRequest { model: model.clone(), input };
                if mode == "closed" {
                    // Closed loop: at most one in-flight request per client.
                    match server.infer_blocking(req) {
                        Ok(resp) => {
                            issued += 1;
                            stats.ok += 1;
                            stats.photonic_s = resp.simulated_photonic_s;
                        }
                        Err(e) if is_queue_full(&e) => {
                            // Back-pressure: retry shortly WITHOUT consuming
                            // budget — the request was shed, not served.
                            stats.rejected += 1;
                            std::thread::sleep(std::time::Duration::from_micros(200));
                        }
                        Err(_) => {
                            issued += 1;
                            stats.failed += 1;
                        }
                    }
                } else {
                    // Open loop: fire-and-forget at Poisson arrivals,
                    // collect replies at the end. Every arrival — even a
                    // shed one — is one unit of offered load.
                    issued += 1;
                    match server.submit(req) {
                        Ok((_replica, rx)) => pending.push(rx),
                        Err(SubmitError::QueueFull { .. }) => stats.rejected += 1,
                        Err(_) => stats.failed += 1,
                    }
                    // Honest Poisson inter-arrival at the requested rate;
                    // in duration mode, never sleep past the deadline.
                    let mut wait =
                        std::time::Duration::from_secs_f64(rng.exp(client_rate));
                    if budget.is_none() {
                        let remaining = deadline
                            .saturating_duration_since(std::time::Instant::now());
                        wait = wait.min(remaining);
                    }
                    std::thread::sleep(wait);
                }
            }
            for rx in pending {
                match rx.recv() {
                    Ok(Ok(resp)) => {
                        stats.ok += 1;
                        stats.photonic_s = resp.simulated_photonic_s;
                    }
                    _ => stats.failed += 1,
                }
            }
            stats
        }));
    }
    let mut stats = LoadStats::default();
    for h in handles {
        match h.join() {
            Ok(s) => stats.absorb(s),
            Err(_) => eprintln!("client thread panicked"),
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let achieved_fps = stats.ok as f64 / elapsed;
    println!(
        "\ncompleted {} requests in {:.3}s → achieved {:.1} FPS ({} failed, \
         {} rejected by back-pressure)",
        stats.ok, elapsed, achieved_fps, stats.failed, stats.rejected
    );
    if stats.photonic_s > 0.0 {
        let photonic_fps = 1.0 / stats.photonic_s;
        println!(
            "simulated photonic frame ({} / {} backend): {} → {:.1} FPS; \
             serving achieves {:.2}% of photonic",
            accel_name,
            sim_backend,
            fmt_time(stats.photonic_s),
            photonic_fps,
            100.0 * achieved_fps / photonic_fps
        );
    }
    println!("\n{}", server.metrics.lock().unwrap().report());
    // Accounting invariant: every routed request must have completed.
    let mut leaked = 0usize;
    for m in server.models() {
        leaked += server.outstanding(&m);
    }
    println!("router outstanding after drain: {}", leaked);
    match std::sync::Arc::try_unwrap(server) {
        Ok(s) => s.shutdown(),
        Err(_) => unreachable!("clients joined"),
    }
    if leaked > 0 {
        eprintln!("error: router leaked {} outstanding slots", leaked);
        return 1;
    }
    (stats.ok == 0) as i32
}

fn cmd_sweep(args: &[String]) -> i32 {
    let cmd = Command::new(
        "oxbnn sweep",
        "CSV sweep of FPS/FPS-per-W over DR and XPE count (for plotting)",
    )
    .opt("workload", "vgg_small", "workload name")
    .opt("xpes", "100,250,500,1000,2000", "comma-separated XPE counts")
    .opt(
        "backend",
        "analytic",
        "analytic|event|functional (analytic recommended for sweeps)",
    )
    .opt("batch", "1", "frames per cell (pipelined batches report batched FPS)")
    .opt(
        "pipeline",
        "auto",
        "auto|true|false — whole-frame pipelined batches (auto: on when batch > 1)",
    )
    .opt("out", "-", "output CSV path ('-' for stdout)");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_cli(e),
    };
    let Some(workload) = Workload::evaluation_set()
        .into_iter()
        .find(|w| w.name == parsed.get("workload"))
    else {
        eprintln!("unknown workload '{}'", parsed.get("workload"));
        return 2;
    };
    let backend = match parse_backend(parsed.get("backend")) {
        Ok(b) => b,
        Err(code) => return code,
    };
    let batch = match parsed.get_usize("batch") {
        Ok(b) => b.max(1),
        Err(e) => return handle_cli(e),
    };
    let pipeline = match parse_pipeline(parsed.get("pipeline")) {
        Ok(p) => p,
        Err(code) => return code,
    };
    let xpes: Vec<usize> = parsed
        .get("xpes")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    if xpes.is_empty() {
        eprintln!("--xpes must list at least one integer");
        return 2;
    }
    let solver = ScalabilitySolver::default();
    // All (DR × XPE-count) cells run in parallel; each cell is an
    // independent simulation of a distinct accelerator config, so the
    // sweep scales with cores even on the event backend.
    let cells: Vec<(f64, usize, u64, usize)> = solver
        .table2()
        .iter()
        .flat_map(|row| xpes.iter().map(move |&x| (row.dr_gsps, row.n, row.gamma, x)))
        .collect();
    let lines: Vec<String> = parallel_map(cells, host_threads(), |(dr, n, gamma, x)| {
        let cfg = AcceleratorConfig {
            name: format!("OXBNN_{}x{}", dr, x),
            dr_gsps: dr,
            n,
            xpe_total: x,
            bitcount: oxbnn::arch::BitcountMode::Pca { gamma },
            ..AcceleratorConfig::oxbnn_50()
        };
        let mut builder = Session::builder()
            .accelerator(cfg)
            .workload(workload.clone())
            .backend(backend)
            .batch(batch);
        if let Some(p) = pipeline {
            builder = builder.pipeline(p);
        }
        let report = builder.build().expect("sweep session").run();
        format!(
            "{},{},{},{},{:.1},{:.2},{:.2}\n",
            dr, n, gamma, x, report.fps, report.fps_per_w, report.static_power_w
        )
    });
    let mut csv = String::from("dr_gsps,n,gamma,xpe_total,fps,fps_per_w,static_w\n");
    for line in &lines {
        csv.push_str(line);
    }
    if parsed.get("out") == "-" {
        print!("{}", csv);
    } else if let Err(e) = std::fs::write(parsed.get("out"), csv) {
        eprintln!("write failed: {}", e);
        return 1;
    }
    0
}

fn cmd_dump_config(args: &[String]) -> i32 {
    let cmd = Command::new("oxbnn dump-config", "write a built-in accelerator config as JSON")
        .opt("accelerator", "OXBNN_50", "which built-in to dump")
        .opt("out", "-", "output path ('-' for stdout)");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_cli(e),
    };
    let Some(cfg) = oxbnn::config::builtin(parsed.get("accelerator")) else {
        eprintln!("unknown accelerator '{}'", parsed.get("accelerator"));
        return 2;
    };
    let text = oxbnn::config::to_json(&cfg).to_string_pretty();
    if parsed.get("out") == "-" {
        print!("{}", text);
    } else if let Err(e) = std::fs::write(parsed.get("out"), text) {
        eprintln!("write failed: {}", e);
        return 1;
    }
    0
}

fn cmd_info() -> i32 {
    let mut t = Table::new(&[
        "accelerator",
        "DR (GS/s)",
        "N",
        "XPEs",
        "XPCs",
        "tiles",
        "bitcount",
        "static W",
        "area mm^2",
    ]);
    for a in AcceleratorConfig::evaluation_set() {
        t.row(&[
            a.name.clone(),
            format!("{}", a.dr_gsps),
            format!("{}", a.n),
            format!("{}", a.xpe_total),
            format!("{}", a.xpc_count()),
            format!("{}", a.tile_count()),
            match a.bitcount {
                oxbnn::arch::BitcountMode::Pca { gamma } => format!("PCA(γ={})", gamma),
                oxbnn::arch::BitcountMode::Reduction { .. } => "psum-reduction".into(),
            },
            format!("{:.2}", a.static_power_w()),
            format!("{:.1}", a.area_mm2()),
        ]);
    }
    t.print();
    0
}
