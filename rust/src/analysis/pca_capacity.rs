//! PCA accumulation-capacity analysis (γ and α of paper Table II).
//!
//! The paper derived γ (max accumulable '1's within the TIR's 5 V dynamic
//! range) by extracting photodetector current pulses from Lumerical
//! INTERCONNECT and integrating them in a MultiSim TIR model. We provide
//! two sources:
//!
//! * **Calibrated**: the paper's own Table II γ values per data rate —
//!   treated as the MultiSim-extracted calibration (DESIGN.md substitution
//!   table). The system simulator uses these so α = γ/N matches the paper
//!   exactly.
//! * **Analytic**: first-principles charge model δV = gain·i·δt/C from
//!   [`crate::devices::pca::PcaParams`] — used for the ablation bench and
//!   to sanity-check the calibrated values' order of magnitude.

use crate::devices::pca::PcaParams;
use crate::devices::photodetector::Photodetector;
use crate::util::units::{dbm_to_watt, gsps_period_s};

/// Paper Table II: (DR GS/s, P_PD-opt dBm, N, γ, α).
pub const PAPER_TABLE2: [(f64, f64, usize, u64, u64); 7] = [
    (3.0, -24.69, 66, 39_682, 601),
    (5.0, -23.49, 53, 29_761, 561),
    (10.0, -21.9, 39, 19_841, 508),
    (20.0, -20.5, 29, 14_880, 513),
    (30.0, -19.5, 24, 10_822, 450),
    (40.0, -18.9, 21, 9_920, 472),
    (50.0, -18.5, 19, 8_503, 447),
];

/// Calibrated γ for a data rate: looks up the paper's MultiSim-derived
/// value, linearly interpolating between characterized rates (and clamping
/// outside the characterized range).
pub fn gamma_calibrated(dr_gsps: f64) -> u64 {
    let table = &PAPER_TABLE2;
    if dr_gsps <= table[0].0 {
        return table[0].3;
    }
    if dr_gsps >= table[table.len() - 1].0 {
        return table[table.len() - 1].3;
    }
    for w in table.windows(2) {
        let (d0, _, _, g0, _) = w[0];
        let (d1, _, _, g1, _) = w[1];
        if dr_gsps >= d0 && dr_gsps <= d1 {
            let f = (dr_gsps - d0) / (d1 - d0);
            return (g0 as f64 + f * (g1 as f64 - g0 as f64)).round() as u64;
        }
    }
    unreachable!("interpolation table covers the range");
}

/// Analytic γ from the charge model, given the PD-received optical power.
pub fn gamma_analytic(
    params: &PcaParams,
    pd: &Photodetector,
    p_recv_dbm: f64,
    dr_gsps: f64,
) -> u64 {
    let current = pd.current_a(dbm_to_watt(p_recv_dbm));
    params.gamma_analytic(current, gsps_period_s(dr_gsps))
}

/// α = γ / N: how many N-bit XNOR vector slices the PCA absorbs before
/// saturating (paper Section III-B2).
pub fn alpha(gamma: u64, n: usize) -> u64 {
    assert!(n > 0);
    gamma / n as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_matches_paper_rows() {
        for (dr, _, _, gamma, _) in PAPER_TABLE2 {
            assert_eq!(gamma_calibrated(dr), gamma, "DR = {}", dr);
        }
    }

    #[test]
    fn calibrated_interpolates_and_clamps() {
        let mid = gamma_calibrated(7.5);
        assert!(mid < gamma_calibrated(5.0) && mid > gamma_calibrated(10.0));
        assert_eq!(gamma_calibrated(1.0), 39_682);
        assert_eq!(gamma_calibrated(80.0), 8_503);
    }

    #[test]
    fn alpha_matches_paper_rows() {
        // α = floor(γ / N) reproduces the paper's α column exactly.
        for (dr, _, n, gamma, want_alpha) in PAPER_TABLE2 {
            assert_eq!(alpha(gamma, n), want_alpha, "DR = {}", dr);
        }
    }

    #[test]
    fn gamma_decreases_with_datarate() {
        assert!(gamma_calibrated(3.0) > gamma_calibrated(50.0));
        for w in PAPER_TABLE2.windows(2) {
            assert!(w[0].3 > w[1].3);
        }
    }

    #[test]
    fn analytic_gamma_same_order_of_magnitude() {
        // The analytic charge model should land within ~5x of the
        // calibrated MultiSim-derived values (the paper's own extraction
        // includes pulse-shape effects we don't re-simulate).
        let params = PcaParams::default();
        let pd = Photodetector::default();
        for (dr, p_pd, _, gamma, _) in PAPER_TABLE2 {
            // Received power = sensitivity less the network penalty that
            // Eq. 5 budgets between PD and laser.
            let g = gamma_analytic(&params, &pd, p_pd - 4.8, dr);
            let ratio = g as f64 / gamma as f64;
            assert!(
                (0.05..20.0).contains(&ratio),
                "DR {}: analytic {} vs calibrated {} (ratio {:.2})",
                dr,
                g,
                gamma,
                ratio
            );
        }
    }

    #[test]
    fn paper_claim_gamma_covers_modern_cnns() {
        // §IV-C: max XNOR vector size across modern CNNs is S = 4608,
        // below γ = 8503 at DR = 50 GS/s → no psum reduction needed.
        assert!(4608 < gamma_calibrated(50.0));
    }
}
