//! Area-proportionate accelerator scaling analysis (paper Section V-B).
//!
//! "For fair comparison, we perform area proportionate analysis, wherein
//! we altered the XPE count for each photonic BNN accelerator ... to match
//! with the area of OXBNN_5 having 100 XPEs. Accordingly, the scaled XPE
//! counts of OXBNN_50 (N=19), ROBIN_PO (N=50), ROBIN_EO (N=10), and
//! LIGHTBULB (N=16) are 1123, 183, 916, and 1139, respectively."
//!
//! This module checks what model of area those published counts imply.
//! Findings (pinned by the tests below):
//!
//! * **ROBIN_EO vs ROBIN_PO are exactly gate-linear**: 916·10 ≈ 183·50
//!   (9160 vs 9150 gates) — the paper scaled ROBIN by resonator count.
//! * **LIGHTBULB matches ROBIN's resonator population**: 1139·16 = 18224
//!   microdisk-gates vs ROBIN's 9160 two-MRR gates = 18320 resonators —
//!   consistent if a LIGHTBULB gate occupies one microdisk-equivalent.
//! * **OXBNN_50 sits near the same resonator population**: 1123·19 =
//!   21337 single-MRR gates (+16% of 18320).
//! * **The OXBNN_5 anchor is the outlier**: 100·53 = 5300 resonators —
//!   3.5–4× fewer than every other design at the *same* claimed area.
//!   Under any resonator-dominated area model the paper *under-provisions
//!   its own anchor*, which makes OXBNN_5's reported wins conservative
//!   rather than inflated. We therefore keep the published counts in the
//!   evaluation configs (exact reproduction) and expose
//!   [`resonator_population`] so benches can report both views.

/// Resonators (ring/disk count) implied by a (gates/bit, N, XPEs) design.
pub fn resonator_population(resonators_per_gate: f64, n: usize, xpes: usize) -> f64 {
    resonators_per_gate * (n * xpes) as f64
}

/// Published Section V-B counts.
#[derive(Debug, Clone, PartialEq)]
pub struct ScaledCounts {
    pub oxbnn_5: usize,
    pub oxbnn_50: usize,
    pub robin_po: usize,
    pub robin_eo: usize,
    pub lightbulb: usize,
}

pub const PAPER_COUNTS: ScaledCounts = ScaledCounts {
    oxbnn_5: 100,
    oxbnn_50: 1123,
    robin_po: 183,
    robin_eo: 916,
    lightbulb: 1139,
};

/// Resonator populations of the five published configurations.
/// (OXBNN: 1 MRR/gate; ROBIN: 2 MRRs/gate; LIGHTBULB: 1 microdisk-pair
/// footprint treated as one resonator-equivalent per gate.)
pub fn paper_populations() -> [(&'static str, f64); 5] {
    [
        ("OXBNN_5", resonator_population(1.0, 53, PAPER_COUNTS.oxbnn_5)),
        ("OXBNN_50", resonator_population(1.0, 19, PAPER_COUNTS.oxbnn_50)),
        ("ROBIN_EO", resonator_population(2.0, 10, PAPER_COUNTS.robin_eo)),
        ("ROBIN_PO", resonator_population(2.0, 50, PAPER_COUNTS.robin_po)),
        ("LIGHTBULB", resonator_population(1.0, 16, PAPER_COUNTS.lightbulb)),
    ]
}

/// XPE count for a design (gates/bit g, XPE size n) that matches a target
/// resonator population — the scaling rule the non-anchor counts follow.
pub fn xpes_for_population(resonators_per_gate: f64, n: usize, target: f64) -> usize {
    (target / (resonators_per_gate * n as f64)).round() as usize
}

/// Re-derive the non-anchor counts from ROBIN_EO's population (the
/// cleanest published pair), reproducing the paper's numbers within 17%.
pub fn derive_from_resonator_parity() -> ScaledCounts {
    let target = resonator_population(2.0, 10, PAPER_COUNTS.robin_eo);
    ScaledCounts {
        oxbnn_5: PAPER_COUNTS.oxbnn_5, // the anchor is taken as published
        oxbnn_50: xpes_for_population(1.0, 19, target),
        robin_po: xpes_for_population(2.0, 50, target),
        robin_eo: PAPER_COUNTS.robin_eo,
        lightbulb: xpes_for_population(1.0, 16, target),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn robin_variants_are_gate_linear() {
        let eo = 10 * PAPER_COUNTS.robin_eo;
        let po = 50 * PAPER_COUNTS.robin_po;
        let rel = (eo as f64 - po as f64).abs() / po as f64;
        assert!(rel < 0.002, "EO {} vs PO {} gates", eo, po);
    }

    #[test]
    fn non_anchor_designs_share_resonator_population() {
        let pops = paper_populations();
        let robin_eo = pops[2].1;
        for (name, pop) in &pops[1..] {
            let rel = (pop - robin_eo).abs() / robin_eo;
            assert!(
                rel < 0.17,
                "{}: population {} vs ROBIN_EO {} ({:.0}% off)",
                name,
                pop,
                robin_eo,
                rel * 100.0
            );
        }
    }

    #[test]
    fn anchor_is_underprovisioned() {
        // OXBNN_5 has 3.5-4x fewer resonators than the designs it is
        // compared against — its published wins are conservative.
        let pops = paper_populations();
        let anchor = pops[0].1;
        for (name, pop) in &pops[1..] {
            assert!(
                pop / anchor > 3.0,
                "{}: {} vs anchor {}",
                name,
                pop,
                anchor
            );
        }
    }

    #[test]
    fn parity_derivation_close_to_paper() {
        let got = derive_from_resonator_parity();
        let pairs = [
            (got.oxbnn_50, PAPER_COUNTS.oxbnn_50, "OXBNN_50"),
            (got.robin_po, PAPER_COUNTS.robin_po, "ROBIN_PO"),
            (got.lightbulb, PAPER_COUNTS.lightbulb, "LIGHTBULB"),
        ];
        for (got, paper, name) in pairs {
            let rel = (got as f64 - paper as f64).abs() / paper as f64;
            assert!(
                rel < 0.17,
                "{}: derived {} vs paper {} ({:.0}% off)",
                name,
                got,
                paper,
                rel * 100.0
            );
        }
    }

    #[test]
    fn evaluation_set_uses_paper_counts() {
        use crate::arch::accelerator::AcceleratorConfig;
        let set = AcceleratorConfig::evaluation_set();
        let by_name = |n: &str| set.iter().find(|a| a.name == n).unwrap().xpe_total;
        assert_eq!(by_name("OXBNN_5"), PAPER_COUNTS.oxbnn_5);
        assert_eq!(by_name("OXBNN_50"), PAPER_COUNTS.oxbnn_50);
        assert_eq!(by_name("ROBIN_PO"), PAPER_COUNTS.robin_po);
        assert_eq!(by_name("ROBIN_EO"), PAPER_COUNTS.robin_eo);
        assert_eq!(by_name("LIGHTBULB"), PAPER_COUNTS.lightbulb);
    }
}
