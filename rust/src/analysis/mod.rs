//! Scalability and capacity analysis (paper Section IV → Table II).

pub mod area_scaling;
pub mod pca_capacity;
pub mod pca_resolution;
pub mod scalability;

pub use pca_capacity::{alpha, gamma_calibrated, PAPER_TABLE2};
pub use scalability::{ScalabilitySolver, Table2Row};
