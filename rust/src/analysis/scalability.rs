//! XPC scalability analysis (paper Section IV-A → Table II).
//!
//! Chains the receiver-sensitivity solve (Eqs. 3–4, in
//! [`crate::devices::photodetector`]) with the optical loss budget
//! (Eq. 5, in [`crate::devices::laser`]) to produce, per data rate:
//! the minimum PD power `P_PD-opt`, the feasible XPE size `N`, the PCA
//! capacity `γ`, and the slice capacity `α = γ/N`.

use crate::analysis::pca_capacity::{alpha, gamma_calibrated, PAPER_TABLE2};
use crate::devices::laser::LossBudget;
use crate::devices::photodetector::Photodetector;
use crate::util::units::watt_to_dbm;

/// Bit precision processed by the XPC; binarized vectors → B = 1.
pub const BNN_BITS: f64 = 1.0;
/// OOK average-vs-peak sensitivity margin (×2 in optical power). See
/// `Photodetector::min_power_w`; calibrated against paper Table II.
pub const OOK_MARGIN: f64 = 2.0;
/// Paper spectral assumptions: FSR and inter-wavelength gap (nm).
pub const FSR_NM: f64 = 50.0;
pub const WAVELENGTH_GAP_NM: f64 = 0.7;

/// One row of the scalability table.
#[derive(Debug, Clone, PartialEq)]
pub struct Table2Row {
    pub dr_gsps: f64,
    pub p_pd_opt_dbm: f64,
    pub n: usize,
    pub gamma: u64,
    pub alpha: u64,
}

/// Configuration for the solver (device + budget models).
#[derive(Debug, Clone, Default)]
pub struct ScalabilitySolver {
    pub pd: Photodetector,
    pub budget: LossBudget,
}

impl ScalabilitySolver {
    /// Solve one data rate.
    pub fn solve(&self, dr_gsps: f64) -> Table2Row {
        let p_w = self.pd.min_power_w(BNN_BITS, dr_gsps * 1e9, OOK_MARGIN);
        let p_dbm = watt_to_dbm(p_w);
        let n = self.budget.max_n(p_dbm);
        let n_spectral = self.max_n_spectral();
        let n = n.min(n_spectral);
        let gamma = gamma_calibrated(dr_gsps);
        Table2Row {
            dr_gsps,
            p_pd_opt_dbm: p_dbm,
            n,
            gamma,
            alpha: alpha(gamma, n.max(1)),
        }
    }

    /// Spectral cap: all N wavelengths must fit in one FSR at the chosen
    /// inter-wavelength gap (paper verifies N = 66 < 50 nm / 0.7 nm).
    pub fn max_n_spectral(&self) -> usize {
        (FSR_NM / WAVELENGTH_GAP_NM).floor() as usize
    }

    /// Regenerate the full Table II for the paper's data-rate sweep.
    pub fn table2(&self) -> Vec<Table2Row> {
        PAPER_TABLE2
            .iter()
            .map(|&(dr, ..)| self.solve(dr))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_reproduces_paper_n_within_one() {
        // With our first-principles P_PD-opt solve, N matches the paper on
        // 6 of 7 rows and is within ±1 on the remaining row (DR=10; the
        // paper's own P value there is rounded to 3 significant digits).
        let solver = ScalabilitySolver::default();
        let mut exact = 0;
        for (row, &(dr, _, n_paper, ..)) in
            solver.table2().iter().zip(PAPER_TABLE2.iter())
        {
            assert_eq!(row.dr_gsps, dr);
            assert!(
                (row.n as i64 - n_paper as i64).abs() <= 1,
                "DR {}: N = {} vs paper {}",
                dr,
                row.n,
                n_paper
            );
            if row.n == n_paper {
                exact += 1;
            }
        }
        assert!(exact >= 6, "only {}/7 rows exact", exact);
    }

    #[test]
    fn table2_p_pd_within_tolerance() {
        let solver = ScalabilitySolver::default();
        for (row, &(dr, p_paper, ..)) in
            solver.table2().iter().zip(PAPER_TABLE2.iter())
        {
            assert!(
                (row.p_pd_opt_dbm - p_paper).abs() < 0.15,
                "DR {}: {:.2} dBm vs paper {} dBm",
                dr,
                row.p_pd_opt_dbm,
                p_paper
            );
        }
    }

    #[test]
    fn n_monotone_decreasing_in_dr() {
        let solver = ScalabilitySolver::default();
        let rows = solver.table2();
        for w in rows.windows(2) {
            assert!(w[0].n >= w[1].n);
            assert!(w[0].p_pd_opt_dbm < w[1].p_pd_opt_dbm);
        }
    }

    #[test]
    fn spectral_cap_applies() {
        let solver = ScalabilitySolver::default();
        assert_eq!(solver.max_n_spectral(), 71);
        // Paper: max N = 66 fits within the FSR.
        assert!(solver.solve(3.0).n <= 71);
    }

    #[test]
    fn alpha_consistent_with_gamma_and_n() {
        let solver = ScalabilitySolver::default();
        for row in solver.table2() {
            assert_eq!(row.alpha, row.gamma / row.n as u64);
        }
    }
}
