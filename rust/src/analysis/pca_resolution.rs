//! PCA analog count-resolution analysis.
//!
//! The PCA represents a bitcount as an analog voltage with quantum
//! δV = V_range/γ per '1'. For the comparator decision (and any future
//! multi-bit readout) to be meaningful, that quantum must clear the
//! integrator's noise floor. This module checks the paper's Table II γ
//! design points against the dominant noise terms:
//!
//! * kTC (reset) noise of the integration capacitor: σ = √(kT/C), the
//!   irreducible sampled-charge noise, referred to the TIR output through
//!   the same gain as the signal;
//! * comparator input-referred offset/noise (σ_cmp, ~1 mV class).
//!
//! A count quantum is "resolvable" when δV > k_margin · σ_total — the
//! criterion bounding how large γ could grow before single-count
//! information drowns; the comparator-only use of the paper (threshold at
//! 0.5·S) needs far less margin, which the tests also verify.

use crate::devices::pca::PcaParams;
use crate::util::units::BOLTZMANN;

/// Noise model for the PCA readout chain.
#[derive(Debug, Clone)]
pub struct PcaNoise {
    /// Absolute temperature (K).
    pub temperature_k: f64,
    /// Comparator input-referred noise + offset sigma (V).
    pub sigma_comparator_v: f64,
}

impl Default for PcaNoise {
    fn default() -> Self {
        PcaNoise { temperature_k: 300.0, sigma_comparator_v: 1e-3 }
    }
}

impl PcaNoise {
    /// kTC noise at the capacitor, referred to the TIR output (V).
    pub fn ktc_output_v(&self, params: &PcaParams) -> f64 {
        (BOLTZMANN * self.temperature_k / params.capacitance_f).sqrt() * params.gain
    }

    /// Total output-referred sigma (V).
    pub fn sigma_total_v(&self, params: &PcaParams) -> f64 {
        let ktc = self.ktc_output_v(params);
        (ktc * ktc + self.sigma_comparator_v * self.sigma_comparator_v).sqrt()
    }

    /// Voltage quantum of one '1' at capacity γ.
    pub fn count_quantum_v(&self, params: &PcaParams, gamma: u64) -> f64 {
        params.v_range / gamma as f64
    }

    /// Largest γ at which a single count still clears `k_margin` sigmas.
    pub fn max_gamma_for_unit_resolution(&self, params: &PcaParams, k_margin: f64) -> u64 {
        (params.v_range / (k_margin * self.sigma_total_v(params))).floor() as u64
    }

    /// Sigma of the *count* error at the comparator decision for a vector
    /// of size S mapped onto capacity γ (how many counts of uncertainty
    /// the analog chain adds to the 0.5·S threshold decision).
    pub fn count_sigma(&self, params: &PcaParams, gamma: u64) -> f64 {
        self.sigma_total_v(params) / self.count_quantum_v(params, gamma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::pca_capacity::PAPER_TABLE2;

    #[test]
    fn ktc_noise_magnitude() {
        // √(kT/C) at 10 pF, 300 K ≈ 20.3 µV; ×50 gain ≈ 1.02 mV.
        let n = PcaNoise::default();
        let p = PcaParams::default();
        let v = n.ktc_output_v(&p);
        assert!((v - 1.02e-3).abs() < 0.05e-3, "ktc out {}", v);
    }

    #[test]
    fn pca_is_a_thresholder_not_a_counter_at_paper_gammas() {
        // Honest finding: at the published capacities, one count's
        // quantum (5 V / γ ≈ 0.13–0.59 mV) sits BELOW 3σ of the analog
        // noise (σ_total ≈ 1.4 mV) — unit-resolution would cap γ near
        // ~1.2k. The paper's PCA therefore works as the *comparator* it
        // is used as (V_REF = 0.5·range), not as an exact digital
        // counter. Both facts are pinned here.
        let n = PcaNoise::default();
        let p = PcaParams::default();
        let max_gamma = n.max_gamma_for_unit_resolution(&p, 3.0);
        assert!((800..2000).contains(&(max_gamma as i64)), "bound {}", max_gamma);
        for (dr, _, _, gamma, _) in PAPER_TABLE2 {
            assert!(
                gamma > max_gamma,
                "DR {}: paper gamma {} unexpectedly unit-resolvable (bound {})",
                dr,
                gamma,
                max_gamma
            );
        }
    }

    #[test]
    fn comparator_decision_noise_small_vs_typical_margins() {
        // compare(z, 0.5·S) on random binarized data: |z − S/2| has
        // sigma 0.5·√S ≈ 34 counts at S = 4608; the analog chain adds
        // only ~2.4 counts of noise at γ = 8503 (DR = 50) and ~11 at the
        // worst case γ = 39682 — well under the data-driven margin.
        let n = PcaNoise::default();
        let p = PcaParams::default();
        let data_sigma = 0.5 * (4608f64).sqrt();
        let analog_50 = n.count_sigma(&p, 8503);
        let analog_3 = n.count_sigma(&p, 39_682);
        assert!(analog_50 < 3.0, "count sigma {}", analog_50);
        assert!(analog_3 < 12.0, "count sigma {}", analog_3);
        assert!(analog_3 < data_sigma / 2.0);
    }

    #[test]
    fn bigger_capacitor_trades_gamma_headroom() {
        // C↑ lowers kTC noise → higher resolvable gamma (design knob).
        let n = PcaNoise::default();
        let small = PcaParams { capacitance_f: 1e-12, ..PcaParams::default() };
        let big = PcaParams { capacitance_f: 100e-12, ..PcaParams::default() };
        assert!(
            n.max_gamma_for_unit_resolution(&big, 3.0)
                > n.max_gamma_for_unit_resolution(&small, 3.0)
        );
    }
}
