//! PJRT execution client: load AOT HLO-text artifacts, compile them once on
//! the CPU PJRT backend, and execute them from the rust hot path.
//!
//! Python is never on the request path — the artifacts were produced once
//! by `make artifacts`; this module is the only component that touches XLA
//! at runtime.  Pattern follows /opt/xla-example/load_hlo (HLO *text*
//! interchange; `return_tuple=True` on the python side so results unwrap
//! with `to_tuple1`).
//!
//! # Engines
//!
//! [`Runtime`] fronts one of two engines:
//!
//! * **PJRT** (`--features xla-runtime` + the `xla` crate): compiles the
//!   artifact's HLO text and dispatches on the CPU PJRT device.
//! * **Sim** (the offline default): a functional interpreter over the
//!   manifest geometry — `bnn_forward` artifacts evaluate through
//!   [`crate::functional::bnn`], `xnor_gemm` artifacts through the same
//!   arithmetic the Pallas kernel lowers to. Bit-exact with the PJRT
//!   path by construction, so the serving stack, benches and tests run
//!   everywhere. Each dispatch charges a small fixed overhead
//!   ([`SIM_DISPATCH_OVERHEAD`]) emulating the real per-invocation launch
//!   cost, which is what batched execution amortizes.
//!
//! Both engines support a leading batch dimension via
//! [`Runtime::load_artifact_batched`]: N frames stack into one argument,
//! one upload, ONE executable invocation (counted by
//! [`super::xla_stub::executable_invocations`]).

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

// Offline builds (the default) bind the PJRT names to the in-repo stub;
// with `--features xla-runtime` (plus the `xla` dependency) the same paths
// resolve to the real crate. See rust/src/runtime/xla_stub.rs.
#[cfg(not(feature = "xla-runtime"))]
use super::xla_stub as xla;

use super::manifest::Artifact;
use super::xla_stub::record_invocation;
use crate::functional::packed::{self, PackedMatrix};
use crate::functional::FunctionalMode;
use crate::util::threadpool::{host_threads, parallel_map};

/// Fixed per-dispatch overhead charged by the sim engine, emulating the
/// host-side launch cost (buffer hand-off, executable dispatch, result
/// fetch) a real PJRT invocation pays. This is the fixed cost that true
/// batching amortizes: N frames in one invocation pay it once, N separate
/// invocations pay it N times — mirroring the measured PJRT behaviour the
/// serving layer's batch path exists to exploit.
pub const SIM_DISPATCH_OVERHEAD: std::time::Duration =
    std::time::Duration::from_micros(50);

/// A host-side f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<HostTensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} implies {} elements, got {}", shape, n, data.len());
        }
        Ok(HostTensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor { shape, data: vec![0.0; n] }
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// 2-D accessor (row-major).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }
}

// Which engine a Runtime / Executable / DeviceTensor belongs to. Variant
// liveness depends on the `xla-runtime` feature (PJRT variants are never
// constructed offline; Sim is never constructed with a real PJRT client).
#[allow(dead_code)]
enum RuntimeImpl {
    Pjrt(xla::PjRtClient),
    Sim,
}

/// Wraps the process-wide PJRT CPU client (or the offline sim engine).
pub struct Runtime {
    imp: RuntimeImpl,
    /// Pack meter: how many weight tensors uploaded through this runtime
    /// have been bit-packed. Reloading an artifact builds a fresh
    /// runtime + tensors, so the meter makes "a reload repacks exactly
    /// once" deterministically assertable (unlike the global invocation
    /// counter, which other test threads also bump).
    packs: Arc<AtomicU64>,
}

#[allow(dead_code)]
enum TensorRepr {
    Pjrt(xla::PjRtBuffer),
    Host(Vec<f32>),
}

/// A tensor resident on the execution device (pre-staged weights stay here
/// so the hot path never re-converts them — EXPERIMENTS.md §Perf L3).
pub struct DeviceTensor {
    repr: TensorRepr,
    pub shape: Vec<usize>,
    /// Bit-packed view of this tensor as a (S, K) weight matrix, built at
    /// most once per tensor (first use or eager staging) and shared by
    /// every later dispatch. Caching on the tensor itself — rather than
    /// keying an external map by data pointer — means a reloaded artifact
    /// (new tensors) naturally repacks exactly once and a dropped tensor
    /// can never alias a stale entry.
    packed: OnceLock<Arc<PackedMatrix>>,
    /// The owning runtime's pack meter.
    packs: Arc<AtomicU64>,
}

impl DeviceTensor {
    /// The packed (S, K) weight-matrix view of this tensor, built on
    /// first use and cached for the tensor's lifetime (sim engine only).
    pub fn packed_matrix(&self, s: usize, k: usize) -> Result<Arc<PackedMatrix>> {
        let data = match &self.repr {
            TensorRepr::Host(data) => data,
            TensorRepr::Pjrt(_) => bail!(
                "packed weights are a sim-engine cache; PJRT buffers stay on device"
            ),
        };
        let m = self.packed.get_or_init(|| {
            self.packs.fetch_add(1, Ordering::Relaxed);
            Arc::new(PackedMatrix::pack(data, s, k))
        });
        if (m.s(), m.k()) != (s, k) {
            bail!(
                "tensor packed as ({}, {}) cannot be reused as ({}, {})",
                m.s(),
                m.k(),
                s,
                k
            );
        }
        Ok(Arc::clone(m))
    }
}

#[allow(dead_code)]
enum ExecImpl {
    Pjrt(xla::PjRtLoadedExecutable),
    /// Functional interpreter over the artifact's manifest geometry.
    Sim(Artifact),
}

/// One compiled executable (an AOT artifact after `client.compile`, or a
/// sim-engine program). `batch` is the leading batch dimension it was
/// built for: one invocation evaluates `batch` frames.
pub struct Executable {
    imp: ExecImpl,
    pub name: String,
    pub arg_shapes: Vec<Vec<usize>>,
    pub output_shape: Vec<usize>,
    /// Frames evaluated per invocation (leading batch dimension).
    pub batch: usize,
    /// Wall-clock spent in compile (for EXPERIMENTS.md §Perf accounting).
    pub compile_seconds: f64,
    /// Which functional implementation the sim engine dispatches
    /// `bnn_forward` artifacts to (ignored by PJRT and `xnor_gemm`).
    mode: FunctionalMode,
}

impl Runtime {
    /// Create the CPU PJRT client (with `--features xla-runtime`), or the
    /// offline sim engine otherwise.
    #[cfg(feature = "xla-runtime")]
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            imp: RuntimeImpl::Pjrt(client),
            packs: Arc::new(AtomicU64::new(0)),
        })
    }

    /// Create the CPU PJRT client (with `--features xla-runtime`), or the
    /// offline sim engine otherwise.
    #[cfg(not(feature = "xla-runtime"))]
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { imp: RuntimeImpl::Sim, packs: Arc::new(AtomicU64::new(0)) })
    }

    /// True when this runtime is the offline functional sim engine.
    pub fn is_sim(&self) -> bool {
        matches!(self.imp, RuntimeImpl::Sim)
    }

    /// How many weight tensors uploaded through this runtime have been
    /// bit-packed (each tensor packs at most once, ever).
    pub fn weight_packs(&self) -> u64 {
        self.packs.load(Ordering::Relaxed)
    }

    pub fn platform(&self) -> String {
        match &self.imp {
            RuntimeImpl::Pjrt(client) => client.platform_name(),
            RuntimeImpl::Sim => "sim-functional".to_string(),
        }
    }

    pub fn device_count(&self) -> usize {
        match &self.imp {
            RuntimeImpl::Pjrt(client) => client.device_count(),
            RuntimeImpl::Sim => 1,
        }
    }

    /// Load an HLO-text file and compile it (PJRT engine only; the sim
    /// engine interprets manifest geometry and has no HLO parser).
    pub fn load_hlo_text(
        &self,
        path: impl AsRef<Path>,
        name: &str,
        arg_shapes: Vec<Vec<usize>>,
        output_shape: Vec<usize>,
    ) -> Result<Executable> {
        let path = path.as_ref();
        let client = match &self.imp {
            RuntimeImpl::Pjrt(client) => client,
            RuntimeImpl::Sim => bail!(
                "the sim engine executes manifest artifacts only (no HLO \
                 parser) — use load_artifact for {}",
                path.display()
            ),
        };
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            imp: ExecImpl::Pjrt(exe),
            name: name.to_string(),
            arg_shapes,
            output_shape,
            batch: 1,
            compile_seconds: t0.elapsed().as_secs_f64(),
            mode: FunctionalMode::default(),
        })
    }

    /// Upload a host tensor to the device once; reuse across executes.
    pub fn to_device(&self, t: &HostTensor) -> Result<DeviceTensor> {
        let repr = match &self.imp {
            RuntimeImpl::Pjrt(client) => TensorRepr::Pjrt(
                client
                    .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                    .context("host->device transfer")?,
            ),
            RuntimeImpl::Sim => TensorRepr::Host(t.data.clone()),
        };
        Ok(DeviceTensor {
            repr,
            shape: t.shape.clone(),
            packed: OnceLock::new(),
            packs: Arc::clone(&self.packs),
        })
    }

    /// Load an artifact described by the manifest (batch = 1).
    pub fn load_artifact(&self, artifact: &Artifact) -> Result<Executable> {
        self.load_artifact_batched(artifact, 1)
    }

    /// Load an artifact with a leading batch dimension of `batch` frames:
    /// argument 0 and the output get their leading dim scaled from 1 to
    /// `batch`; weights are unchanged. One `run`/`run_device` call then
    /// evaluates the whole batch in a single invocation.
    ///
    /// The PJRT engine compiles fixed-shape AOT artifacts, so it only
    /// supports `batch == 1` today (callers fall back to per-frame
    /// dispatch); the sim engine supports any batch. The functional mode
    /// comes from the environment (`OXBNN_FUNCTIONAL`); callers that must
    /// control it explicitly use [`Runtime::load_artifact_batched_mode`].
    pub fn load_artifact_batched(
        &self,
        artifact: &Artifact,
        batch: usize,
    ) -> Result<Executable> {
        self.load_artifact_batched_mode(artifact, batch, FunctionalMode::from_env())
    }

    /// [`Runtime::load_artifact_batched`] with an explicit functional
    /// mode for the sim engine's `bnn_forward` dispatch (packed XNOR +
    /// popcount vs the f32 reference).
    pub fn load_artifact_batched_mode(
        &self,
        artifact: &Artifact,
        batch: usize,
        mode: FunctionalMode,
    ) -> Result<Executable> {
        if batch == 0 {
            bail!("{}: batch must be >= 1", artifact.name);
        }
        let mut arg_shapes: Vec<Vec<usize>> =
            artifact.args.iter().map(|a| a.shape.clone()).collect();
        let mut output_shape = artifact.output_shape.clone();
        if batch > 1 {
            if artifact.kind != "bnn_forward" {
                bail!(
                    "{}: batched execution supports bnn_forward artifacts, \
                     not '{}'",
                    artifact.name,
                    artifact.kind
                );
            }
            if arg_shapes[0].first() != Some(&1) || output_shape.first() != Some(&1) {
                bail!(
                    "{}: artifact lacks a leading batch-1 dimension to scale",
                    artifact.name
                );
            }
            arg_shapes[0][0] = batch;
            output_shape[0] = batch;
        }
        match &self.imp {
            RuntimeImpl::Pjrt(_) => {
                if batch > 1 {
                    bail!(
                        "{}: AOT HLO is compiled for batch=1; re-export a \
                         batched artifact to use batch={} on PJRT",
                        artifact.name,
                        batch
                    );
                }
                self.load_hlo_text(
                    &artifact.file,
                    &artifact.name,
                    arg_shapes,
                    output_shape,
                )
            }
            RuntimeImpl::Sim => Ok(Executable {
                imp: ExecImpl::Sim(artifact.clone()),
                name: artifact.name.clone(),
                arg_shapes,
                output_shape,
                batch,
                compile_seconds: 0.0,
                mode,
            }),
        }
    }
}

/// Below this much per-frame GEMM work (Σ H·S·K over layers), batched
/// dispatch stays sequential: scoped-thread spawn + hand-off costs more
/// than the frames themselves for the tiny synthetic serving models.
const SIM_PARALLEL_MIN_OPS: usize = 1_000_000;

/// Split `batch` stacked frames out of argument 0.
fn sim_frames<'a>(artifact: &Artifact, batch: usize, arg0: &'a [f32]) -> Vec<&'a [f32]> {
    let frame_len = artifact.args[0].element_count();
    (0..batch).map(|f| &arg0[f * frame_len..(f + 1) * frame_len]).collect()
}

/// Per-frame GEMM work of one forward pass (decides batch fan-out).
fn sim_frame_ops(artifact: &Artifact) -> usize {
    artifact.layers.iter().map(|l| l.h * l.s * l.k).sum()
}

/// Evaluate a `bnn_forward` artifact on the packed XNOR-popcount path:
/// weights arrive already packed (from the per-tensor staging cache or a
/// transient pack), frames fan across the threadpool when the batch is
/// worth it.
fn sim_execute_bnn_packed(
    artifact: &Artifact,
    batch: usize,
    arg0: &[f32],
    weights: &[&PackedMatrix],
) -> Vec<f32> {
    // Charge the per-invocation dispatch overhead once per call (see
    // SIM_DISPATCH_OVERHEAD) so invocation-count effects are observable.
    std::thread::sleep(SIM_DISPATCH_OVERHEAD);
    let frames = sim_frames(artifact, batch, arg0);
    let outs: Vec<Vec<f32>> = if batch > 1 && sim_frame_ops(artifact) >= SIM_PARALLEL_MIN_OPS {
        parallel_map(frames, host_threads(), |x| {
            packed::forward_packed(artifact, x, weights)
        })
    } else {
        let mut scratch = packed::Scratch::default();
        frames
            .into_iter()
            .map(|x| packed::forward_packed_with(artifact, x, weights, &mut scratch))
            .collect()
    };
    outs.into_iter().flatten().collect()
}

/// Evaluate a sim-engine program: `args[i]` is the raw data of positional
/// argument i (argument 0 carries `batch` stacked frames). `bnn_forward`
/// artifacts run the f32 reference here; the packed default goes through
/// [`sim_execute_bnn_packed`].
fn sim_execute(artifact: &Artifact, batch: usize, args: &[&[f32]]) -> Result<Vec<f32>> {
    // Charge the per-invocation dispatch overhead once per call (see
    // SIM_DISPATCH_OVERHEAD) so invocation-count effects are observable.
    std::thread::sleep(SIM_DISPATCH_OVERHEAD);
    match artifact.kind.as_str() {
        "bnn_forward" => {
            // Weight slices are borrowed straight from the staged device
            // tensors — no per-dispatch copies.
            let weights = &args[1..];
            let frames = sim_frames(artifact, batch, args[0]);
            let outs: Vec<Vec<f32>> =
                if batch > 1 && sim_frame_ops(artifact) >= SIM_PARALLEL_MIN_OPS {
                    parallel_map(frames, host_threads(), |x| {
                        crate::functional::bnn::forward(artifact, x, weights)
                    })
                } else {
                    let mut scratch = crate::functional::bnn::Scratch::default();
                    frames
                        .into_iter()
                        .map(|x| {
                            crate::functional::bnn::forward_with(
                                artifact,
                                x,
                                weights,
                                &mut scratch,
                            )
                        })
                        .collect()
                };
            Ok(outs.into_iter().flatten().collect())
        }
        "xnor_gemm" => {
            // Same arithmetic the Pallas kernel lowers to:
            // count = Σ a·b + (1-a)(1-b), optionally fused comparator.
            let h = artifact.args[0].shape[0];
            let s = artifact.args[0].shape[1];
            let k = artifact.args[1].shape[1];
            let apply = artifact.apply_activation.unwrap_or(false);
            let (inputs, weights) = (args[0], args[1]);
            let mut out = vec![0.0f32; h * k];
            for i in 0..h {
                for j in 0..k {
                    let mut count = 0.0f32;
                    for t in 0..s {
                        let a = inputs[i * s + t];
                        let b = weights[t * k + j];
                        count += a * b + (1.0 - a) * (1.0 - b);
                    }
                    out[i * k + j] = if apply {
                        if count > 0.5 * s as f32 {
                            1.0
                        } else {
                            0.0
                        }
                    } else {
                        count
                    };
                }
            }
            Ok(out)
        }
        other => bail!(
            "{}: sim engine cannot interpret artifact kind '{}'",
            artifact.name,
            other
        ),
    }
}

impl Executable {
    /// Which functional implementation sim-engine `bnn_forward` dispatch
    /// uses.
    pub fn mode(&self) -> FunctionalMode {
        self.mode
    }

    fn check_args(&self, shapes: &[&Vec<usize>]) -> Result<()> {
        if shapes.len() != self.arg_shapes.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.name,
                self.arg_shapes.len(),
                shapes.len()
            );
        }
        for (i, (got, want)) in shapes.iter().zip(&self.arg_shapes).enumerate() {
            if *got != want {
                bail!(
                    "{}: arg {} shape {:?} != manifest {:?}",
                    self.name,
                    i,
                    got,
                    want
                );
            }
        }
        Ok(())
    }

    fn check_output(&self, data: &[f32]) -> Result<()> {
        let expect: usize = self.output_shape.iter().product();
        if data.len() != expect {
            bail!(
                "{}: output has {} elements, manifest says {:?}",
                self.name,
                data.len(),
                self.output_shape
            );
        }
        Ok(())
    }

    /// Execute with positional f32 host tensors; returns the single
    /// (tupled) output as a host tensor. One call = one invocation.
    pub fn run(&self, args: &[HostTensor]) -> Result<HostTensor> {
        let shapes: Vec<&Vec<usize>> = args.iter().map(|a| &a.shape).collect();
        self.check_args(&shapes)?;
        record_invocation();
        let data = match &self.imp {
            ExecImpl::Sim(artifact) => {
                let raw: Vec<&[f32]> = args.iter().map(|a| a.data.as_slice()).collect();
                if self.mode == FunctionalMode::Packed && artifact.kind == "bnn_forward" {
                    // Host-tensor path has no staged tensors to cache on:
                    // pack transiently (O(S·K) bit writes, negligible next
                    // to the O(H·S·K) forward pass it feeds).
                    let mats: Vec<PackedMatrix> = artifact
                        .layers
                        .iter()
                        .zip(&raw[1..])
                        .map(|(dim, w)| PackedMatrix::pack(w, dim.s, dim.k))
                        .collect();
                    let refs: Vec<&PackedMatrix> = mats.iter().collect();
                    sim_execute_bnn_packed(artifact, self.batch, raw[0], &refs)
                } else {
                    sim_execute(artifact, self.batch, &raw)?
                }
            }
            ExecImpl::Pjrt(exe) => {
                let mut literals = Vec::with_capacity(args.len());
                for (i, arg) in args.iter().enumerate() {
                    let dims: Vec<i64> = arg.shape.iter().map(|&d| d as i64).collect();
                    let lit = xla::Literal::vec1(&arg.data)
                        .reshape(&dims)
                        .with_context(|| format!("{}: reshaping arg {}", self.name, i))?;
                    literals.push(lit);
                }
                let result = exe
                    .execute::<xla::Literal>(&literals)
                    .with_context(|| format!("executing {}", self.name))?;
                let literal = result[0][0]
                    .to_literal_sync()
                    .context("fetching result literal")?;
                // python lowers with return_tuple=True → single-element tuple.
                let out = literal.to_tuple1().context("unwrapping 1-tuple result")?;
                out.to_vec::<f32>().context("reading f32 result")?
            }
        };
        self.check_output(&data)?;
        Ok(HostTensor { shape: self.output_shape.clone(), data })
    }

    /// Execute with device-resident arguments (zero host conversion on
    /// the hot path). Shapes are checked against the manifest. One call =
    /// one invocation regardless of the batch dimension.
    pub fn run_device(&self, args: &[&DeviceTensor]) -> Result<HostTensor> {
        let shapes: Vec<&Vec<usize>> = args.iter().map(|a| &a.shape).collect();
        self.check_args(&shapes)?;
        record_invocation();
        let data = match &self.imp {
            ExecImpl::Sim(artifact) => {
                let raw: Vec<&[f32]> = args
                    .iter()
                    .map(|a| match &a.repr {
                        TensorRepr::Host(data) => Ok(data.as_slice()),
                        TensorRepr::Pjrt(_) => Err(anyhow::anyhow!(
                            "{}: PJRT buffer passed to the sim engine",
                            self.name
                        )),
                    })
                    .collect::<Result<Vec<_>>>()?;
                if self.mode == FunctionalMode::Packed && artifact.kind == "bnn_forward" {
                    // Staged weights: each tensor's packed view is built
                    // once (at staging or first dispatch) and reused here.
                    let mats = args[1..]
                        .iter()
                        .zip(&artifact.layers)
                        .map(|(t, dim)| t.packed_matrix(dim.s, dim.k))
                        .collect::<Result<Vec<_>>>()?;
                    let refs: Vec<&PackedMatrix> = mats.iter().map(|m| m.as_ref()).collect();
                    sim_execute_bnn_packed(artifact, self.batch, raw[0], &refs)
                } else {
                    sim_execute(artifact, self.batch, &raw)?
                }
            }
            ExecImpl::Pjrt(exe) => {
                let buffers: Vec<&xla::PjRtBuffer> = args
                    .iter()
                    .map(|a| match &a.repr {
                        TensorRepr::Pjrt(buffer) => Ok(buffer),
                        TensorRepr::Host(_) => Err(anyhow::anyhow!(
                            "{}: sim tensor passed to the PJRT engine",
                            self.name
                        )),
                    })
                    .collect::<Result<Vec<_>>>()?;
                let result = exe
                    .execute_b(&buffers)
                    .with_context(|| format!("executing {} (device args)", self.name))?;
                let literal = result[0][0]
                    .to_literal_sync()
                    .context("fetching result literal")?;
                let out = literal.to_tuple1().context("unwrapping 1-tuple result")?;
                out.to_vec::<f32>().context("reading f32 result")?
            }
        };
        self.check_output(&data)?;
        Ok(HostTensor { shape: self.output_shape.clone(), data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checked() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn host_tensor_accessors() {
        let t = HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(t.at2(0, 1), 2.0);
        assert_eq!(t.at2(1, 0), 3.0);
        assert_eq!(t.element_count(), 4);
        assert_eq!(HostTensor::zeros(vec![3, 4]).element_count(), 12);
    }

    #[cfg(not(feature = "xla-runtime"))]
    mod sim_engine {
        use super::*;
        use crate::functional::FunctionalMode;
        use crate::runtime::manifest::{ArgSpec, Artifact, LayerDim};

        fn gemm_artifact(h: usize, s: usize, k: usize, apply: bool) -> Artifact {
            Artifact {
                name: "g".into(),
                kind: "xnor_gemm".into(),
                file: std::path::PathBuf::from("<none>"),
                args: vec![
                    ArgSpec { name: "i".into(), shape: vec![h, s], dtype: "f32".into() },
                    ArgSpec { name: "w".into(), shape: vec![s, k], dtype: "f32".into() },
                ],
                output_shape: vec![h, k],
                layers: Vec::new(),
                model: None,
                input_hw: None,
                input_channels: None,
                num_classes: None,
                apply_activation: Some(apply),
            }
        }

        #[test]
        fn sim_runtime_reports_itself() {
            let rt = Runtime::cpu().unwrap();
            assert!(rt.is_sim());
            assert_eq!(rt.platform(), "sim-functional");
            assert_eq!(rt.device_count(), 1);
        }

        #[test]
        fn sim_gemm_matches_xnor_popcount() {
            let (h, s, k) = (4, 6, 3);
            let art = gemm_artifact(h, s, k, false);
            let rt = Runtime::cpu().unwrap();
            let exe = rt.load_artifact(&art).unwrap();
            let mut rng = crate::util::rng::Rng::new(0x51);
            let a = rng.bits(h * s);
            let b = rng.bits(s * k);
            let got = exe
                .run(&[
                    HostTensor::new(vec![h, s], a.clone()).unwrap(),
                    HostTensor::new(vec![s, k], b.clone()).unwrap(),
                ])
                .unwrap();
            for i in 0..h {
                for j in 0..k {
                    let row = &a[i * s..(i + 1) * s];
                    let col: Vec<f32> = (0..s).map(|t| b[t * k + j]).collect();
                    let want = crate::functional::bnn::xnor_popcount(row, &col);
                    assert_eq!(got.at2(i, j), want, "({}, {})", i, j);
                }
            }
        }

        #[test]
        fn sim_rejects_bad_args_and_counts_invocations() {
            let art = gemm_artifact(2, 4, 2, true);
            let rt = Runtime::cpu().unwrap();
            let exe = rt.load_artifact(&art).unwrap();
            assert!(exe.run(&[]).is_err());
            let bad = HostTensor::zeros(vec![1, 1]);
            let ok = HostTensor::zeros(vec![4, 2]);
            assert!(exe.run(&[bad, ok]).is_err());
            let before = crate::runtime::xla_stub::executable_invocations();
            let a = HostTensor::zeros(vec![2, 4]);
            let b = HostTensor::zeros(vec![4, 2]);
            exe.run(&[a, b]).unwrap();
            assert!(crate::runtime::xla_stub::executable_invocations() > before);
        }

        #[test]
        fn batched_load_rejected_for_gemm_kind() {
            let art = gemm_artifact(2, 4, 2, true);
            let rt = Runtime::cpu().unwrap();
            assert!(rt.load_artifact_batched(&art, 2).is_err());
            assert!(rt.load_artifact_batched(&art, 0).is_err());
        }

        /// 4×4×3 input → conv (s = 27, k = 8, no pool) → fc (s = 128,
        /// k = 10): small enough for debug-build tests, geometry-complete.
        fn bnn_artifact() -> Artifact {
            Artifact {
                name: "b".into(),
                kind: "bnn_forward".into(),
                file: std::path::PathBuf::from("<none>"),
                args: vec![
                    ArgSpec {
                        name: "x".into(),
                        shape: vec![1, 4, 4, 3],
                        dtype: "f32".into(),
                    },
                    ArgSpec { name: "w0".into(), shape: vec![27, 8], dtype: "f32".into() },
                    ArgSpec {
                        name: "w1".into(),
                        shape: vec![128, 10],
                        dtype: "f32".into(),
                    },
                ],
                output_shape: vec![1, 10],
                layers: vec![
                    LayerDim { kind: "conv".into(), h: 16, s: 27, k: 8, fmap_hw: 4 },
                    LayerDim { kind: "fc".into(), h: 1, s: 128, k: 10, fmap_hw: 1 },
                ],
                model: Some("t".into()),
                input_hw: Some(4),
                input_channels: Some(3),
                num_classes: Some(10),
                apply_activation: None,
            }
        }

        #[test]
        fn bnn_packed_and_f32_modes_agree() {
            let art = bnn_artifact();
            let rt = Runtime::cpu().unwrap();
            let packed_exe = rt
                .load_artifact_batched_mode(&art, 2, FunctionalMode::Packed)
                .unwrap();
            let f32_exe = rt
                .load_artifact_batched_mode(&art, 2, FunctionalMode::F32)
                .unwrap();
            assert_eq!(packed_exe.mode(), FunctionalMode::Packed);
            assert_eq!(f32_exe.mode(), FunctionalMode::F32);
            let mut rng = crate::util::rng::Rng::new(0xB2);
            let x: Vec<f32> = (0..2 * 48).map(|_| rng.f64() as f32 - 0.5).collect();
            let args = [
                HostTensor::new(vec![2, 4, 4, 3], x).unwrap(),
                HostTensor::new(vec![27, 8], rng.bits(27 * 8)).unwrap(),
                HostTensor::new(vec![128, 10], rng.bits(128 * 10)).unwrap(),
            ];
            let a = packed_exe.run(&args).unwrap();
            let b = f32_exe.run(&args).unwrap();
            assert_eq!(a, b);
        }
    }
}
