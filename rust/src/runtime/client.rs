//! PJRT execution client: load AOT HLO-text artifacts, compile them once on
//! the CPU PJRT backend, and execute them from the rust hot path.
//!
//! Python is never on the request path — the artifacts were produced once
//! by `make artifacts`; this module is the only component that touches XLA
//! at runtime.  Pattern follows /opt/xla-example/load_hlo (HLO *text*
//! interchange; `return_tuple=True` on the python side so results unwrap
//! with `to_tuple1`).

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

// Offline builds (the default) bind the PJRT names to the in-repo stub;
// with `--features xla-runtime` (plus the `xla` dependency) the same paths
// resolve to the real crate. See rust/src/runtime/xla_stub.rs.
#[cfg(not(feature = "xla-runtime"))]
use super::xla_stub as xla;

use super::manifest::Artifact;

/// A host-side f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<HostTensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} implies {} elements, got {}", shape, n, data.len());
        }
        Ok(HostTensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor { shape, data: vec![0.0; n] }
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// 2-D accessor (row-major).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }
}

/// Wraps the process-wide PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// A tensor resident on the PJRT device (pre-staged weights stay here so
/// the hot path never re-converts them — EXPERIMENTS.md §Perf L3).
pub struct DeviceTensor {
    buffer: xla::PjRtBuffer,
    pub shape: Vec<usize>,
}

/// One compiled executable (an AOT artifact after `client.compile`).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub arg_shapes: Vec<Vec<usize>>,
    pub output_shape: Vec<usize>,
    /// Wall-clock spent in compile (for EXPERIMENTS.md §Perf accounting).
    pub compile_seconds: f64,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text file and compile it.
    pub fn load_hlo_text(
        &self,
        path: impl AsRef<Path>,
        name: &str,
        arg_shapes: Vec<Vec<usize>>,
        output_shape: Vec<usize>,
    ) -> Result<Executable> {
        let path = path.as_ref();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: name.to_string(),
            arg_shapes,
            output_shape,
            compile_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Upload a host tensor to the device once; reuse across executes.
    pub fn to_device(&self, t: &HostTensor) -> Result<DeviceTensor> {
        let buffer = self
            .client
            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
            .context("host->device transfer")?;
        Ok(DeviceTensor { buffer, shape: t.shape.clone() })
    }

    /// Load an artifact described by the manifest.
    pub fn load_artifact(&self, artifact: &Artifact) -> Result<Executable> {
        self.load_hlo_text(
            &artifact.file,
            &artifact.name,
            artifact.args.iter().map(|a| a.shape.clone()).collect(),
            artifact.output_shape.clone(),
        )
    }
}

impl Executable {
    /// Execute with positional f32 tensors; returns the single (tupled)
    /// output as a host tensor.
    pub fn run(&self, args: &[HostTensor]) -> Result<HostTensor> {
        if args.len() != self.arg_shapes.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.name,
                self.arg_shapes.len(),
                args.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (i, (arg, want)) in args.iter().zip(&self.arg_shapes).enumerate() {
            if &arg.shape != want {
                bail!(
                    "{}: arg {} shape {:?} != manifest {:?}",
                    self.name,
                    i,
                    arg.shape,
                    want
                );
            }
            let dims: Vec<i64> = arg.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&arg.data)
                .reshape(&dims)
                .with_context(|| format!("{}: reshaping arg {}", self.name, i))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let literal = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // python lowers with return_tuple=True → single-element tuple.
        let out = literal.to_tuple1().context("unwrapping 1-tuple result")?;
        let data = out.to_vec::<f32>().context("reading f32 result")?;
        let expect: usize = self.output_shape.iter().product();
        if data.len() != expect {
            bail!(
                "{}: output has {} elements, manifest says {:?}",
                self.name,
                data.len(),
                self.output_shape
            );
        }
        Ok(HostTensor { shape: self.output_shape.clone(), data })
    }
}

impl Executable {
    /// Execute with device-resident arguments (zero host conversion on
    /// the hot path). Shapes are checked against the manifest.
    pub fn run_device(&self, args: &[&DeviceTensor]) -> Result<HostTensor> {
        if args.len() != self.arg_shapes.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.name,
                self.arg_shapes.len(),
                args.len()
            );
        }
        for (i, (arg, want)) in args.iter().zip(&self.arg_shapes).enumerate() {
            if &arg.shape != want {
                bail!(
                    "{}: device arg {} shape {:?} != manifest {:?}",
                    self.name,
                    i,
                    arg.shape,
                    want
                );
            }
        }
        let buffers: Vec<&xla::PjRtBuffer> = args.iter().map(|a| &a.buffer).collect();
        let result = self
            .exe
            .execute_b(&buffers)
            .with_context(|| format!("executing {} (device args)", self.name))?;
        let literal = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let out = literal.to_tuple1().context("unwrapping 1-tuple result")?;
        let data = out.to_vec::<f32>().context("reading f32 result")?;
        let expect: usize = self.output_shape.iter().product();
        if data.len() != expect {
            bail!(
                "{}: output has {} elements, manifest says {:?}",
                self.name,
                data.len(),
                self.output_shape
            );
        }
        Ok(HostTensor { shape: self.output_shape.clone(), data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checked() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn host_tensor_accessors() {
        let t = HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(t.at2(0, 1), 2.0);
        assert_eq!(t.at2(1, 0), 3.0);
        assert_eq!(t.element_count(), 4);
        assert_eq!(HostTensor::zeros(vec![3, 4]).element_count(), 12);
    }
}
