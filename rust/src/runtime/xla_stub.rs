//! Offline stand-in for the `xla` crate (PJRT bindings), plus the
//! process-wide executable invocation counter.
//!
//! The PJRT client in [`super::client`] is written against the `xla`
//! crate's API, but that crate (and the XLA C++ runtime it links) is not
//! part of the offline toolchain. This module mirrors the exact API
//! surface `client.rs` uses so the whole crate — coordinator, serving
//! examples, benches — compiles and tests everywhere. When the `xla`
//! crate is absent, [`super::client::Runtime`] falls back to a functional
//! *sim engine* that interprets manifest artifacts directly (see
//! `client.rs`); the stub types below exist purely so the PJRT code paths
//! type-check.
//!
//! To execute real AOT artifacts, add `xla = "0.1"` to `[dependencies]`
//! and build with `--features xla-runtime`; `client.rs` then binds to the
//! real crate and the stub types here are compiled out. The invocation
//! counter is compiled unconditionally so serving tests can assert
//! "one executable invocation per cut batch" on either engine.

use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide count of `Executable` invocations (one per `run` /
/// `run_device` call, i.e. one per compiled-graph dispatch — a batched
/// execution of N frames counts once). Tests use this to assert the
/// serving hot path issues exactly one invocation per cut batch.
static INVOCATIONS: AtomicU64 = AtomicU64::new(0);

/// Record one executable dispatch (called by `client.rs` on every run).
pub fn record_invocation() {
    INVOCATIONS.fetch_add(1, Ordering::SeqCst);
}

/// Total executable dispatches since process start (or the last reset).
///
/// The counter is process-wide: tests that assert on deltas must
/// serialize against other executable-running tests in the same binary.
pub fn executable_invocations() -> u64 {
    INVOCATIONS.load(Ordering::SeqCst)
}

/// Reset the invocation counter to zero (test helper).
pub fn reset_executable_invocations() {
    INVOCATIONS.store(0, Ordering::SeqCst);
}

/// Error returned by every stub entry point.
#[cfg(not(feature = "xla-runtime"))]
#[derive(Debug, thiserror::Error)]
#[error(
    "PJRT is unavailable: built without the `xla` crate (enable the \
     `xla-runtime` feature and add the dependency to run AOT artifacts)"
)]
pub struct XlaError;

/// Stub of `xla::PjRtClient`.
#[cfg(not(feature = "xla-runtime"))]
pub struct PjRtClient;

#[cfg(not(feature = "xla-runtime"))]
#[allow(dead_code)]
impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(XlaError)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError)
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, XlaError> {
        Err(XlaError)
    }
}

/// Stub of `xla::HloModuleProto`.
#[cfg(not(feature = "xla-runtime"))]
pub struct HloModuleProto;

#[cfg(not(feature = "xla-runtime"))]
#[allow(dead_code)]
impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(XlaError)
    }
}

/// Stub of `xla::XlaComputation`.
#[cfg(not(feature = "xla-runtime"))]
pub struct XlaComputation;

#[cfg(not(feature = "xla-runtime"))]
#[allow(dead_code)]
impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of `xla::Literal`.
#[cfg(not(feature = "xla-runtime"))]
pub struct Literal;

#[cfg(not(feature = "xla-runtime"))]
#[allow(dead_code)]
impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(XlaError)
    }

    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        Err(XlaError)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(XlaError)
    }
}

/// Stub of `xla::PjRtBuffer`.
#[cfg(not(feature = "xla-runtime"))]
pub struct PjRtBuffer;

#[cfg(not(feature = "xla-runtime"))]
#[allow(dead_code)]
impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError)
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
#[cfg(not(feature = "xla-runtime"))]
pub struct PjRtLoadedExecutable;

#[cfg(not(feature = "xla-runtime"))]
#[allow(dead_code)]
impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError)
    }

    pub fn execute_b(
        &self,
        _args: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invocation_counter_counts() {
        let before = executable_invocations();
        record_invocation();
        record_invocation();
        assert!(executable_invocations() >= before + 2);
    }
}
