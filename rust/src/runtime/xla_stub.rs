//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The PJRT client in [`super::client`] is written against the `xla`
//! crate's API, but that crate (and the XLA C++ runtime it links) is not
//! part of the offline toolchain. This module mirrors the exact API
//! surface `client.rs` uses so the whole crate — coordinator, serving
//! examples, benches — compiles and tests everywhere; any attempt to
//! actually construct the PJRT client reports a clear error instead.
//!
//! Every artifact-dependent test and example already skips gracefully when
//! `artifacts/manifest.json` is absent, so the stub is never reached in a
//! default checkout. To execute real AOT artifacts, add `xla = "0.1"` to
//! `[dependencies]` and build with `--features xla-runtime`; `client.rs`
//! then binds to the real crate and this module is compiled out.

/// Error returned by every stub entry point.
#[derive(Debug, thiserror::Error)]
#[error(
    "PJRT is unavailable: built without the `xla` crate (enable the \
     `xla-runtime` feature and add the dependency to run AOT artifacts)"
)]
pub struct XlaError;

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(XlaError)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError)
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, XlaError> {
        Err(XlaError)
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(XlaError)
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(XlaError)
    }

    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        Err(XlaError)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(XlaError)
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError)
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError)
    }

    pub fn execute_b(
        &self,
        _args: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError)
    }
}
