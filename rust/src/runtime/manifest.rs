//! Parser for `artifacts/manifest.json` emitted by `python/compile/aot.py`.
//!
//! The manifest is the contract between the build-time python layer and the
//! runtime rust layer: artifact file names, positional argument shapes, the
//! output shape, and (for BNN graphs) the per-layer GEMM geometry that the
//! analytic simulator consumes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One positional argument of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Geometry of one XNOR-GEMM layer (mirrors ModelSpec.layer_dims()).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerDim {
    pub kind: String, // "conv" | "fc"
    pub h: usize,
    pub s: usize,
    pub k: usize,
    pub fmap_hw: usize,
}

/// One AOT artifact.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub kind: String, // "xnor_gemm" | "bnn_forward"
    pub file: PathBuf,
    pub args: Vec<ArgSpec>,
    pub output_shape: Vec<usize>,
    pub layers: Vec<LayerDim>,
    pub model: Option<String>,
    pub input_hw: Option<usize>,
    pub input_channels: Option<usize>,
    pub num_classes: Option<usize>,
    /// For `xnor_gemm` kinds: whether the comparator activation is fused
    /// into the kernel (aot.py exports both variants; the sim engine in
    /// `runtime::client` needs this to reproduce the artifact's output).
    pub apply_activation: Option<bool>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, Artifact>,
}

/// Manifest loading errors.
#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    #[error("io error reading {path}: {source}")]
    Io {
        path: PathBuf,
        #[source]
        source: std::io::Error,
    },
    #[error("{0}")]
    Json(#[from] crate::util::json::JsonError),
    #[error("manifest schema error: {0}")]
    Schema(String),
}

fn schema(msg: impl Into<String>) -> ManifestError {
    ManifestError::Schema(msg.into())
}

fn parse_shape(j: &Json, ctx: &str) -> Result<Vec<usize>, ManifestError> {
    j.as_arr()
        .ok_or_else(|| schema(format!("{}: shape must be an array", ctx)))?
        .iter()
        .map(|d| {
            d.as_usize()
                .ok_or_else(|| schema(format!("{}: non-integer dim", ctx)))
        })
        .collect()
}

fn parse_arg(j: &Json, ctx: &str) -> Result<ArgSpec, ManifestError> {
    Ok(ArgSpec {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| schema(format!("{}: arg missing name", ctx)))?
            .to_string(),
        shape: parse_shape(
            j.get("shape")
                .ok_or_else(|| schema(format!("{}: arg missing shape", ctx)))?,
            ctx,
        )?,
        dtype: j
            .get("dtype")
            .and_then(Json::as_str)
            .unwrap_or("f32")
            .to_string(),
    })
}

fn parse_layer(j: &Json, ctx: &str) -> Result<LayerDim, ManifestError> {
    let field = |k: &str| {
        j.get(k)
            .and_then(Json::as_usize)
            .ok_or_else(|| schema(format!("{}: layer missing '{}'", ctx, k)))
    };
    Ok(LayerDim {
        kind: j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| schema(format!("{}: layer missing kind", ctx)))?
            .to_string(),
        h: field("h")?,
        s: field("s")?,
        k: field("k")?,
        fmap_hw: field("fmap_hw")?,
    })
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, ManifestError> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|source| ManifestError::Io { path: path.clone(), source })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (dir is where artifact files live).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest, ManifestError> {
        let j = Json::parse(text)?;
        if j.get("format").and_then(Json::as_str) != Some("hlo-text") {
            return Err(schema("format must be 'hlo-text'"));
        }
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| schema("missing 'artifacts' object"))?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in arts {
            let ctx = format!("artifact '{}'", name);
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| schema(format!("{}: missing file", ctx)))?;
            let args = a
                .get("args")
                .and_then(Json::as_arr)
                .ok_or_else(|| schema(format!("{}: missing args", ctx)))?
                .iter()
                .map(|arg| parse_arg(arg, &ctx))
                .collect::<Result<Vec<_>, _>>()?;
            let output_shape = parse_shape(
                a.path(&["output", "shape"])
                    .ok_or_else(|| schema(format!("{}: missing output.shape", ctx)))?,
                &ctx,
            )?;
            let layers = match a.get("layers").and_then(Json::as_arr) {
                Some(ls) => ls
                    .iter()
                    .map(|l| parse_layer(l, &ctx))
                    .collect::<Result<Vec<_>, _>>()?,
                None => Vec::new(),
            };
            artifacts.insert(
                name.clone(),
                Artifact {
                    name: name.clone(),
                    kind: a
                        .get("kind")
                        .and_then(Json::as_str)
                        .unwrap_or("unknown")
                        .to_string(),
                    file: dir.join(file),
                    args,
                    output_shape,
                    layers,
                    model: a.get("model").and_then(Json::as_str).map(String::from),
                    input_hw: a.get("input_hw").and_then(Json::as_usize),
                    input_channels: a.get("input_channels").and_then(Json::as_usize),
                    num_classes: a.get("num_classes").and_then(Json::as_usize),
                    apply_activation: a.get("apply_activation").and_then(Json::as_bool),
                },
            );
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&Artifact, ManifestError> {
        self.artifacts
            .get(name)
            .ok_or_else(|| schema(format!("artifact '{}' not in manifest", name)))
    }

    /// A manifest holding only the `bnn_<model>` artifacts for `models` —
    /// the per-model slice the serving registry hands each model's
    /// `Server`, so hot-loading one model never depends on a sibling
    /// artifact validating.
    pub fn subset(&self, models: &[&str]) -> Result<Manifest, ManifestError> {
        let mut artifacts = BTreeMap::new();
        for model in models {
            let name = format!("bnn_{}", model);
            let a = self.get(&name)?;
            artifacts.insert(name, a.clone());
        }
        Ok(Manifest { dir: self.dir.clone(), artifacts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "artifacts": {
        "xnor_gemm": {
          "kind": "xnor_gemm",
          "file": "xnor_gemm.hlo.txt",
          "apply_activation": true,
          "args": [
            {"name": "inputs", "shape": [64, 288], "dtype": "f32"},
            {"name": "weights", "shape": [288, 64], "dtype": "f32"}
          ],
          "output": {"shape": [64, 64], "dtype": "f32"}
        },
        "bnn_tiny": {
          "kind": "bnn_forward",
          "model": "tiny",
          "file": "bnn_tiny.hlo.txt",
          "args": [{"name": "x", "shape": [1, 8, 8, 3], "dtype": "f32"}],
          "output": {"shape": [1, 10], "dtype": "f32"},
          "layers": [
            {"kind": "conv", "h": 64, "s": 27, "k": 8, "fmap_hw": 8},
            {"kind": "fc", "h": 1, "s": 64, "k": 10, "fmap_hw": 1}
          ],
          "input_hw": 8, "input_channels": 3, "num_classes": 10
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/art")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let g = m.get("xnor_gemm").unwrap();
        assert_eq!(g.args.len(), 2);
        assert_eq!(g.args[0].shape, vec![64, 288]);
        assert_eq!(g.args[0].element_count(), 64 * 288);
        assert_eq!(g.output_shape, vec![64, 64]);
        assert_eq!(g.file, PathBuf::from("/art/xnor_gemm.hlo.txt"));
        assert_eq!(g.apply_activation, Some(true));
        let b = m.get("bnn_tiny").unwrap();
        assert_eq!(b.layers.len(), 2);
        assert_eq!(b.layers[0].s, 27);
        assert_eq!(b.model.as_deref(), Some("tiny"));
        assert_eq!(b.num_classes, Some(10));
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/art")).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn subset_slices_per_model() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/art")).unwrap();
        let s = m.subset(&["tiny"]).unwrap();
        assert_eq!(s.artifacts.len(), 1);
        assert!(s.get("bnn_tiny").is_ok());
        assert_eq!(s.dir, m.dir);
        assert!(m.subset(&["tiny", "nope"]).is_err());
    }

    #[test]
    fn bad_format_rejected() {
        let bad = r#"{"format": "proto", "artifacts": {}}"#;
        assert!(Manifest::parse(bad, PathBuf::from("/")).is_err());
    }

    #[test]
    fn schema_errors_reported() {
        let bad = r#"{"format": "hlo-text", "artifacts": {"a": {"file": "f"}}}"#;
        let err = Manifest::parse(bad, PathBuf::from("/")).unwrap_err();
        assert!(err.to_string().contains("missing args"));
    }
}
