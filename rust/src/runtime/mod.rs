//! Runtime layer: PJRT client + AOT artifact manifest + batched execution.
//!
//! `Runtime` owns the execution engine (the PJRT CPU client with
//! `--features xla-runtime`, the offline functional sim engine otherwise);
//! `Manifest` describes the artifacts produced by `make artifacts`;
//! `Executable::run`/`run_device` is the only place model compute happens
//! at serving time (python is build-time only). `BatchRunner` stacks N
//! frames into one leading batch dimension so a cut batch costs one
//! upload and ONE executable invocation; `xla_stub::executable_invocations`
//! counts dispatches so tests can assert that.

pub mod batch;
pub mod client;
pub mod manifest;
pub mod xla_stub;

pub use batch::BatchRunner;
pub use client::{Executable, HostTensor, Runtime};
pub use manifest::{ArgSpec, Artifact, LayerDim, Manifest, ManifestError};
pub use xla_stub::{executable_invocations, reset_executable_invocations};
