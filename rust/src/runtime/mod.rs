//! Runtime layer: PJRT client + AOT artifact manifest.
//!
//! `Runtime` owns the PJRT CPU client; `Manifest` describes the artifacts
//! produced by `make artifacts`; `Executable::run` is the only place model
//! compute happens at serving time (python is build-time only).

pub mod client;
pub mod manifest;
#[cfg(not(feature = "xla-runtime"))]
pub(crate) mod xla_stub;

pub use client::{Executable, HostTensor, Runtime};
pub use manifest::{ArgSpec, Artifact, LayerDim, Manifest, ManifestError};
