//! True batched execution over a compiled artifact: stack N frames into
//! one leading batch dimension, ONE host→device upload, ONE executable
//! invocation, split the outputs on the way out.
//!
//! [`BatchRunner`] owns the runtime, the staged weights, and a
//! compiled-batch-size cache: the first time a batch of size N is cut it
//! compiles (or, on the sim engine, instantiates) an executable whose
//! argument 0 and output carry a leading dim of N, then reuses it for
//! every later batch of that size. Batch sizes are bounded by the
//! server's `max_batch`, so the cache holds at most `max_batch` entries.
//!
//! If the engine cannot provide a batched executable (the PJRT path
//! compiles fixed-shape batch-1 AOT artifacts), the runner falls back to
//! per-frame dispatch — the pre-batching behaviour — and remembers the
//! failure so it never re-attempts the compile on the hot path.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use super::client::{DeviceTensor, Executable, HostTensor, Runtime};
use super::manifest::Artifact;

/// Owns everything one serving worker needs to execute cut batches.
pub struct BatchRunner {
    runtime: Runtime,
    artifact: Artifact,
    /// Weights staged on the device ONCE; the hot path only uploads the
    /// stacked input frames (EXPERIMENTS.md §Perf L3).
    weights: Vec<DeviceTensor>,
    /// Compiled-batch-size cache: batch size → executable.
    exes: BTreeMap<usize, Executable>,
    /// Set after a batched compile fails; all later batches run frame by
    /// frame without re-attempting the compile.
    batched_unsupported: bool,
    /// Wall-clock spent compiling the base (batch = 1) executable.
    pub compile_seconds: f64,
}

impl BatchRunner {
    /// Stage `weight_bits` (one {0,1} tensor per weight argument) and
    /// compile the base batch-1 executable.
    pub fn new(
        runtime: Runtime,
        artifact: Artifact,
        weight_bits: Vec<Vec<f32>>,
    ) -> Result<BatchRunner> {
        let weights = weight_bits
            .into_iter()
            .zip(&artifact.args[1..])
            .map(|(bits, spec)| {
                let host = HostTensor::new(spec.shape.clone(), bits)
                    .context("weight shape")?;
                runtime.to_device(&host).context("weight upload")
            })
            .collect::<Result<Vec<_>>>()?;
        let base = runtime
            .load_artifact(&artifact)
            .with_context(|| format!("compiling {}", artifact.name))?;
        let compile_seconds = base.compile_seconds;
        let mut exes = BTreeMap::new();
        exes.insert(1, base);
        Ok(BatchRunner {
            runtime,
            artifact,
            weights,
            exes,
            batched_unsupported: false,
            compile_seconds,
        })
    }

    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    /// True when batches of `n > 1` frames execute as one invocation (vs
    /// the per-frame fallback).
    pub fn supports_batched(&self) -> bool {
        !self.batched_unsupported
    }

    /// Distinct batch sizes an executable has been built for.
    pub fn compiled_batch_sizes(&self) -> Vec<usize> {
        self.exes.keys().copied().collect()
    }

    fn ensure_exe(&mut self, batch: usize) -> Result<()> {
        if self.exes.contains_key(&batch) {
            return Ok(());
        }
        let exe = self.runtime.load_artifact_batched(&self.artifact, batch)?;
        self.exes.insert(batch, exe);
        Ok(())
    }

    /// Execute `frames` (each one flat frame of input values) and return
    /// one logits vector per frame, in order. A batch of N frames issues
    /// exactly one executable invocation (or N on the per-frame fallback).
    pub fn run(&mut self, frames: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let n = frames.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let frame_shape = self.artifact.args[0].shape.clone();
        let frame_len: usize = frame_shape.iter().product();
        for (i, f) in frames.iter().enumerate() {
            if f.len() != frame_len {
                return Err(anyhow!(
                    "{}: frame {} has {} values, expected {}",
                    self.artifact.name,
                    i,
                    f.len(),
                    frame_len
                ));
            }
        }
        if n > 1 && !self.batched_unsupported {
            if let Err(e) = self.ensure_exe(n) {
                crate::log_warn!(
                    "{}: batched executable unavailable ({:#}); falling back \
                     to per-frame dispatch",
                    self.artifact.name,
                    e
                );
                self.batched_unsupported = true;
            }
        }
        if n == 1 || self.batched_unsupported {
            return self.run_per_frame(frames, &frame_shape);
        }

        // Stack into one [N, ...frame] tensor: one upload, one invocation.
        let mut stacked = Vec::with_capacity(n * frame_len);
        for f in frames {
            stacked.extend_from_slice(f);
        }
        let mut shape = frame_shape;
        shape[0] = n; // manifest frames carry a leading batch-1 dim
        let input = self.runtime.to_device(&HostTensor::new(shape, stacked)?)?;
        let mut args: Vec<&DeviceTensor> = Vec::with_capacity(1 + self.weights.len());
        args.push(&input);
        args.extend(self.weights.iter());
        let exe = self.exes.get(&n).expect("ensured above");
        let out = exe.run_device(&args)?;
        let per_frame = out.data.len() / n;
        Ok(out
            .data
            .chunks(per_frame)
            .map(|chunk| chunk.to_vec())
            .collect())
    }

    /// Pre-batching behaviour: one upload + one invocation per frame.
    fn run_per_frame(
        &self,
        frames: &[&[f32]],
        frame_shape: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        let exe = self.exes.get(&1).expect("base executable");
        let mut outputs = Vec::with_capacity(frames.len());
        for f in frames {
            let input = self
                .runtime
                .to_device(&HostTensor::new(frame_shape.to_vec(), f.to_vec())?)?;
            let mut args: Vec<&DeviceTensor> =
                Vec::with_capacity(1 + self.weights.len());
            args.push(&input);
            args.extend(self.weights.iter());
            outputs.push(exe.run_device(&args)?.data);
        }
        Ok(outputs)
    }
}
