//! True batched execution over a compiled artifact: stack N frames into
//! one leading batch dimension, ONE host→device upload, ONE executable
//! invocation, split the outputs on the way out.
//!
//! [`BatchRunner`] owns the runtime, the staged weights, and a
//! compiled-batch-size cache: the first time a batch of size N is cut it
//! compiles (or, on the sim engine, instantiates) an executable whose
//! argument 0 and output carry a leading dim of N, then reuses it for
//! every later batch of that size. Batch sizes are bounded by the
//! server's `max_batch`, so the cache holds at most `max_batch` entries.
//!
//! If the engine cannot provide a batched executable (the PJRT path
//! compiles fixed-shape batch-1 AOT artifacts), the runner falls back to
//! per-frame dispatch — the pre-batching behaviour — and remembers the
//! failure so it never re-attempts the compile on the hot path.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use super::client::{DeviceTensor, Executable, HostTensor, Runtime};
use super::manifest::Artifact;
use crate::functional::FunctionalMode;

/// Owns everything one serving worker needs to execute cut batches.
pub struct BatchRunner {
    runtime: Runtime,
    artifact: Artifact,
    /// Which functional implementation sim-engine dispatch uses (packed
    /// XNOR + popcount by default; `OXBNN_FUNCTIONAL=f32` reverts).
    mode: FunctionalMode,
    /// Weights staged on the device ONCE; the hot path only uploads the
    /// stacked input frames (EXPERIMENTS.md §Perf L3).
    weights: Vec<DeviceTensor>,
    /// Compiled-batch-size cache: batch size → executable.
    exes: BTreeMap<usize, Executable>,
    /// Set after a batched compile fails; all later batches run frame by
    /// frame without re-attempting the compile.
    batched_unsupported: bool,
    /// Wall-clock spent compiling the base (batch = 1) executable.
    pub compile_seconds: f64,
}

impl BatchRunner {
    /// Stage `weight_bits` (one {0,1} tensor per weight argument) and
    /// compile the base batch-1 executable. The functional mode comes
    /// from the environment (`OXBNN_FUNCTIONAL`); use
    /// [`BatchRunner::with_mode`] to pin it explicitly.
    pub fn new(
        runtime: Runtime,
        artifact: Artifact,
        weight_bits: Vec<Vec<f32>>,
    ) -> Result<BatchRunner> {
        Self::with_mode(runtime, artifact, weight_bits, FunctionalMode::from_env())
    }

    /// [`BatchRunner::new`] with an explicit functional mode.
    pub fn with_mode(
        runtime: Runtime,
        artifact: Artifact,
        weight_bits: Vec<Vec<f32>>,
        mode: FunctionalMode,
    ) -> Result<BatchRunner> {
        let weights = weight_bits
            .into_iter()
            .zip(&artifact.args[1..])
            .map(|(bits, spec)| {
                let host = HostTensor::new(spec.shape.clone(), bits)
                    .context("weight shape")?;
                runtime.to_device(&host).context("weight upload")
            })
            .collect::<Result<Vec<_>>>()?;
        // Pack weights into u64 lanes ONCE at staging time: every later
        // dispatch reuses each tensor's cached packed view, so the hot
        // path never re-reads the staged f32 weights.
        if mode == FunctionalMode::Packed
            && runtime.is_sim()
            && artifact.kind == "bnn_forward"
        {
            for (tensor, dim) in weights.iter().zip(&artifact.layers) {
                tensor
                    .packed_matrix(dim.s, dim.k)
                    .with_context(|| format!("packing {} weights", artifact.name))?;
            }
        }
        let base = runtime
            .load_artifact_batched_mode(&artifact, 1, mode)
            .with_context(|| format!("compiling {}", artifact.name))?;
        let compile_seconds = base.compile_seconds;
        let mut exes = BTreeMap::new();
        exes.insert(1, base);
        Ok(BatchRunner {
            runtime,
            artifact,
            mode,
            weights,
            exes,
            batched_unsupported: false,
            compile_seconds,
        })
    }

    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    /// The functional mode this runner's executables dispatch with.
    pub fn mode(&self) -> FunctionalMode {
        self.mode
    }

    /// How many weight tensors this runner's runtime has bit-packed
    /// (once per staged tensor; a reload builds a fresh runner and packs
    /// its own tensors exactly once).
    pub fn weight_packs(&self) -> u64 {
        self.runtime.weight_packs()
    }

    /// True when batches of `n > 1` frames execute as one invocation (vs
    /// the per-frame fallback).
    pub fn supports_batched(&self) -> bool {
        !self.batched_unsupported
    }

    /// Distinct batch sizes an executable has been built for.
    pub fn compiled_batch_sizes(&self) -> Vec<usize> {
        self.exes.keys().copied().collect()
    }

    fn ensure_exe(&mut self, batch: usize) -> Result<()> {
        if self.exes.contains_key(&batch) {
            return Ok(());
        }
        let exe =
            self.runtime
                .load_artifact_batched_mode(&self.artifact, batch, self.mode)?;
        self.exes.insert(batch, exe);
        Ok(())
    }

    /// Execute `frames` (each one flat frame of input values) and return
    /// one logits vector per frame, in order. A batch of N frames issues
    /// exactly one executable invocation (or N on the per-frame fallback).
    pub fn run(&mut self, frames: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let n = frames.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let frame_shape = self.artifact.args[0].shape.clone();
        let frame_len: usize = frame_shape.iter().product();
        for (i, f) in frames.iter().enumerate() {
            if f.len() != frame_len {
                return Err(anyhow!(
                    "{}: frame {} has {} values, expected {}",
                    self.artifact.name,
                    i,
                    f.len(),
                    frame_len
                ));
            }
        }
        if n > 1 && !self.batched_unsupported {
            if let Err(e) = self.ensure_exe(n) {
                crate::log_warn!(
                    "{}: batched executable unavailable ({:#}); falling back \
                     to per-frame dispatch",
                    self.artifact.name,
                    e
                );
                self.batched_unsupported = true;
            }
        }
        if n == 1 || self.batched_unsupported {
            return self.run_per_frame(frames, &frame_shape);
        }

        // Stack into one [N, ...frame] tensor: one upload, one invocation.
        let mut stacked = Vec::with_capacity(n * frame_len);
        for f in frames {
            stacked.extend_from_slice(f);
        }
        let mut shape = frame_shape;
        shape[0] = n; // manifest frames carry a leading batch-1 dim
        let input = self.runtime.to_device(&HostTensor::new(shape, stacked)?)?;
        let mut args: Vec<&DeviceTensor> = Vec::with_capacity(1 + self.weights.len());
        args.push(&input);
        args.extend(self.weights.iter());
        let exe = self.exes.get(&n).expect("ensured above");
        let out = exe.run_device(&args)?;
        let per_frame = out.data.len() / n;
        Ok(out
            .data
            .chunks(per_frame)
            .map(|chunk| chunk.to_vec())
            .collect())
    }

    /// Pre-batching behaviour: one upload + one invocation per frame.
    fn run_per_frame(
        &self,
        frames: &[&[f32]],
        frame_shape: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        let exe = self.exes.get(&1).expect("base executable");
        let mut outputs = Vec::with_capacity(frames.len());
        for f in frames {
            let input = self
                .runtime
                .to_device(&HostTensor::new(frame_shape.to_vec(), f.to_vec())?)?;
            let mut args: Vec<&DeviceTensor> =
                Vec::with_capacity(1 + self.weights.len());
            args.push(&input);
            args.extend(self.weights.iter());
            outputs.push(exe.run_device(&args)?.data);
        }
        Ok(outputs)
    }
}

#[cfg(all(test, not(feature = "xla-runtime")))]
mod tests {
    use super::*;
    use crate::runtime::manifest::{ArgSpec, LayerDim};
    use crate::runtime::xla_stub::executable_invocations;
    use crate::util::rng::Rng;

    /// 4×4×3 input → conv (s = 27, k = 8, no pool) → fc (s = 128, k = 10).
    fn bnn_artifact() -> Artifact {
        Artifact {
            name: "b".into(),
            kind: "bnn_forward".into(),
            file: std::path::PathBuf::from("<none>"),
            args: vec![
                ArgSpec { name: "x".into(), shape: vec![1, 4, 4, 3], dtype: "f32".into() },
                ArgSpec { name: "w0".into(), shape: vec![27, 8], dtype: "f32".into() },
                ArgSpec { name: "w1".into(), shape: vec![128, 10], dtype: "f32".into() },
            ],
            output_shape: vec![1, 10],
            layers: vec![
                LayerDim { kind: "conv".into(), h: 16, s: 27, k: 8, fmap_hw: 4 },
                LayerDim { kind: "fc".into(), h: 1, s: 128, k: 10, fmap_hw: 1 },
            ],
            model: Some("t".into()),
            input_hw: Some(4),
            input_channels: Some(3),
            num_classes: Some(10),
            apply_activation: None,
        }
    }

    fn weights(rng: &mut Rng) -> Vec<Vec<f32>> {
        vec![rng.bits(27 * 8), rng.bits(128 * 10)]
    }

    fn runner(mode: FunctionalMode, seed: u64) -> BatchRunner {
        let mut rng = Rng::new(seed);
        BatchRunner::with_mode(
            Runtime::cpu().unwrap(),
            bnn_artifact(),
            weights(&mut rng),
            mode,
        )
        .unwrap()
    }

    #[test]
    fn staging_packs_once_and_dispatches_never_repack() {
        let mut r = runner(FunctionalMode::Packed, 0x11);
        // Both layers packed eagerly at staging time — before any run.
        assert_eq!(r.weight_packs(), 2);
        assert_eq!(r.mode(), FunctionalMode::Packed);
        let mut rng = Rng::new(0x12);
        let f1: Vec<f32> = (0..48).map(|_| rng.f64() as f32 - 0.5).collect();
        let f2: Vec<f32> = (0..48).map(|_| rng.f64() as f32 - 0.5).collect();
        let before = executable_invocations();
        r.run(&[f1.as_slice()]).unwrap();
        r.run(&[f1.as_slice(), f2.as_slice()]).unwrap();
        // This runner issued (at least) two more invocations...
        assert!(executable_invocations() >= before + 2);
        // ...and none of them repacked a weight tensor.
        assert_eq!(r.weight_packs(), 2);
    }

    #[test]
    fn reload_repacks_exactly_once() {
        let r1 = runner(FunctionalMode::Packed, 0x21);
        assert_eq!(r1.weight_packs(), 2);
        // A reload builds a fresh runtime + staged tensors (what the
        // serving worker does): its meter counts one pack per layer, once.
        let r2 = runner(FunctionalMode::Packed, 0x21);
        assert_eq!(r2.weight_packs(), 2);
        drop(r2);
        assert_eq!(r1.weight_packs(), 2);
    }

    #[test]
    fn f32_mode_never_packs() {
        let mut r = runner(FunctionalMode::F32, 0x31);
        assert_eq!(r.weight_packs(), 0);
        let frame = vec![0.25f32; 48];
        r.run(&[frame.as_slice()]).unwrap();
        assert_eq!(r.weight_packs(), 0);
    }

    #[test]
    fn packed_and_f32_runners_agree_across_batch_sizes() {
        let mut packed = runner(FunctionalMode::Packed, 0x41);
        let mut reference = runner(FunctionalMode::F32, 0x41);
        let mut rng = Rng::new(0x42);
        for n in [1usize, 2, 5] {
            let frames: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..48).map(|_| rng.f64() as f32 - 0.5).collect())
                .collect();
            let refs: Vec<&[f32]> = frames.iter().map(|f| f.as_slice()).collect();
            let a = packed.run(&refs).unwrap();
            let b = reference.run(&refs).unwrap();
            assert_eq!(a, b, "batch {}", n);
        }
    }
}
