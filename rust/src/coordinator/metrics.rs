//! Serving metrics: latency histogram with percentiles and throughput
//! accounting, shared by the coordinator's workers.

/// Fixed-memory latency recorder (stores raw samples up to a cap, then
/// reservoir-samples; serving runs here are bounded so the cap is ample).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    samples: Vec<f64>,
    cap: usize,
    total_count: u64,
    total_sum: f64,
    rng_state: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new(100_000)
    }
}

impl LatencyHistogram {
    pub fn new(cap: usize) -> LatencyHistogram {
        LatencyHistogram {
            samples: Vec::new(),
            cap: cap.max(1),
            total_count: 0,
            total_sum: 0.0,
            rng_state: 0x9E3779B97F4A7C15,
        }
    }

    pub fn record(&mut self, seconds: f64) {
        self.total_count += 1;
        self.total_sum += seconds;
        if self.samples.len() < self.cap {
            self.samples.push(seconds);
        } else {
            // Reservoir sampling keeps percentiles unbiased past the cap.
            self.rng_state ^= self.rng_state << 13;
            self.rng_state ^= self.rng_state >> 7;
            self.rng_state ^= self.rng_state << 17;
            let idx = (self.rng_state % self.total_count) as usize;
            if idx < self.cap {
                self.samples[idx] = seconds;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.total_count
    }

    pub fn mean(&self) -> f64 {
        if self.total_count == 0 {
            0.0
        } else {
            self.total_sum / self.total_count as f64
        }
    }

    /// Percentile over recorded samples (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(f64::total_cmp);
        let rank = (p / 100.0 * (sorted.len() - 1) as f64).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Aggregated serving metrics.
#[derive(Debug, Clone, Default)]
pub struct ServerMetrics {
    pub queue: LatencyHistogram,
    pub execute: LatencyHistogram,
    pub end_to_end: LatencyHistogram,
    pub completed: u64,
    /// Requests that reached a worker but whose execution errored.
    pub failed: u64,
    /// Requests refused at admission (replica queue full — back-pressure).
    pub rejected: u64,
    pub batches: u64,
    pub batched_requests: u64,
    /// Batch-size distribution: cut batch size → number of batches.
    pub batch_sizes: std::collections::BTreeMap<usize, u64>,
}

impl ServerMetrics {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    /// Record one cut batch of `size` requests.
    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        self.batched_requests += size as u64;
        *self.batch_sizes.entry(size).or_insert(0) += 1;
    }

    /// Largest batch size cut so far.
    pub fn max_batch_size(&self) -> usize {
        self.batch_sizes.keys().next_back().copied().unwrap_or(0)
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "completed={} failed={} rejected={} batches={} mean_batch={:.2}\n\
             queue:     p50={} p95={} p99={} mean={}\n\
             execute:   p50={} p95={} p99={} mean={}\n\
             end2end:   p50={} p95={} p99={} mean={}",
            self.completed,
            self.failed,
            self.rejected,
            self.batches,
            self.mean_batch_size(),
            crate::util::units::fmt_time(self.queue.p50()),
            crate::util::units::fmt_time(self.queue.p95()),
            crate::util::units::fmt_time(self.queue.p99()),
            crate::util::units::fmt_time(self.queue.mean()),
            crate::util::units::fmt_time(self.execute.p50()),
            crate::util::units::fmt_time(self.execute.p95()),
            crate::util::units::fmt_time(self.execute.p99()),
            crate::util::units::fmt_time(self.execute.mean()),
            crate::util::units::fmt_time(self.end_to_end.p50()),
            crate::util::units::fmt_time(self.end_to_end.p95()),
            crate::util::units::fmt_time(self.end_to_end.p99()),
            crate::util::units::fmt_time(self.end_to_end.mean()),
        );
        if !self.batch_sizes.is_empty() {
            let dist: Vec<String> = self
                .batch_sizes
                .iter()
                .map(|(size, count)| format!("{}x{}", size, count))
                .collect();
            s.push_str(&format!("\nbatch sizes (size x count): {}", dist.join(" ")));
        }
        s
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests may unwrap
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut h = LatencyHistogram::default();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 100);
        assert!((h.p50() - 0.050).abs() < 2e-3);
        assert!((h.p95() - 0.095).abs() < 2e-3);
        assert!(h.p50() <= h.p95() && h.p95() <= h.p99());
        assert!((h.mean() - 0.0505).abs() < 1e-6);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn reservoir_keeps_cap() {
        let mut h = LatencyHistogram::new(10);
        for i in 0..1000 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.samples.len(), 10);
    }

    #[test]
    fn batch_stats() {
        let mut m = ServerMetrics::default();
        m.batches = 4;
        m.batched_requests = 10;
        assert!((m.mean_batch_size() - 2.5).abs() < 1e-12);
        assert!(m.report().contains("mean_batch=2.50"));
    }

    #[test]
    fn batch_size_distribution() {
        let mut m = ServerMetrics::default();
        m.record_batch(1);
        m.record_batch(4);
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.batches, 4);
        assert_eq!(m.batched_requests, 17);
        assert_eq!(m.batch_sizes.get(&4), Some(&2));
        assert_eq!(m.max_batch_size(), 8);
        let r = m.report();
        assert!(r.contains("4x2"), "{}", r);
        assert!(r.contains("failed=0"));
    }
}
