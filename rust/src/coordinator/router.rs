//! Request router: maps inference requests to model engines/replicas.
//!
//! Policy: exact model-name match, then least-outstanding-work among the
//! model's replicas (falls back to round-robin on ties, deterministic).

use std::collections::BTreeMap;

/// A routable engine replica.
#[derive(Debug, Clone, PartialEq)]
pub struct Replica {
    pub model: String,
    pub replica_id: usize,
    /// Outstanding (queued + executing) requests.
    pub outstanding: usize,
}

/// Router state.
#[derive(Debug, Default)]
pub struct Router {
    replicas: Vec<Replica>,
    rr_state: BTreeMap<String, usize>,
}

/// Routing errors.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum RouteError {
    #[error("no engine registered for model '{0}'")]
    UnknownModel(String),
}

impl Router {
    pub fn register(&mut self, model: &str, replica_id: usize) {
        self.replicas.push(Replica {
            model: model.to_string(),
            replica_id,
            outstanding: 0,
        });
    }

    pub fn models(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.replicas.iter().map(|r| r.model.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        names
    }

    /// Choose a replica for `model`; increments its outstanding count.
    pub fn route(&mut self, model: &str) -> Result<usize, RouteError> {
        let candidates: Vec<usize> = self
            .replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.model == model)
            .map(|(i, _)| i)
            .collect();
        let Some(min_out) = candidates
            .iter()
            .map(|&i| self.replicas[i].outstanding)
            .min()
        else {
            return Err(RouteError::UnknownModel(model.to_string()));
        };
        let tied: Vec<usize> = candidates
            .iter()
            .copied()
            .filter(|&i| self.replicas[i].outstanding == min_out)
            .collect();
        // Round-robin among the least-loaded replicas.
        let rr = self.rr_state.entry(model.to_string()).or_insert(0);
        let pick = tied[*rr % tied.len()];
        *rr = rr.wrapping_add(1);
        self.replicas[pick].outstanding += 1;
        Ok(self.replicas[pick].replica_id)
    }

    /// Route to a SPECIFIC replica (session affinity / health probes);
    /// increments its outstanding count. Errors when that replica is not
    /// registered — quarantined replicas reject pinned traffic too.
    pub fn route_to(&mut self, model: &str, replica_id: usize) -> Result<usize, RouteError> {
        match self
            .replicas
            .iter_mut()
            .find(|r| r.model == model && r.replica_id == replica_id)
        {
            Some(r) => {
                r.outstanding += 1;
                Ok(replica_id)
            }
            None => Err(RouteError::UnknownModel(model.to_string())),
        }
    }

    /// Live (still-registered) replica ids for a model, sorted.
    pub fn replica_ids(&self, model: &str) -> Vec<usize> {
        let mut ids: Vec<usize> = self
            .replicas
            .iter()
            .filter(|r| r.model == model)
            .map(|r| r.replica_id)
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Remove a replica from routing entirely (a worker that failed to
    /// start quarantines itself with this; leaving it registered would
    /// make the dead replica the *preferred* least-loaded target, since
    /// it errors instantly and never accumulates outstanding work).
    pub fn deregister(&mut self, model: &str, replica_id: usize) {
        self.replicas
            .retain(|r| !(r.model == model && r.replica_id == replica_id));
    }

    /// Mark completion on a replica.
    pub fn complete(&mut self, model: &str, replica_id: usize) {
        if let Some(r) = self
            .replicas
            .iter_mut()
            .find(|r| r.model == model && r.replica_id == replica_id)
        {
            r.outstanding = r.outstanding.saturating_sub(1);
        }
    }

    pub fn outstanding(&self, model: &str) -> usize {
        self.replicas
            .iter()
            .filter(|r| r.model == model)
            .map(|r| r.outstanding)
            .sum()
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests may unwrap
mod tests {
    use super::*;

    #[test]
    fn unknown_model_errors() {
        let mut r = Router::default();
        r.register("tiny", 0);
        assert_eq!(
            r.route("nope"),
            Err(RouteError::UnknownModel("nope".into()))
        );
    }

    #[test]
    fn round_robin_when_balanced() {
        let mut r = Router::default();
        r.register("tiny", 0);
        r.register("tiny", 1);
        let a = r.route("tiny").unwrap();
        let b = r.route("tiny").unwrap();
        assert_ne!(a, b, "balanced replicas alternate");
    }

    #[test]
    fn least_loaded_wins() {
        let mut r = Router::default();
        r.register("m", 0);
        r.register("m", 1);
        let first = r.route("m").unwrap();
        // Replica `first` now has 1 outstanding; next goes to the other.
        let second = r.route("m").unwrap();
        assert_ne!(first, second);
        // Complete on `second`; it becomes least-loaded... both at 1 vs 0.
        r.complete("m", second);
        let third = r.route("m").unwrap();
        assert_eq!(third, second);
    }

    #[test]
    fn outstanding_accounting() {
        let mut r = Router::default();
        r.register("m", 0);
        assert_eq!(r.outstanding("m"), 0);
        r.route("m").unwrap();
        r.route("m").unwrap();
        assert_eq!(r.outstanding("m"), 2);
        r.complete("m", 0);
        assert_eq!(r.outstanding("m"), 1);
    }

    #[test]
    fn deregistered_replica_never_routed() {
        let mut r = Router::default();
        r.register("m", 0);
        r.register("m", 1);
        r.deregister("m", 0);
        for _ in 0..4 {
            assert_eq!(r.route("m").unwrap(), 1, "only the live replica routes");
        }
        // Deregistering the last replica makes the model unroutable.
        r.deregister("m", 1);
        assert_eq!(r.route("m"), Err(RouteError::UnknownModel("m".into())));
    }

    #[test]
    fn pinned_routing_respects_registration() {
        let mut r = Router::default();
        r.register("m", 0);
        r.register("m", 1);
        assert_eq!(r.route_to("m", 1), Ok(1));
        assert_eq!(r.outstanding("m"), 1);
        r.complete("m", 1);
        r.deregister("m", 1);
        assert_eq!(r.route_to("m", 1), Err(RouteError::UnknownModel("m".into())));
        assert_eq!(r.replica_ids("m"), vec![0]);
        assert_eq!(r.outstanding("m"), 0, "failed pinned route must not leak load");
    }

    #[test]
    fn models_listing() {
        let mut r = Router::default();
        r.register("b", 0);
        r.register("a", 0);
        r.register("a", 1);
        assert_eq!(r.models(), vec!["a", "b"]);
    }
}
