//! Dynamic request batcher.
//!
//! Groups pending inference requests into batches bounded by `max_batch`
//! and `max_wait`. Pure data structure (no threads) so the policy is
//! unit-testable; the server's worker loop drives it with real time,
//! selecting one of two cut policies (`ServerConfig::policy`):
//!
//! * [`Batcher::drain_now`] — `BatchPolicy::Immediate` continuous
//!   batching: take whatever is queued, never wait.
//! * [`Batcher::ready`] / [`Batcher::drain`] — `BatchPolicy::Deadline`:
//!   a batch closes when full OR when its oldest member has waited
//!   `max_wait`.
//!
//! Arrival times are each job's own submit instant (worker-epoch
//! relative), so queue-time metrics and deadlines stay truthful even when
//! the worker absorbs a backlog in one gulp.

use std::collections::VecDeque;

/// A queued item with its arrival time.
#[derive(Debug, Clone)]
pub struct Pending<T> {
    pub item: T,
    pub arrived_s: f64,
}

/// Batching policy + queue.
#[derive(Debug)]
pub struct Batcher<T> {
    queue: VecDeque<Pending<T>>,
    pub max_batch: usize,
    pub max_wait_s: f64,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize, max_wait_s: f64) -> Batcher<T> {
        assert!(max_batch >= 1);
        Batcher { queue: VecDeque::new(), max_batch, max_wait_s }
    }

    pub fn push(&mut self, item: T, now_s: f64) {
        self.queue.push_back(Pending { item, arrived_s: now_s });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should a batch be cut right now?
    pub fn ready(&self, now_s: f64) -> bool {
        if self.queue.len() >= self.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(p) => now_s - p.arrived_s >= self.max_wait_s,
            None => false,
        }
    }

    /// Cut a batch if ready; returns at most `max_batch` items, oldest
    /// first.
    pub fn drain(&mut self, now_s: f64) -> Option<Vec<Pending<T>>> {
        if !self.ready(now_s) {
            return None;
        }
        let n = self.queue.len().min(self.max_batch);
        Some(self.queue.drain(..n).collect())
    }

    /// Unconditionally flush everything (shutdown path).
    pub fn flush(&mut self) -> Vec<Pending<T>> {
        self.queue.drain(..).collect()
    }

    /// Continuous-batching cut: take whatever is queued (up to
    /// `max_batch`) immediately, without waiting for the deadline. Under
    /// load the queue backlog forms real batches; at low load single
    /// requests execute with zero added latency (vLLM-style policy — see
    /// EXPERIMENTS.md §Perf for the measured effect).
    pub fn drain_now(&mut self) -> Option<Vec<Pending<T>>> {
        if self.queue.is_empty() {
            return None;
        }
        let n = self.queue.len().min(self.max_batch);
        Some(self.queue.drain(..n).collect())
    }

    /// Time until the oldest item hits `max_wait` (for worker sleep
    /// intervals); `None` when empty.
    pub fn next_deadline_in(&self, now_s: f64) -> Option<f64> {
        self.queue
            .front()
            .map(|p| (p.arrived_s + self.max_wait_s - now_s).max(0.0))
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests may unwrap
mod tests {
    use super::*;

    #[test]
    fn batch_cuts_when_full() {
        let mut b = Batcher::new(3, 1.0);
        b.push(1, 0.0);
        b.push(2, 0.0);
        assert!(!b.ready(0.0));
        b.push(3, 0.0);
        assert!(b.ready(0.0));
        let batch = b.drain(0.0).unwrap();
        assert_eq!(batch.len(), 3);
        assert!(b.is_empty());
    }

    #[test]
    fn batch_cuts_on_deadline() {
        let mut b = Batcher::new(10, 0.005);
        b.push("a", 0.0);
        assert!(!b.ready(0.004));
        assert!(b.ready(0.005));
        let batch = b.drain(0.006).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn oldest_first_order() {
        let mut b = Batcher::new(2, 1.0);
        b.push(1, 0.0);
        b.push(2, 0.1);
        b.push(3, 0.2);
        let batch = b.drain(0.2).unwrap();
        let items: Vec<i32> = batch.into_iter().map(|p| p.item).collect();
        assert_eq!(items, vec![1, 2]);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn overflow_splits_batches() {
        let mut b = Batcher::new(4, 0.0);
        for i in 0..10 {
            b.push(i, 0.0);
        }
        assert_eq!(b.drain(0.0).unwrap().len(), 4);
        assert_eq!(b.drain(0.0).unwrap().len(), 4);
        assert_eq!(b.drain(0.0).unwrap().len(), 2);
        assert!(b.drain(0.0).is_none());
    }

    #[test]
    fn deadline_tracking() {
        let mut b = Batcher::new(10, 0.01);
        assert_eq!(b.next_deadline_in(0.0), None);
        b.push(0, 1.0);
        let d = b.next_deadline_in(1.002).unwrap();
        assert!((d - 0.008).abs() < 1e-12);
        // Past-due clamps to zero.
        assert_eq!(b.next_deadline_in(2.0).unwrap(), 0.0);
    }

    #[test]
    fn flush_empties() {
        let mut b = Batcher::new(10, 10.0);
        b.push(1, 0.0);
        b.push(2, 0.0);
        assert_eq!(b.flush().len(), 2);
        assert!(b.is_empty());
    }
}
