//! L3 coordinator: request routing, dynamic batching, serving loop and
//! metrics. Python never appears here — the workers execute AOT-compiled
//! artifacts through PJRT and attach simulated photonic latencies from the
//! analytic accelerator model.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::Batcher;
pub use metrics::{LatencyHistogram, ServerMetrics};
pub use router::{RouteError, Router};
pub use server::{
    synthetic_weights, workload_from_artifact, InferenceRequest, InferenceResponse, Server,
    ServerConfig,
};
