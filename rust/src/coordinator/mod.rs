//! L3 coordinator: request routing, dynamic batching, serving loop and
//! metrics. Python never appears here — the workers execute AOT-compiled
//! artifacts through the runtime engine (PJRT, or the offline functional
//! sim engine) and attach simulated photonic latencies from the analytic
//! accelerator model.
//!
//! The serving hot path is genuinely batched: a cut batch of N frames is
//! stacked into one leading batch dimension and dispatched as ONE
//! executable invocation (`runtime::BatchRunner`), with bounded
//! per-replica queues providing admission-control back-pressure
//! (`SubmitError::QueueFull`).
//!
//! Request-path code in this subtree may not `unwrap()`/`expect()` (the
//! `disallowed_methods` deny below + `clippy.toml`): a panic must cost
//! one request, never the process. Locks go through
//! [`crate::util::sync`]; everything else is matched or surfaced as a
//! protocol error. Test modules opt back out locally.

#![deny(clippy::disallowed_methods)]

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::Batcher;
pub use metrics::{LatencyHistogram, ServerMetrics};
pub use router::{RouteError, Router};
pub use server::{
    synthetic_manifest, synthetic_weights, workload_from_artifact, BatchPolicy,
    InferenceRequest, InferenceResponse, Server, ServerConfig, SubmitError,
};
