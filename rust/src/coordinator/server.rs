//! Inference server: the L3 coordinator's serving loop.
//!
//! One worker thread per registered model owns a PJRT runtime and the
//! model's compiled AOT artifact (executables are not `Send`, so they are
//! constructed inside their worker). Requests flow:
//!
//! ```text
//! submit() → Router (least-loaded replica) → worker channel →
//!   Batcher (max_batch / max_wait) → Executable::run per frame →
//!   response channel (+ metrics)
//! ```
//!
//! Each response also carries the *simulated photonic latency* the frame
//! would have on the configured OXBNN accelerator (from the analytic
//! model), tying the serving path to the paper's performance story.
//! Weights are synthetic {0,1} bits generated deterministically per model
//! (DESIGN.md substitution: performance is geometry-driven; functional
//! correctness is validated against the independent rust engine).

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::batcher::Batcher;
use super::metrics::ServerMetrics;
use super::router::Router;
use crate::api::{BackendKind, Session};
use crate::arch::accelerator::AcceleratorConfig;
use crate::mapping::layer::GemmLayer;
use crate::runtime::manifest::{Artifact, Manifest};
use crate::runtime::{HostTensor, Runtime};
use crate::util::rng::Rng;
use crate::workloads::Workload;

/// An inference request (one frame, batch = 1 artifacts).
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub model: String,
    pub input: Vec<f32>,
}

/// The served result.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub logits: Vec<f32>,
    pub queue_s: f64,
    pub execute_s: f64,
    pub total_s: f64,
    /// Frame latency of the same geometry on the simulated accelerator.
    pub simulated_photonic_s: f64,
}

struct Job {
    input: Vec<f32>,
    submitted: Instant,
    reply: mpsc::Sender<Result<InferenceResponse>>,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifacts_dir: std::path::PathBuf,
    pub models: Vec<String>,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Worker replicas per model (each owns its own PJRT runtime +
    /// compiled executable; the router load-balances across them).
    pub replicas: usize,
    /// Accelerator whose simulated latency is attached to responses.
    pub accelerator: AcceleratorConfig,
    /// Execution model used for that simulated latency (analytic by
    /// default; the event backend is far more detailed and far slower —
    /// it runs once per worker at startup, not per request).
    pub sim_backend: BackendKind,
    pub weight_seed: u64,
}

impl ServerConfig {
    pub fn new(artifacts_dir: impl Into<std::path::PathBuf>, models: &[&str]) -> ServerConfig {
        ServerConfig {
            artifacts_dir: artifacts_dir.into(),
            models: models.iter().map(|m| m.to_string()).collect(),
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            replicas: 1,
            accelerator: AcceleratorConfig::oxbnn_50(),
            sim_backend: BackendKind::Analytic,
            weight_seed: 0x0B17,
        }
    }
}

/// Running server handle.
pub struct Server {
    /// Keyed by (model, replica id).
    senders: BTreeMap<(String, usize), mpsc::Sender<Job>>,
    router: Mutex<Router>,
    pub metrics: Arc<Mutex<ServerMetrics>>,
    workers: Vec<thread::JoinHandle<()>>,
    input_lens: BTreeMap<String, usize>,
}

/// Generate the deterministic synthetic weights for an artifact.
pub fn synthetic_weights(artifact: &Artifact, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed ^ artifact.name.len() as u64);
    artifact.args[1..]
        .iter()
        .map(|a| rng.bits(a.element_count()))
        .collect()
}

/// Build a Workload (simulator geometry) from a bnn_forward artifact.
pub fn workload_from_artifact(artifact: &Artifact) -> Workload {
    let layers = artifact
        .layers
        .iter()
        .enumerate()
        .map(|(i, d)| GemmLayer::new(format!("{}.{}", artifact.name, i), d.h, d.s, d.k))
        .collect();
    Workload::new(artifact.name.clone(), layers)
}

impl Server {
    /// Start workers for every configured model.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let manifest = Manifest::load(&cfg.artifacts_dir).context("loading manifest")?;
        let metrics = Arc::new(Mutex::new(ServerMetrics::default()));
        let mut senders = BTreeMap::new();
        let mut workers = Vec::new();
        let mut router = Router::default();
        let mut input_lens = BTreeMap::new();

        for model in &cfg.models {
            let artifact_name = format!("bnn_{}", model);
            let artifact = manifest.get(&artifact_name)?.clone();
            if artifact.kind != "bnn_forward" {
                return Err(anyhow!("artifact {} is not a bnn_forward", artifact_name));
            }
            input_lens.insert(model.clone(), artifact.args[0].element_count());
            for replica in 0..cfg.replicas.max(1) {
                let (tx, rx) = mpsc::channel::<Job>();
                senders.insert((model.clone(), replica), tx);
                router.register(model, replica);
                let metrics = Arc::clone(&metrics);
                let cfg2 = cfg.clone();
                let model2 = model.clone();
                let artifact2 = artifact.clone();
                let handle = thread::Builder::new()
                    .name(format!("oxbnn-serve-{}-{}", model, replica))
                    .spawn(move || worker_loop(cfg2, model2, artifact2, rx, metrics))
                    .context("spawning worker")?;
                workers.push(handle);
            }
        }
        Ok(Server {
            senders,
            router: Mutex::new(router),
            metrics,
            workers,
            input_lens,
        })
    }

    /// Expected input length for a model.
    pub fn input_len(&self, model: &str) -> Option<usize> {
        self.input_lens.get(model).copied()
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(
        &self,
        req: InferenceRequest,
    ) -> Result<(usize, mpsc::Receiver<Result<InferenceResponse>>)> {
        let expect = self
            .input_len(&req.model)
            .ok_or_else(|| anyhow!("unknown model '{}'", req.model))?;
        if req.input.len() != expect {
            return Err(anyhow!(
                "model '{}' expects {} input values, got {}",
                req.model,
                expect,
                req.input.len()
            ));
        }
        // Route to the least-loaded replica of the model.
        let replica = self
            .router
            .lock()
            .unwrap()
            .route(&req.model)
            .map_err(|e| anyhow!(e))?;
        let (reply_tx, reply_rx) = mpsc::channel();
        let job = Job { input: req.input, submitted: Instant::now(), reply: reply_tx };
        self.senders
            .get(&(req.model.clone(), replica))
            .expect("router only returns registered replicas")
            .send(job)
            .map_err(|_| anyhow!("worker for '{}' is gone", req.model))?;
        Ok((replica, reply_rx))
    }

    /// Convenience: submit and wait.
    pub fn infer_blocking(&self, req: InferenceRequest) -> Result<InferenceResponse> {
        let model = req.model.clone();
        let (replica, rx) = self.submit(req)?;
        let resp = rx
            .recv()
            .map_err(|_| anyhow!("worker dropped the reply channel"))??;
        self.router.lock().unwrap().complete(&model, replica);
        Ok(resp)
    }

    /// Graceful shutdown: close queues and join workers.
    pub fn shutdown(mut self) {
        self.senders.clear(); // drop all senders → workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    cfg: ServerConfig,
    model: String,
    artifact: Artifact,
    rx: mpsc::Receiver<Job>,
    metrics: Arc<Mutex<ServerMetrics>>,
) {
    // Heavy setup inside the worker: PJRT client + compile + weights.
    let runtime = match Runtime::cpu() {
        Ok(r) => r,
        Err(e) => {
            crate::log_error!("{}: PJRT init failed: {:#}", model, e);
            return;
        }
    };
    let exe = match runtime.load_artifact(&artifact) {
        Ok(e) => e,
        Err(e) => {
            crate::log_error!("{}: artifact compile failed: {:#}", model, e);
            return;
        }
    };
    // Weights are staged on the device ONCE; the request hot path only
    // uploads the input frame (EXPERIMENTS.md §Perf L3).
    let weights: Vec<crate::runtime::client::DeviceTensor> =
        synthetic_weights(&artifact, cfg.weight_seed)
            .into_iter()
            .zip(&artifact.args[1..])
            .map(|(bits, spec)| {
                let host =
                    HostTensor::new(spec.shape.clone(), bits).expect("weight shape");
                runtime.to_device(&host).expect("weight upload")
            })
            .collect();
    let simulated_s = Session::builder()
        .accelerator(cfg.accelerator.clone())
        .workload(workload_from_artifact(&artifact))
        .backend(cfg.sim_backend)
        .build()
        .expect("accelerator and workload are set, the session cannot fail")
        .run()
        .frame_latency_s;
    let input_shape = artifact.args[0].shape.clone();
    crate::log_info!(
        "{}: worker ready (compile {:.3}s, simulated photonic frame {})",
        model,
        exe.compile_seconds,
        crate::util::units::fmt_time(simulated_s)
    );

    let epoch = Instant::now();
    let mut batcher: Batcher<Job> = Batcher::new(cfg.max_batch, cfg.max_wait.as_secs_f64());
    loop {
        // Wait bounded by the batcher's next deadline.
        let now = epoch.elapsed().as_secs_f64();
        let timeout = batcher
            .next_deadline_in(now)
            .map(Duration::from_secs_f64)
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(job) => {
                let now = epoch.elapsed().as_secs_f64();
                batcher.push(job, now);
                // Opportunistically absorb everything already queued.
                while batcher.len() < batcher.max_batch {
                    match rx.try_recv() {
                        Ok(j) => batcher.push(j, now),
                        Err(_) => break,
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Shutdown: flush what's left, then exit.
                let rest = batcher.flush();
                if !rest.is_empty() {
                    run_batch(&runtime, &exe, &weights, &input_shape, rest, simulated_s, &metrics);
                }
                return;
            }
        }
        // Continuous batching: execute whatever is queued right away.
        // Backlog under load forms real batches; a lone request never
        // waits on the max_wait timer (EXPERIMENTS.md §Perf L3).
        if let Some(batch) = batcher.drain_now() {
            run_batch(&runtime, &exe, &weights, &input_shape, batch, simulated_s, &metrics);
        }
    }
}

fn run_batch(
    runtime: &Runtime,
    exe: &crate::runtime::Executable,
    weights: &[crate::runtime::client::DeviceTensor],
    input_shape: &[usize],
    batch: Vec<super::batcher::Pending<Job>>,
    simulated_s: f64,
    metrics: &Arc<Mutex<ServerMetrics>>,
) {
    let size = batch.len();
    for pending in batch {
        let job = pending.item;
        let queue_s = job.submitted.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let result = (|| -> Result<InferenceResponse> {
            // Only the input frame crosses host->device per request.
            let input = runtime
                .to_device(&HostTensor::new(input_shape.to_vec(), job.input.clone())?)?;
            let mut args: Vec<&crate::runtime::client::DeviceTensor> =
                Vec::with_capacity(1 + weights.len());
            args.push(&input);
            args.extend(weights.iter());
            let out = exe.run_device(&args)?;
            let execute_s = t0.elapsed().as_secs_f64();
            Ok(InferenceResponse {
                logits: out.data,
                queue_s,
                execute_s,
                total_s: job.submitted.elapsed().as_secs_f64(),
                simulated_photonic_s: simulated_s,
            })
        })();
        if let Ok(resp) = &result {
            let mut m = metrics.lock().unwrap();
            m.queue.record(resp.queue_s);
            m.execute.record(resp.execute_s);
            m.end_to_end.record(resp.total_s);
            m.completed += 1;
        }
        let _ = job.reply.send(result);
    }
    let mut m = metrics.lock().unwrap();
    m.batches += 1;
    m.batched_requests += size as u64;
}
