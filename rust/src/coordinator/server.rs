//! Inference server: the L3 coordinator's serving loop.
//!
//! One worker thread per (model, replica) owns a runtime engine and the
//! model's compiled artifact (executables are not `Send`, so they are
//! constructed inside their worker). Requests flow:
//!
//! ```text
//! submit() → Router (least-loaded replica) → bounded worker queue →
//!   Batcher (policy: Immediate | Deadline) → BatchRunner (N frames,
//!   ONE executable invocation) → response channel (+ metrics)
//! ```
//!
//! Back-pressure: each replica queue is a bounded `sync_channel` of
//! `queue_depth` slots; when it is full `submit` fails fast with
//! [`SubmitError::QueueFull`] instead of growing an unbounded backlog.
//! Total in-flight work per replica is therefore bounded by
//! `queue_depth + max_batch + one executing batch`.
//!
//! Router accounting: `route` increments a replica's outstanding count;
//! the owning worker decrements it on the reply path (success, failure,
//! or shutdown flush), so counts return to zero no matter how the caller
//! consumes (or drops) the reply receiver.
//!
//! Lifecycle: [`Server::drain`] gracefully flushes and joins through a
//! shared handle (the HTTP front-end holds `Arc<Server>`), and
//! [`Server::quarantine`] removes one replica from routing while still
//! flushing its accepted jobs — both guarantee zero lost accepted
//! requests.
//!
//! Each response also carries the *simulated photonic latency* the frame
//! would have on the configured OXBNN accelerator (from the analytic
//! model), tying the serving path to the paper's performance story.
//! Weights are synthetic {0,1} bits generated deterministically per model
//! (DESIGN.md substitution: performance is geometry-driven; functional
//! correctness is validated against the independent rust engine).

use std::collections::BTreeMap;
use std::fmt;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::batcher::{Batcher, Pending};
use super::metrics::ServerMetrics;
use super::router::{RouteError, Router};
use crate::api::BackendKind;
use crate::arch::accelerator::AcceleratorConfig;
use crate::mapping::layer::GemmLayer;
use crate::runtime::manifest::{ArgSpec, Artifact, LayerDim, Manifest};
use crate::runtime::{BatchRunner, Runtime};
use crate::util::sync::lock_unpoisoned;
use crate::workloads::Workload;

/// An inference request (one frame, batch = 1 artifacts).
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub model: String,
    pub input: Vec<f32>,
}

/// The served result.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub logits: Vec<f32>,
    pub queue_s: f64,
    pub execute_s: f64,
    pub total_s: f64,
    /// Frame latency of the same geometry on the simulated accelerator.
    pub simulated_photonic_s: f64,
}

/// Admission/routing errors from [`Server::submit`]. `QueueFull` is the
/// back-pressure signal: the chosen replica's bounded queue had no free
/// slot, and the request was NOT enqueued — callers retry later or shed.
#[derive(Debug, thiserror::Error)]
pub enum SubmitError {
    #[error("unknown model '{0}'")]
    UnknownModel(String),
    #[error("model '{model}' expects {expect} input values, got {got}")]
    InvalidInput { model: String, expect: usize, got: usize },
    #[error(
        "model '{model}' replica {replica}: queue full ({depth} requests \
         deep) — back-pressure, retry later"
    )]
    QueueFull { model: String, replica: usize, depth: usize },
    #[error("worker for '{0}' is gone")]
    WorkerGone(String),
}

/// How the worker loop cuts batches from its queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPolicy {
    /// Continuous batching (vLLM-style): execute whatever is queued right
    /// away; backlog under load forms real batches, a lone request never
    /// waits. `max_wait` is not consulted.
    Immediate,
    /// Deadline batching: hold requests until the batch is full OR the
    /// oldest has waited `max_wait`, maximizing batch occupancy at the
    /// cost of bounded added latency.
    Deadline,
}

impl std::str::FromStr for BatchPolicy {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<BatchPolicy, String> {
        match s.to_ascii_lowercase().as_str() {
            "immediate" | "continuous" => Ok(BatchPolicy::Immediate),
            "deadline" | "max-wait" => Ok(BatchPolicy::Deadline),
            other => Err(format!(
                "unknown batch policy '{}' (expected immediate|deadline)",
                other
            )),
        }
    }
}

impl fmt::Display for BatchPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            BatchPolicy::Immediate => "immediate",
            BatchPolicy::Deadline => "deadline",
        })
    }
}

struct Job {
    input: Vec<f32>,
    submitted: Instant,
    reply: mpsc::Sender<Result<InferenceResponse>>,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifacts_dir: std::path::PathBuf,
    pub models: Vec<String>,
    pub max_batch: usize,
    /// Oldest-request deadline for [`BatchPolicy::Deadline`] (ignored by
    /// `Immediate`).
    pub max_wait: Duration,
    /// Batch-cut policy (default `Immediate`).
    pub policy: BatchPolicy,
    /// Bounded per-replica queue depth; a full queue rejects at admission
    /// with [`SubmitError::QueueFull`].
    pub queue_depth: usize,
    /// Worker replicas per model (each owns its own runtime engine +
    /// compiled executable; the router load-balances across them).
    pub replicas: usize,
    /// Accelerator whose simulated latency is attached to responses.
    pub accelerator: AcceleratorConfig,
    /// Execution model used for that simulated latency (analytic by
    /// default; the event backend is far more detailed and far slower —
    /// it runs once per worker at startup, not per request).
    pub sim_backend: BackendKind,
    /// Simulate the photonic reference as a *pipelined batch* of
    /// `max_batch` frames instead of one isolated frame — the honest
    /// per-frame latency for a server that batches requests anyway.
    /// Default ON (the pipelined path has conformance coverage): the
    /// analytic backend estimates the overlap from the plan's exact
    /// admission thresholds; `sim_backend: Event` runs the
    /// transaction-level whole-frame event space instead.
    pub sim_pipeline: bool,
    pub weight_seed: u64,
    /// Which functional implementation the sim engine dispatches frames
    /// to: bit-packed XNOR + popcount by default, with the f32 reference
    /// as escape hatch. The default comes from `OXBNN_FUNCTIONAL` (unset
    /// → packed); set the field to pin it regardless of the environment.
    pub functional_mode: crate::functional::FunctionalMode,
    /// Extra per-batch execution delay (test/chaos knob for emulating a
    /// slow backend; zero in production).
    pub execute_delay: Duration,
    /// In-memory manifest override: serve without an artifacts directory
    /// (see [`synthetic_manifest`]). When `None`, the manifest is loaded
    /// from `artifacts_dir`.
    pub manifest: Option<Manifest>,
    /// Shared execution-plan cache: every worker replica computing the
    /// simulated photonic latency of the same (accelerator, model
    /// geometry) pair reuses one compiled mapping. Share one cache
    /// across servers (or with api sessions) by cloning the `Arc`.
    pub plan_cache: Arc<crate::plan::PlanCache>,
}

impl ServerConfig {
    pub fn new(artifacts_dir: impl Into<std::path::PathBuf>, models: &[&str]) -> ServerConfig {
        ServerConfig {
            artifacts_dir: artifacts_dir.into(),
            models: models.iter().map(|m| m.to_string()).collect(),
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            policy: BatchPolicy::Immediate,
            queue_depth: 1024,
            replicas: 1,
            accelerator: AcceleratorConfig::oxbnn_50(),
            sim_backend: BackendKind::Analytic,
            sim_pipeline: true,
            weight_seed: 0x0B17,
            functional_mode: crate::functional::FunctionalMode::from_env(),
            execute_delay: Duration::ZERO,
            manifest: None,
            plan_cache: Arc::new(crate::plan::PlanCache::default()),
        }
    }

    /// Serve `models` from an in-memory synthetic manifest (no artifacts
    /// directory needed — the offline "stub backend" serving path).
    pub fn synthetic(models: &[&str]) -> ServerConfig {
        let mut cfg = ServerConfig::new("<synthetic>", models);
        cfg.manifest = Some(synthetic_manifest(models));
        cfg
    }
}

/// Running server handle.
///
/// Interior mutability on `senders`/`workers` lets a SHARED handle
/// (`Arc<Server>`, as the HTTP front-end holds) drain gracefully via
/// [`Server::drain`] and quarantine individual replicas via
/// [`Server::quarantine`]; the consuming [`Server::shutdown`] remains for
/// exclusive owners.
pub struct Server {
    /// Keyed by (model, replica id). Bounded: this is the back-pressure
    /// surface.
    senders: Mutex<BTreeMap<(String, usize), mpsc::SyncSender<Job>>>,
    router: Arc<Mutex<Router>>,
    pub metrics: Arc<Mutex<ServerMetrics>>,
    workers: Mutex<Vec<thread::JoinHandle<()>>>,
    input_lens: BTreeMap<String, usize>,
    queue_depth: usize,
}

/// FNV-1a over a byte string (weight-seed derivation: the full artifact
/// name must contribute, not a length-collision-prone digest of it).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut hash: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x0000_0100_0000_01B3);
    }
    hash
}

/// Generate the deterministic synthetic weights for an artifact. The RNG
/// stream is keyed by `seed` and an FNV-1a hash of the artifact name, so
/// distinct models get distinct weights even when their names are the
/// same length.
pub fn synthetic_weights(artifact: &Artifact, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = crate::util::rng::Rng::new(seed ^ fnv1a(artifact.name.as_bytes()));
    artifact.args[1..]
        .iter()
        .map(|a| rng.bits(a.element_count()))
        .collect()
}

/// Build a Workload (simulator geometry) from a bnn_forward artifact.
pub fn workload_from_artifact(artifact: &Artifact) -> Workload {
    let layers = artifact
        .layers
        .iter()
        .enumerate()
        .map(|(i, d)| GemmLayer::new(format!("{}.{}", artifact.name, i), d.h, d.s, d.k))
        .collect();
    Workload::new(artifact.name.clone(), layers)
}

/// An in-memory manifest of `bnn_<model>` artifacts over a small fixed
/// BNN geometry (8×8×3 input → 3×3 conv ×8 → 2×2 pool → FC 10), one per
/// requested model name. The sim engine executes these without any HLO
/// files on disk, so the full serving stack — and `serve-bench` — runs in
/// a bare checkout.
pub fn synthetic_manifest(models: &[&str]) -> Manifest {
    let mut artifacts = BTreeMap::new();
    for model in models {
        let name = format!("bnn_{}", model);
        // Models named `*-overcap` get an FC stage whose per-pass
        // accumulation exceeds any shipped PCA capacity (γ = 8 503 on
        // the default serving accelerator), so the static plan lint
        // refuses them with PL301 — the deterministic trigger for the
        // 422 load-rejection path.
        let fc_s = if model.ends_with("-overcap") { 40_000 } else { 128 };
        artifacts.insert(
            name.clone(),
            Artifact {
                name: name.clone(),
                kind: "bnn_forward".to_string(),
                file: std::path::PathBuf::from(format!("<synthetic>/{}.hlo.txt", name)),
                args: vec![
                    ArgSpec {
                        name: "x".to_string(),
                        shape: vec![1, 8, 8, 3],
                        dtype: "f32".to_string(),
                    },
                    ArgSpec {
                        name: "w0".to_string(),
                        shape: vec![27, 8],
                        dtype: "f32".to_string(),
                    },
                    ArgSpec {
                        name: "w1".to_string(),
                        shape: vec![fc_s, 10],
                        dtype: "f32".to_string(),
                    },
                ],
                output_shape: vec![1, 10],
                layers: vec![
                    LayerDim {
                        kind: "conv".to_string(),
                        h: 64,
                        s: 27,
                        k: 8,
                        fmap_hw: 8,
                    },
                    LayerDim {
                        kind: "fc".to_string(),
                        h: 1,
                        s: fc_s,
                        k: 10,
                        fmap_hw: 1,
                    },
                ],
                model: Some(model.to_string()),
                input_hw: Some(8),
                input_channels: Some(3),
                num_classes: Some(10),
                apply_activation: None,
            },
        );
    }
    Manifest { dir: std::path::PathBuf::from("<synthetic>"), artifacts }
}

/// Reject malformed bnn_forward artifacts up front: the functional
/// engine asserts on this geometry, and a worker-thread panic would
/// strand queued requests (dropped replies, leaked router accounting).
fn validate_artifact(artifact: &Artifact) -> Result<()> {
    let name = &artifact.name;
    if artifact.kind != "bnn_forward" {
        return Err(anyhow!("artifact {} is not a bnn_forward", name));
    }
    if artifact.layers.is_empty() {
        return Err(anyhow!("artifact {} has no layer table", name));
    }
    if artifact.args.len() != artifact.layers.len() + 1 {
        return Err(anyhow!(
            "artifact {}: {} args for {} layers (want input + one weight per layer)",
            name,
            artifact.args.len(),
            artifact.layers.len()
        ));
    }
    let hw = artifact
        .input_hw
        .ok_or_else(|| anyhow!("artifact {} missing input_hw", name))?;
    let c = artifact
        .input_channels
        .ok_or_else(|| anyhow!("artifact {} missing input_channels", name))?;
    if artifact.args[0].element_count() != hw * hw * c {
        return Err(anyhow!(
            "artifact {}: input arg has {} elements, geometry says {}x{}x{}",
            name,
            artifact.args[0].element_count(),
            hw,
            hw,
            c
        ));
    }
    for (spec, layer) in artifact.args[1..].iter().zip(&artifact.layers) {
        if spec.element_count() != layer.s * layer.k {
            return Err(anyhow!(
                "artifact {}: weight arg '{}' has {} elements, layer wants S*K = {}",
                name,
                spec.name,
                spec.element_count(),
                layer.s * layer.k
            ));
        }
    }
    Ok(())
}

impl Server {
    /// Start workers for every configured model.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        // Normalize the knobs once so workers can trust them (a zero
        // max_batch would panic Batcher::new inside the worker thread,
        // after start() already returned Ok).
        let mut cfg = cfg;
        cfg.max_batch = cfg.max_batch.max(1);
        cfg.queue_depth = cfg.queue_depth.max(1);
        cfg.replicas = cfg.replicas.max(1);
        let manifest = match &cfg.manifest {
            Some(m) => m.clone(),
            None => Manifest::load(&cfg.artifacts_dir).context("loading manifest")?,
        };
        let metrics = Arc::new(Mutex::new(ServerMetrics::default()));
        let router = Arc::new(Mutex::new(Router::default()));
        let mut senders = BTreeMap::new();
        let mut workers = Vec::new();
        let mut input_lens = BTreeMap::new();
        let queue_depth = cfg.queue_depth;

        for model in &cfg.models {
            let artifact_name = format!("bnn_{}", model);
            let artifact = manifest.get(&artifact_name)?.clone();
            validate_artifact(&artifact)?;
            input_lens.insert(model.clone(), artifact.args[0].element_count());
            for replica in 0..cfg.replicas {
                let (tx, rx) = mpsc::sync_channel::<Job>(queue_depth);
                senders.insert((model.clone(), replica), tx);
                lock_unpoisoned(&router).register(model, replica);
                let metrics = Arc::clone(&metrics);
                let router = Arc::clone(&router);
                let cfg2 = cfg.clone();
                let model2 = model.clone();
                let artifact2 = artifact.clone();
                let handle = thread::Builder::new()
                    .name(format!("oxbnn-serve-{}-{}", model, replica))
                    .spawn(move || {
                        worker_loop(cfg2, model2, replica, artifact2, rx, router, metrics)
                    })
                    .context("spawning worker")?;
                workers.push(handle);
            }
        }
        Ok(Server {
            senders: Mutex::new(senders),
            router,
            metrics,
            workers: Mutex::new(workers),
            input_lens,
            queue_depth,
        })
    }

    /// Expected input length for a model.
    pub fn input_len(&self, model: &str) -> Option<usize> {
        self.input_lens.get(model).copied()
    }

    /// Served model names.
    pub fn models(&self) -> Vec<String> {
        self.input_lens.keys().cloned().collect()
    }

    /// Outstanding (queued + executing) requests across a model's
    /// replicas. Returns to zero once all replies have been issued.
    pub fn outstanding(&self, model: &str) -> usize {
        lock_unpoisoned(&self.router).outstanding(model)
    }

    /// Bounded per-replica queue depth (the admission-control limit).
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    fn validate(&self, req: &InferenceRequest) -> std::result::Result<(), SubmitError> {
        let expect = self
            .input_lens
            .get(&req.model)
            .copied()
            .ok_or_else(|| SubmitError::UnknownModel(req.model.clone()))?;
        if req.input.len() != expect {
            return Err(SubmitError::InvalidInput {
                model: req.model.clone(),
                expect,
                got: req.input.len(),
            });
        }
        Ok(())
    }

    /// Enqueue on a routed replica. The router's outstanding count was
    /// already incremented for `replica`; every failure path here rolls
    /// it back.
    fn enqueue(
        &self,
        model: String,
        replica: usize,
        input: Vec<f32>,
    ) -> std::result::Result<mpsc::Receiver<Result<InferenceResponse>>, SubmitError> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let job = Job { input, submitted: Instant::now(), reply: reply_tx };
        let sender = lock_unpoisoned(&self.senders)
            .get(&(model.clone(), replica))
            .cloned();
        let sender = match sender {
            Some(s) => s,
            // Quarantined or drained between routing and enqueue.
            None => {
                lock_unpoisoned(&self.router).complete(&model, replica);
                return Err(SubmitError::WorkerGone(model));
            }
        };
        match sender.try_send(job) {
            Ok(()) => Ok(reply_rx),
            Err(mpsc::TrySendError::Full(_)) => {
                lock_unpoisoned(&self.router).complete(&model, replica);
                lock_unpoisoned(&self.metrics).rejected += 1;
                Err(SubmitError::QueueFull { model, replica, depth: self.queue_depth })
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                lock_unpoisoned(&self.router).complete(&model, replica);
                Err(SubmitError::WorkerGone(model))
            }
        }
    }

    /// Submit a request; returns the chosen replica and a receiver for
    /// the response. Fails fast with [`SubmitError::QueueFull`] when the
    /// replica's bounded queue has no free slot (back-pressure).
    pub fn submit(
        &self,
        req: InferenceRequest,
    ) -> std::result::Result<(usize, mpsc::Receiver<Result<InferenceResponse>>), SubmitError>
    {
        self.validate(&req)?;
        // Route to the least-loaded replica of the model. The router's
        // outstanding count is decremented by the worker on the reply
        // path (or in enqueue, if admission fails).
        let replica = lock_unpoisoned(&self.router)
            .route(&req.model)
            .map_err(|e| match e {
                RouteError::UnknownModel(m) => SubmitError::UnknownModel(m),
            })?;
        let rx = self.enqueue(req.model, replica, req.input)?;
        Ok((replica, rx))
    }

    /// Submit pinned to a SPECIFIC replica (session affinity, health
    /// probes). No load balancing is applied; a quarantined or absent
    /// replica fails with [`SubmitError::WorkerGone`].
    pub fn submit_to(
        &self,
        req: InferenceRequest,
        replica: usize,
    ) -> std::result::Result<mpsc::Receiver<Result<InferenceResponse>>, SubmitError> {
        self.validate(&req)?;
        if lock_unpoisoned(&self.router)
            .route_to(&req.model, replica)
            .is_err()
        {
            return Err(SubmitError::WorkerGone(req.model));
        }
        self.enqueue(req.model, replica, req.input)
    }

    /// Convenience: submit and wait.
    pub fn infer_blocking(&self, req: InferenceRequest) -> Result<InferenceResponse> {
        let (_replica, rx) = self.submit(req)?;
        let resp = rx
            .recv()
            .map_err(|_| anyhow!("worker dropped the reply channel"))??;
        Ok(resp)
    }

    /// Live (non-quarantined) replica ids for a model.
    pub fn replicas(&self, model: &str) -> Vec<usize> {
        lock_unpoisoned(&self.router).replica_ids(model)
    }

    /// Quarantine one replica: deregister it from routing and close its
    /// queue. Already-accepted jobs are NOT lost — the worker receives
    /// every buffered job before it observes the disconnect, flushes its
    /// batcher, and exits. Returns `false` when the replica was already
    /// gone. The worker thread is joined later by `drain`/`shutdown`.
    pub fn quarantine(&self, model: &str, replica: usize) -> bool {
        lock_unpoisoned(&self.router).deregister(model, replica);
        lock_unpoisoned(&self.senders)
            .remove(&(model.to_string(), replica))
            .is_some()
    }

    /// Graceful drain through a SHARED handle (`&self`, so `Arc<Server>`
    /// holders can drain too): close every queue, let workers flush all
    /// accepted requests, and join them. Idempotent; new submissions
    /// racing the drain fail with [`SubmitError::WorkerGone`] instead of
    /// being silently dropped.
    pub fn drain(&self) {
        lock_unpoisoned(&self.senders).clear(); // workers see Disconnected
        let workers: Vec<thread::JoinHandle<()>> =
            lock_unpoisoned(&self.workers).drain(..).collect();
        for w in workers {
            let _ = w.join();
        }
    }

    /// Graceful shutdown for exclusive owners: every accepted request
    /// receives its reply first. Equivalent to [`Server::drain`].
    pub fn shutdown(self) {
        self.drain();
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    cfg: ServerConfig,
    model: String,
    replica: usize,
    artifact: Artifact,
    rx: mpsc::Receiver<Job>,
    router: Arc<Mutex<Router>>,
    metrics: Arc<Mutex<ServerMetrics>>,
) {
    // Heavy setup inside the worker: engine init + compile + weights.
    let runtime = match Runtime::cpu() {
        Ok(r) => r,
        Err(e) => {
            crate::log_error!("{}[{}]: engine init failed: {:#}", model, replica, e);
            return fail_all(rx, &router, &model, replica, &metrics, &format!("{:#}", e));
        }
    };
    let mut runner = match BatchRunner::with_mode(
        runtime,
        artifact.clone(),
        synthetic_weights(&artifact, cfg.weight_seed),
        cfg.functional_mode,
    ) {
        Ok(r) => r,
        Err(e) => {
            crate::log_error!("{}[{}]: artifact compile failed: {:#}", model, replica, e);
            return fail_all(rx, &router, &model, replica, &metrics, &format!("{:#}", e));
        }
    };
    // With `sim_pipeline`, the photonic reference is the effective
    // per-frame latency of a pipelined `max_batch`-frame run (frames
    // overlap in one event space) rather than one isolated frame.
    let simulated_s = match crate::api::simulated_effective_latency_cached(
        &cfg.plan_cache,
        &cfg.accelerator,
        &workload_from_artifact(&artifact),
        cfg.sim_backend,
        if cfg.sim_pipeline { cfg.max_batch } else { 1 },
        cfg.sim_pipeline,
    ) {
        Ok(s) => s,
        Err(e) => {
            crate::log_error!("{}[{}]: photonic reference sim failed: {:#}", model, replica, e);
            return fail_all(rx, &router, &model, replica, &metrics, &format!("{:#}", e));
        }
    };
    crate::log_info!(
        "{}[{}]: worker ready (compile {:.3}s, {} policy, {} functional engine, \
         simulated photonic frame {})",
        model,
        replica,
        runner.compile_seconds,
        cfg.policy,
        runner.mode(),
        crate::util::units::fmt_time(simulated_s)
    );

    // Sleep bound while idle (no deadline pending).
    const IDLE_POLL: Duration = Duration::from_millis(50);
    let epoch = Instant::now();
    let mut batcher: Batcher<Job> = Batcher::new(cfg.max_batch, cfg.max_wait.as_secs_f64());
    let push_job = |batcher: &mut Batcher<Job>, job: Job| {
        // Each job keeps its OWN arrival time (epoch-relative) so queue
        // metrics and deadline cuts stay truthful for absorbed backlogs.
        let arrived = job.submitted.saturating_duration_since(epoch).as_secs_f64();
        batcher.push(job, arrived);
    };
    loop {
        let timeout = match cfg.policy {
            BatchPolicy::Deadline => {
                let now = epoch.elapsed().as_secs_f64();
                batcher
                    .next_deadline_in(now)
                    .map(Duration::from_secs_f64)
                    .unwrap_or(IDLE_POLL)
            }
            // Immediate drains the batcher every iteration, so any wait
            // here only happens while empty.
            BatchPolicy::Immediate => IDLE_POLL,
        };
        match rx.recv_timeout(timeout) {
            Ok(job) => {
                push_job(&mut batcher, job);
                // Opportunistically absorb everything already queued, up
                // to one full batch.
                while batcher.len() < batcher.max_batch {
                    match rx.try_recv() {
                        Ok(j) => push_job(&mut batcher, j),
                        Err(_) => break,
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Shutdown: flush what's left in max_batch chunks, then
                // exit. Buffered channel jobs were already returned by
                // recv_timeout before Disconnected fired.
                while let Some(batch) = batcher.drain_now() {
                    run_batch(
                        &mut runner, batch, simulated_s, cfg.execute_delay, &model,
                        replica, &router, &metrics,
                    );
                }
                return;
            }
        }
        match cfg.policy {
            BatchPolicy::Immediate => {
                while let Some(batch) = batcher.drain_now() {
                    run_batch(
                        &mut runner, batch, simulated_s, cfg.execute_delay, &model,
                        replica, &router, &metrics,
                    );
                }
            }
            BatchPolicy::Deadline => {
                let now = epoch.elapsed().as_secs_f64();
                while let Some(batch) = batcher.drain(now) {
                    run_batch(
                        &mut runner, batch, simulated_s, cfg.execute_delay, &model,
                        replica, &router, &metrics,
                    );
                }
            }
        }
    }
}

/// Worker-startup failure path: quarantine the replica (so least-loaded
/// routing stops preferring a dead-but-instantly-erroring target), then
/// give every already-queued job an error reply until shutdown.
fn fail_all(
    rx: mpsc::Receiver<Job>,
    router: &Arc<Mutex<Router>>,
    model: &str,
    replica: usize,
    metrics: &Arc<Mutex<ServerMetrics>>,
    why: &str,
) {
    // Deregistration also forgets this replica's outstanding counts, so
    // the jobs drained below need no complete() calls.
    lock_unpoisoned(router).deregister(model, replica);
    while let Ok(job) = rx.recv() {
        lock_unpoisoned(metrics).failed += 1;
        let _ = job
            .reply
            .send(Err(anyhow!("{}[{}]: worker failed to start: {}", model, replica, why)));
    }
}

/// Execute one cut batch: N frames → one `BatchRunner::run` call (one
/// executable invocation on a batch-capable engine), then split replies.
/// Router accounting is released per job BEFORE its reply is sent, so
/// observers never see a completed request still counted as outstanding.
#[allow(clippy::too_many_arguments)]
fn run_batch(
    runner: &mut BatchRunner,
    batch: Vec<Pending<Job>>,
    simulated_s: f64,
    execute_delay: Duration,
    model: &str,
    replica: usize,
    router: &Arc<Mutex<Router>>,
    metrics: &Arc<Mutex<ServerMetrics>>,
) {
    let size = batch.len();
    if size == 0 {
        return;
    }
    let cut = Instant::now();
    let jobs: Vec<Job> = batch.into_iter().map(|p| p.item).collect();
    let queue_s: Vec<f64> = jobs
        .iter()
        .map(|j| cut.saturating_duration_since(j.submitted).as_secs_f64())
        .collect();
    let frames: Vec<&[f32]> = jobs.iter().map(|j| j.input.as_slice()).collect();
    let t0 = Instant::now();
    if !execute_delay.is_zero() {
        thread::sleep(execute_delay);
    }
    // A panicking executable (e.g. geometry the functional engine
    // rejects) must not kill the worker: that would strand every queued
    // request and leak router accounting. Contain it as a failed batch.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        runner.run(&frames)
    }))
    .unwrap_or_else(|_| Err(anyhow!("executable panicked")));
    let execute_s = t0.elapsed().as_secs_f64();
    // Release router accounting for the WHOLE batch before any reply is
    // sent (one lock), so observers never see a completed request still
    // counted as outstanding.
    {
        let mut r = lock_unpoisoned(router);
        for _ in 0..size {
            r.complete(model, replica);
        }
    }
    match result {
        Ok(outputs) => {
            // A well-behaved engine returns one output per frame. If it
            // comes up short, the unmatched jobs MUST still get replies:
            // zip truncation would silently drop their reply senders and
            // strand blocking callers forever (a release-mode-only loss,
            // since the old debug_assert compiled out).
            let n_ok = outputs.len().min(size);
            if outputs.len() != size {
                crate::log_error!(
                    "{}[{}]: engine returned {} outputs for a batch of {}",
                    model,
                    replica,
                    outputs.len(),
                    size
                );
            }
            let total_s: Vec<f64> = jobs
                .iter()
                .map(|j| j.submitted.elapsed().as_secs_f64())
                .collect();
            {
                let mut m = lock_unpoisoned(metrics);
                for (q, t) in queue_s.iter().zip(&total_s).take(n_ok) {
                    m.queue.record(*q);
                    m.execute.record(execute_s);
                    m.end_to_end.record(*t);
                    m.completed += 1;
                }
                m.failed += (size - n_ok) as u64;
                m.record_batch(size);
            }
            let mut out_iter = outputs.into_iter();
            for (job, (q, t)) in jobs
                .into_iter()
                .zip(queue_s.into_iter().zip(total_s))
            {
                match out_iter.next() {
                    Some(logits) => {
                        let _ = job.reply.send(Ok(InferenceResponse {
                            logits,
                            queue_s: q,
                            execute_s,
                            total_s: t,
                            simulated_photonic_s: simulated_s,
                        }));
                    }
                    None => {
                        let _ = job.reply.send(Err(anyhow!(
                            "engine returned a short batch ({} of {} outputs)",
                            n_ok,
                            size
                        )));
                    }
                }
            }
        }
        Err(e) => {
            let msg = format!("executing batch of {}: {:#}", size, e);
            crate::log_error!("{}[{}]: {}", model, replica, msg);
            {
                let mut m = lock_unpoisoned(metrics);
                m.failed += size as u64;
                m.record_batch(size);
            }
            for job in jobs {
                let _ = job.reply.send(Err(anyhow!("{}", msg)));
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests may unwrap
mod tests {
    use super::*;

    fn artifact_named(name: &str) -> Artifact {
        let mut m = synthetic_manifest(&["x"]);
        let mut a = m.artifacts.remove("bnn_x").unwrap();
        a.name = name.to_string();
        a
    }

    #[test]
    fn synthetic_weights_diverge_for_equal_length_names() {
        // Regression: seeding by name *length* gave identical weights to
        // any two models with same-length names.
        let a = synthetic_weights(&artifact_named("bnn_alpha"), 7);
        let b = synthetic_weights(&artifact_named("bnn_betaa"), 7);
        assert_eq!(a.len(), b.len());
        assert_ne!(a, b, "equal-length names must still diverge");
        // Deterministic per (name, seed).
        let a2 = synthetic_weights(&artifact_named("bnn_alpha"), 7);
        assert_eq!(a, a2);
        let a3 = synthetic_weights(&artifact_named("bnn_alpha"), 8);
        assert_ne!(a, a3);
    }

    #[test]
    fn batch_policy_parses() {
        assert_eq!("immediate".parse::<BatchPolicy>().unwrap(), BatchPolicy::Immediate);
        assert_eq!("Deadline".parse::<BatchPolicy>().unwrap(), BatchPolicy::Deadline);
        assert_eq!("continuous".parse::<BatchPolicy>().unwrap(), BatchPolicy::Immediate);
        assert!("sometimes".parse::<BatchPolicy>().is_err());
        assert_eq!(BatchPolicy::Deadline.to_string(), "deadline");
    }

    #[test]
    fn malformed_artifacts_rejected_at_start() {
        // Well-formed baseline passes.
        assert!(validate_artifact(&artifact_named("bnn_ok")).is_ok());
        // The functional engine would panic on these inside a worker
        // thread; they must be rejected up front instead.
        let mut a = artifact_named("bnn_bad");
        a.input_hw = None;
        assert!(validate_artifact(&a).is_err());
        let mut a = artifact_named("bnn_bad");
        a.args.pop();
        assert!(validate_artifact(&a).is_err());
        let mut a = artifact_named("bnn_bad");
        a.layers[0].s = 99;
        assert!(validate_artifact(&a).is_err());
        let mut a = artifact_named("bnn_bad");
        a.kind = "xnor_gemm".into();
        assert!(validate_artifact(&a).is_err());
    }

    #[test]
    fn replicas_share_one_plan_compile() {
        // Both replicas simulate the same model geometry on the same
        // accelerator: the shared PlanCache must hold exactly one plan.
        let mut cfg = ServerConfig::synthetic(&["tiny"]);
        cfg.replicas = 2;
        let cache = Arc::clone(&cfg.plan_cache);
        let server = Server::start(cfg).unwrap();
        let input_len = server.input_len("tiny").unwrap();
        let resp = server
            .infer_blocking(InferenceRequest {
                model: "tiny".into(),
                input: vec![0.25; input_len],
            })
            .unwrap();
        assert!(resp.simulated_photonic_s > 0.0);
        assert_eq!(cache.len(), 1, "replicas must share one compiled plan");
        server.shutdown();
    }

    #[test]
    fn sim_pipeline_reference_is_no_slower_per_frame() {
        use crate::api::BackendKind;
        // Same synthetic model, event-backend photonic reference, with and
        // without the pipelined-batch reference: the pipelined effective
        // per-frame latency can only improve on the isolated frame.
        let run = |pipeline: bool| {
            let mut cfg = ServerConfig::synthetic(&["tiny"]);
            cfg.sim_backend = BackendKind::Event;
            cfg.sim_pipeline = pipeline;
            cfg.max_batch = 8;
            let server = Server::start(cfg).unwrap();
            let input_len = server.input_len("tiny").unwrap();
            let resp = server
                .infer_blocking(InferenceRequest {
                    model: "tiny".into(),
                    input: vec![0.25; input_len],
                })
                .unwrap();
            server.shutdown();
            resp.simulated_photonic_s
        };
        let frame = run(false);
        let pipelined = run(true);
        assert!(frame > 0.0 && pipelined > 0.0);
        assert!(
            pipelined <= frame * (1.0 + 1e-9),
            "pipelined photonic reference {} vs frame {}",
            pipelined,
            frame
        );
    }

    #[test]
    fn synthetic_manifest_geometry_is_consistent() {
        let m = synthetic_manifest(&["tiny", "other"]);
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("bnn_tiny").unwrap();
        assert_eq!(a.kind, "bnn_forward");
        assert_eq!(a.args[0].element_count(), 8 * 8 * 3);
        // Weight shapes must match the layer table (S × K).
        for (w, l) in a.args[1..].iter().zip(&a.layers) {
            assert_eq!(w.shape, vec![l.s, l.k]);
        }
        // The functional engine accepts the geometry end to end.
        let weights = synthetic_weights(a, 1);
        let x = vec![0.25f32; a.args[0].element_count()];
        let logits = crate::functional::bnn::forward(a, &x, &weights);
        assert_eq!(logits.len(), 10);
    }
}
