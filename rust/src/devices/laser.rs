//! Laser / WDM optical power budget (paper Eq. 5 and Table I).
//!
//! An XPC sources N DWDM wavelengths; the combined comb is split into M
//! branch waveguides (one per XPE), passes the OXG array, and lands on the
//! PCA photodetector. Eq. 5 balances the laser power per wavelength
//! against all path losses so the PD still receives `P_PD-opt`.
//!
//! In dB form, the budget used here (verified to reproduce Table II's N
//! column, see analysis::scalability):
//!
//! ```text
//! P_laser(dBm) − [ IL_EC + IL_SMF + IL_i/p-OXG + IL_penalty
//!                  + IL_WG·(N·d_OXG + d_element)
//!                  + OBL_OXG·(N−1)
//!                  + EL_splitter·log2(M)
//!                  + 10·log10(M) ]  ≥  P_PD-opt(dBm)
//! ```
//!
//! The wall-plug efficiency η_WPE converts the *optical* laser power into
//! *electrical* power for the energy model (it does not belong in the
//! optical budget).

/// Optical path-loss parameters (paper Table I values as defaults).
#[derive(Debug, Clone)]
pub struct LossBudget {
    /// Laser power per wavelength (dBm); Table I: 5 dBm.
    pub p_laser_dbm: f64,
    /// Single-mode fiber insertion loss (dB).
    pub il_smf_db: f64,
    /// Fiber-to-chip coupling loss (dB).
    pub il_ec_db: f64,
    /// Waveguide propagation loss (dB/mm).
    pub il_wg_db_per_mm: f64,
    /// Splitter excess loss per stage (dB).
    pub el_splitter_db: f64,
    /// Insertion loss of the in-path OXG (dB).
    pub il_oxg_db: f64,
    /// Out-of-band loss of each non-resonant OXG passed (dB).
    pub obl_oxg_db: f64,
    /// Network penalty (crosstalk etc.) (dB).
    pub il_penalty_db: f64,
    /// Gap between adjacent OXGs (mm); Table I: 20 µm.
    pub d_oxg_mm: f64,
    /// Extra element length (mm); not specified by Table I → 0.
    pub d_element_mm: f64,
    /// Laser wall-plug efficiency (for electrical power conversion only).
    pub eta_wpe: f64,
}

impl Default for LossBudget {
    fn default() -> Self {
        LossBudget {
            p_laser_dbm: 5.0,
            il_smf_db: 0.0,
            il_ec_db: 1.6,
            il_wg_db_per_mm: 0.3,
            el_splitter_db: 0.01,
            il_oxg_db: 4.0,
            obl_oxg_db: 0.01,
            il_penalty_db: 4.8,
            d_oxg_mm: 0.02,
            d_element_mm: 0.0,
            eta_wpe: 0.1,
        }
    }
}

impl LossBudget {
    /// Total path loss (dB) for an XPE array of `n` OXGs in an XPC with
    /// `m` branches.
    pub fn total_loss_db(&self, n: usize, m: usize) -> f64 {
        assert!(n >= 1 && m >= 1);
        let split_db = 10.0 * (m as f64).log10();
        let splitter_excess = self.el_splitter_db * (m as f64).log2().max(0.0);
        let wg = self.il_wg_db_per_mm * (n as f64 * self.d_oxg_mm + self.d_element_mm);
        let obl = self.obl_oxg_db * (n as f64 - 1.0);
        self.il_smf_db
            + self.il_ec_db
            + self.il_oxg_db
            + self.il_penalty_db
            + wg
            + obl
            + splitter_excess
            + split_db
    }

    /// Received power at the PD (dBm) for a given (n, m).
    pub fn received_dbm(&self, n: usize, m: usize) -> f64 {
        self.p_laser_dbm - self.total_loss_db(n, m)
    }

    /// Largest XPE size N (with M = N, as the paper assumes) such that the
    /// PD still receives `p_pd_dbm`.
    ///
    /// The paper's Table II values correspond to the *ceiling* of the
    /// continuous solution of `loss(N) = budget` (validated: reproduces
    /// all seven N rows from the paper's P_PD-opt column). We therefore
    /// accept N where the loss overshoot is < the loss increment of one
    /// more gate.
    pub fn max_n(&self, p_pd_dbm: f64) -> usize {
        let budget = self.p_laser_dbm - p_pd_dbm;
        if self.total_loss_db(1, 1) > budget {
            return 0;
        }
        // Walk up while the *previous* N still fits: ceil of the
        // continuous crossing point.
        let mut n = 1;
        loop {
            let next = n + 1;
            if self.total_loss_db(n, n) >= budget {
                // crossing happened between n-1 and n → ceil = n
                return n;
            }
            if next > 100_000 {
                return n; // guard: budget never exhausted (unphysical)
            }
            n = next;
        }
    }

    /// Electrical wall-plug power (W) for one wavelength's laser.
    pub fn laser_electrical_w(&self) -> f64 {
        crate::util::units::dbm_to_watt(self.p_laser_dbm) / self.eta_wpe
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_monotone_in_n_and_m() {
        let b = LossBudget::default();
        assert!(b.total_loss_db(20, 20) > b.total_loss_db(10, 10));
        assert!(b.total_loss_db(10, 20) > b.total_loss_db(10, 10));
    }

    #[test]
    fn split_loss_dominates() {
        let b = LossBudget::default();
        // Fixed losses = 1.6 + 4 + 4.8 = 10.4 dB at N=M=1 (plus tiny wg).
        let l = b.total_loss_db(1, 1);
        assert!((l - 10.406).abs() < 0.01, "loss = {}", l);
    }

    #[test]
    fn max_n_matches_paper_table2() {
        // (P_PD-opt dBm from paper Table II) → expected N.
        let rows = [
            (-24.69, 66),
            (-23.49, 53),
            (-21.9, 39),
            (-20.5, 29),
            (-19.5, 24),
            (-18.9, 21),
            (-18.5, 19),
        ];
        let b = LossBudget::default();
        for (p_pd, want_n) in rows {
            let n = b.max_n(p_pd);
            assert_eq!(n, want_n, "P_PD-opt = {} dBm", p_pd);
        }
    }

    #[test]
    fn max_n_zero_when_budget_insufficient() {
        let b = LossBudget::default();
        // Sensitivity above the laser power: nothing fits.
        assert_eq!(b.max_n(6.0), 0);
    }

    #[test]
    fn received_power_consistent() {
        let b = LossBudget::default();
        let n = 19;
        let received = b.received_dbm(n, n);
        assert!((received - (5.0 - b.total_loss_db(n, n))).abs() < 1e-12);
    }

    #[test]
    fn electrical_power_uses_wpe() {
        let b = LossBudget::default();
        // 5 dBm ≈ 3.16 mW optical → 31.6 mW electrical at η = 0.1.
        assert!((b.laser_electrical_w() - 0.0316).abs() < 0.001);
    }
}
