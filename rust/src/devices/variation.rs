//! Fabrication process-variation analysis for the OXG.
//!
//! ROBIN's headline concern (paper Section II-C: "uses heterogeneous MRRs
//! to mitigate fabrication process variations") applies to any MRR-based
//! design: die-level variation shifts each ring's cold resonance by
//! O(100 pm) sigma. This module quantifies (a) how much uncorrected
//! resonance offset the single-MRR OXG tolerates before its XNOR decision
//! fails, and (b) the thermal trimming power needed to re-lock a varied
//! population — the extension analysis DESIGN.md lists for the ablation
//! suite.

use super::mrr::Mrr;
use super::oxg::Oxg;
use crate::util::rng::Rng;

/// Monte-Carlo result for one variation sigma.
#[derive(Debug, Clone)]
pub struct VariationResult {
    pub sigma_nm: f64,
    pub gates: usize,
    /// Fraction of gates whose *uncorrected* truth table is wrong for at
    /// least one operand combination.
    pub failing_fraction: f64,
    /// Worst-case static eye across the population (uncorrected).
    pub worst_eye: f64,
    /// Mean per-gate heater power (mW) to trim every gate back to its
    /// programmed κ position (correction is always possible: heaters only
    /// red-shift, so trimming targets the next FSR when needed).
    pub mean_trim_power_mw: f64,
}

/// Apply a resonance offset to a fresh OXG *without* re-programming its
/// heater — the uncorrected post-fabrication state.
fn varied_gate(lambda_nm: f64, offset_nm: f64) -> Oxg {
    let mut gate = Oxg::new(lambda_nm);
    gate.mrr.resonance_nm += offset_nm;
    gate
}

/// Heater pre-bias used for trimming (nm). Heaters only red-shift, so
/// production designs bias every ring slightly red of target; variation of
/// either sign is then corrected by adjusting around the bias instead of
/// wrapping a whole FSR. 0.5 nm covers ±3σ of a 0.15 nm process.
pub const TRIM_PREBIAS_NM: f64 = 0.5;

/// Trim power for one gate: heater power to hold the varied resonance on
/// its programmed position, given the pre-bias scheme above. Offsets
/// beyond the pre-bias red-shift must wrap a full FSR (rare; the cost of
/// that tail is exactly why ROBIN argues for variation-aware design).
pub fn trim_power_mw(mrr: &Mrr, offset_nm: f64) -> f64 {
    let shift_needed = if offset_nm <= TRIM_PREBIAS_NM {
        TRIM_PREBIAS_NM - offset_nm
    } else {
        mrr.fsr_nm + TRIM_PREBIAS_NM - offset_nm
    };
    shift_needed / mrr.thermal_nm_per_mw
}

/// Monte-Carlo sweep of an OXG population under Gaussian resonance
/// variation with standard deviation `sigma_nm`.
pub fn monte_carlo(sigma_nm: f64, gates: usize, seed: u64) -> VariationResult {
    assert!(gates > 0);
    let mut rng = Rng::new(seed);
    let mut failing = 0usize;
    let mut worst_eye = f64::INFINITY;
    let mut trim_sum_mw = 0.0;
    for _ in 0..gates {
        let offset = rng.normal() * sigma_nm;
        let gate = varied_gate(1550.0, offset);
        let ok = gate.xnor(false, false)
            && !gate.xnor(false, true)
            && !gate.xnor(true, false)
            && gate.xnor(true, true);
        if !ok {
            failing += 1;
        }
        worst_eye = worst_eye.min(gate.static_eye());
        trim_sum_mw += trim_power_mw(&gate.mrr, offset);
    }
    VariationResult {
        sigma_nm,
        gates,
        failing_fraction: failing as f64 / gates as f64,
        worst_eye,
        mean_trim_power_mw: trim_sum_mw / gates as f64,
    }
}

/// Tolerance: the largest deterministic offset that keeps the truth table
/// intact without trimming (bisection over the offset magnitude).
pub fn max_tolerated_offset_nm() -> f64 {
    let ok = |off: f64| {
        let g = varied_gate(1550.0, off);
        g.xnor(false, false)
            && !g.xnor(false, true)
            && !g.xnor(true, false)
            && g.xnor(true, true)
    };
    let mut lo = 0.0;
    let mut hi = 2.0;
    debug_assert!(ok(lo));
    for _ in 0..40 {
        let mid = 0.5 * (lo + hi);
        if ok(mid) && ok(-mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_sigma_is_perfect() {
        let r = monte_carlo(0.0, 200, 1);
        assert_eq!(r.failing_fraction, 0.0);
        assert!(r.worst_eye > 0.5);
        // Trim power at zero variation = holding the pre-bias.
        let hold = TRIM_PREBIAS_NM / Mrr::default().thermal_nm_per_mw;
        assert!((r.mean_trim_power_mw - hold).abs() < 1e-9);
    }

    #[test]
    fn failures_grow_with_sigma() {
        let small = monte_carlo(0.02, 500, 2);
        let large = monte_carlo(0.5, 500, 2);
        assert!(small.failing_fraction <= large.failing_fraction);
        assert!(large.failing_fraction > 0.2, "{}", large.failing_fraction);
        assert!(large.worst_eye < small.worst_eye);
    }

    #[test]
    fn tolerance_is_a_fraction_of_fwhm() {
        // The XNOR decision survives offsets up to roughly half a FWHM
        // (0.35 nm) before a '0' level leaks above threshold.
        let tol = max_tolerated_offset_nm();
        assert!(
            (0.05..0.35).contains(&tol),
            "tolerated offset {} nm",
            tol
        );
    }

    #[test]
    fn typical_foundry_sigma_needs_trimming_not_redesign() {
        // sigma ≈ 0.1 nm (typical die-level): some gates fail untrimmed...
        let r = monte_carlo(0.1, 1000, 3);
        assert!(r.failing_fraction > 0.0);
        // ...but trimming power stays sub-mW per gate on average versus
        // the 275 mW/FSR full-range worst case (Table III TO tuning).
        assert!(
            r.mean_trim_power_mw < 275.0 * 0.05,
            "mean trim {} mW",
            r.mean_trim_power_mw
        );
    }

    #[test]
    fn trim_power_around_prebias() {
        let mrr = Mrr::default();
        // Blue-shifted ring: needs bias + |offset|.
        let neg = trim_power_mw(&mrr, -0.1);
        assert!((neg - 0.6 / mrr.thermal_nm_per_mw).abs() < 1e-12);
        // Mildly red-shifted ring: less than the bias hold.
        let pos = trim_power_mw(&mrr, 0.1);
        assert!((pos - 0.4 / mrr.thermal_nm_per_mw).abs() < 1e-12);
        // Beyond the pre-bias: full-FSR wrap (the expensive tail).
        let tail = trim_power_mw(&mrr, 1.0);
        assert!(tail > mrr.fsr_nm / mrr.thermal_nm_per_mw * 0.9);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = monte_carlo(0.1, 300, 7);
        let b = monte_carlo(0.1, 300, 7);
        assert_eq!(a.failing_fraction, b.failing_fraction);
        assert_eq!(a.worst_eye, b.worst_eye);
    }
}
