//! Photodetector + receiver noise model (paper Eq. 3 and Eq. 4).
//!
//! β (Eq. 4) is the input-referred noise current spectral density
//! (A/√Hz): shot noise of photocurrent + dark current, thermal (Johnson)
//! noise of the load, and laser RIN. Eq. 3 converts the SNR over the
//! receiver bandwidth DR/√2 into an effective number of bits (ENOB); the
//! XPC solver inverts it for the minimum detectable optical power
//! `P_PD-opt` at B = 1 bit.

use crate::util::units::{BOLTZMANN, ELEMENTARY_CHARGE};

/// Receiver-chain parameters (paper Table I values as defaults).
#[derive(Debug, Clone)]
pub struct Photodetector {
    /// Responsivity R_s (A/W).
    pub responsivity_a_per_w: f64,
    /// Load resistance R_L (Ω).
    pub load_ohm: f64,
    /// Dark current I_d (A).
    pub dark_current_a: f64,
    /// Absolute temperature T (K).
    pub temperature_k: f64,
    /// Relative intensity noise (dB/Hz); Table I: −140 dB/Hz.
    pub rin_db_per_hz: f64,
}

impl Default for Photodetector {
    fn default() -> Self {
        Photodetector {
            responsivity_a_per_w: 1.2,
            load_ohm: 50.0,
            dark_current_a: 35e-9,
            temperature_k: 300.0,
            rin_db_per_hz: -140.0,
        }
    }
}

impl Photodetector {
    /// Photocurrent for incident optical power (W).
    pub fn current_a(&self, power_w: f64) -> f64 {
        self.responsivity_a_per_w * power_w
    }

    /// β of paper Eq. 4 (A/√Hz) at optical power `p_w`.
    pub fn beta(&self, p_w: f64) -> f64 {
        let i_ph = self.current_a(p_w);
        let rin_lin = 10f64.powf(self.rin_db_per_hz / 10.0);
        let shot = 2.0 * ELEMENTARY_CHARGE * (i_ph + self.dark_current_a);
        let thermal = 4.0 * BOLTZMANN * self.temperature_k / self.load_ohm;
        let rin = i_ph * i_ph * rin_lin;
        (shot + thermal + rin).sqrt()
    }

    /// Signal-to-noise ratio (linear amplitude ratio) at power `p_w` and
    /// data rate `dr_hz`: Rs·P / (β·√(DR/√2)).
    pub fn snr(&self, p_w: f64, dr_hz: f64) -> f64 {
        self.current_a(p_w) / (self.beta(p_w) * (dr_hz / 2f64.sqrt()).sqrt())
    }

    /// Effective number of bits at power/rate (paper Eq. 3):
    /// B = (20·log10(SNR) − 1.76) / 6.02.
    pub fn enob(&self, p_w: f64, dr_hz: f64) -> f64 {
        (20.0 * self.snr(p_w, dr_hz).log10() - 1.76) / 6.02
    }

    /// Minimum optical power (W) for `bits` of resolution at `dr_hz`,
    /// including the OOK peak/average margin (×2 power; the sensitivity is
    /// quoted for the average of an on-off-keyed stream, so the '1' level
    /// must carry twice the average power). Calibrated against paper
    /// Table II: reproduces P_PD-opt within 0.13 dB on all seven rows.
    pub fn min_power_w(&self, bits: f64, dr_hz: f64, ook_margin: f64) -> f64 {
        let snr_req = 10f64.powf((6.02 * bits + 1.76) / 20.0);
        // Fixed point: P = margin · snr_req · β(P) · √(BW) / Rs.
        // β depends only weakly on P (thermal dominated), so this
        // converges in a handful of iterations.
        let bw_term = (dr_hz / 2f64.sqrt()).sqrt();
        let mut p = 1e-6;
        for _ in 0..64 {
            let next = ook_margin * snr_req * self.beta(p) * bw_term / self.responsivity_a_per_w;
            if (next - p).abs() < 1e-18 {
                p = next;
                break;
            }
            p = next;
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{dbm_to_watt, watt_to_dbm};

    #[test]
    fn beta_is_thermal_dominated_at_microwatts() {
        let pd = Photodetector::default();
        let b = pd.beta(dbm_to_watt(-20.0));
        // 4kT/R_L = 3.31e-22 A²/Hz → β ≈ 1.82e-11 A/√Hz.
        assert!((b - 1.82e-11).abs() / 1.82e-11 < 0.05, "beta = {}", b);
    }

    #[test]
    fn enob_increases_with_power() {
        let pd = Photodetector::default();
        let e1 = pd.enob(dbm_to_watt(-25.0), 10e9);
        let e2 = pd.enob(dbm_to_watt(-15.0), 10e9);
        assert!(e2 > e1 + 1.0);
    }

    #[test]
    fn enob_decreases_with_datarate() {
        let pd = Photodetector::default();
        let e1 = pd.enob(dbm_to_watt(-20.0), 3e9);
        let e2 = pd.enob(dbm_to_watt(-20.0), 50e9);
        assert!(e1 > e2);
    }

    #[test]
    fn min_power_matches_paper_table2() {
        // Paper Table II P_PD-opt values (dBm) per DR (GS/s).
        let paper = [
            (3.0, -24.69),
            (5.0, -23.49),
            (10.0, -21.9),
            (20.0, -20.5),
            (30.0, -19.5),
            (40.0, -18.9),
            (50.0, -18.5),
        ];
        let pd = Photodetector::default();
        for (dr, want_dbm) in paper {
            let p = pd.min_power_w(1.0, dr * 1e9, 2.0);
            let got_dbm = watt_to_dbm(p);
            assert!(
                (got_dbm - want_dbm).abs() < 0.15,
                "DR={} GS/s: got {:.2} dBm, paper {} dBm",
                dr,
                got_dbm,
                want_dbm
            );
        }
    }

    #[test]
    fn min_power_self_consistent_with_enob() {
        let pd = Photodetector::default();
        let p = pd.min_power_w(1.0, 10e9, 2.0);
        // At the solved power (which includes the ×2 OOK margin), the raw
        // ENOB equation should report ≥ 1 bit with margin to spare.
        assert!(pd.enob(p, 10e9) >= 1.0);
        assert!(pd.enob(p / 2.0, 10e9) >= 0.99); // margin-stripped ≈ 1 bit
    }
}
