//! Photonic / analog device substrate: the paper characterized these with
//! Lumerical + MultiSim; we model them analytically (DESIGN.md
//! §Hardware-Adaptation) at the fidelity the system evaluation needs.

pub mod laser;
pub mod mrr;
pub mod oxg;
pub mod pca;
pub mod photodetector;
pub mod variation;

pub use laser::LossBudget;
pub use mrr::Mrr;
pub use oxg::Oxg;
pub use pca::{BitcountResult, Pca, PcaParams};
pub use photodetector::Photodetector;
