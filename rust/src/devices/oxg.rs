//! Optical XNOR Gate (OXG) — the paper's core device contribution.
//!
//! A *single* add-drop MRR with two PN-junction operand terminals
//! (paper Fig. 3(a)). The microheater programs the zero-drive resonance to
//! κ = λ_in + Δ_pn. Then:
//!
//! | (i, w) | junctions high | resonance      | through T(λ_in) | XNOR |
//! |--------|----------------|----------------|-----------------|------|
//! | (0,0)  | 0              | λ_in + Δ_pn    | high            | 1    |
//! | (0,1)  | 1              | λ_in           | extinguished    | 0    |
//! | (1,0)  | 1              | λ_in           | extinguished    | 0    |
//! | (1,1)  | 2              | λ_in − Δ_pn    | high            | 1    |
//!
//! i.e. the through-port *optically computes XNOR* with one ring — prior
//! works (ROBIN, LIGHTBULB) need two MRRs/microdisks per 1-bit XNOR.
//! This module also provides the transient simulation used to regenerate
//! paper Fig. 3(c) and to establish the max data rate.

use super::mrr::Mrr;

/// Paper Section III-B: measured OXG energy per 1-bit XNOR (nJ).
pub const OXG_ENERGY_NJ: f64 = 0.032;
/// Paper Section III-B: OXG area footprint (mm²).
pub const OXG_AREA_MM2: f64 = 0.011;
/// Paper Section III-B: validated max data rate (GS/s).
pub const OXG_MAX_DR_GSPS: f64 = 50.0;

/// A programmed single-MRR optical XNOR gate.
#[derive(Debug, Clone)]
pub struct Oxg {
    pub mrr: Mrr,
    /// The DWDM wavelength this gate operates on (nm).
    pub lambda_in_nm: f64,
    /// Logic decision threshold on through-port transmission.
    pub threshold: f64,
}

impl Oxg {
    /// Build an OXG on `lambda_in_nm` and program its heater so the
    /// zero-drive resonance sits one PN shift red of the carrier.
    pub fn new(lambda_in_nm: f64) -> Oxg {
        let mut mrr = Mrr::default();
        let offset = mrr.pn_shift_nm;
        mrr.program_kappa(lambda_in_nm, offset);
        Oxg { mrr, lambda_in_nm, threshold: 0.4 }
    }

    /// Steady-state through-port transmission for operand bits (i, w).
    pub fn transmission(&self, i: bool, w: bool) -> f64 {
        let junctions = i as u32 + w as u32;
        self.mrr.through_transmission(self.lambda_in_nm, junctions)
    }

    /// Steady-state optical logic output.
    pub fn xnor(&self, i: bool, w: bool) -> bool {
        self.transmission(i, w) > self.threshold
    }

    /// Worst-case optical modulation depth between the '1' set
    /// {(0,0),(1,1)} and the '0' set {(0,1),(1,0)} — the static eye.
    pub fn static_eye(&self) -> f64 {
        let ones = [self.transmission(false, false), self.transmission(true, true)];
        let zeros = [self.transmission(false, true), self.transmission(true, false)];
        let min_one = ones.iter().cloned().fold(f64::INFINITY, f64::min);
        let max_zero = zeros.iter().cloned().fold(0.0, f64::max);
        min_one - max_zero
    }

    /// Transient response: drive the two PN junctions with bit streams at
    /// `dr_gsps` and return the through-port trace (`samples_per_bit`
    /// points per symbol). The junction drive (and hence the resonance
    /// position) follows a first-order exponential with time constant
    /// `tau_dev_ps` — the carrier + photon-lifetime dynamics that limit
    /// the gate's data rate. Regenerates paper Fig. 3(c).
    pub fn transient(
        &self,
        bits_i: &[bool],
        bits_w: &[bool],
        dr_gsps: f64,
        samples_per_bit: usize,
        tau_dev_ps: f64,
    ) -> Vec<f64> {
        assert_eq!(bits_i.len(), bits_w.len());
        assert!(samples_per_bit >= 1);
        let period_ps = 1000.0 / dr_gsps;
        let dt = period_ps / samples_per_bit as f64;
        // State: effective junction drive levels, each relaxing toward its
        // target bit with time constant tau_dev.
        let mut drive_i = 0.0f64;
        let mut drive_w = 0.0f64;
        let alpha = 1.0 - (-dt / tau_dev_ps).exp();
        let mut trace = Vec::with_capacity(bits_i.len() * samples_per_bit);
        for (bi, bw) in bits_i.iter().zip(bits_w) {
            let ti = if *bi { 1.0 } else { 0.0 };
            let tw = if *bw { 1.0 } else { 0.0 };
            for _ in 0..samples_per_bit {
                drive_i += alpha * (ti - drive_i);
                drive_w += alpha * (tw - drive_w);
                // Fractional junction drive produces a fractional blue
                // shift; evaluate the Lorentzian at the instantaneous
                // resonance position.
                let shift = (drive_i + drive_w) * self.mrr.pn_shift_nm;
                let resonance =
                    self.mrr.resonance_nm + self.mrr.heater_mw * self.mrr.thermal_nm_per_mw - shift;
                let t_min = 10f64.powf(-self.mrr.extinction_db / 10.0);
                let x = 2.0 * (self.lambda_in_nm - resonance) / self.mrr.fwhm_nm;
                trace.push(1.0 - (1.0 - t_min) / (1.0 + x * x));
            }
        }
        trace
    }

    /// Decode a transient trace back to logic bits by sampling at the last
    /// sample of each symbol (worst-case settled point).
    pub fn decode_trace(&self, trace: &[f64], samples_per_bit: usize) -> Vec<bool> {
        trace
            .chunks(samples_per_bit)
            .map(|sym| sym[samples_per_bit - 1] > self.threshold)
            .collect()
    }

    /// Max data rate (GS/s) at which a pseudo-random operand pattern still
    /// decodes without error, given the device time constant.
    pub fn max_error_free_dr(&self, tau_dev_ps: f64, seed: u64) -> f64 {
        let mut rng = crate::util::rng::Rng::new(seed);
        let bits_i: Vec<bool> = (0..256).map(|_| rng.bool()).collect();
        let bits_w: Vec<bool> = (0..256).map(|_| rng.bool()).collect();
        let want: Vec<bool> = bits_i.iter().zip(&bits_w).map(|(a, b)| a == b).collect();
        let mut best = 0.0;
        for dr in [1.0, 2.0, 3.0, 5.0, 10.0, 20.0, 30.0, 40.0, 50.0, 64.0, 80.0] {
            let trace = self.transient(&bits_i, &bits_w, dr, 8, tau_dev_ps);
            let got = self.decode_trace(&trace, 8);
            if got == want {
                best = dr;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn truth_table_is_xnor() {
        let g = Oxg::new(1550.0);
        assert!(g.xnor(false, false));
        assert!(!g.xnor(false, true));
        assert!(!g.xnor(true, false));
        assert!(g.xnor(true, true));
    }

    #[test]
    fn static_eye_open() {
        let g = Oxg::new(1550.0);
        assert!(g.static_eye() > 0.5, "eye = {}", g.static_eye());
    }

    #[test]
    fn transmission_levels_match_lorentzian() {
        let g = Oxg::new(1550.0);
        // (0,1): on resonance → deeply extinguished.
        assert!(g.transmission(false, true) < 0.05);
        // (0,0) and (1,1): one FWHM detuned → depth 1/5 → T = 0.8.
        assert!((g.transmission(false, false) - 0.8).abs() < 0.02);
        assert!((g.transmission(true, true) - 0.8).abs() < 0.02);
    }

    #[test]
    fn transient_decodes_pattern_at_10gsps() {
        // Regeneration of paper Fig. 3(c): 8-bit streams at 10 GS/s.
        let g = Oxg::new(1550.0);
        let bits_i = [false, true, false, true, true, false, true, false];
        let bits_w = [false, false, true, true, false, true, true, false];
        let trace = g.transient(&bits_i, &bits_w, 10.0, 16, 5.0);
        assert_eq!(trace.len(), 8 * 16);
        let got = g.decode_trace(&trace, 16);
        let want: Vec<bool> = bits_i.iter().zip(&bits_w).map(|(a, b)| a == b).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn operates_at_50gsps_like_paper() {
        // With the device time constant implied by the ring (ps-scale),
        // the gate must decode error-free at 50 GS/s (paper claim).
        let g = Oxg::new(1550.0);
        let max = g.max_error_free_dr(3.0, 0x05EED);
        assert!(max >= OXG_MAX_DR_GSPS, "max error-free DR = {} GS/s", max);
    }

    #[test]
    fn slow_device_fails_high_dr() {
        // Sanity: an artificially slow junction (1 ns) cannot do 50 GS/s.
        let g = Oxg::new(1550.0);
        let bits_i = [false, true, false, true];
        let bits_w = [true, true, false, false];
        let trace = g.transient(&bits_i, &bits_w, 50.0, 8, 1000.0);
        let got = g.decode_trace(&trace, 8);
        let want: Vec<bool> = bits_i.iter().zip(&bits_w).map(|(a, b)| a == b).collect();
        assert_ne!(got, want);
    }

    #[test]
    fn paper_constants_recorded() {
        assert_eq!(OXG_ENERGY_NJ, 0.032);
        assert_eq!(OXG_AREA_MM2, 0.011);
    }
}
