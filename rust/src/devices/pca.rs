//! Photo-Charge Accumulator (PCA) — the paper's bitcount contribution.
//!
//! Fig. 4 of the paper: a photodetector feeds one of two time-integrating
//! receivers (TIR1/TIR2, selected by demux/mux). Each incident optical '1'
//! deposits a charge packet on the active capacitor; the TIR output voltage
//! grows linearly (δV = i·δt/C) until the dynamic range (5 V) saturates.
//! The final voltage *is* the bitcount. A comparator against V_REF = 2.5 V
//! produces the next layer's activation. While one capacitor discharges,
//! the redundant TIR continues accumulating — hiding discharge latency.
//!
//! This module models the charge dynamics (used by the event-driven sim
//! and the PCA-capacity analysis) with explicit dual-capacitor state.

/// TIR/PCA circuit parameters (paper Section IV-A).
#[derive(Debug, Clone)]
pub struct PcaParams {
    /// Integration capacitance (F); paper: C1 = C2 = 10 pF.
    pub capacitance_f: f64,
    /// TIR voltage gain; paper: 50.
    pub gain: f64,
    /// Usable TIR output dynamic range (V); paper: 5 V (0..5).
    pub v_range: f64,
    /// Comparator reference; paper Fig. 4: V_REF = 2.5 V.
    pub v_ref: f64,
    /// Time to discharge a capacitor before it can accumulate again (s).
    /// ~5 RC of the discharge switch; hidden by the redundant TIR unless
    /// both saturate back-to-back.
    pub discharge_s: f64,
}

impl Default for PcaParams {
    fn default() -> Self {
        PcaParams {
            capacitance_f: 10e-12,
            gain: 50.0,
            v_range: 5.0,
            v_ref: 2.5,
            discharge_s: 5e-9,
        }
    }
}

impl PcaParams {
    /// Output voltage increment contributed by a single optical '1':
    /// δV = gain · (i·δt)/C, where i is the PD current pulse and δt the
    /// symbol period.
    pub fn delta_v_per_one(&self, pd_current_a: f64, symbol_s: f64) -> f64 {
        self.gain * pd_current_a * symbol_s / self.capacitance_f
    }

    /// Analytic accumulation capacity γ: how many '1's fit in the dynamic
    /// range (first-principles counterpart of the paper's MultiSim-derived
    /// Table II γ column; see analysis::pca_capacity for the calibrated
    /// values).
    pub fn gamma_analytic(&self, pd_current_a: f64, symbol_s: f64) -> u64 {
        (self.v_range / self.delta_v_per_one(pd_current_a, symbol_s)).floor() as u64
    }
}

/// Which TIR is currently integrating.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActiveTir {
    Tir1,
    Tir2,
}

/// Runtime state of a PCA instance in the event-driven simulator.
#[derive(Debug, Clone)]
pub struct Pca {
    pub params: PcaParams,
    /// Capacity in '1's (γ) for the operating point; counts are tracked in
    /// integer '1's to keep the simulator exact.
    pub gamma: u64,
    active: ActiveTir,
    /// Accumulated '1's on the active capacitor.
    count: u64,
    /// Simulation time when the *inactive* capacitor finishes discharging.
    inactive_ready_at: f64,
}

/// Result of closing out an accumulation phase.
#[derive(Debug, Clone, PartialEq)]
pub struct BitcountResult {
    /// Total '1's accumulated (the bitcount).
    pub count: u64,
    /// TIR output voltage representing the count.
    pub voltage: f64,
    /// Comparator output against V_REF (the BNN activation bit).
    pub activation: bool,
    /// True if the accumulation railed at γ (information lost).
    pub saturated: bool,
}

impl Pca {
    pub fn new(params: PcaParams, gamma: u64) -> Pca {
        assert!(gamma > 0, "PCA capacity must be positive");
        Pca { params, gamma, active: ActiveTir::Tir1, count: 0, inactive_ready_at: 0.0 }
    }

    pub fn active_tir(&self) -> ActiveTir {
        self.active
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Headroom before saturation.
    pub fn remaining(&self) -> u64 {
        self.gamma - self.count
    }

    /// Accumulate the '1's of one XNOR vector slice (one PASS). Returns
    /// `true` if the TIR railed (count clamped at γ — callers schedule a
    /// readout *before* this in correct operation; paper §IV-C shows
    /// S_max = 4608 < γ so it never rails for real workloads).
    pub fn accumulate(&mut self, ones: u64) -> bool {
        let new = self.count.saturating_add(ones);
        if new >= self.gamma {
            self.count = self.gamma;
            true
        } else {
            self.count = new;
            false
        }
    }

    /// Voltage the active TIR currently outputs. Each '1' contributes an
    /// equal quantum v_range/γ by the definition of γ.
    pub fn voltage(&self) -> f64 {
        self.count as f64 * self.params.v_range / self.gamma as f64
    }

    /// Finish the accumulation phase at simulation time `now_s`: read out
    /// the bitcount, fire the comparator, swap to the redundant TIR and
    /// start discharging the old capacitor.
    ///
    /// Returns the result plus any *stall* time (> 0 only when the
    /// redundant capacitor has not finished discharging yet — i.e. two
    /// readouts closer together than `discharge_s`).
    pub fn readout(&mut self, now_s: f64) -> (BitcountResult, f64) {
        let saturated = self.count == self.gamma;
        let result = BitcountResult {
            count: self.count,
            voltage: self.voltage(),
            activation: self.voltage() > self.params.v_ref,
            saturated,
        };
        let stall = (self.inactive_ready_at - now_s).max(0.0);
        // Swap: the old active capacitor begins discharging once we have
        // (possibly after the stall) switched over.
        self.inactive_ready_at = now_s + stall + self.params.discharge_s;
        self.active = match self.active {
            ActiveTir::Tir1 => ActiveTir::Tir2,
            ActiveTir::Tir2 => ActiveTir::Tir1,
        };
        self.count = 0;
        (result, stall)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_v_matches_paper_equation() {
        // δV = i·δt/C × gain: 5.6 µA over 20 ps on 10 pF with gain 50.
        let p = PcaParams::default();
        let dv = p.delta_v_per_one(5.6e-6, 20e-12);
        let expect = 50.0 * 5.6e-6 * 20e-12 / 10e-12;
        assert!((dv - expect).abs() < 1e-15);
    }

    #[test]
    fn gamma_analytic_counts_dynamic_range() {
        let p = PcaParams::default();
        let dv = p.delta_v_per_one(5.6e-6, 20e-12);
        let g = p.gamma_analytic(5.6e-6, 20e-12);
        assert_eq!(g, (5.0 / dv).floor() as u64);
    }

    #[test]
    fn accumulate_and_voltage_linear() {
        let mut pca = Pca::new(PcaParams::default(), 1000);
        assert!(!pca.accumulate(250));
        assert!((pca.voltage() - 1.25).abs() < 1e-12);
        assert!(!pca.accumulate(250));
        assert!((pca.voltage() - 2.5).abs() < 1e-12);
        assert_eq!(pca.remaining(), 500);
    }

    #[test]
    fn saturation_clamps() {
        let mut pca = Pca::new(PcaParams::default(), 100);
        assert!(pca.accumulate(150));
        assert_eq!(pca.count(), 100);
        let (r, _) = pca.readout(0.0);
        assert!(r.saturated);
        assert_eq!(r.count, 100);
    }

    #[test]
    fn comparator_at_vref() {
        let mut pca = Pca::new(PcaParams::default(), 100);
        pca.accumulate(50); // exactly 2.5 V → NOT > V_REF
        let (r, _) = pca.readout(0.0);
        assert!(!r.activation);
        let mut pca = Pca::new(PcaParams::default(), 100);
        pca.accumulate(51);
        let (r, _) = pca.readout(0.0);
        assert!(r.activation);
    }

    #[test]
    fn dual_tir_hides_discharge() {
        let mut pca = Pca::new(PcaParams::default(), 100);
        pca.accumulate(10);
        let (_, stall) = pca.readout(0.0);
        assert_eq!(stall, 0.0);
        assert_eq!(pca.active_tir(), ActiveTir::Tir2);
        // Second readout long after discharge completes: still no stall.
        pca.accumulate(10);
        let (_, stall) = pca.readout(100e-9);
        assert_eq!(stall, 0.0);
    }

    #[test]
    fn back_to_back_readouts_stall() {
        let mut pca = Pca::new(PcaParams::default(), 100);
        pca.accumulate(1);
        let (_, s1) = pca.readout(0.0);
        assert_eq!(s1, 0.0);
        pca.accumulate(1);
        // 1 ns later TIR1's capacitor (discharging until 5 ns) isn't ready.
        let (_, s2) = pca.readout(1e-9);
        assert!((s2 - 4e-9).abs() < 1e-15, "stall = {}", s2);
        assert_eq!(pca.active_tir(), ActiveTir::Tir1);
    }

    #[test]
    fn counts_reset_after_readout() {
        let mut pca = Pca::new(PcaParams::default(), 100);
        pca.accumulate(42);
        let (r, _) = pca.readout(0.0);
        assert_eq!(r.count, 42);
        assert_eq!(pca.count(), 0);
        assert_eq!(pca.voltage(), 0.0);
    }
}
