//! Add-drop microring resonator (MRR) device model.
//!
//! The paper's OXG (Fig. 3) is a single add-drop MRR with two embedded
//! PN-junction phase shifters (operand terminals) and an integrated
//! microheater (thermal bias). The paper characterized it in Lumerical;
//! here we model the through-port transmission analytically as a
//! Lorentzian notch — the standard first-order approximation for a weakly
//! coupled ring — which reproduces the spectral behaviour the system model
//! needs: FWHM, extinction, resonance shifts from carrier injection and
//! heating (DESIGN.md §Hardware-Adaptation).

/// Lorentzian add-drop MRR.
#[derive(Debug, Clone)]
pub struct Mrr {
    /// Fabrication-defined cold resonance wavelength (nm) — position η in
    /// paper Fig. 3(b).
    pub resonance_nm: f64,
    /// Full width at half maximum of the resonance notch (nm). The paper's
    /// OXG has FWHM = 0.35 nm (Section III-B).
    pub fwhm_nm: f64,
    /// Through-port extinction ratio at resonance (dB); >15 dB typical for
    /// foundry add-drop rings.
    pub extinction_db: f64,
    /// Thermal tuning efficiency (nm of red-shift per mW of heater power).
    pub thermal_nm_per_mw: f64,
    /// Electro-refractive blue-shift per PN junction when driven with a
    /// logic '1' (nm). Carrier injection blue-shifts the resonance.
    pub pn_shift_nm: f64,
    /// Current heater power (mW) — sets the programmed position κ.
    pub heater_mw: f64,
    /// Free spectral range (nm); paper assumes FSR = 50 nm.
    pub fsr_nm: f64,
}

impl Default for Mrr {
    fn default() -> Self {
        // Constants from paper Section III-B / Table I and typical foundry
        // values for a 10 µm-radius silicon ring.
        Mrr {
            resonance_nm: 1550.0,
            fwhm_nm: 0.35,
            extinction_db: 20.0,
            thermal_nm_per_mw: 0.25,
            pn_shift_nm: 0.35, // one FWHM per injected junction
            heater_mw: 0.0,
            fsr_nm: 50.0,
        }
    }
}

impl Mrr {
    /// Effective resonance position given heater power and the number of
    /// PN junctions driven high (each contributes a blue shift).
    pub fn effective_resonance_nm(&self, junctions_high: u32) -> f64 {
        self.resonance_nm + self.heater_mw * self.thermal_nm_per_mw
            - junctions_high as f64 * self.pn_shift_nm
    }

    /// Through-port power transmission (linear, 0..1) at `lambda_nm` with
    /// `junctions_high` PN junctions driven.
    ///
    /// Lorentzian notch: `T(λ) = 1 - (1 - T_min) / (1 + (2Δ/FWHM)^2)`.
    pub fn through_transmission(&self, lambda_nm: f64, junctions_high: u32) -> f64 {
        let t_min = 10f64.powf(-self.extinction_db / 10.0);
        let delta = lambda_nm - self.effective_resonance_nm(junctions_high);
        let x = 2.0 * delta / self.fwhm_nm;
        1.0 - (1.0 - t_min) / (1.0 + x * x)
    }

    /// Drop-port power transmission (complement of the notch, minus loss).
    pub fn drop_transmission(&self, lambda_nm: f64, junctions_high: u32) -> f64 {
        let t_min = 10f64.powf(-self.extinction_db / 10.0);
        let delta = lambda_nm - self.effective_resonance_nm(junctions_high);
        let x = 2.0 * delta / self.fwhm_nm;
        (1.0 - t_min) / (1.0 + x * x)
    }

    /// Program the heater so the *zero-drive* resonance sits `offset_nm`
    /// away from `lambda_nm` (the κ position of paper Fig. 3(b)).
    pub fn program_kappa(&mut self, lambda_nm: f64, offset_nm: f64) {
        let target = lambda_nm + offset_nm;
        let shift_needed = target - self.resonance_nm;
        self.heater_mw = shift_needed / self.thermal_nm_per_mw;
    }

    /// Q factor implied by FWHM.
    pub fn q_factor(&self) -> f64 {
        self.resonance_nm / self.fwhm_nm
    }

    /// Cavity linewidth in frequency terms: Δf = c·FWHM/λ² (Hz).
    pub fn linewidth_hz(&self) -> f64 {
        let c = crate::util::units::SPEED_OF_LIGHT;
        let lambda_m = crate::util::units::nm_to_m(self.resonance_nm);
        let fwhm_m = crate::util::units::nm_to_m(self.fwhm_nm);
        c * fwhm_m / (lambda_m * lambda_m)
    }

    /// Photon-lifetime-limited maximum modulation rate (GS/s).
    ///
    /// NRZ modulation of a ring is usable up to ≈ 1.15× its optical
    /// linewidth before inter-symbol interference exceeds the ~1 dB
    /// penalty the paper budgets (its IL_penalty term); with
    /// FWHM = 0.35 nm this yields ≈ 50 GS/s — the paper's claimed limit.
    pub fn max_datarate_gsps(&self) -> f64 {
        1.15 * self.linewidth_hz() / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn notch_at_resonance() {
        let m = Mrr::default();
        let t_on = m.through_transmission(1550.0, 0);
        assert!(t_on < 0.02, "on-resonance through should be extinguished: {}", t_on);
        let t_off = m.through_transmission(1550.0 + 5.0, 0);
        assert!(t_off > 0.99, "far off-resonance should pass: {}", t_off);
    }

    #[test]
    fn fwhm_definition_holds() {
        let m = Mrr::default();
        // At Δ = FWHM/2 the notch depth should be half of its max depth.
        let t_half = m.through_transmission(1550.0 + m.fwhm_nm / 2.0, 0);
        let t_min = m.through_transmission(1550.0, 0);
        let depth_half = 1.0 - t_half;
        let depth_max = 1.0 - t_min;
        assert!((depth_half - depth_max / 2.0).abs() < 1e-9);
    }

    #[test]
    fn drop_complements_through() {
        let m = Mrr::default();
        for d in [-1.0, -0.2, 0.0, 0.2, 1.0] {
            let t = m.through_transmission(1550.0 + d, 0);
            let dr = m.drop_transmission(1550.0 + d, 0);
            assert!((t + dr - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn pn_junctions_blue_shift() {
        let m = Mrr::default();
        assert!(m.effective_resonance_nm(1) < m.effective_resonance_nm(0));
        assert!(
            (m.effective_resonance_nm(0) - m.effective_resonance_nm(2)).abs()
                - 2.0 * m.pn_shift_nm
                < 1e-12
        );
    }

    #[test]
    fn heater_red_shifts_and_programs_kappa() {
        let mut m = Mrr::default();
        m.program_kappa(1550.0, 0.35);
        assert!(m.heater_mw > 0.0);
        assert!((m.effective_resonance_nm(0) - 1550.35).abs() < 1e-9);
    }

    #[test]
    fn paper_fwhm_supports_50gsps() {
        // Paper Section III-B: OXG operates up to DR = 50 GS/s with
        // FWHM = 0.35 nm. Our photon-lifetime bound must allow that.
        let m = Mrr::default();
        assert!(
            m.max_datarate_gsps() >= 50.0,
            "photon-lifetime limit {} GS/s should exceed 50",
            m.max_datarate_gsps()
        );
    }

    #[test]
    fn q_factor_plausible() {
        let q = Mrr::default().q_factor();
        assert!((4000.0..6000.0).contains(&q), "Q = {}", q);
    }
}
