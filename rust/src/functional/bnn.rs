//! Functional (bit-exact) BNN engine in pure rust.
//!
//! Mirrors `python/compile/model.py` operation-for-operation — same im2col
//! layout (`(ki·KW + kj)·C + c`), SAME zero padding, XNOR-bitcount GEMM,
//! comparator activation, 2×2 binary max-pool — so the PJRT-executed AOT
//! artifact can be cross-validated against an independent implementation
//! (integration test `rust/tests/functional_vs_pjrt.rs`).
//!
//! This is also the reference the coordinator uses when asked to verify a
//! served response.

use crate::runtime::manifest::{Artifact, LayerDim};

/// NHWC {0,1} feature map (N = 1).
#[derive(Debug, Clone)]
pub struct FeatureMap {
    pub hw: usize,
    pub c: usize,
    /// Row-major (h, w, c), length hw·hw·c.
    pub data: Vec<f32>,
}

impl FeatureMap {
    pub fn new(hw: usize, c: usize, data: Vec<f32>) -> FeatureMap {
        assert_eq!(data.len(), hw * hw * c);
        FeatureMap { hw, c, data }
    }

    /// Padding-aware accessor: SAME zero padding, so out-of-bounds reads
    /// return binary 0.
    pub fn at(&self, i: isize, j: isize, ch: usize) -> f32 {
        // SAME zero padding: out-of-bounds reads are binary 0.
        if i < 0 || j < 0 || i >= self.hw as isize || j >= self.hw as isize {
            0.0
        } else {
            self.data[(i as usize * self.hw + j as usize) * self.c + ch]
        }
    }
}

/// Binarize a real-valued input into {0,1} (paper Eq. 1, {0,1} encoding).
pub fn binarize01(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| if v >= 0.0 { 1.0 } else { 0.0 }).collect()
}

/// Fill `row` with the im2col window for output position `pos`: python
/// layout `(ki·k + kj)·C + c`, SAME zero padding, given stride. In-bounds
/// kernel positions are contiguous C-length runs of the map, copied
/// slice-wise; the reused buffer is cleared, not reallocated.
fn fill_row(
    data: &[f32],
    hw: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    pos: (usize, usize),
    row: &mut Vec<f32>,
) {
    let (oi, oj) = pos;
    let pad = (kernel - 1) / 2;
    row.clear();
    for ki in 0..kernel {
        let i = (oi * stride + ki) as isize - pad as isize;
        for kj in 0..kernel {
            let j = (oj * stride + kj) as isize - pad as isize;
            if i < 0 || i >= hw as isize || j < 0 || j >= hw as isize {
                row.resize(row.len() + c, 0.0);
            } else {
                let base = (i as usize * hw + j as usize) * c;
                row.extend_from_slice(&data[base..base + c]);
            }
        }
    }
}

/// im2col with the python layout: row per output position, feature index
/// (ki·k + kj)·C + c, SAME padding, given stride.
pub fn im2col(map: &FeatureMap, kernel: usize, stride: usize) -> Vec<Vec<f32>> {
    let pad = (kernel - 1) / 2;
    let out_hw = (map.hw + 2 * pad - kernel) / stride + 1;
    let mut rows = Vec::with_capacity(out_hw * out_hw);
    for oi in 0..out_hw {
        for oj in 0..out_hw {
            let mut row = Vec::with_capacity(kernel * kernel * map.c);
            fill_row(&map.data, map.hw, map.c, kernel, stride, (oi, oj), &mut row);
            rows.push(row);
        }
    }
    rows
}

/// XNOR-bitcount VDP over {0,1} vectors (integer-exact in f32).
pub fn xnor_popcount(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut count = 0u32;
    for (x, y) in a.iter().zip(b) {
        if (*x > 0.5) == (*y > 0.5) {
            count += 1;
        }
    }
    count as f32
}

/// Comparator activation: z > 0.5·S (paper Section II-A).
pub fn activation(z: f32, s: usize) -> f32 {
    if z > 0.5 * s as f32 {
        1.0
    } else {
        0.0
    }
}

/// 2×2 stride-2 max pool into a reused buffer (max over {0,1} == OR).
fn maxpool2_into(data: &[f32], hw: usize, c: usize, out: &mut Vec<f32>) {
    assert_eq!(hw % 2, 0, "pooling needs even hw");
    let out_hw = hw / 2;
    out.clear();
    out.resize(out_hw * out_hw * c, 0.0);
    for i in 0..out_hw {
        for j in 0..out_hw {
            for ch in 0..c {
                let mut m = 0.0f32;
                for di in 0..2 {
                    for dj in 0..2 {
                        m = m.max(data[((2 * i + di) * hw + (2 * j + dj)) * c + ch]);
                    }
                }
                out[(i * out_hw + j) * c + ch] = m;
            }
        }
    }
}

/// 2×2 stride-2 max pool of a binary map (max == OR).
pub fn maxpool2(map: &FeatureMap) -> FeatureMap {
    let mut data = Vec::new();
    maxpool2_into(&map.data, map.hw, map.c, &mut data);
    FeatureMap::new(map.hw / 2, map.c, data)
}

/// Reused f32 buffers for [`forward_with`]: one im2col row plus two
/// ping-pong feature maps. One `Scratch` held across frames (and layers
/// within a frame) removes the per-row/per-layer allocation storm the
/// original `forward` paid via fresh `Vec<Vec<f32>>` im2col tables.
#[derive(Debug, Clone, Default)]
pub struct Scratch {
    row: Vec<f32>,
    map: Vec<f32>,
    next: Vec<f32>,
}

/// Full forward pass following the manifest's layer table. `weights[l]`
/// is the (S, K) row-major weight matrix of layer l (conv layers then FC);
/// any slice-of-slices shape works (`&[Vec<f32>]`, `&[&[f32]]`, ...) so
/// callers holding staged device tensors never have to copy.
pub fn forward(artifact: &Artifact, x: &[f32], weights: &[impl AsRef<[f32]>]) -> Vec<f32> {
    forward_with(artifact, x, weights, &mut Scratch::default())
}

/// [`forward`] with caller-owned scratch buffers, so per-frame loops
/// allocate nothing beyond the returned logits after warmup.
pub fn forward_with(
    artifact: &Artifact,
    x: &[f32],
    weights: &[impl AsRef<[f32]>],
    scratch: &mut Scratch,
) -> Vec<f32> {
    let input_hw = artifact.input_hw.expect("bnn artifact has input_hw");
    let input_c = artifact.input_channels.expect("input_channels");
    assert_eq!(x.len(), input_hw * input_hw * input_c);
    assert_eq!(weights.len(), artifact.layers.len());

    let Scratch { row, map, next } = scratch;
    // Binarize (paper Eq. 1, {0,1} encoding) into the reused map buffer.
    map.clear();
    map.extend(x.iter().map(|&v| if v >= 0.0 { 1.0 } else { 0.0 }));
    let mut hw = input_hw;
    let mut c = input_c;

    let conv_layers: Vec<&LayerDim> =
        artifact.layers.iter().filter(|l| l.kind == "conv").collect();
    for (li, dim) in conv_layers.iter().enumerate() {
        let w = weights[li].as_ref();
        assert_eq!(w.len(), dim.s * dim.k, "layer {} weight size", li);
        // SAME/stride-1 3×3 conv: one output row per input position.
        assert_eq!(hw * hw, dim.h, "layer {} H", li);
        next.clear();
        next.resize(dim.h * dim.k, 0.0);
        for oi in 0..hw {
            for oj in 0..hw {
                fill_row(map, hw, c, 3, 1, (oi, oj), row);
                let r = oi * hw + oj;
                for k in 0..dim.k {
                    // Weight matrix is (S, K) row-major: column k.
                    let mut count = 0u32;
                    for s in 0..dim.s {
                        let a = row[s] > 0.5;
                        let b = w[s * dim.k + k] > 0.5;
                        if a == b {
                            count += 1;
                        }
                    }
                    next[r * dim.k + k] = activation(count as f32, dim.s);
                }
            }
        }
        std::mem::swap(map, next);
        hw = dim.fmap_hw;
        c = dim.k;
        // The python model pools whenever the next layer's input is half
        // the current fmap; infer pooling from the geometry chain.
        let next_hw = if li + 1 < conv_layers.len() {
            // conv is SAME/stride-1 → its input hw equals fmap_hw of its
            // input map; derive from s = 9·C and h.
            let next = conv_layers[li + 1];
            (next.h as f64).sqrt() as usize
        } else {
            // Before FC: fc S = hw²·C defines the final hw.
            let fc = artifact.layers.last().expect("fc layer");
            let hw2 = fc.s / dim.k;
            (hw2 as f64).sqrt() as usize
        };
        if next_hw * 2 == hw {
            maxpool2_into(map, hw, c, next);
            std::mem::swap(map, next);
            hw = next_hw;
        } else {
            assert_eq!(next_hw, hw, "geometry chain broken at layer {}", li);
        }
    }
    // Final FC: raw bitcount logits (no activation).
    let fc = artifact.layers.last().expect("fc layer");
    let w = weights[weights.len() - 1].as_ref();
    assert_eq!(w.len(), fc.s * fc.k);
    assert_eq!(map.len(), fc.s, "flattened features");
    let mut logits = vec![0.0f32; fc.k];
    for k in 0..fc.k {
        let mut count = 0u32;
        for s in 0..fc.s {
            let a = map[s] > 0.5;
            let b = w[s * fc.k + k] > 0.5;
            if a == b {
                count += 1;
            }
        }
        logits[k] = count as f32;
    }
    logits
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binarize_thresholds_at_zero() {
        assert_eq!(binarize01(&[-1.0, -0.0, 0.0, 0.5]), vec![0.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn xnor_popcount_cases() {
        assert_eq!(xnor_popcount(&[1.0, 0.0], &[1.0, 0.0]), 2.0);
        assert_eq!(xnor_popcount(&[1.0, 0.0], &[0.0, 1.0]), 0.0);
        assert_eq!(xnor_popcount(&[1.0, 1.0, 0.0], &[1.0, 0.0, 0.0]), 2.0);
    }

    #[test]
    fn activation_strict_majority() {
        assert_eq!(activation(5.0, 10), 0.0); // exactly half → 0
        assert_eq!(activation(6.0, 10), 1.0);
    }

    #[test]
    fn im2col_layout_matches_python_convention() {
        // 2×2 map, 1 channel, 3×3 kernel, SAME pad: center position sees
        // the full map in kernel-position-major order.
        let m = FeatureMap::new(2, 1, vec![1.0, 0.0, 0.0, 1.0]);
        let rows = im2col(&m, 3, 1);
        assert_eq!(rows.len(), 4);
        // Output (0,0): kernel window centered there; (ki,kj) = (1,1) is
        // the map's (0,0) = 1.0, (1,2) is (0,1) = 0.0, etc.
        let r = &rows[0];
        assert_eq!(r.len(), 9);
        assert_eq!(r[4], 1.0); // center
        assert_eq!(r[5], 0.0); // right of center
        assert_eq!(r[8], 1.0); // bottom-right = map (1,1)
        assert_eq!(r[0], 0.0); // top-left = padding
    }

    #[test]
    fn maxpool_is_or() {
        let m = FeatureMap::new(2, 1, vec![0.0, 1.0, 0.0, 0.0]);
        let p = maxpool2(&m);
        assert_eq!(p.hw, 1);
        assert_eq!(p.data, vec![1.0]);
        let z = FeatureMap::new(2, 1, vec![0.0; 4]);
        assert_eq!(maxpool2(&z).data, vec![0.0]);
    }

    #[test]
    fn padding_reads_zero() {
        let m = FeatureMap::new(2, 1, vec![1.0; 4]);
        assert_eq!(m.at(-1, 0, 0), 0.0);
        assert_eq!(m.at(0, 2, 0), 0.0);
        assert_eq!(m.at(1, 1, 0), 1.0);
    }
}
