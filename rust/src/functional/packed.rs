//! Bit-packed XNOR-popcount execution path for the functional BNN engine.
//!
//! The paper's premise is that binarization turns convolution into XNOR +
//! bitcount; this module finally computes it that way in software. Weights
//! and activations pack into `u64` lanes — one bit per synapse, 64
//! synapses per word — and every VDP is `count_ones(!(a ^ b))` over the
//! packed words with a tail mask for depths that are not a multiple of 64.
//! Mirrors the electronic XNOR engines the paper cites (XNOR Neural
//! Engine, XNORBIN): the datapath IS the wide XNOR+popcount.
//!
//! [`forward_packed`] follows [`super::bnn::forward`]'s layer chain
//! operation-for-operation — same im2col layout (`(ki·KW + kj)·C + c`),
//! SAME zero padding, comparator activation, 2×2 binary max-pool computed
//! as word-wise OR — and is bit-exact against it (differential suite in
//! `rust/tests/functional_packed.rs`; the f32 path is kept as the
//! reference). The packed im2col writes window bits directly into a
//! reused row buffer via word-level bit runs, so the hot loop performs no
//! per-row allocation.

use crate::runtime::manifest::{Artifact, LayerDim};

/// Bits per packed word.
pub const WORD_BITS: usize = 64;

/// Packed words needed to hold `bits` bits.
#[inline]
pub fn words_for(bits: usize) -> usize {
    bits.div_ceil(WORD_BITS)
}

/// Mask selecting the valid bits of the LAST word of a `len`-bit buffer
/// (all ones when `len` is a multiple of 64).
#[inline]
fn tail_mask(len: usize) -> u64 {
    let rem = len % WORD_BITS;
    if rem == 0 {
        !0u64
    } else {
        (1u64 << rem) - 1
    }
}

/// A fixed-length bit buffer (LSB-first within each word). Bits past
/// `len` are kept zero — every mutator below preserves that invariant, so
/// popcounts only need to mask the final word.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PackedBits {
    words: Vec<u64>,
    len: usize,
}

impl PackedBits {
    /// An all-zero buffer of `len` bits.
    pub fn zeros(len: usize) -> PackedBits {
        PackedBits { words: vec![0u64; words_for(len)], len }
    }

    /// Reset to `len` bits, all zero, reusing the existing allocation
    /// when it is large enough (the buffer-reuse contract of the packed
    /// forward path).
    pub fn clear_resize(&mut self, len: usize) {
        self.words.clear();
        self.words.resize(words_for(len), 0);
        self.len = len;
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Set bit `i` to 1.
    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / WORD_BITS] |= 1u64 << (i % WORD_BITS);
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / WORD_BITS] >> (i % WORD_BITS)) & 1 != 0
    }

    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    #[inline]
    pub fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> u64 {
        self.words.iter().map(|w| w.count_ones() as u64).sum()
    }
}

/// Pack a {0,1}-valued f32 slice (bit = `v > 0.5`, matching the f32
/// engine's comparisons on binarized data).
pub fn pack01(xs: &[f32]) -> PackedBits {
    let mut out = PackedBits::zeros(xs.len());
    for (i, &v) in xs.iter().enumerate() {
        if v > 0.5 {
            out.set(i);
        }
    }
    out
}

/// Pack a real-valued input (bit = `v >= 0.0` — paper Eq. 1's {0,1}
/// binarization, identical to `binarize01` followed by [`pack01`]).
pub fn pack_real(xs: &[f32]) -> PackedBits {
    let mut out = PackedBits::zeros(xs.len());
    for (i, &v) in xs.iter().enumerate() {
        if v >= 0.0 {
            out.set(i);
        }
    }
    out
}

/// XNOR + popcount over two packed `len`-bit vectors: the number of
/// positions where the operands agree. The tail of the last word is
/// masked, so callers may hand over buffers whose spare bits disagree.
#[inline]
pub fn xnor_popcount_u64(a: &[u64], b: &[u64], len: usize) -> u64 {
    let nw = words_for(len);
    debug_assert!(a.len() >= nw && b.len() >= nw);
    if nw == 0 {
        return 0;
    }
    let mut count = 0u64;
    for (x, y) in a[..nw - 1].iter().zip(&b[..nw - 1]) {
        count += (!(x ^ y)).count_ones() as u64;
    }
    count + ((!(a[nw - 1] ^ b[nw - 1])) & tail_mask(len)).count_ones() as u64
}

/// Read `n` (1..=64) bits starting at bit offset `off` of `words`,
/// returned in the low bits of a u64.
#[inline]
fn read_bits(words: &[u64], off: usize, n: usize) -> u64 {
    debug_assert!((1..=WORD_BITS).contains(&n));
    let w = off / WORD_BITS;
    let b = off % WORD_BITS;
    let mut val = words[w] >> b;
    if b != 0 && b + n > WORD_BITS {
        val |= words[w + 1] << (WORD_BITS - b);
    }
    if n == WORD_BITS {
        val
    } else {
        val & ((1u64 << n) - 1)
    }
}

/// OR the low `n` (1..=64) bits of `val` into `words` at bit offset
/// `off`. Destination bits are assumed to start zero (the cleared-buffer
/// invariant), so OR equals write.
#[inline]
fn or_bits(words: &mut [u64], off: usize, n: usize, val: u64) {
    debug_assert!((1..=WORD_BITS).contains(&n));
    let val = if n == WORD_BITS { val } else { val & ((1u64 << n) - 1) };
    let w = off / WORD_BITS;
    let b = off % WORD_BITS;
    words[w] |= val << b;
    if b != 0 && b + n > WORD_BITS {
        words[w + 1] |= val >> (WORD_BITS - b);
    }
}

/// Copy an `n`-bit run from `src` (starting at `src_off`) into `dst`
/// (starting at `dst_off`, assumed zero). Word-level blit: ≤64-bit chunks
/// with two-word combines, never bit-by-bit.
pub fn copy_bits(src: &[u64], src_off: usize, dst: &mut [u64], dst_off: usize, mut n: usize) {
    let mut s = src_off;
    let mut d = dst_off;
    while n > 0 {
        let chunk = n.min(WORD_BITS);
        or_bits(dst, d, chunk, read_bits(src, s, chunk));
        s += chunk;
        d += chunk;
        n -= chunk;
    }
}

/// One layer's weight matrix with every column packed into `u64` lanes:
/// column `k` of the (S, K) row-major f32 matrix becomes a contiguous
/// `ceil(S/64)`-word bit vector, ready for [`xnor_popcount_u64`] against
/// a packed activation row. Packing happens ONCE (at artifact staging
/// time on the serving path); every dispatch afterwards only reads.
#[derive(Debug, Clone)]
pub struct PackedMatrix {
    s: usize,
    k: usize,
    /// Words per column.
    wpc: usize,
    /// K columns × wpc words, column-major.
    cols: Vec<u64>,
}

impl PackedMatrix {
    /// Pack a (S, K) row-major {0,1} f32 weight matrix (bit = `w > 0.5`).
    pub fn pack(data: &[f32], s: usize, k: usize) -> PackedMatrix {
        assert_eq!(data.len(), s * k, "weight matrix must be S*K");
        let wpc = words_for(s).max(1);
        let mut cols = vec![0u64; wpc * k];
        for si in 0..s {
            let row = si * k;
            let word = si / WORD_BITS;
            let bit = 1u64 << (si % WORD_BITS);
            for (ki, &v) in data[row..row + k].iter().enumerate() {
                if v > 0.5 {
                    cols[ki * wpc + word] |= bit;
                }
            }
        }
        PackedMatrix { s, k, wpc, cols }
    }

    #[inline]
    pub fn s(&self) -> usize {
        self.s
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// The packed bit-vector of column `ki` (length `ceil(S/64)` words).
    #[inline]
    pub fn col(&self, ki: usize) -> &[u64] {
        debug_assert!(ki < self.k);
        &self.cols[ki * self.wpc..(ki + 1) * self.wpc]
    }

    /// Heap bytes held by the packed representation (64× smaller than
    /// the staged f32 matrix, modulo per-column padding).
    pub fn packed_bytes(&self) -> usize {
        self.cols.len() * std::mem::size_of::<u64>()
    }
}

/// All of a bnn_forward artifact's weights packed once — one
/// [`PackedMatrix`] per layer (conv layers then FC), geometry taken from
/// the manifest layer table.
#[derive(Debug, Clone)]
pub struct PackedWeights {
    mats: Vec<PackedMatrix>,
}

impl PackedWeights {
    /// Pack every layer's (S, K) weight matrix of `artifact`.
    pub fn pack(artifact: &Artifact, weights: &[impl AsRef<[f32]>]) -> PackedWeights {
        assert_eq!(weights.len(), artifact.layers.len(), "one weight matrix per layer");
        let mats = weights
            .iter()
            .zip(&artifact.layers)
            .map(|(w, dim)| PackedMatrix::pack(w.as_ref(), dim.s, dim.k))
            .collect();
        PackedWeights { mats }
    }

    pub fn layers(&self) -> &[PackedMatrix] {
        &self.mats
    }

    /// Borrowed per-layer views, the shape [`forward_packed`] consumes.
    pub fn refs(&self) -> Vec<&PackedMatrix> {
        self.mats.iter().collect()
    }
}

/// Reused packed buffers for [`forward_packed_with`]: one im2col row and
/// two ping-pong feature maps. Holding one `Scratch` per worker/frame
/// loop makes the packed hot path allocation-free after warmup (gated in
/// `rust/benches/bench_functional.rs`).
#[derive(Debug, Clone, Default)]
pub struct Scratch {
    row: PackedBits,
    map: PackedBits,
    next: PackedBits,
}

/// Fill `row` with the packed im2col window for output position
/// (`oi`, `oj`): python layout `(ki·KW + kj)·C + c`, SAME zero padding
/// (out-of-bounds bits stay zero in the cleared buffer), given stride.
/// Each in-bounds kernel position contributes one contiguous C-bit run,
/// blitted word-wise from the packed map.
fn fill_packed_row(
    map: &PackedBits,
    hw: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    pos: (usize, usize),
    row: &mut PackedBits,
) {
    let (oi, oj) = pos;
    row.clear_resize(kernel * kernel * c);
    let pad = (kernel - 1) / 2;
    for ki in 0..kernel {
        let i = (oi * stride + ki) as isize - pad as isize;
        if i < 0 || i >= hw as isize {
            continue;
        }
        for kj in 0..kernel {
            let j = (oj * stride + kj) as isize - pad as isize;
            if j < 0 || j >= hw as isize {
                continue;
            }
            copy_bits(
                map.words(),
                (i as usize * hw + j as usize) * c,
                row.words_mut(),
                (ki * kernel + kj) * c,
                c,
            );
        }
    }
}

/// 2×2 stride-2 max pool of a packed binary map: max over {0,1} is OR,
/// computed as word-wise OR of the four window positions' channel runs.
fn maxpool2_packed(map: &PackedBits, hw: usize, c: usize, out: &mut PackedBits) {
    assert_eq!(hw % 2, 0, "pooling needs even hw");
    let out_hw = hw / 2;
    out.clear_resize(out_hw * out_hw * c);
    for i in 0..out_hw {
        for j in 0..out_hw {
            let mut ch = 0;
            while ch < c {
                let n = (c - ch).min(WORD_BITS);
                let mut v = 0u64;
                for (di, dj) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                    let src = ((2 * i + di) * hw + (2 * j + dj)) * c + ch;
                    v |= read_bits(map.words(), src, n);
                }
                or_bits(out.words_mut(), (i * out_hw + j) * c + ch, n, v);
                ch += n;
            }
        }
    }
}

/// Bit-packed full forward pass: identical layer chain to
/// [`super::bnn::forward`] (conv layers then FC, pooling inferred from
/// the geometry chain), computed as XNOR + `count_ones` over `u64` lanes.
/// Allocates its own scratch; hot loops should hold a [`Scratch`] and
/// call [`forward_packed_with`].
pub fn forward_packed(
    artifact: &Artifact,
    x: &[f32],
    weights: &[&PackedMatrix],
) -> Vec<f32> {
    let mut scratch = Scratch::default();
    forward_packed_with(artifact, x, weights, &mut scratch)
}

/// [`forward_packed`] with caller-owned scratch buffers (no per-frame
/// allocation beyond the returned logits).
pub fn forward_packed_with(
    artifact: &Artifact,
    x: &[f32],
    weights: &[&PackedMatrix],
    scratch: &mut Scratch,
) -> Vec<f32> {
    let input_hw = artifact.input_hw.expect("bnn artifact has input_hw");
    let input_c = artifact.input_channels.expect("input_channels");
    assert_eq!(x.len(), input_hw * input_hw * input_c);
    assert_eq!(weights.len(), artifact.layers.len());

    let Scratch { row, map, next } = scratch;

    // Binarize the real-valued input straight into packed form (Eq. 1).
    map.clear_resize(x.len());
    for (i, &v) in x.iter().enumerate() {
        if v >= 0.0 {
            map.set(i);
        }
    }
    let mut hw = input_hw;
    let mut c = input_c;

    let conv_layers: Vec<&LayerDim> =
        artifact.layers.iter().filter(|l| l.kind == "conv").collect();
    for (li, dim) in conv_layers.iter().enumerate() {
        let pm = weights[li];
        assert_eq!(pm.s(), dim.s, "layer {} packed weight S", li);
        assert_eq!(pm.k(), dim.k, "layer {} packed weight K", li);
        // SAME-padded stride-1 3×3 conv: one output position per input
        // position (the same geometry `forward` asserts via im2col).
        assert_eq!(hw * hw, dim.h, "layer {} H", li);
        next.clear_resize(dim.h * dim.k);
        for oi in 0..hw {
            for oj in 0..hw {
                fill_packed_row(map, hw, c, 3, 1, (oi, oj), row);
                let r = oi * hw + oj;
                for k in 0..dim.k {
                    let count = xnor_popcount_u64(row.words(), pm.col(k), dim.s);
                    // Comparator activation `count > 0.5·S`, integer-exact.
                    if 2 * count > dim.s as u64 {
                        next.set(r * dim.k + k);
                    }
                }
            }
        }
        std::mem::swap(map, next);
        assert_eq!(dim.fmap_hw * dim.fmap_hw * dim.k, map.len(), "layer {} fmap", li);
        hw = dim.fmap_hw;
        c = dim.k;
        // Pooling is inferred from the geometry chain exactly as in the
        // f32 reference: pool whenever the next layer's input is half
        // the current fmap.
        let next_hw = if li + 1 < conv_layers.len() {
            let nxt = conv_layers[li + 1];
            (nxt.h as f64).sqrt() as usize
        } else {
            let fc = artifact.layers.last().expect("fc layer");
            let hw2 = fc.s / dim.k;
            (hw2 as f64).sqrt() as usize
        };
        if next_hw * 2 == hw {
            maxpool2_packed(map, hw, c, next);
            std::mem::swap(map, next);
            hw = next_hw;
        } else {
            assert_eq!(next_hw, hw, "geometry chain broken at layer {}", li);
        }
    }

    // Final FC: raw bitcount logits (no activation).
    let fc = artifact.layers.last().expect("fc layer");
    let pm = weights[weights.len() - 1];
    assert_eq!(pm.s(), fc.s);
    assert_eq!(pm.k(), fc.k);
    assert_eq!(map.len(), fc.s, "flattened features");
    (0..fc.k)
        .map(|k| xnor_popcount_u64(map.words(), pm.col(k), fc.s) as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pack_roundtrip_and_invariant() {
        let xs = [0.0f32, 1.0, 1.0, 0.0, 1.0];
        let p = pack01(&xs);
        assert_eq!(p.len(), 5);
        for (i, &v) in xs.iter().enumerate() {
            assert_eq!(p.get(i), v > 0.5);
        }
        assert_eq!(p.count_ones(), 3);
        // Spare bits of the last word stay zero.
        assert_eq!(p.words()[0] >> 5, 0);
    }

    #[test]
    fn pack_real_matches_binarize01_then_pack01() {
        let mut rng = Rng::new(0xACE);
        let xs: Vec<f32> = (0..200).map(|_| rng.f64() as f32 - 0.5).collect();
        let direct = pack_real(&xs);
        let via_f32 = pack01(&crate::functional::bnn::binarize01(&xs));
        assert_eq!(direct, via_f32);
    }

    /// Scalar reference for the packed popcount.
    fn xnor_ref(a: &[f32], b: &[f32]) -> u64 {
        a.iter().zip(b).filter(|(x, y)| (**x > 0.5) == (**y > 0.5)).count() as u64
    }

    #[test]
    fn xnor_popcount_tail_mask_edges() {
        let mut rng = Rng::new(0x7A11);
        // depth % 64 ∈ {0, 1, 63} plus the word-boundary edges themselves.
        for len in [1usize, 63, 64, 65, 127, 128, 129, 191, 192, 513] {
            let a = rng.bits(len);
            let b = rng.bits(len);
            let pa = pack01(&a);
            let pb = pack01(&b);
            assert_eq!(
                xnor_popcount_u64(pa.words(), pb.words(), len),
                xnor_ref(&a, &b),
                "len {}",
                len
            );
        }
        assert_eq!(xnor_popcount_u64(&[], &[], 0), 0);
    }

    #[test]
    fn xnor_popcount_ignores_spare_tail_bits() {
        // Buffers whose spare bits DISAGREE must still count only len bits.
        let len = 70;
        let mut a = PackedBits::zeros(len);
        let b = PackedBits::zeros(len);
        for i in 0..len {
            a.set(i);
        }
        // Corrupt a's spare tail bits (simulating a dirty scratch word).
        a.words_mut()[1] |= !tail_mask(len);
        assert_eq!(xnor_popcount_u64(a.words(), b.words(), len), 0);
    }

    #[test]
    fn copy_bits_matches_per_bit_reference() {
        let mut rng = Rng::new(0xB117);
        for _ in 0..50 {
            let n_src = 300;
            let src_f = rng.bits(n_src);
            let src = pack01(&src_f);
            let src_off = (rng.f64() * 200.0) as usize;
            let n = 1 + (rng.f64() * (n_src - src_off - 1).max(1) as f64) as usize;
            let dst_off = (rng.f64() * 100.0) as usize;
            let mut dst = PackedBits::zeros(dst_off + n + 64);
            copy_bits(src.words(), src_off, dst.words_mut(), dst_off, n);
            for i in 0..dst.len() {
                let want = if i >= dst_off && i < dst_off + n {
                    src.get(src_off + (i - dst_off))
                } else {
                    false
                };
                assert_eq!(dst.get(i), want, "bit {} (src_off {}, n {})", i, src_off, n);
            }
        }
    }

    #[test]
    fn packed_matrix_columns_match_f32_layout() {
        let (s, k) = (67, 5); // tail-mask depth
        let mut rng = Rng::new(0x90);
        let w = rng.bits(s * k);
        let pm = PackedMatrix::pack(&w, s, k);
        assert_eq!((pm.s(), pm.k()), (s, k));
        for ki in 0..k {
            let col = pm.col(ki);
            for si in 0..s {
                let bit = (col[si / 64] >> (si % 64)) & 1 != 0;
                assert_eq!(bit, w[si * k + ki] > 0.5, "({}, {})", si, ki);
            }
        }
        assert_eq!(pm.packed_bytes(), words_for(s) * k * 8);
    }

    #[test]
    fn packed_maxpool_is_or() {
        // 2×2 map, 3 channels: out bit = OR over the four positions.
        let c = 3;
        let mut map = PackedBits::zeros(4 * c);
        map.set(c + 1); // position (0,1), channel 1
        map.set(3 * c + 1); // position (1,1), channel 1
        let mut out = PackedBits::zeros(0);
        maxpool2_packed(&map, 2, c, &mut out);
        assert_eq!(out.len(), c);
        assert!(!out.get(0));
        assert!(out.get(1));
        assert!(!out.get(2));
    }

    #[test]
    fn packed_im2col_row_matches_f32_im2col() {
        use crate::functional::bnn::{im2col, FeatureMap};
        let mut rng = Rng::new(0x1C01);
        for (hw, c) in [(2usize, 1usize), (4, 3), (5, 7), (6, 64), (4, 65)] {
            let data = rng.bits(hw * hw * c);
            let fmap = FeatureMap::new(hw, c, data.clone());
            let rows = im2col(&fmap, 3, 1);
            let packed_map = pack01(&data);
            let mut row = PackedBits::zeros(0);
            for oi in 0..hw {
                for oj in 0..hw {
                    fill_packed_row(&packed_map, hw, c, 3, 1, (oi, oj), &mut row);
                    let want = &rows[oi * hw + oj];
                    assert_eq!(row.len(), want.len());
                    for (i, &v) in want.iter().enumerate() {
                        assert_eq!(
                            row.get(i),
                            v > 0.5,
                            "hw {} c {} pos ({}, {}) bit {}",
                            hw,
                            c,
                            oi,
                            oj,
                            i
                        );
                    }
                }
            }
        }
    }
}
