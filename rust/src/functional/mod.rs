//! Bit-exact functional BNN engine (independent of XLA) for
//! cross-validating the AOT artifacts and served responses.

pub mod bnn;

pub use bnn::{activation, binarize01, forward, im2col, maxpool2, xnor_popcount, FeatureMap};
