//! Bit-exact functional BNN engine (independent of XLA) for
//! cross-validating the AOT artifacts and served responses.
//!
//! Two executions of the same contract:
//! - [`bnn`] — the f32 reference: binarized values carried as `f32`,
//!   scalar compare-and-count VDPs. Slow, obviously correct.
//! - [`packed`] — the production path: weights/activations packed one
//!   bit per synapse into `u64` lanes, VDPs computed as XNOR +
//!   `count_ones`. Bit-exact against the reference (differential suite
//!   in `rust/tests/functional_packed.rs`) and the default everywhere.
//!
//! [`FunctionalMode`] selects between them; `OXBNN_FUNCTIONAL=f32` is
//! the escape hatch back to the reference implementation.

pub mod bnn;
pub mod packed;

pub use bnn::{activation, binarize01, forward, im2col, maxpool2, xnor_popcount, FeatureMap};
pub use packed::{
    forward_packed, pack01, xnor_popcount_u64, PackedBits, PackedMatrix, PackedWeights,
};

/// Which functional implementation executes BNN forward passes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FunctionalMode {
    /// Bit-packed XNOR + popcount over `u64` lanes (the default).
    #[default]
    Packed,
    /// The scalar f32 reference (differential baseline / escape hatch).
    F32,
}

impl FunctionalMode {
    /// Resolve the mode from the `OXBNN_FUNCTIONAL` environment variable:
    /// `f32` selects the reference path, anything else (or unset) packed.
    pub fn from_env() -> FunctionalMode {
        match std::env::var("OXBNN_FUNCTIONAL") {
            Ok(v) if v.eq_ignore_ascii_case("f32") => FunctionalMode::F32,
            _ => FunctionalMode::Packed,
        }
    }
}

impl std::fmt::Display for FunctionalMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FunctionalMode::Packed => write!(f, "packed"),
            FunctionalMode::F32 => write!(f, "f32"),
        }
    }
}

impl std::str::FromStr for FunctionalMode {
    type Err = String;

    fn from_str(s: &str) -> Result<FunctionalMode, String> {
        match s.to_ascii_lowercase().as_str() {
            "packed" => Ok(FunctionalMode::Packed),
            "f32" => Ok(FunctionalMode::F32),
            other => Err(format!(
                "unknown functional mode '{}' (expected 'packed' or 'f32')",
                other
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::FunctionalMode;

    #[test]
    fn mode_parses_and_displays() {
        assert_eq!("packed".parse::<FunctionalMode>(), Ok(FunctionalMode::Packed));
        assert_eq!("F32".parse::<FunctionalMode>(), Ok(FunctionalMode::F32));
        assert!("qbits".parse::<FunctionalMode>().is_err());
        assert_eq!(FunctionalMode::Packed.to_string(), "packed");
        assert_eq!(FunctionalMode::F32.to_string(), "f32");
        assert_eq!(FunctionalMode::default(), FunctionalMode::Packed);
    }
}
