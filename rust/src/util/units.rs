//! Physical-unit helpers shared by the photonic device models and the
//! scalability analysis (paper Eqs. 3–5 mix dB, dBm, watts, amps, volts,
//! seconds and samples-per-second; keeping conversions in one audited
//! place prevents the classic dB-vs-linear bugs).

/// Convert decibel-milliwatts to watts.
pub fn dbm_to_watt(dbm: f64) -> f64 {
    1e-3 * 10f64.powf(dbm / 10.0)
}

/// Convert watts to decibel-milliwatts.
pub fn watt_to_dbm(w: f64) -> f64 {
    10.0 * (w / 1e-3).log10()
}

/// Convert a dB quantity to a linear power ratio.
pub fn db_to_linear(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// Convert a linear power ratio to dB.
pub fn linear_to_db(ratio: f64) -> f64 {
    10.0 * ratio.log10()
}

/// Boltzmann constant (J/K).
pub const BOLTZMANN: f64 = 1.380_649e-23;

/// Elementary charge (C).
pub const ELEMENTARY_CHARGE: f64 = 1.602_176_634e-19;

/// Speed of light in vacuum (m/s).
pub const SPEED_OF_LIGHT: f64 = 2.997_924_58e8;

/// Giga-samples-per-second to samples-per-second.
pub fn gsps_to_hz(gsps: f64) -> f64 {
    gsps * 1e9
}

/// Seconds per sample at a data rate in GS/s.
pub fn gsps_period_s(gsps: f64) -> f64 {
    1.0 / gsps_to_hz(gsps)
}

/// Nanometres to metres.
pub fn nm_to_m(nm: f64) -> f64 {
    nm * 1e-9
}

/// Human-readable time: picks ps/ns/us/ms/s.
pub fn fmt_time(seconds: f64) -> String {
    let abs = seconds.abs();
    if abs == 0.0 {
        "0 s".to_string()
    } else if abs < 1e-9 {
        format!("{:.3} ps", seconds * 1e12)
    } else if abs < 1e-6 {
        format!("{:.3} ns", seconds * 1e9)
    } else if abs < 1e-3 {
        format!("{:.3} us", seconds * 1e6)
    } else if abs < 1.0 {
        format!("{:.3} ms", seconds * 1e3)
    } else {
        format!("{:.3} s", seconds)
    }
}

/// Human-readable power: pW/nW/uW/mW/W.
pub fn fmt_power(watts: f64) -> String {
    let abs = watts.abs();
    if abs == 0.0 {
        "0 W".to_string()
    } else if abs < 1e-9 {
        format!("{:.3} pW", watts * 1e12)
    } else if abs < 1e-6 {
        format!("{:.3} nW", watts * 1e9)
    } else if abs < 1e-3 {
        format!("{:.3} uW", watts * 1e6)
    } else if abs < 1.0 {
        format!("{:.3} mW", watts * 1e3)
    } else {
        format!("{:.3} W", watts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * b.abs().max(1e-30)
    }

    #[test]
    fn dbm_watt_roundtrip() {
        // Paper Table I: laser power 5 dBm ≈ 3.162 mW.
        assert!(close(dbm_to_watt(5.0), 3.1623e-3, 1e-4));
        assert!(close(dbm_to_watt(0.0), 1e-3, 1e-12));
        for dbm in [-24.69, -18.5, 0.0, 5.0, 10.0] {
            assert!(close(watt_to_dbm(dbm_to_watt(dbm)), dbm, 1e-9));
        }
    }

    #[test]
    fn db_linear_roundtrip() {
        assert!(close(db_to_linear(3.0), 1.9953, 1e-4));
        assert!(close(db_to_linear(-4.8), 0.33113, 1e-4));
        for db in [-10.0, -4.8, 0.0, 0.01, 4.0] {
            assert!(close(linear_to_db(db_to_linear(db)), db, 1e-9));
        }
    }

    #[test]
    fn datarate_periods() {
        // Paper: tau as low as 20 ps at DR=50 GS/s.
        assert!(close(gsps_period_s(50.0), 20e-12, 1e-12));
        assert!(close(gsps_period_s(3.0), 333.33e-12, 1e-4));
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_time(20e-12), "20.000 ps");
        assert_eq!(fmt_time(3.125e-9), "3.125 ns");
        assert_eq!(fmt_time(4e-6), "4.000 us");
        assert_eq!(fmt_power(41.1e-3), "41.100 mW");
        assert_eq!(fmt_power(80e-6), "80.000 uW");
    }
}
