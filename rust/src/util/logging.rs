//! Leveled logging substrate (no `log`/`env_logger` facade wiring needed).
//!
//! Global level is an atomic; macros compile to a level check plus an
//! eprintln. `RUST_LOG`-style control comes from `Level::from_env()` or the
//! CLI `--log-level` option.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn from_env() -> Level {
        std::env::var("OXBNN_LOG")
            .ok()
            .and_then(|v| Level::parse(&v))
            .unwrap_or(Level::Info)
    }

    pub fn label(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Set the global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current global log level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// True if a message at `l` would be emitted.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

#[doc(hidden)]
pub fn emit(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{:5}] {}: {}", l.label(), module, msg);
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Error, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Warn, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Info, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Debug, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Trace, module_path!(), format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("Info"), Some(Level::Info));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn labels() {
        assert_eq!(Level::Debug.label(), "DEBUG");
    }
}
