//! Benchmark harness substrate (no `criterion` offline).
//!
//! Provides warmup, calibrated iteration counts, robust statistics
//! (median/MAD plus mean/stddev/min/max), throughput reporting, and a
//! table printer used by every `rust/benches/bench_*.rs` target (all are
//! `harness = false` binaries).

use std::time::{Duration, Instant};

/// Result statistics of one benchmark case, in seconds.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: f64,
    pub stddev: f64,
    pub median: f64,
    pub mad: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn from_samples(name: &str, samples: &[f64]) -> Stats {
        assert!(!samples.is_empty());
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = percentile(&sorted, 50.0);
        let mut devs: Vec<f64> = sorted.iter().map(|s| (s - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Stats {
            name: name.to_string(),
            iters: samples.len(),
            mean,
            stddev: var.sqrt(),
            median,
            mad: percentile(&devs, 50.0),
            min: sorted[0],
            max: *sorted.last().unwrap(),
        }
    }

    /// ops/second given `ops` operations per measured iteration.
    pub fn throughput(&self, ops: f64) -> f64 {
        ops / self.median
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_samples: 200,
        }
    }
}

impl Bencher {
    /// Fast preset for CI-style smoke runs (`OXBNN_BENCH_FAST=1`).
    pub fn from_env() -> Bencher {
        if std::env::var("OXBNN_BENCH_FAST").is_ok() {
            Bencher {
                warmup: Duration::from_millis(20),
                measure: Duration::from_millis(100),
                max_samples: 20,
            }
        } else {
            Bencher::default()
        }
    }

    /// Measure `f` repeatedly; returns robust stats over per-call times.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Stats {
        // Warmup until the time budget is spent (at least one call).
        let start = Instant::now();
        loop {
            std::hint::black_box(f());
            if start.elapsed() >= self.warmup {
                break;
            }
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && samples.len() < self.max_samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        Stats::from_samples(name, &samples)
    }
}

/// Fixed-width results table printer shared by the bench binaries.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>width$}", cells[i], width = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds for bench output.
pub fn fmt_secs(s: f64) -> String {
    crate::util::units::fmt_time(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::from_samples("t", &[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.iters, 5);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!(s.mean > s.median); // outlier pulls the mean
        assert_eq!(s.mad, 1.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn bencher_runs_and_measures() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            max_samples: 50,
        };
        let mut count = 0u64;
        let s = b.run("spin", || {
            count += 1;
            std::hint::black_box(count)
        });
        assert!(s.iters >= 1);
        assert!(s.median >= 0.0);
        assert!(count as usize >= s.iters);
    }

    #[test]
    fn throughput() {
        let s = Stats::from_samples("t", &[0.5]);
        assert_eq!(s.throughput(100.0), 200.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[2].ends_with("1"));
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
