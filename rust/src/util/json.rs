//! Minimal, dependency-free JSON parser and serializer.
//!
//! The offline crate set for this repository has no `serde`, so this module
//! is the substrate used everywhere structured data crosses a boundary: the
//! AOT `artifacts/manifest.json`, accelerator config files, and benchmark
//! result dumps.
//!
//! Supports the full JSON grammar (RFC 8259): objects, arrays, strings with
//! escapes (including `\uXXXX` and surrogate pairs), numbers, booleans and
//! null. Numbers are held as `f64` (adequate: every number we exchange is a
//! shape, count, or measurement).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps serialization deterministic (sorted keys).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Path lookup: `j.path(&["artifacts", "bnn_tiny", "file"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // -- construction helpers ---------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(vals: &[f64]) -> Json {
        Json::Arr(vals.iter().map(|v| Json::Num(*v)).collect())
    }

    pub fn arr_usize(vals: &[usize]) -> Json {
        Json::Arr(vals.iter().map(|v| Json::Num(*v as f64)).collect())
    }

    // -- serialization -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_number(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_number(out: &mut String, n: f64) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{}", n));
    } else {
        // JSON has no Inf/NaN; encode as null (documented lossy behaviour).
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{08}'),
                    Some(b'f') => s.push('\u{0c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // Surrogate pair.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        s.push(
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("invalid unicode escape"))?,
                        );
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences from raw bytes.
                    let len = utf8_len(b).ok_or_else(|| self.err("invalid utf-8"))?;
                    if len == 1 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(b: u8) -> Option<usize> {
    match b {
        0x00..=0x7F => Some(1),
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.path(&["a"]).unwrap().as_arr().unwrap()[2].get("b"),
            Some(&Json::Null)
        );
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\ A 😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let j = Json::parse("\"héllo → ∞\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo → ∞");
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"nums":[1,2.5,-3],"s":"x\ny","t":true,"u":null}"#;
        let j = Json::parse(src).unwrap();
        let compact = j.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), j);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn accessors() {
        let j = Json::parse(r#"{"n": 7, "f": 7.5, "b": true}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("f").unwrap().as_usize(), None);
        assert_eq!(j.get("f").unwrap().as_f64(), Some(7.5));
        assert_eq!(j.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("missing"), None);
    }

    #[test]
    fn deterministic_object_order() {
        let j = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        assert_eq!(j.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn real_manifest_shape() {
        // Mirrors the structure emitted by python/compile/aot.py.
        let src = r#"{
          "format": "hlo-text",
          "artifacts": {
            "bnn_tiny": {
              "kind": "bnn_forward",
              "file": "bnn_tiny.hlo.txt",
              "args": [{"name": "x", "shape": [1, 8, 8, 3], "dtype": "f32"}],
              "output": {"shape": [1, 10], "dtype": "f32"}
            }
          }
        }"#;
        let j = Json::parse(src).unwrap();
        let a = j.path(&["artifacts", "bnn_tiny"]).unwrap();
        assert_eq!(a.get("file").unwrap().as_str(), Some("bnn_tiny.hlo.txt"));
        let shape = a.get("args").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap();
        let dims: Vec<usize> =
            shape.as_arr().unwrap().iter().map(|d| d.as_usize().unwrap()).collect();
        assert_eq!(dims, vec![1, 8, 8, 3]);
    }
}
