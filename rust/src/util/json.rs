//! Minimal, dependency-free JSON parser and serializer.
//!
//! The offline crate set for this repository has no `serde`, so this module
//! is the substrate used everywhere structured data crosses a boundary: the
//! AOT `artifacts/manifest.json`, accelerator config files, and benchmark
//! result dumps.
//!
//! Supports the full JSON grammar (RFC 8259): objects, arrays, strings with
//! escapes (including `\uXXXX` and surrogate pairs), numbers, booleans and
//! null. Numbers are held as `f64` (adequate: every number we exchange is a
//! shape, count, or measurement).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps serialization deterministic (sorted keys).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Path lookup: `j.path(&["artifacts", "bnn_tiny", "file"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // -- construction helpers ---------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(vals: &[f64]) -> Json {
        Json::Arr(vals.iter().map(|v| Json::Num(*v)).collect())
    }

    pub fn arr_usize(vals: &[usize]) -> Json {
        Json::Arr(vals.iter().map(|v| Json::Num(*v as f64)).collect())
    }

    // -- serialization -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_number(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_number(out: &mut String, n: f64) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{}", n));
    } else {
        // JSON has no Inf/NaN; encode as null (documented lossy behaviour).
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{08}'),
                    Some(b'f') => s.push('\u{0c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // Surrogate pair.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        s.push(
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("invalid unicode escape"))?,
                        );
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences from raw bytes.
                    let len = utf8_len(b).ok_or_else(|| self.err("invalid utf-8"))?;
                    if len == 1 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(b: u8) -> Option<usize> {
    match b {
        0x00..=0x7F => Some(1),
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Lazy path scanner
// ---------------------------------------------------------------------------
//
// The serving hot path must pull two fields (`model`, `input`) out of every
// request body; building a full `Json` tree allocates a node per array
// element. These extractors walk the raw bytes instead, skipping values they
// don't need, so a request costs one `String` (the model name) and one
// reused `Vec<f32>` — O(1) allocations per request.
//
// Semantics mirror `Json::parse(..).path(keys)` followed by the typed
// accessor: a missing key, a non-object on the path, or a leaf of the wrong
// type yields `None`/`false`, never an error. Only malformed JSON *along the
// scanned route* errors; with duplicate keys the scanner takes the first
// occurrence while the tree parser keeps the last (the serializer never
// emits duplicates).

/// Extract the string at `path` from raw JSON bytes without building a tree.
pub fn path_str(bytes: &[u8], path: &[&str]) -> Result<Option<String>, JsonError> {
    let mut s = Scan { bytes, pos: 0 };
    if !s.seek(path)? {
        return Ok(None);
    }
    if s.peek() != Some(b'"') {
        return Ok(None);
    }
    let mut p = Parser { bytes, pos: s.pos };
    Ok(Some(p.string()?))
}

/// Extract the number at `path` from raw JSON bytes without building a tree.
pub fn path_f64(bytes: &[u8], path: &[&str]) -> Result<Option<f64>, JsonError> {
    let mut s = Scan { bytes, pos: 0 };
    if !s.seek(path)? {
        return Ok(None);
    }
    match s.peek() {
        Some(b'-' | b'0'..=b'9') => {
            let end = scan_number_end(bytes, s.pos);
            Ok(Some(parse_f64_span(bytes, s.pos, end)?))
        }
        _ => Ok(None),
    }
}

/// Fill `out` with the number array at `path`. `Ok(true)` means extracted;
/// `Ok(false)` means the path is missing, not an array, or holds a
/// non-number element. `out` is cleared first and its capacity reused, so
/// steady-state callers pay zero allocations here.
pub fn path_f32_slice(
    bytes: &[u8],
    path: &[&str],
    out: &mut Vec<f32>,
) -> Result<bool, JsonError> {
    out.clear();
    let mut s = Scan { bytes, pos: 0 };
    if !s.seek(path)? {
        return Ok(false);
    }
    if s.peek() != Some(b'[') {
        return Ok(false);
    }
    s.pos += 1;
    s.skip_ws();
    if s.peek() == Some(b']') {
        s.pos += 1;
        return Ok(true);
    }
    loop {
        s.skip_ws();
        match s.peek() {
            Some(b'-' | b'0'..=b'9') => {
                let end = scan_number_end(bytes, s.pos);
                let v = parse_f64_span(bytes, s.pos, end)?;
                s.pos = end;
                out.push(v as f32);
            }
            _ => {
                out.clear();
                return Ok(false);
            }
        }
        s.skip_ws();
        match s.peek() {
            Some(b',') => s.pos += 1,
            Some(b']') => {
                s.pos += 1;
                return Ok(true);
            }
            _ => return Err(s.err("expected ',' or ']' in array")),
        }
    }
}

/// Byte-walking cursor shared by the `path_*` extractors.
struct Scan<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Scan<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    /// Position the cursor at the value addressed by `path`. `Ok(false)`
    /// when a key is missing or an intermediate value is not an object.
    fn seek(&mut self, path: &[&str]) -> Result<bool, JsonError> {
        self.skip_ws();
        for want in path {
            if self.peek() != Some(b'{') {
                return Ok(false);
            }
            self.pos += 1;
            self.skip_ws();
            if self.peek() == Some(b'}') {
                self.pos += 1;
                return Ok(false);
            }
            loop {
                self.skip_ws();
                let hit = self.key_matches(want)?;
                self.skip_ws();
                if self.peek() != Some(b':') {
                    return Err(self.err("expected ':' after key"));
                }
                self.pos += 1;
                self.skip_ws();
                if hit {
                    break;
                }
                self.skip_value()?;
                self.skip_ws();
                match self.peek() {
                    Some(b',') => self.pos += 1,
                    Some(b'}') => {
                        self.pos += 1;
                        return Ok(false);
                    }
                    _ => return Err(self.err("expected ',' or '}' in object")),
                }
            }
        }
        Ok(true)
    }

    /// Consume an object key, reporting whether it equals `want`. Keys
    /// without escapes compare raw; escaped keys decode via the tree
    /// parser's string routine, so equality semantics are identical.
    fn key_matches(&mut self, want: &str) -> Result<bool, JsonError> {
        if self.peek() != Some(b'"') {
            return Err(self.err("expected object key"));
        }
        let start = self.pos;
        let mut i = self.pos + 1;
        while let Some(&b) = self.bytes.get(i) {
            match b {
                b'"' => {
                    let raw = &self.bytes[start + 1..i];
                    self.pos = i + 1;
                    return Ok(raw == want.as_bytes());
                }
                b'\\' => {
                    let mut p = Parser { bytes: self.bytes, pos: start };
                    let s = p.string()?;
                    self.pos = p.pos;
                    return Ok(s == *want);
                }
                _ => i += 1,
            }
        }
        self.pos = i;
        Err(self.err("unterminated string"))
    }

    fn skip_value(&mut self) -> Result<(), JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'"') => self.skip_string(),
            Some(b'{') => self.skip_container(b'{', b'}'),
            Some(b'[') => self.skip_container(b'[', b']'),
            Some(b't') => self.skip_lit("true"),
            Some(b'f') => self.skip_lit("false"),
            Some(b'n') => self.skip_lit("null"),
            Some(b'-' | b'0'..=b'9') => {
                self.pos = scan_number_end(self.bytes, self.pos);
                Ok(())
            }
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn skip_container(&mut self, open: u8, close: u8) -> Result<(), JsonError> {
        let mut depth = 0usize;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated container")),
                Some(b'"') => self.skip_string()?,
                Some(b) => {
                    self.pos += 1;
                    if b == open {
                        depth += 1;
                    } else if b == close {
                        depth -= 1;
                        if depth == 0 {
                            return Ok(());
                        }
                    }
                }
            }
        }
    }

    fn skip_string(&mut self) -> Result<(), JsonError> {
        self.pos += 1; // opening quote
        loop {
            match self.bytes.get(self.pos) {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(());
                }
                // An escape pair never hides a closing quote (\uXXXX hex
                // digits contain neither quotes nor backslashes).
                Some(b'\\') => {
                    if self.pos + 2 > self.bytes.len() {
                        return Err(self.err("unterminated string"));
                    }
                    self.pos += 2;
                }
                _ => self.pos += 1,
            }
        }
    }

    fn skip_lit(&mut self, word: &str) -> Result<(), JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(())
        } else {
            Err(self.err("unexpected character"))
        }
    }
}

/// Lex a number with the exact grammar `Parser::number` uses; returns the
/// end offset.
fn scan_number_end(bytes: &[u8], mut pos: usize) -> usize {
    let at = |p: usize| bytes.get(p).copied();
    if at(pos) == Some(b'-') {
        pos += 1;
    }
    while matches!(at(pos), Some(b'0'..=b'9')) {
        pos += 1;
    }
    if at(pos) == Some(b'.') {
        pos += 1;
        while matches!(at(pos), Some(b'0'..=b'9')) {
            pos += 1;
        }
    }
    if matches!(at(pos), Some(b'e' | b'E')) {
        pos += 1;
        if matches!(at(pos), Some(b'+' | b'-')) {
            pos += 1;
        }
        while matches!(at(pos), Some(b'0'..=b'9')) {
            pos += 1;
        }
    }
    pos
}

/// Exact powers of ten representable in f64 (10^0 ..= 10^22).
const POW10: [f64; 23] = [
    1e0, 1e1, 1e2, 1e3, 1e4, 1e5, 1e6, 1e7, 1e8, 1e9, 1e10, 1e11, 1e12, 1e13,
    1e14, 1e15, 1e16, 1e17, 1e18, 1e19, 1e20, 1e21, 1e22,
];

/// Decimal → f64 over `bytes[start..end]`, bit-identical to `str::parse`.
///
/// Fast path (Clinger): when the mantissa fits below 2^53 and the decimal
/// exponent is within ±22, `m * 10^e` (or `m / 10^-e`) is a single exactly
/// rounded IEEE operation on exact operands — the same correctly rounded
/// result `str::parse` produces, without its digit-by-digit machinery.
/// Everything outside that window falls back to `str::parse`.
fn parse_f64_span(bytes: &[u8], start: usize, end: usize) -> Result<f64, JsonError> {
    let s = &bytes[start..end];
    let fallback = |offset: usize| {
        std::str::from_utf8(s)
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .ok_or(JsonError { msg: "invalid number".to_string(), offset })
    };
    let mut i = 0usize;
    let neg = s.first() == Some(&b'-');
    if neg {
        i += 1;
    }
    let mut mant: u64 = 0;
    let mut sig = 0usize; // significant digits accumulated into mant
    let mut exp10: i64 = 0;
    let mut wide = false; // more significant digits than mant can hold
    while let Some(b @ b'0'..=b'9') = s.get(i).copied() {
        i += 1;
        if sig == 0 && b == b'0' {
            continue; // leading integer zeros carry no information
        }
        if sig < 17 {
            mant = mant * 10 + (b - b'0') as u64;
            sig += 1;
        } else {
            wide = true;
        }
    }
    if s.get(i) == Some(&b'.') {
        i += 1;
        while let Some(b @ b'0'..=b'9') = s.get(i).copied() {
            i += 1;
            if sig == 0 && b == b'0' {
                exp10 -= 1; // zeros before the first significant digit
                continue;
            }
            if sig < 17 {
                mant = mant * 10 + (b - b'0') as u64;
                sig += 1;
                exp10 -= 1;
            } else {
                wide = true;
            }
        }
    }
    if matches!(s.get(i).copied(), Some(b'e' | b'E')) {
        i += 1;
        let eneg = match s.get(i) {
            Some(b'-') => {
                i += 1;
                true
            }
            Some(b'+') => {
                i += 1;
                false
            }
            _ => false,
        };
        let mut e: i64 = 0;
        let mut any = false;
        while let Some(b @ b'0'..=b'9') = s.get(i).copied() {
            i += 1;
            any = true;
            if e < 10_000 {
                e = e * 10 + (b - b'0') as i64;
            }
        }
        if !any {
            return fallback(start);
        }
        exp10 += if eneg { -e } else { e };
    }
    if i != s.len() {
        return fallback(start); // unconsumed input: defer to str::parse
    }
    if mant == 0 && !wide {
        // All-zero digits (or none at all, which str::parse rejects).
        return if sig == 0 && !s.iter().any(|b| b.is_ascii_digit()) {
            fallback(start)
        } else {
            Ok(if neg { -0.0 } else { 0.0 })
        };
    }
    if wide || mant >= (1u64 << 53) || !(-22..=22).contains(&exp10) {
        return fallback(start);
    }
    let v = mant as f64;
    let v = if exp10 >= 0 { v * POW10[exp10 as usize] } else { v / POW10[(-exp10) as usize] };
    Ok(if neg { -v } else { v })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.path(&["a"]).unwrap().as_arr().unwrap()[2].get("b"),
            Some(&Json::Null)
        );
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\ A 😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let j = Json::parse("\"héllo → ∞\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo → ∞");
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"nums":[1,2.5,-3],"s":"x\ny","t":true,"u":null}"#;
        let j = Json::parse(src).unwrap();
        let compact = j.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), j);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn accessors() {
        let j = Json::parse(r#"{"n": 7, "f": 7.5, "b": true}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("f").unwrap().as_usize(), None);
        assert_eq!(j.get("f").unwrap().as_f64(), Some(7.5));
        assert_eq!(j.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("missing"), None);
    }

    #[test]
    fn deterministic_object_order() {
        let j = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        assert_eq!(j.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn lazy_path_extracts_infer_body() {
        let body =
            br#"{"session":"u1","model":"bnn_tiny","input":[0.5,-1,2e-3],"pad":{"x":[1,2]}}"#;
        assert_eq!(path_str(body, &["model"]).unwrap().as_deref(), Some("bnn_tiny"));
        assert_eq!(path_str(body, &["session"]).unwrap().as_deref(), Some("u1"));
        assert_eq!(path_str(body, &["nope"]).unwrap(), None);
        assert_eq!(path_str(body, &["input"]).unwrap(), None); // wrong type
        let mut buf = Vec::new();
        assert!(path_f32_slice(body, &["input"], &mut buf).unwrap());
        assert_eq!(buf, vec![0.5, -1.0, 0.002]);
        assert!(path_f32_slice(body, &["pad", "x"], &mut buf).unwrap());
        assert_eq!(buf, vec![1.0, 2.0]);
        assert!(!path_f32_slice(body, &["model"], &mut buf).unwrap());
        assert_eq!(path_f64(body, &["pad", "x"]).unwrap(), None);
        assert!(path_str(br#"{"model" "x"}"#, &["model"]).is_err());
        assert!(path_str(br#"{"a":[1,}"#, &["b"]).is_err());
        // Mixed array: rejected like the tree accessor chain would.
        assert!(!path_f32_slice(br#"{"a":[1,"x"]}"#, &["a"], &mut buf).unwrap());
        // Empty array extracts as empty.
        assert!(path_f32_slice(br#"{"a":[]}"#, &["a"], &mut buf).unwrap());
        assert!(buf.is_empty());
    }

    #[test]
    fn lazy_path_handles_escaped_keys_and_whitespace() {
        let body = "{\n  \"k\\\"ey\" : { \"v\" : 7.25 },\n  \"z\" : \"s\\n\"\n}".as_bytes();
        assert_eq!(path_f64(body, &["k\"ey", "v"]).unwrap(), Some(7.25));
        assert_eq!(path_str(body, &["z"]).unwrap().as_deref(), Some("s\n"));
        assert_eq!(path_f64(body, &["k\"ey", "w"]).unwrap(), None);
    }

    fn gen_string(g: &mut crate::util::quickcheck::Gen) -> String {
        const PIECES: [&str; 10] =
            ["a", "Z", "0", " ", "\"", "\\", "\n", "\t", "é", "😀"];
        (0..g.usize_in(0, 6)).map(|_| *g.choose(&PIECES)).collect()
    }

    fn gen_num(g: &mut crate::util::quickcheck::Gen) -> f64 {
        match g.usize_in(0, 4) {
            0 => g.usize_in(0, 1_000_000) as f64,
            1 => -(g.usize_in(0, 1_000_000) as f64),
            2 => g.f64_in(-1.0, 1.0),
            3 => g.f64_in(-1e18, 1e18),
            _ => g.f64_in(-1.0, 1.0) * 10f64.powi(g.usize_in(0, 44) as i32 - 22),
        }
    }

    fn gen_json(g: &mut crate::util::quickcheck::Gen, depth: usize) -> Json {
        // Keys come from a small pool so random walks revisit them; the
        // BTreeMap dedups, so serialized documents never hold duplicates.
        const KEYS: [&str; 8] = ["model", "input", "a", "b", "c", "k\"ey", "né", "x"];
        let hi = if depth == 0 { 3 } else { 5 };
        match g.usize_in(0, hi) {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num(gen_num(g)),
            3 => Json::Str(gen_string(g)),
            4 => Json::Arr((0..g.usize_in(0, 4)).map(|_| gen_json(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.usize_in(0, 4))
                    .map(|_| (g.choose(&KEYS).to_string(), gen_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }

    fn gen_path(g: &mut crate::util::quickcheck::Gen, doc: &Json) -> Vec<String> {
        let mut path = Vec::new();
        let mut cur = doc.clone();
        for _ in 0..3 {
            match cur.as_obj() {
                Some(o) if !o.is_empty() => {
                    if g.usize_in(0, 4) == 0 {
                        path.push("missing-key".to_string());
                        return path;
                    }
                    let keys: Vec<&String> = o.keys().collect();
                    let k = keys[g.usize_in(0, keys.len() - 1)].clone();
                    let next = o[&k].clone();
                    path.push(k);
                    cur = next;
                    if g.bool() {
                        return path;
                    }
                }
                _ => {
                    if g.usize_in(0, 2) == 0 {
                        path.push("x".to_string());
                    }
                    return path;
                }
            }
        }
        path
    }

    /// The property the serving hot path relies on: lazy extraction over
    /// raw bytes agrees exactly (bit-for-bit on floats) with building the
    /// tree and walking it, on arbitrary documents and paths.
    #[test]
    fn lazy_scan_agrees_with_tree_parser() {
        use crate::util::quickcheck::{forall, prop_assert, prop_assert_eq, Config};
        forall(Config::default().cases(300), |g| {
            let doc = gen_json(g, 3);
            let text = if g.bool() { doc.to_string() } else { doc.to_string_pretty() };
            let bytes = text.as_bytes();
            let tree = Json::parse(&text).expect("serializer output reparses");
            let path = gen_path(g, &tree);
            let keys: Vec<&str> = path.iter().map(|s| s.as_str()).collect();
            let node = tree.path(&keys);

            prop_assert_eq(
                path_str(bytes, &keys).unwrap(),
                node.and_then(|n| n.as_str().map(String::from)),
            )?;
            prop_assert_eq(
                path_f64(bytes, &keys).unwrap().map(f64::to_bits),
                node.and_then(Json::as_f64).map(f64::to_bits),
            )?;

            let mut buf = Vec::new();
            let got = path_f32_slice(bytes, &keys, &mut buf).unwrap();
            let want: Option<Vec<f32>> = node.and_then(|n| n.as_arr()).and_then(|a| {
                a.iter().map(|v| v.as_f64().map(|f| f as f32)).collect()
            });
            match want {
                Some(w) => {
                    prop_assert(got, "f32 array present but scanner missed it")?;
                    prop_assert_eq(
                        buf.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                        w.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                    )?;
                }
                None => prop_assert(!got, "scanner accepted a non-f32-array leaf")?,
            }
            Ok(())
        });
    }

    /// The Clinger fast path must be invisible: byte-identical to
    /// `str::parse::<f64>` on random decimal literals, including ones that
    /// force the wide-mantissa / large-exponent fallback.
    #[test]
    fn lazy_number_parse_is_bit_identical_to_std() {
        use crate::util::quickcheck::{forall, prop_assert_eq, Config};
        forall(Config::default().cases(500), |g| {
            let mut s = String::new();
            if g.bool() {
                s.push('-');
            }
            for _ in 0..g.usize_in(1, 22) {
                s.push((b'0' + g.usize_in(0, 9) as u8) as char);
            }
            if g.bool() {
                s.push('.');
                for _ in 0..g.usize_in(1, 22) {
                    s.push((b'0' + g.usize_in(0, 9) as u8) as char);
                }
            }
            if g.bool() {
                s.push(*g.choose(&['e', 'E']));
                match g.usize_in(0, 2) {
                    0 => s.push('-'),
                    1 => s.push('+'),
                    _ => {}
                }
                for _ in 0..g.usize_in(1, 3) {
                    s.push((b'0' + g.usize_in(0, 9) as u8) as char);
                }
            }
            let bytes = s.as_bytes();
            prop_assert_eq(scan_number_end(bytes, 0), bytes.len())?;
            let lazy = parse_f64_span(bytes, 0, bytes.len()).unwrap();
            let full: f64 = s.parse().unwrap();
            prop_assert_eq(lazy.to_bits(), full.to_bits())
        });
    }

    #[test]
    fn real_manifest_shape() {
        // Mirrors the structure emitted by python/compile/aot.py.
        let src = r#"{
          "format": "hlo-text",
          "artifacts": {
            "bnn_tiny": {
              "kind": "bnn_forward",
              "file": "bnn_tiny.hlo.txt",
              "args": [{"name": "x", "shape": [1, 8, 8, 3], "dtype": "f32"}],
              "output": {"shape": [1, 10], "dtype": "f32"}
            }
          }
        }"#;
        let j = Json::parse(src).unwrap();
        let a = j.path(&["artifacts", "bnn_tiny"]).unwrap();
        assert_eq!(a.get("file").unwrap().as_str(), Some("bnn_tiny.hlo.txt"));
        let shape = a.get("args").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap();
        let dims: Vec<usize> =
            shape.as_arr().unwrap().iter().map(|d| d.as_usize().unwrap()).collect();
        assert_eq!(dims, vec![1, 8, 8, 3]);
    }
}
