//! Poison-tolerant lock acquisition for request paths.
//!
//! The serving and coordinator request paths ban `unwrap()`/`expect()`
//! (clippy `disallowed_methods`, denied subtree-wide): a panicking
//! worker must degrade one request, not wedge every thread that later
//! touches the same lock. A poisoned `std::sync` lock only means some
//! thread panicked while holding it — the protected data is still
//! there, and every structure these paths guard (metrics counters,
//! registry maps, router tables) is valid after any partial update. So
//! the right recovery is to take the lock anyway via
//! [`PoisonError::into_inner`], which these helpers centralize.

use std::sync::{Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock a [`Mutex`], recovering the guard if a previous holder panicked.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Read-lock an [`RwLock`], recovering the guard from poisoning.
pub fn read_unpoisoned<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// Write-lock an [`RwLock`], recovering the guard from poisoning.
pub fn write_unpoisoned<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex, RwLock};

    #[test]
    fn recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().expect("first take");
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "lock really is poisoned");
        assert_eq!(*lock_unpoisoned(&m), 7, "data survives the poisoning");
    }

    #[test]
    fn recovers_a_poisoned_rwlock() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _guard = l2.write().expect("first take");
            panic!("poison the lock");
        })
        .join();
        assert_eq!(read_unpoisoned(&l).len(), 3);
        write_unpoisoned(&l).push(4);
        assert_eq!(read_unpoisoned(&l).len(), 4);
    }
}
