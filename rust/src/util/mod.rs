//! Substrate utilities built from scratch for the offline environment
//! (see DESIGN.md "System inventory" items 1–8): JSON, CLI parsing, PRNG,
//! property testing, benchmarking, logging, thread pool, and unit
//! conversions.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod quickcheck;
pub mod rng;
pub mod sync;
pub mod threadpool;
pub mod units;
