//! Deterministic PRNG substrate (xoshiro256**).
//!
//! The offline crate set has no `rand`; every stochastic component in the
//! repository (synthetic weights, property-test case generation, workload
//! traces, request arrival jitter) draws from this generator so runs are
//! reproducible from a single seed.
//!
//! Algorithm: xoshiro256** by Blackman & Vigna (public domain), seeded via
//! SplitMix64 as its authors recommend.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small/consecutive seeds decorrelate.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` using Lemire's multiply-shift rejection method
    /// (unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
            // Rejected: retry (probability < n / 2^64).
        }
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fair coin.
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// A {0.0, 1.0} bit — the binarized value set of the paper.
    pub fn bit(&mut self) -> f32 {
        if self.bool() {
            1.0
        } else {
            0.0
        }
    }

    /// Vector of {0,1} bits.
    pub fn bits(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.bit()).collect()
    }

    /// Exponentially distributed value with the given rate (for Poisson
    /// arrival processes in the serving coordinator).
    pub fn exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Standard normal via Box–Muller (used for noise injection tests).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {}", mean);
    }

    #[test]
    fn bits_are_binary_and_balanced() {
        let mut r = Rng::new(11);
        let bits = r.bits(10_000);
        assert!(bits.iter().all(|&b| b == 0.0 || b == 1.0));
        let ones: f32 = bits.iter().sum();
        assert!((ones - 5000.0).abs() < 300.0);
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(5);
        let rate = 4.0;
        let mean: f64 = (0..20_000).map(|_| r.exp(rate)).sum::<f64>() / 20_000.0;
        assert!((mean - 0.25).abs() < 0.02, "mean {}", mean);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.1, "var {}", var);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(19);
        for _ in 0..200 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
        }
        assert_eq!(r.range(9, 9), 9);
    }
}
