//! Thread-pool substrate (no `tokio`/`rayon` offline).
//!
//! A fixed-size worker pool with a simple channel-based queue, plus a
//! `scope`-style `parallel_map` used by the benchmark sweeps (independent
//! accelerator simulations fan out across cores) and the coordinator's
//! worker shards.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (clamped to >= 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                let q = Arc::clone(&queued);
                thread::Builder::new()
                    .name(format!("oxbnn-worker-{}", i))
                    .spawn(move || loop {
                        let job = {
                            let lock = rx.lock().expect("worker queue poisoned");
                            lock.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                q.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("failed to spawn worker")
            })
            .collect();
        ThreadPool { sender: Some(sender), workers, queued }
    }

    /// Pool sized to the machine (with an override for tests/benches).
    pub fn for_host() -> ThreadPool {
        ThreadPool::new(host_threads())
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.sender
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take()); // close channel; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Worker count for this host: the `OXBNN_THREADS` override when set,
/// else the available hardware parallelism. Shared by [`ThreadPool`],
/// the CLI sweep fan-out and the benches so one knob tunes them all.
pub fn host_threads() -> usize {
    std::env::var("OXBNN_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| thread::available_parallelism().map(|n| n.get()).unwrap_or(4))
        .max(1)
}

/// Map `f` over `items` in parallel, preserving order. Spawns scoped
/// threads in chunks so no 'static bound is needed on inputs or outputs.
pub fn parallel_map<T: Send, U: Send, F>(items: Vec<T>, threads: usize, f: F) -> Vec<U>
where
    F: Fn(T) -> U + Sync,
{
    let threads = threads.max(1);
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let mut slots: Vec<Option<U>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let work = Mutex::new(work);
    let slots_mtx = Mutex::new(&mut slots);
    thread::scope(|scope| {
        for _ in 0..threads.min(n) {
            scope.spawn(|| loop {
                let item = { work.lock().unwrap().pop() };
                match item {
                    Some((idx, t)) => {
                        let u = f(t);
                        slots_mtx.lock().unwrap()[idx] = Some(u);
                    }
                    None => break,
                }
            });
        }
    });
    slots.into_iter().map(|s| s.expect("slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn pool_executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drains_on_drop_even_with_slow_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                thread::sleep(Duration::from_millis(5));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(items, 8, |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty_and_single() {
        let out: Vec<usize> = parallel_map(Vec::<usize>::new(), 4, |x| x);
        assert!(out.is_empty());
        assert_eq!(parallel_map(vec![7usize], 4, |x| x + 1), vec![8]);
    }

    #[test]
    fn parallel_map_uses_threads() {
        // With 4 threads, 8 sleeps of 20 ms should take well under 160 ms.
        let t0 = std::time::Instant::now();
        let _ = parallel_map((0..8).collect::<Vec<_>>(), 4, |x| {
            thread::sleep(Duration::from_millis(20));
            x
        });
        assert!(t0.elapsed() < Duration::from_millis(140));
    }

    #[test]
    fn worker_count_clamped() {
        assert_eq!(ThreadPool::new(0).worker_count(), 1);
    }
}
