//! Property-testing substrate (no `proptest` offline).
//!
//! A small, deterministic property harness: generators over a seeded
//! [`Rng`], a configurable case count, and greedy shrinking for integers
//! and vectors. Used by the coordinator/mapping invariant tests
//! (`rust/tests/prop_*.rs`).
//!
//! ```no_run
//! use oxbnn::util::quickcheck::{forall, prop_assert, Config};
//! forall(Config::default().cases(100), |g| {
//!     let n = g.usize_in(1, 64);
//!     let s = g.usize_in(1, 4096);
//!     let slices = (s + n - 1) / n;
//!     prop_assert(slices * n >= s, "slices must cover the vector")
//! });
//! ```

use crate::util::rng::Rng;

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Assert helper returning a `PropResult`.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Assert equality with a formatted failure message.
pub fn prop_assert_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T) -> PropResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{:?} != {:?}", a, b))
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 100, seed: 0xD0E5EED, max_shrink: 200 }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Draw source handed to each property case. Records every drawn integer so
/// failing cases can be replayed and shrunk.
pub struct Gen {
    rng: Rng,
    /// Choice trace: (lo, hi, picked) for each `usize_in` draw.
    trace: Vec<(usize, usize, usize)>,
    /// When replaying a shrunk trace, draws come from here instead.
    replay: Option<Vec<usize>>,
    cursor: usize,
}

impl Gen {
    fn fresh(seed: u64) -> Gen {
        Gen { rng: Rng::new(seed), trace: Vec::new(), replay: None, cursor: 0 }
    }

    fn replaying(seed: u64, picks: Vec<usize>) -> Gen {
        Gen { rng: Rng::new(seed), trace: Vec::new(), replay: Some(picks), cursor: 0 }
    }

    /// Uniform integer in `[lo, hi]` — the primitive all other generators
    /// build on (and the unit of shrinking).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = match &self.replay {
            Some(picks) => {
                let raw = picks.get(self.cursor).copied().unwrap_or(lo);
                raw.clamp(lo, hi)
            }
            None => self.rng.range(lo, hi),
        };
        self.cursor += 1;
        self.trace.push((lo, hi, v));
        v
    }

    pub fn bool(&mut self) -> bool {
        self.usize_in(0, 1) == 1
    }

    pub fn f64_unit(&mut self) -> f64 {
        // 2^20 buckets are plenty for property discovery and keep draws
        // shrinkable through the integer trace.
        self.usize_in(0, (1 << 20) - 1) as f64 / (1u64 << 20) as f64
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64_unit() * (hi - lo)
    }

    /// A vector of `len` values in `[lo, hi]`.
    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.usize_in(lo, hi)).collect()
    }

    /// A {0,1} bit-vector of length `len`.
    pub fn bits(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.usize_in(0, 1) as f32).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }
}

/// Run `prop` over `cfg.cases` random cases; on failure, shrink the choice
/// trace greedily toward the lower bounds and panic with the minimal
/// counterexample found.
pub fn forall<F: FnMut(&mut Gen) -> PropResult>(cfg: Config, mut prop: F) {
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64);
        let mut g = Gen::fresh(seed);
        if let Err(msg) = prop(&mut g) {
            let trace = g.trace.clone();
            let (min_picks, min_msg) = shrink(&cfg, &mut prop, seed, trace, msg);
            panic!(
                "property failed (case {}, seed {:#x}): {}\n  minimal picks: {:?}",
                case, seed, min_msg, min_picks
            );
        }
    }
}

fn shrink<F: FnMut(&mut Gen) -> PropResult>(
    cfg: &Config,
    prop: &mut F,
    seed: u64,
    trace: Vec<(usize, usize, usize)>,
    first_msg: String,
) -> (Vec<usize>, String) {
    let mut picks: Vec<usize> = trace.iter().map(|t| t.2).collect();
    let lows: Vec<usize> = trace.iter().map(|t| t.0).collect();
    let mut msg = first_msg;
    let mut budget = cfg.max_shrink;
    let mut improved = true;
    while improved && budget > 0 {
        improved = false;
        for i in 0..picks.len() {
            if budget == 0 {
                break;
            }
            let lo = *lows.get(i).unwrap_or(&0);
            // Try: set to lo, then halve the distance to lo.
            let candidates = [lo, lo + (picks[i].saturating_sub(lo)) / 2];
            for &cand in &candidates {
                if cand >= picks[i] || budget == 0 {
                    continue;
                }
                budget -= 1;
                let mut attempt = picks.clone();
                attempt[i] = cand;
                let mut g = Gen::replaying(seed, attempt.clone());
                if let Err(m) = prop(&mut g) {
                    picks = attempt;
                    msg = m;
                    improved = true;
                    break;
                }
            }
        }
    }
    (picks, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(Config::default().cases(50), |g| {
            count += 1;
            let a = g.usize_in(0, 100);
            let b = g.usize_in(0, 100);
            prop_assert(a + b >= a, "monotone add")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(Config::default().cases(200), |g| {
            let v = g.usize_in(0, 1000);
            prop_assert(v < 900, "v too big")
        });
    }

    #[test]
    fn shrinking_minimizes() {
        // Capture the panic to inspect the shrunk counterexample.
        let result = std::panic::catch_unwind(|| {
            forall(Config::default().cases(100), |g| {
                let v = g.usize_in(0, 10_000);
                prop_assert(v < 500, "ge 500")
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        // Greedy halving should land well below the initial random failure.
        let picks_part = msg.split("minimal picks: ").nth(1).unwrap();
        let v: usize = picks_part
            .trim_matches(|c| c == '[' || c == ']')
            .trim()
            .parse()
            .unwrap();
        assert!(v >= 500, "still failing case");
        assert!(v < 1100, "should have shrunk near the 500 boundary, got {}", v);
    }

    #[test]
    fn gen_ranges_respected() {
        forall(Config::default().cases(100), |g| {
            let v = g.usize_in(5, 9);
            prop_assert(v >= 5 && v <= 9, "range")?;
            let f = g.f64_in(-1.0, 1.0);
            prop_assert((-1.0..=1.0).contains(&f), "f64 range")?;
            let bits = g.bits(8);
            prop_assert(bits.iter().all(|&b| b == 0.0 || b == 1.0), "bits binary")
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut vals = Vec::new();
            forall(Config::default().cases(10).seed(seed), |g| {
                vals.push(g.usize_in(0, 1_000_000));
                Ok(())
            });
            vals
        };
        assert_eq!(run(1), run(1));
        assert_ne!(run(1), run(2));
    }
}
