//! Command-line argument parsing substrate (no `clap` offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options,
//! positional arguments, defaults, and auto-generated `--help` text — the
//! subset the `oxbnn` binary and examples need.

use std::collections::BTreeMap;
use std::fmt;

/// Declarative option spec.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Declarative command spec: options + positionals.
#[derive(Debug, Clone, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positionals: Vec<(&'static str, &'static str)>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new(), positionals: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn pos(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{}>", p));
        }
        s.push_str(" [OPTIONS]\n");
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{}>  {}\n", p, h));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let mut line = format!("  --{}", o.name);
                if !o.is_flag {
                    line.push_str(" <value>");
                }
                if let Some(d) = o.default {
                    line.push_str(&format!(" [default: {}]", d));
                }
                s.push_str(&format!("{}\n      {}\n", line, o.help));
            }
        }
        s
    }

    /// Parse `args` (without argv[0]).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positionals: Vec<String> = Vec::new();
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(CliError::Help(self.usage()));
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(CliError::Other(format!("flag --{} takes no value", name)));
                    }
                    flags.push(name);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(name.clone()))?,
                    };
                    values.insert(name, v);
                }
            } else {
                positionals.push(arg.clone());
            }
        }
        if positionals.len() > self.positionals.len() {
            return Err(CliError::Other(format!(
                "unexpected positional argument '{}'",
                positionals[self.positionals.len()]
            )));
        }
        // Fill defaults; error on missing required opts.
        for o in &self.opts {
            if o.is_flag || values.contains_key(o.name) {
                continue;
            }
            match o.default {
                Some(d) => {
                    values.insert(o.name.to_string(), d.to_string());
                }
                None => return Err(CliError::MissingValue(o.name.to_string())),
            }
        }
        Ok(Parsed { values, flags, positionals })
    }
}

/// Parsed arguments with typed accessors.
#[derive(Debug, Clone)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{} not declared", name))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError::Other(format!("--{} expects an integer", name)))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError::Other(format!("--{} expects a number", name)))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(|s| s.as_str())
    }
}

/// CLI parse errors (Help is the `--help` early exit, not a failure).
#[derive(Debug, Clone)]
pub enum CliError {
    Help(String),
    Unknown(String),
    MissingValue(String),
    Other(String),
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CliError::Help(u) => write!(f, "{}", u),
            CliError::Unknown(n) => write!(f, "unknown option --{}", n),
            CliError::MissingValue(n) => write!(f, "missing value for --{}", n),
            CliError::Other(m) => write!(f, "{}", m),
        }
    }
}

impl std::error::Error for CliError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn strs(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("sim", "run simulation")
            .opt("model", "tiny", "model name")
            .opt("passes", "10", "number of passes")
            .req("out", "output path")
            .flag("verbose", "chatty output")
            .pos("workload", "workload file")
    }

    #[test]
    fn defaults_and_required() {
        let p = cmd().parse(&strs(&["--out", "x.json"])).unwrap();
        assert_eq!(p.get("model"), "tiny");
        assert_eq!(p.get_usize("passes").unwrap(), 10);
        assert_eq!(p.get("out"), "x.json");
        assert!(!p.has_flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let p = cmd()
            .parse(&strs(&["--out=o", "--model=vgg", "--verbose", "wl.json"]))
            .unwrap();
        assert_eq!(p.get("model"), "vgg");
        assert!(p.has_flag("verbose"));
        assert_eq!(p.positional(0), Some("wl.json"));
    }

    #[test]
    fn missing_required_errors() {
        match cmd().parse(&strs(&[])) {
            Err(CliError::MissingValue(n)) => assert_eq!(n, "out"),
            other => panic!("unexpected {:?}", other),
        }
    }

    #[test]
    fn unknown_option_errors() {
        assert!(matches!(
            cmd().parse(&strs(&["--out", "o", "--bogus", "1"])),
            Err(CliError::Unknown(_))
        ));
    }

    #[test]
    fn help_short_circuits() {
        assert!(matches!(
            cmd().parse(&strs(&["--help"])),
            Err(CliError::Help(_))
        ));
        let usage = cmd().usage();
        assert!(usage.contains("--passes"));
        assert!(usage.contains("<workload>"));
    }

    #[test]
    fn numeric_parse_errors() {
        let p = cmd().parse(&strs(&["--out", "o", "--passes", "abc"])).unwrap();
        assert!(p.get_usize("passes").is_err());
    }

    #[test]
    fn too_many_positionals() {
        assert!(cmd().parse(&strs(&["--out", "o", "a", "b"])).is_err());
    }
}
