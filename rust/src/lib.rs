//! # OXBNN — Optical XNOR-Bitcount BNN Accelerator (full-system reproduction)
//!
//! Rust implementation of the system described in *"An Optical
//! XNOR-Bitcount Based Accelerator for Efficient Inference of Binary Neural
//! Networks"* (Sri Vatsavai, Karempudi, Thakkar — IEEE ISQED 2023).
//!
//! ## Library API
//!
//! The front door is [`api`]: a [`api::Session`] runs one accelerator ×
//! workload pair through any execution model ([`api::Backend`]) and returns
//! one unified [`api::Report`] — FPS, FPS/W, energy breakdown, transaction
//! counts, and (for the functional backend) a correctness block:
//!
//! ```no_run
//! use oxbnn::api::{BackendKind, Session};
//!
//! // Analytic sweep numbers, event-driven dynamics, or functional
//! // correctness — same builder, same report shape.
//! for kind in BackendKind::all() {
//!     let report = Session::builder()
//!         .accelerator_named("OXBNN_50")
//!         .workload_named("vgg_small")
//!         .backend(kind)
//!         .build()
//!         .unwrap()
//!         .run();
//!     println!("[{}] {:.0} FPS, {:.2} FPS/W, {} passes, {} psums",
//!         report.backend, report.fps, report.fps_per_w,
//!         report.passes, report.psums);
//! }
//! ```
//!
//! Custom accelerators come from [`config`] (JSON), custom execution models
//! plug in via [`api::SessionBuilder::backend_impl`]. The `oxbnn` CLI
//! (`simulate`, `fps`, `sweep` — each with `--backend`), the serving
//! coordinator and the Fig. 7 benches are all thin layers over this facade.
//!
//! ## Layers (see DESIGN.md)
//!
//! * [`util`] — offline substrates (JSON, CLI, PRNG, bench, quickcheck, ...)
//! * [`runtime`] — execution engine for AOT-lowered JAX/Pallas artifacts:
//!   PJRT (feature `xla-runtime`) or the offline functional sim engine,
//!   with true batched dispatch via [`runtime::BatchRunner`]
//! * `devices` — photonic device models (OXG MRR, PCA, photodetector, laser)
//! * `analysis` — scalability solver (paper Eqs. 3–5 → Table II)
//! * `sim` — event-driven transaction-level simulation engine
//! * `arch` — XPE / XPC / tile / accelerator architecture model
//! * `mapping` — convolution flattening, slicing, scheduling (paper Fig. 5)
//! * [`plan`] — compiled execution plans: compile → cache → stream (the
//!   event backend's O(#XPEs)-memory schedule representation)
//! * [`check`] — static checking: plan lint (admission/conservation/PCA
//!   capacity findings) + deterministic-interleaving model checker
//! * `baselines` — ROBIN and LIGHTBULB accelerator models
//! * `workloads` — the four evaluated BNNs (layer geometry)
//! * `energy` — power/energy accounting (paper Table III)
//! * `functional` — integer reference BNN engine for cross-validation
//! * `coordinator` — inference serving: router, batched back-pressured
//!   worker loop, admission control, metrics
//! * [`serving`] — HTTP front-end: multi-model registry with hot reload,
//!   shard router with retry budgets, health probes, metrics exposition
//! * [`api`] — the `Session`/`Backend` facade unifying the execution models

pub mod analysis;
pub mod api;
pub mod arch;
pub mod baselines;
pub mod check;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod functional;
pub mod mapping;
pub mod plan;
pub mod serving;
pub mod sim;
pub mod workloads;
pub mod devices;
pub mod runtime;
pub mod util;
