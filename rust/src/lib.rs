//! # OXBNN — Optical XNOR-Bitcount BNN Accelerator (full-system reproduction)
//!
//! Rust implementation of the system described in *"An Optical
//! XNOR-Bitcount Based Accelerator for Efficient Inference of Binary Neural
//! Networks"* (Sri Vatsavai, Karempudi, Thakkar — IEEE ISQED 2023).
//!
//! Layers (see DESIGN.md):
//! * [`util`] — offline substrates (JSON, CLI, PRNG, bench, quickcheck, ...)
//! * [`runtime`] — PJRT client executing AOT-lowered JAX/Pallas artifacts
//! * `devices` — photonic device models (OXG MRR, PCA, photodetector, laser)
//! * `analysis` — scalability solver (paper Eqs. 3–5 → Table II)
//! * `sim` — event-driven transaction-level simulation engine
//! * `arch` — XPE / XPC / tile / accelerator architecture model
//! * `mapping` — convolution flattening, slicing, scheduling (paper Fig. 5)
//! * `baselines` — ROBIN and LIGHTBULB accelerator models
//! * `workloads` — the four evaluated BNNs (layer geometry)
//! * `energy` — power/energy accounting (paper Table III)
//! * `functional` — integer reference BNN engine for cross-validation
//! * `coordinator` — inference serving: router, batcher, scheduler

pub mod analysis;
pub mod arch;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod functional;
pub mod mapping;
pub mod sim;
pub mod workloads;
pub mod devices;
pub mod runtime;
pub mod util;
