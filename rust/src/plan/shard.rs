//! Multi-accelerator scale-out plans: one model sharded across K chips.
//!
//! The paper evaluates OXBNN at single-chip N (Table II); production
//! serving asks the question the paper doesn't — how many chips serve a
//! given traffic level. A [`ShardPlan`] splits one compiled model across
//! `K` identical accelerators in one of two ways:
//!
//! * [`ShardPolicy::LayerPipeline`] — contiguous layer ranges per chip
//!   (pipeline parallelism). Chip boundaries are chosen by a contiguous
//!   partition DP that minimizes the bottleneck stage (per-layer cost =
//!   the critical-path pass count `max_queue_len`). Activations crossing
//!   a stage boundary traverse the inter-chip link.
//! * [`ShardPolicy::VdpSplit`] — every layer's VDPs/slices spread over
//!   all K chips (tensor parallelism): the pass maps are recompiled onto
//!   a `K × T` XPE grid, which the modular index maps spread evenly, so
//!   each chip owns the contiguous flat-slot block `[c·T, (c+1)·T)`.
//!   Every produced activation must be visible on the other chips, so
//!   every cross-layer edge traverses the link.
//!
//! The inter-chip link is modeled as one more shared serialized channel
//! (like the eDRAM fetch channel): per-activation flits are
//! bandwidth-charged back-to-back and arrive one hop latency later. The
//! receptive-field-exact `need_acts` thresholds of
//! [`super::FramePlan`] are reused verbatim for cross-chip admission —
//! a consumer chip admits a pass exactly when the producer's raster
//! prefix has *arrived* over the link, not merely drained on the
//! producer chip.
//!
//! A `K = 1` shard plan compiles to the identical [`ExecutionPlan`] and
//! drives the identical event world — the differential suite
//! (`rust/tests/scaleout.rs`) pins event-identity per zoo model.

use crate::arch::accelerator::AcceleratorConfig;
use crate::mapping::scheduler::MappingPolicy;
use crate::workloads::Workload;

use super::ExecutionPlan;

/// How a model is split across the K chips of a [`ShardPlan`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Contiguous layer ranges per chip (pipeline parallelism).
    LayerPipeline,
    /// Every layer's VDPs spread over all chips (tensor parallelism).
    VdpSplit,
}

impl ShardPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShardPolicy::LayerPipeline => "layer",
            ShardPolicy::VdpSplit => "vdp",
        }
    }

    pub fn all() -> [ShardPolicy; 2] {
        [ShardPolicy::LayerPipeline, ShardPolicy::VdpSplit]
    }
}

impl std::str::FromStr for ShardPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<ShardPolicy, String> {
        match s {
            "layer" | "pipeline" | "layer-pipeline" => Ok(ShardPolicy::LayerPipeline),
            "vdp" | "split" | "vdp-split" => Ok(ShardPolicy::VdpSplit),
            other => Err(format!("unknown shard policy '{}' (use layer|vdp)", other)),
        }
    }
}

/// The shared inter-chip activation link: a serialized channel with a
/// per-hop latency and a flit budget per activation. Derived
/// deterministically from the accelerator config so every (config, K)
/// pair models the same fabric.
#[derive(Debug, Clone, PartialEq)]
pub struct ChipLink {
    /// One-hop transfer latency (charged once per activation, pipelined
    /// with the bandwidth term).
    pub latency_s: f64,
    /// Serialized link bandwidth shared by all chip pairs.
    pub bits_per_s: f64,
    /// Flit size per transferred activation (1 binary value + routing
    /// header).
    pub bits_per_act: u64,
}

impl ChipLink {
    /// The link a K-chip group of `cfg` instances would share: one
    /// router + bus hop of latency, SerDes bandwidth at 1/8 of the
    /// on-chip eDRAM aggregate.
    pub fn for_config(cfg: &AcceleratorConfig) -> ChipLink {
        ChipLink {
            latency_s: cfg.peripherals.router.latency_s + cfg.peripherals.bus.latency_s,
            bits_per_s: cfg.mem_bw_bits_per_s / 8.0,
            bits_per_act: 32,
        }
    }

    /// Serialized channel occupancy of one activation flit.
    pub fn occupancy_s(&self) -> f64 {
        self.bits_per_act as f64 / self.bits_per_s
    }
}

/// One model compiled across a group of `chips` identical accelerators.
///
/// For [`ShardPolicy::LayerPipeline`] the inner [`ExecutionPlan`] is the
/// ordinary single-chip compile and [`ShardPlan::chip_of_layer`] maps
/// each layer to its stage chip. For [`ShardPolicy::VdpSplit`] the inner
/// plan is recompiled onto a grid of `chips × T` XPE slots (`T` = the
/// single-chip slot count `m · xpc_count`) and `chip_of_layer` is empty
/// — every layer runs on every chip.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    chips: usize,
    policy: ShardPolicy,
    /// The per-chip accelerator (timing/energy/peripherals come from
    /// here; the grid of `plan` may span `chips ×` its slots).
    pub base: AcceleratorConfig,
    /// The compiled pass maps the shard group executes.
    pub plan: ExecutionPlan,
    /// Stage chip per layer (LayerPipeline; empty under VdpSplit).
    pub chip_of_layer: Vec<usize>,
    /// The shared inter-chip activation channel.
    pub link: ChipLink,
}

impl ShardPlan {
    /// Compile `workload` onto a group of `chips` copies of `cfg` under
    /// mapping `policy`, sharded by `shard`. `chips = 1` compiles the
    /// identical single-chip [`ExecutionPlan`] (event-identity is pinned
    /// by the differential suite).
    pub fn compile(
        cfg: &AcceleratorConfig,
        workload: &Workload,
        policy: MappingPolicy,
        chips: usize,
        shard: ShardPolicy,
    ) -> ShardPlan {
        assert!(chips > 0, "a shard plan needs at least one chip");
        let link = ChipLink::for_config(cfg);
        match shard {
            ShardPolicy::LayerPipeline => {
                let plan = ExecutionPlan::compile(cfg, workload, policy);
                let costs: Vec<f64> =
                    plan.layers.iter().map(|l| l.max_queue_len() as f64).collect();
                let chip_of_layer = balance_contiguous(&costs, chips);
                ShardPlan { chips, policy: shard, base: cfg.clone(), plan, chip_of_layer, link }
            }
            ShardPolicy::VdpSplit => {
                let plan = if chips == 1 {
                    ExecutionPlan::compile(cfg, workload, policy)
                } else {
                    // Scale the slot grid, not `xpe_total`'s ceil: K · T
                    // slots where T = m · xpc_count, so each chip owns an
                    // identically-shaped contiguous block (the last XPC
                    // of each chip may be partially populated, exactly as
                    // on a single chip).
                    let mut scaled = cfg.clone();
                    scaled.xpe_total = cfg.xpc_count() * cfg.m() * chips;
                    ExecutionPlan::compile(&scaled, workload, policy)
                };
                ShardPlan {
                    chips,
                    policy: shard,
                    base: cfg.clone(),
                    plan,
                    chip_of_layer: Vec::new(),
                    link,
                }
            }
        }
    }

    pub fn chips(&self) -> usize {
        self.chips
    }

    pub fn policy(&self) -> ShardPolicy {
        self.policy
    }

    /// XPE slots per member chip (`T = m · xpc_count` of the base
    /// accelerator — identical for both shard policies).
    pub fn per_chip_xpes(&self) -> usize {
        self.base.xpc_count() * self.base.m()
    }

    /// True when every layer runs on every chip (tensor parallelism).
    pub fn vdp_split(&self) -> bool {
        self.policy == ShardPolicy::VdpSplit
    }

    /// Does the edge feeding `layer` cross chips (and therefore the
    /// inter-chip link)? Layer 0 has no input edge.
    pub fn edge_crosses(&self, layer: usize) -> bool {
        if layer == 0 || self.chips == 1 {
            return false;
        }
        match self.policy {
            ShardPolicy::VdpSplit => true,
            ShardPolicy::LayerPipeline => {
                self.chip_of_layer[layer - 1] != self.chip_of_layer[layer]
            }
        }
    }

    /// Activation flits crossing the link per frame.
    pub fn transfers_per_frame(&self) -> usize {
        (0..self.plan.layers.len())
            .filter(|&l| self.edge_crosses(l))
            .map(|l| self.plan.layers[l - 1].vdp_count())
            .sum()
    }

    /// Analytic per-layer service time: critical-path compute vs the
    /// per-chip operand fetch (VdpSplit fetches each chip's share in
    /// parallel).
    pub fn layer_time_s(&self, layer: usize) -> f64 {
        let lp = &self.plan.layers[layer];
        let compute = lp.max_queue_len() as f64 * self.base.tau_s();
        let split = if self.vdp_split() { self.chips } else { 1 };
        let memory =
            lp.layer.operand_bits() as f64 / (self.base.mem_bw_bits_per_s * split as f64);
        compute.max(memory)
    }

    /// Serialized link time of the edge feeding `layer` (0 when the edge
    /// stays on-chip).
    pub fn transfer_time_s(&self, layer: usize) -> f64 {
        if !self.edge_crosses(layer) {
            return 0.0;
        }
        let produced = self.plan.layers[layer - 1].vdp_count() as f64;
        produced * self.link.occupancy_s() + self.link.latency_s
    }

    /// Analytic per-chip stage time (LayerPipeline: the sum of the
    /// chip's layers plus its incoming transfers; VdpSplit: every chip
    /// sees the whole frame, so the stage is the frame itself).
    pub fn stage_times_s(&self) -> Vec<f64> {
        let frame: f64 = (0..self.plan.layers.len())
            .map(|l| self.layer_time_s(l) + self.transfer_time_s(l))
            .sum();
        match self.policy {
            ShardPolicy::VdpSplit => vec![frame; self.chips],
            ShardPolicy::LayerPipeline => {
                let mut stages = vec![0.0; self.chips];
                for (l, &chip) in self.chip_of_layer.iter().enumerate() {
                    stages[chip] += self.layer_time_s(l) + self.transfer_time_s(l);
                }
                stages
            }
        }
    }

    /// Closed-form batched-FPS estimate the conformance suite pins the
    /// event simulation against: fill one frame, then stream at the
    /// bottleneck stage (which is never faster than the shared link can
    /// carry all cross-chip activations of a frame).
    pub fn analytic_batched_fps(&self, batch: usize) -> f64 {
        assert!(batch > 0);
        let layers = self.plan.layers.len();
        let frame: f64 =
            (0..layers).map(|l| self.layer_time_s(l) + self.transfer_time_s(l)).sum();
        let link_serial: f64 = self.transfers_per_frame() as f64 * self.link.occupancy_s();
        let per_layer_bottleneck = (0..layers)
            .map(|l| self.layer_time_s(l) + self.transfer_time_s(l))
            .fold(0.0f64, f64::max);
        let stage_bottleneck =
            self.stage_times_s().into_iter().fold(0.0f64, f64::max);
        let bottleneck = match self.policy {
            ShardPolicy::VdpSplit => per_layer_bottleneck,
            ShardPolicy::LayerPipeline => stage_bottleneck,
        }
        .max(link_serial);
        batch as f64 / (frame + (batch - 1) as f64 * bottleneck)
    }
}

/// Partition `costs` into (at most) `chips` contiguous groups minimizing
/// the bottleneck group sum — classic linear-partition DP, O(K·L²).
/// Returns the group id per element, non-decreasing from 0; when there
/// are fewer elements than chips the tail chips stay empty.
fn balance_contiguous(costs: &[f64], chips: usize) -> Vec<usize> {
    let l = costs.len();
    if l == 0 {
        return Vec::new();
    }
    let k = chips.min(l).max(1);
    let mut prefix = vec![0.0; l + 1];
    for (i, &c) in costs.iter().enumerate() {
        prefix[i + 1] = prefix[i] + c;
    }
    let seg = |a: usize, b: usize| prefix[b] - prefix[a]; // [a, b)
    // dp[j][i]: min bottleneck splitting the first i elements into j parts.
    let inf = f64::INFINITY;
    let mut dp = vec![vec![inf; l + 1]; k + 1];
    let mut cut = vec![vec![0usize; l + 1]; k + 1];
    dp[0][0] = 0.0;
    for j in 1..=k {
        for i in j..=l {
            for s in (j - 1)..i {
                let cand = dp[j - 1][s].max(seg(s, i));
                if cand < dp[j][i] {
                    dp[j][i] = cand;
                    cut[j][i] = s;
                }
            }
        }
    }
    let mut bounds = vec![l; k + 1];
    for j in (1..=k).rev() {
        bounds[j - 1] = cut[j][bounds[j]];
    }
    let mut out = vec![0usize; l];
    for j in 0..k {
        for slot in out.iter_mut().take(bounds[j + 1]).skip(bounds[j]) {
            *slot = j;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::layer::GemmLayer;

    fn wl() -> Workload {
        Workload::new(
            "shard-t",
            vec![
                GemmLayer::new("a", 16, 120, 8),
                GemmLayer::new("b", 16, 90, 8),
                GemmLayer::new("c", 8, 60, 4),
                GemmLayer::fc("fc", 64, 10),
            ],
        )
    }

    #[test]
    fn k1_compiles_the_single_chip_plan() {
        let cfg = AcceleratorConfig::oxbnn_5();
        for shard in ShardPolicy::all() {
            let sp = ShardPlan::compile(&cfg, &wl(), MappingPolicy::PcaLocal, 1, shard);
            let single = ExecutionPlan::compile(&cfg, &wl(), MappingPolicy::PcaLocal);
            assert_eq!(sp.plan.layers.len(), single.layers.len());
            for (a, b) in sp.plan.layers.iter().zip(&single.layers) {
                assert_eq!(a.total_xpes(), b.total_xpes());
                assert_eq!(a.total_passes(), b.total_passes());
                assert_eq!(a.max_queue_len(), b.max_queue_len());
            }
            assert!(!sp.edge_crosses(1), "K=1 has no cross-chip edges");
            assert_eq!(sp.transfers_per_frame(), 0);
        }
    }

    #[test]
    fn vdp_split_scales_the_grid_and_shrinks_queues() {
        let cfg = AcceleratorConfig::oxbnn_50();
        let single =
            ShardPlan::compile(&cfg, &wl(), MappingPolicy::PcaLocal, 1, ShardPolicy::VdpSplit);
        for k in [2usize, 3, 4] {
            let sp =
                ShardPlan::compile(&cfg, &wl(), MappingPolicy::PcaLocal, k, ShardPolicy::VdpSplit);
            assert_eq!(sp.per_chip_xpes(), cfg.xpc_count() * cfg.m());
            for (lp, lp1) in sp.plan.layers.iter().zip(&single.plan.layers) {
                assert_eq!(lp.total_xpes(), k * sp.per_chip_xpes());
                assert_eq!(lp.total_passes(), lp1.total_passes(), "multiset size conserved");
                assert!(lp.max_queue_len() <= lp1.max_queue_len());
            }
            assert!(sp.edge_crosses(1), "every edge crosses under VdpSplit");
            assert!(sp.analytic_batched_fps(8) >= single.analytic_batched_fps(8));
        }
    }

    #[test]
    fn layer_pipeline_partition_is_contiguous_and_covering() {
        let cfg = AcceleratorConfig::oxbnn_5();
        for k in [1usize, 2, 3, 4, 8] {
            let sp = ShardPlan::compile(
                &cfg,
                &wl(),
                MappingPolicy::PcaLocal,
                k,
                ShardPolicy::LayerPipeline,
            );
            assert_eq!(sp.chip_of_layer.len(), sp.plan.layers.len());
            let mut prev = 0usize;
            for &c in &sp.chip_of_layer {
                assert!(c < k, "chip id in range");
                assert!(c == prev || c == prev + 1, "contiguous non-decreasing stages");
                prev = c;
            }
            assert_eq!(sp.chip_of_layer[0], 0, "stage 0 starts the pipeline");
            // Stage times cover the frame.
            let stages = sp.stage_times_s();
            assert_eq!(stages.len(), k);
            assert!(stages.iter().all(|s| *s >= 0.0));
        }
    }

    #[test]
    fn balance_dp_minimizes_the_bottleneck() {
        // Costs 8,1,1,8 into 2 chips: the optimal contiguous cut is
        // [8,1] | [1,8] (bottleneck 9), not [8] | [1,1,8] (10).
        let out = balance_contiguous(&[8.0, 1.0, 1.0, 8.0], 2);
        assert_eq!(out, vec![0, 0, 1, 1]);
        // More chips than layers: one layer per chip, tail chips empty.
        let out = balance_contiguous(&[3.0, 2.0], 4);
        assert_eq!(out, vec![0, 1]);
    }

    #[test]
    fn link_is_deterministic_from_config() {
        let cfg = AcceleratorConfig::oxbnn_50();
        let link = ChipLink::for_config(&cfg);
        assert!(link.latency_s > 0.0);
        assert!(link.bits_per_s > 0.0);
        assert_eq!(link.bits_per_act, 32);
        assert_eq!(link, ChipLink::for_config(&cfg));
    }
}
