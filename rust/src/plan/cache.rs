//! Memoized plan compilation: one [`ExecutionPlan`] per distinct
//! `(accelerator, workload, policy)` triple, shared across sessions,
//! sweep cells and serving replicas via `Arc`.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::ExecutionPlan;
use crate::arch::accelerator::{AcceleratorConfig, BitcountMode};
use crate::mapping::scheduler::MappingPolicy;
use crate::workloads::Workload;

/// Thread-safe compile-once cache of [`ExecutionPlan`]s with LRU
/// eviction.
///
/// The key covers every field that shapes the plan or its timing:
/// accelerator identity (name, DR, N, XPE count, bitcount mode, memory
/// bandwidth), the workload's full layer geometry, and the mapping
/// policy. Compilation is cheap (no materialization), so on a rare
/// concurrent miss two threads may compile the same plan; the first
/// insert wins and both get the same `Arc` afterwards.
///
/// Eviction is least-recently-used: at capacity, the single entry with
/// the stalest access tick is dropped — a hot serving model's plan
/// survives any amount of cold-key churn (sweeps rotating hundreds of
/// throwaway geometries through a shared cache), where the previous
/// flush-everything policy evicted the hot plan along with the cold ones.
pub struct PlanCache {
    inner: Mutex<HashMap<String, CacheEntry>>,
    capacity: usize,
    /// Monotone access clock for LRU ordering (ticks on hit and insert).
    clock: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

struct CacheEntry {
    plan: Arc<ExecutionPlan>,
    last_used: u64,
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::with_capacity(256)
    }
}

impl PlanCache {
    /// A cache holding at most `capacity` plans, evicting the
    /// least-recently-used entry when full.
    pub fn with_capacity(capacity: usize) -> PlanCache {
        PlanCache {
            inner: Mutex::new(HashMap::new()),
            capacity: capacity.max(1),
            clock: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Fetch the plan for this triple, compiling it on first use.
    pub fn get_or_compile(
        &self,
        cfg: &AcceleratorConfig,
        workload: &Workload,
        policy: MappingPolicy,
    ) -> Arc<ExecutionPlan> {
        let key = fingerprint(cfg, workload, policy);
        if let Some(entry) = self.inner.lock().unwrap().get_mut(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            entry.last_used = self.tick();
            return Arc::clone(&entry.plan);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        // Compile outside the lock: parallel sweep cells must not
        // serialize on each other's compilations.
        let plan = Arc::new(ExecutionPlan::compile(cfg, workload, policy));
        let mut map = self.inner.lock().unwrap();
        // Evict the least-recently-used entry (O(n) scan — capacity is
        // small and eviction only runs on a miss at capacity). Re-check
        // presence first: a concurrent miss may have inserted this key.
        if !map.contains_key(&key) && map.len() >= self.capacity {
            if let Some(stalest) = map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            {
                map.remove(&stalest);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        let last_used = self.tick();
        let entry = map
            .entry(key)
            .or_insert(CacheEntry { plan, last_used });
        entry.last_used = last_used;
        Arc::clone(&entry.plan)
    }

    /// Plans currently cached.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cache hits since construction.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses (= compilations attempted) since construction.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// LRU evictions since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// True if the plan for this triple is currently resident (test/ops
    /// introspection; does not count as an access).
    pub fn contains(
        &self,
        cfg: &AcceleratorConfig,
        workload: &Workload,
        policy: MappingPolicy,
    ) -> bool {
        self.inner
            .lock()
            .unwrap()
            .contains_key(&fingerprint(cfg, workload, policy))
    }
}

impl fmt::Debug for PlanCache {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PlanCache")
            .field("entries", &self.len())
            .field("capacity", &self.capacity)
            .field("hits", &self.hits())
            .field("misses", &self.misses())
            .field("evictions", &self.evictions())
            .finish()
    }
}

/// Stable identity string for a `(accelerator, workload, policy)` triple.
///
/// Must cover EVERY field the cached plan's embedded accelerator/workload
/// can influence downstream: the mapping geometry (N, XPE count), the
/// timing scalars (DR, bitcount, memory bandwidth), and — because the
/// event backend simulates with `plan.accelerator` — the energy model,
/// peripherals and loss budget too (two configs differing only in, say,
/// `activation_unit.latency_s` must not share a plan). The `Debug`
/// renderings of those structs are plain scalar field dumps, which makes
/// them stable, deterministic keys.
fn fingerprint(
    cfg: &AcceleratorConfig,
    workload: &Workload,
    policy: MappingPolicy,
) -> String {
    use fmt::Write;
    let mut s = String::with_capacity(256 + 32 * workload.layers.len());
    let bitcount = match &cfg.bitcount {
        BitcountMode::Pca { gamma } => format!("pca:{}", gamma),
        BitcountMode::Reduction { latency_s, psum_bits } => {
            format!("red:{}:{}", latency_s, psum_bits)
        }
    };
    let _ = write!(
        s,
        "{}|{}|{}|{}|{}|{}|{:?}|{:?}|{:?}|{:?}|{}",
        cfg.name,
        cfg.dr_gsps,
        cfg.n,
        cfg.xpe_total,
        bitcount,
        cfg.mem_bw_bits_per_s,
        cfg.energy,
        cfg.peripherals,
        cfg.loss_budget,
        policy,
        workload.name
    );
    for l in &workload.layers {
        let _ = write!(s, "|{}:{},{},{},{}", l.name, l.h, l.s, l.k, u8::from(l.pool));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::layer::GemmLayer;

    fn wl(name: &str) -> Workload {
        Workload::new(name, vec![GemmLayer::new("l", 4, 30, 2)])
    }

    #[test]
    fn same_triple_shares_one_plan() {
        let cache = PlanCache::default();
        let cfg = AcceleratorConfig::oxbnn_5();
        let a = cache.get_or_compile(&cfg, &wl("w"), MappingPolicy::PcaLocal);
        let b = cache.get_or_compile(&cfg, &wl("w"), MappingPolicy::PcaLocal);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 1);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }

    #[test]
    fn distinct_inputs_get_distinct_plans() {
        let cache = PlanCache::default();
        let cfg = AcceleratorConfig::oxbnn_5();
        let a = cache.get_or_compile(&cfg, &wl("w"), MappingPolicy::PcaLocal);
        let b = cache.get_or_compile(&cfg, &wl("w"), MappingPolicy::SlicedSpread);
        assert!(!Arc::ptr_eq(&a, &b));
        let mut cfg2 = cfg.clone();
        cfg2.xpe_total += 1;
        let c = cache.get_or_compile(&cfg2, &wl("w"), MappingPolicy::PcaLocal);
        assert!(!Arc::ptr_eq(&a, &c));
        // Same name but different geometry must not collide.
        let mut wl2 = wl("w");
        wl2.layers[0].s = 31;
        let d = cache.get_or_compile(&cfg, &wl2, MappingPolicy::PcaLocal);
        assert!(!Arc::ptr_eq(&a, &d));
        // Same mapping geometry but a different energy/peripheral model
        // must not collide either: the event backend simulates with the
        // plan's embedded accelerator.
        let mut cfg3 = cfg.clone();
        cfg3.energy = crate::energy::power::EnergyModel::robin();
        let e = cache.get_or_compile(&cfg3, &wl("w"), MappingPolicy::PcaLocal);
        assert!(!Arc::ptr_eq(&a, &e));
        let mut cfg4 = cfg.clone();
        cfg4.peripherals.activation_unit.latency_s *= 2.0;
        let f = cache.get_or_compile(&cfg4, &wl("w"), MappingPolicy::PcaLocal);
        assert!(!Arc::ptr_eq(&a, &f));
        assert_eq!(cache.len(), 6);
    }

    #[test]
    fn overflow_evicts_one_lru_entry_and_recovers() {
        let cache = PlanCache::with_capacity(2);
        let cfg = AcceleratorConfig::oxbnn_5();
        for i in 0..5 {
            let _ = cache.get_or_compile(&cfg, &wl(&format!("w{}", i)), MappingPolicy::PcaLocal);
        }
        // LRU keeps the cache full (never a wholesale flush) and evicts
        // exactly one entry per overflowing insert.
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 3);
        // The most recent entries survive.
        let a = cache.get_or_compile(&cfg, &wl("w4"), MappingPolicy::PcaLocal);
        let b = cache.get_or_compile(&cfg, &wl("w4"), MappingPolicy::PcaLocal);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(cache.contains(&cfg, &wl("w3"), MappingPolicy::PcaLocal));
    }

    #[test]
    fn hot_plan_survives_cold_key_churn() {
        // The serving scenario the LRU exists for: one hot model geometry
        // interleaved with a long rotation of cold sweep geometries must
        // keep its compiled plan resident throughout.
        let cache = PlanCache::with_capacity(8);
        let cfg = AcceleratorConfig::oxbnn_5();
        let hot = wl("hot_model");
        let first = cache.get_or_compile(&cfg, &hot, MappingPolicy::PcaLocal);
        for i in 0..64 {
            let _ = cache.get_or_compile(
                &cfg,
                &wl(&format!("cold{}", i)),
                MappingPolicy::PcaLocal,
            );
            // The hot plan is touched between cold misses (a serving
            // replica answering traffic) — every touch must be a hit on
            // the SAME compiled plan.
            let again = cache.get_or_compile(&cfg, &hot, MappingPolicy::PcaLocal);
            assert!(
                Arc::ptr_eq(&first, &again),
                "hot plan recompiled after {} cold keys",
                i + 1
            );
        }
        assert_eq!(cache.len(), 8);
        assert_eq!(
            cache.misses(),
            1 + 64,
            "exactly one compile for the hot plan, one per cold key"
        );
        assert!(cache.evictions() >= 64 - 7, "cold keys churn through the LRU");
        assert!(cache.contains(&cfg, &hot, MappingPolicy::PcaLocal));
    }
}
