//! Memoized plan compilation: one [`ExecutionPlan`] per distinct
//! `(accelerator, workload, policy)` triple, shared across sessions,
//! sweep cells and serving replicas via `Arc`.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::ExecutionPlan;
use crate::arch::accelerator::{AcceleratorConfig, BitcountMode};
use crate::mapping::scheduler::MappingPolicy;
use crate::workloads::Workload;

/// Thread-safe compile-once cache of [`ExecutionPlan`]s with LRU
/// eviction.
///
/// The key covers every field that shapes the plan or its timing:
/// accelerator identity (name, DR, N, XPE count, bitcount mode, memory
/// bandwidth), the workload's full layer geometry, and the mapping
/// policy.
///
/// Each map slot is a per-key once guard (`Arc<OnceLock<..>>`): the map
/// lock is held only to look up or insert the slot, and compilation runs
/// through the slot's `get_or_init` *outside* the map lock. Concurrent
/// misses on the **same** key serialize on that key's cell alone (one
/// compilation, everyone shares the result); misses on **distinct** keys
/// never wait on each other, and readers of resident plans never wait on
/// anyone's compilation.
///
/// Eviction is least-recently-used: at capacity, the single entry with
/// the stalest access tick is dropped — a hot serving model's plan
/// survives any amount of cold-key churn (sweeps rotating hundreds of
/// throwaway geometries through a shared cache), where the previous
/// flush-everything policy evicted the hot plan along with the cold ones.
pub struct PlanCache {
    inner: Mutex<HashMap<String, Slot>>,
    capacity: usize,
    /// Monotone access clock for LRU ordering (ticks on hit and insert).
    clock: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

/// One cache slot: a per-key once guard. The cell is `Arc`-shared so
/// same-key waiters hold it across the map lock being released (and so
/// an eviction cannot invalidate an in-flight compilation — the evicted
/// compiler still completes against its own handle).
struct Slot {
    cell: Arc<OnceLock<Arc<ExecutionPlan>>>,
    last_used: u64,
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::with_capacity(256)
    }
}

impl PlanCache {
    /// A cache holding at most `capacity` plans, evicting the
    /// least-recently-used entry when full.
    pub fn with_capacity(capacity: usize) -> PlanCache {
        PlanCache {
            inner: Mutex::new(HashMap::new()),
            capacity: capacity.max(1),
            clock: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Fetch the plan for this triple, compiling it on first use.
    pub fn get_or_compile(
        &self,
        cfg: &AcceleratorConfig,
        workload: &Workload,
        policy: MappingPolicy,
    ) -> Arc<ExecutionPlan> {
        let key = fingerprint(cfg, workload, policy);
        self.get_or_init_with(key, || Arc::new(ExecutionPlan::compile(cfg, workload, policy)))
    }

    /// The cache's real machinery, with the compilation injectable so
    /// tests can pin a slow compile deterministically: resolve (or
    /// insert) the key's once cell under the map lock, then initialize
    /// it *outside* the lock — only same-key callers ever wait on a
    /// compilation.
    fn get_or_init_with(
        &self,
        key: String,
        compile: impl FnOnce() -> Arc<ExecutionPlan>,
    ) -> Arc<ExecutionPlan> {
        let cell = {
            let mut map = self.inner.lock().unwrap();
            if let Some(slot) = map.get_mut(&key) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                slot.last_used = self.tick();
                Arc::clone(&slot.cell)
            } else {
                self.misses.fetch_add(1, Ordering::Relaxed);
                // Evict the least-recently-used entry (O(n) scan —
                // capacity is small and eviction only runs on a miss at
                // capacity).
                if map.len() >= self.capacity {
                    if let Some(stalest) = map
                        .iter()
                        .min_by_key(|(_, e)| e.last_used)
                        .map(|(k, _)| k.clone())
                    {
                        map.remove(&stalest);
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                }
                let slot = Slot { cell: Arc::new(OnceLock::new()), last_used: self.tick() };
                let cell = Arc::clone(&slot.cell);
                map.insert(key, slot);
                cell
            }
        };
        Arc::clone(cell.get_or_init(compile))
    }

    /// Plans currently cached.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cache hits since construction.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses (= distinct-key compilations started) since
    /// construction. Same-key concurrent misses count once: the slot's
    /// once guard makes the second caller a hit that waits on the cell.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// LRU evictions since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// True if the plan for this triple is currently resident (test/ops
    /// introspection; does not count as an access).
    pub fn contains(
        &self,
        cfg: &AcceleratorConfig,
        workload: &Workload,
        policy: MappingPolicy,
    ) -> bool {
        self.inner
            .lock()
            .unwrap()
            .contains_key(&fingerprint(cfg, workload, policy))
    }
}

impl fmt::Debug for PlanCache {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PlanCache")
            .field("entries", &self.len())
            .field("capacity", &self.capacity)
            .field("hits", &self.hits())
            .field("misses", &self.misses())
            .field("evictions", &self.evictions())
            .finish()
    }
}

/// Stable identity string for a `(accelerator, workload, policy)` triple.
///
/// Must cover EVERY field the cached plan's embedded accelerator/workload
/// can influence downstream: the mapping geometry (N, XPE count), the
/// timing scalars (DR, bitcount, memory bandwidth), and — because the
/// event backend simulates with `plan.accelerator` — the energy model,
/// peripherals and loss budget too (two configs differing only in, say,
/// `activation_unit.latency_s` must not share a plan). The `Debug`
/// renderings of those structs are plain scalar field dumps, which makes
/// them stable, deterministic keys.
fn fingerprint(
    cfg: &AcceleratorConfig,
    workload: &Workload,
    policy: MappingPolicy,
) -> String {
    use fmt::Write;
    let mut s = String::with_capacity(256 + 32 * workload.layers.len());
    let bitcount = match &cfg.bitcount {
        BitcountMode::Pca { gamma } => format!("pca:{}", gamma),
        BitcountMode::Reduction { latency_s, psum_bits } => {
            format!("red:{}:{}", latency_s, psum_bits)
        }
    };
    let _ = write!(
        s,
        "{}|{}|{}|{}|{}|{}|{:?}|{:?}|{:?}|{:?}|{}",
        cfg.name,
        cfg.dr_gsps,
        cfg.n,
        cfg.xpe_total,
        bitcount,
        cfg.mem_bw_bits_per_s,
        cfg.energy,
        cfg.peripherals,
        cfg.loss_budget,
        policy,
        workload.name
    );
    for l in &workload.layers {
        let _ = write!(s, "|{}:{},{},{},{}", l.name, l.h, l.s, l.k, u8::from(l.pool));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::layer::GemmLayer;

    fn wl(name: &str) -> Workload {
        Workload::new(name, vec![GemmLayer::new("l", 4, 30, 2)])
    }

    #[test]
    fn same_triple_shares_one_plan() {
        let cache = PlanCache::default();
        let cfg = AcceleratorConfig::oxbnn_5();
        let a = cache.get_or_compile(&cfg, &wl("w"), MappingPolicy::PcaLocal);
        let b = cache.get_or_compile(&cfg, &wl("w"), MappingPolicy::PcaLocal);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 1);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }

    #[test]
    fn distinct_inputs_get_distinct_plans() {
        let cache = PlanCache::default();
        let cfg = AcceleratorConfig::oxbnn_5();
        let a = cache.get_or_compile(&cfg, &wl("w"), MappingPolicy::PcaLocal);
        let b = cache.get_or_compile(&cfg, &wl("w"), MappingPolicy::SlicedSpread);
        assert!(!Arc::ptr_eq(&a, &b));
        let mut cfg2 = cfg.clone();
        cfg2.xpe_total += 1;
        let c = cache.get_or_compile(&cfg2, &wl("w"), MappingPolicy::PcaLocal);
        assert!(!Arc::ptr_eq(&a, &c));
        // Same name but different geometry must not collide.
        let mut wl2 = wl("w");
        wl2.layers[0].s = 31;
        let d = cache.get_or_compile(&cfg, &wl2, MappingPolicy::PcaLocal);
        assert!(!Arc::ptr_eq(&a, &d));
        // Same mapping geometry but a different energy/peripheral model
        // must not collide either: the event backend simulates with the
        // plan's embedded accelerator.
        let mut cfg3 = cfg.clone();
        cfg3.energy = crate::energy::power::EnergyModel::robin();
        let e = cache.get_or_compile(&cfg3, &wl("w"), MappingPolicy::PcaLocal);
        assert!(!Arc::ptr_eq(&a, &e));
        let mut cfg4 = cfg.clone();
        cfg4.peripherals.activation_unit.latency_s *= 2.0;
        let f = cache.get_or_compile(&cfg4, &wl("w"), MappingPolicy::PcaLocal);
        assert!(!Arc::ptr_eq(&a, &f));
        assert_eq!(cache.len(), 6);
    }

    #[test]
    fn overflow_evicts_one_lru_entry_and_recovers() {
        let cache = PlanCache::with_capacity(2);
        let cfg = AcceleratorConfig::oxbnn_5();
        for i in 0..5 {
            let _ = cache.get_or_compile(&cfg, &wl(&format!("w{}", i)), MappingPolicy::PcaLocal);
        }
        // LRU keeps the cache full (never a wholesale flush) and evicts
        // exactly one entry per overflowing insert.
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 3);
        // The most recent entries survive.
        let a = cache.get_or_compile(&cfg, &wl("w4"), MappingPolicy::PcaLocal);
        let b = cache.get_or_compile(&cfg, &wl("w4"), MappingPolicy::PcaLocal);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(cache.contains(&cfg, &wl("w3"), MappingPolicy::PcaLocal));
    }

    #[test]
    fn hot_plan_survives_cold_key_churn() {
        // The serving scenario the LRU exists for: one hot model geometry
        // interleaved with a long rotation of cold sweep geometries must
        // keep its compiled plan resident throughout.
        let cache = PlanCache::with_capacity(8);
        let cfg = AcceleratorConfig::oxbnn_5();
        let hot = wl("hot_model");
        let first = cache.get_or_compile(&cfg, &hot, MappingPolicy::PcaLocal);
        for i in 0..64 {
            let _ = cache.get_or_compile(
                &cfg,
                &wl(&format!("cold{}", i)),
                MappingPolicy::PcaLocal,
            );
            // The hot plan is touched between cold misses (a serving
            // replica answering traffic) — every touch must be a hit on
            // the SAME compiled plan.
            let again = cache.get_or_compile(&cfg, &hot, MappingPolicy::PcaLocal);
            assert!(
                Arc::ptr_eq(&first, &again),
                "hot plan recompiled after {} cold keys",
                i + 1
            );
        }
        assert_eq!(cache.len(), 8);
        assert_eq!(
            cache.misses(),
            1 + 64,
            "exactly one compile for the hot plan, one per cold key"
        );
        assert!(cache.evictions() >= 64 - 7, "cold keys churn through the LRU");
        assert!(cache.contains(&cfg, &hot, MappingPolicy::PcaLocal));
    }

    #[test]
    fn concurrent_cold_misses_on_distinct_keys_do_not_serialize() {
        use std::sync::mpsc;
        use std::thread;
        use std::time::Duration;

        let cache = Arc::new(PlanCache::default());
        let cfg = AcceleratorConfig::oxbnn_5();
        let plan =
            Arc::new(ExecutionPlan::compile(&cfg, &wl("proto"), MappingPolicy::PcaLocal));

        // A cold miss whose "compilation" stays open until released —
        // deterministic stand-in for a slow compile.
        let (started_tx, started_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let slow = {
            let cache = Arc::clone(&cache);
            let plan = Arc::clone(&plan);
            thread::spawn(move || {
                cache.get_or_init_with("slow-key".to_string(), move || {
                    started_tx.send(()).expect("test driver listens");
                    let _ = release_rx.recv_timeout(Duration::from_secs(30));
                    plan
                })
            })
        };
        started_rx
            .recv_timeout(Duration::from_secs(30))
            .expect("slow compile must start");

        // While the slow key is mid-compilation, a miss on a DIFFERENT
        // key must complete: it may wait on its own cell only, never on
        // the map or another key's compilation.
        let (done_tx, done_rx) = mpsc::channel();
        let fast = {
            let cache = Arc::clone(&cache);
            let plan = Arc::clone(&plan);
            thread::spawn(move || {
                let got = cache.get_or_init_with("fast-key".to_string(), move || plan);
                done_tx.send(()).expect("test driver listens");
                got
            })
        };
        let fast_done = done_rx.recv_timeout(Duration::from_secs(10));
        release_tx.send(()).expect("slow compile waits for release");
        fast_done.expect("distinct-key miss serialized behind another key's compilation");
        let _ = fast.join().expect("fast thread");
        let _ = slow.join().expect("slow thread");
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn concurrent_same_key_misses_compile_once() {
        use std::sync::atomic::AtomicUsize;
        use std::sync::mpsc;
        use std::thread;
        use std::time::Duration;

        let cache = Arc::new(PlanCache::default());
        let cfg = AcceleratorConfig::oxbnn_5();
        let plan =
            Arc::new(ExecutionPlan::compile(&cfg, &wl("proto"), MappingPolicy::PcaLocal));
        let compiles = Arc::new(AtomicUsize::new(0));

        let (second_up_tx, second_up_rx) = mpsc::channel();
        let first = {
            let cache = Arc::clone(&cache);
            let plan = Arc::clone(&plan);
            let compiles = Arc::clone(&compiles);
            thread::spawn(move || {
                cache.get_or_init_with("shared".to_string(), move || {
                    compiles.fetch_add(1, Ordering::SeqCst);
                    // Hold the compile open until the second caller has
                    // announced itself, so the two calls provably overlap.
                    let _ = second_up_rx.recv_timeout(Duration::from_secs(30));
                    plan
                })
            })
        };
        // Announce-then-call: whichever caller wins the slot, the loser
        // must share the winner's single compilation.
        second_up_tx.send(()).expect("first closure may be waiting");
        let compiles2 = Arc::clone(&compiles);
        let plan2 = Arc::clone(&plan);
        let b = cache.get_or_init_with("shared".to_string(), move || {
            compiles2.fetch_add(1, Ordering::SeqCst);
            plan2
        });
        let a = first.join().expect("first thread");
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(compiles.load(Ordering::SeqCst), 1, "once guard admits one compile");
        assert_eq!(cache.misses(), 1, "the second caller is a hit on the in-flight slot");
        assert_eq!(cache.hits(), 1);
    }
}
