//! # Compiled execution plans
//!
//! The event-driven simulator used to rebuild — and materialize — the
//! full VDP-to-XPE schedule for every layer of every run: one heap
//! struct per PASS (~millions for a real VGG conv layer), cloned again
//! into the per-XPE queues. This module replaces that with a compile →
//! cache → stream lifecycle:
//!
//! 1. **Compile** ([`ExecutionPlan::compile`]): resolve the mapping of a
//!    whole workload onto an accelerator once. Both mapping policies are
//!    pure index maps, so a [`LayerPlan`] stores only the geometry and
//!    slice table — O(slices) per layer, no per-pass state.
//! 2. **Cache** ([`PlanCache`]): plans are memoized by
//!    `(accelerator, workload, policy)` and shared via `Arc` across
//!    [`crate::api::Session`]s, parallel sweep cells, and the serving
//!    coordinator's replicas.
//! 3. **Stream** ([`PassStream`]): during simulation each XPE pulls its
//!    next [`crate::mapping::scheduler::ScheduledPass`] in O(1); total
//!    live state is one cursor per XPE.
//!
//! The legacy materializer `Schedule::plan` remains as the independent
//! reference implementation — [`LayerPlan::materialize`] exposes it for
//! the property tests that prove stream/materialized equivalence.

pub mod cache;
pub mod shard;
pub mod stream;

pub use cache::PlanCache;
pub use shard::{ChipLink, ShardPlan, ShardPolicy};
pub use stream::{FrameStream, LayerPlan, PassStream};

use crate::arch::accelerator::AcceleratorConfig;
use crate::mapping::layer::GemmLayer;
use crate::mapping::scheduler::MappingPolicy;
use crate::workloads::Workload;

/// A whole workload compiled onto one accelerator under one mapping
/// policy: the unit the event backend simulates and the [`PlanCache`]
/// shares.
///
/// Invariant: `layers[i].layer` is a copy of `workload.layers[i]` — the
/// frame chain reads `workload`, the per-layer simulation reads
/// `layers[i]`, and [`ExecutionPlan::compile`] (the only intended
/// constructor) keeps the two views identical. Don't assemble one by
/// hand from mismatched parts.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    /// The accelerator the plan was compiled for (timing + energy come
    /// from here; the mapping uses its N / M / XPC geometry).
    pub accelerator: AcceleratorConfig,
    /// The workload's layer geometry (layer order defines frame order).
    pub workload: Workload,
    pub policy: MappingPolicy,
    /// One compiled pass map per workload layer, in frame order.
    pub layers: Vec<LayerPlan>,
}

impl ExecutionPlan {
    /// Compile `workload` onto `cfg` under `policy`. Cheap: O(layers ·
    /// slices), no per-pass allocation.
    pub fn compile(
        cfg: &AcceleratorConfig,
        workload: &Workload,
        policy: MappingPolicy,
    ) -> ExecutionPlan {
        let (n, m, xpcs) = (cfg.n, cfg.m(), cfg.xpc_count());
        let layers = workload
            .layers
            .iter()
            .map(|l| LayerPlan::compile(l, policy, n, m, xpcs))
            .collect();
        ExecutionPlan {
            accelerator: cfg.clone(),
            workload: workload.clone(),
            policy,
            layers,
        }
    }

    /// Total passes across the frame.
    pub fn total_passes(&self) -> usize {
        self.layers.iter().map(|l| l.total_passes()).sum()
    }

    /// Longest per-XPE queue across all layers (peak queue length).
    pub fn max_queue_len(&self) -> usize {
        self.layers.iter().map(|l| l.max_queue_len()).max().unwrap_or(0)
    }

    /// Peak live simulator state under streaming (layers run one at a
    /// time, so the peak is the largest layer's state).
    pub fn streamed_state_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.streamed_state_bytes())
            .max()
            .unwrap_or(0)
    }

    /// Peak live state the old materialized path held (largest layer's
    /// schedule + cloned queues).
    pub fn materialized_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.materialized_bytes())
            .max()
            .unwrap_or(0)
    }
}

/// Cross-layer admission rule a [`FramePlan`] applies in
/// [`FramePlan::need_acts`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmissionMode {
    /// Receptive-field-exact (the default): a consumer VDP is admitted
    /// once the producer has drained exactly the raster prefix of
    /// activations its im2col window reaches
    /// ([`crate::mapping::layer::ConvGeom`]), falling back to the
    /// whole-map wait when the geometry is unknown or does not chain.
    Exact,
    /// The legacy PR-4 rule, kept ONLY for the exact-vs-halo differential
    /// tests and `bench_pipeline`: a consumer VDP at spatial fraction `f`
    /// of its own map waits for the producer fraction `min(1, f + halo)`.
    /// The fixed halo is a guess that stands in for the kernel extent the
    /// flattening erased — it under-waits strided windows and over-waits
    /// large stride-1 maps, which is why it is no longer a production
    /// mode.
    RasterHalo(f64),
}

/// A whole *batch of frames* laid over one [`ExecutionPlan`]: the unit
/// table the frame-scoped event world simulates in a single event space.
///
/// Each `(frame, layer)` pair is one **unit**, numbered frame-major
/// (`u = frame · layers + layer`) — the order XPEs prefer work in, so an
/// earlier frame's tail is never starved by a later frame. Units share one
/// global VDP id space (unit `u`'s VDPs occupy `[base_vdp(u),
/// base_vdp(u) + vdps)`), which lets every existing event variant carry
/// frame/layer identity through its `VdpId` untouched.
///
/// The plan also owns the **cross-layer admission rule** ([`Self::need_acts`]):
/// how many of the producer layer's activations must have drained before a
/// given consumer VDP's passes may be admitted. VDP indices are spatial-major
/// (`vdp / channels_per_position` = output raster position). Exact
/// receptive-field thresholds are *not* globally monotone in the VDP index
/// (a row-end window reaches further into the input raster than the next
/// row-start window), which is fine: each XPE drains its queue in order, so
/// only the head pass's threshold ever gates, and the wake index
/// ([`crate::plan::FrameStream`]) keys each waiting XPE by exactly that
/// head threshold.
#[derive(Debug, Clone)]
pub struct FramePlan<'a> {
    plan: &'a ExecutionPlan,
    frames: usize,
    admission: AdmissionMode,
    /// Per-layer VDP base within one frame (prefix sums), plus the total.
    layer_vdp_base: Vec<usize>,
    frame_vdps: usize,
    /// Chips in the shard group (1 = the ordinary single-chip batch).
    chips: usize,
    /// XPE slots per chip. For a single chip (and VdpSplit, whose
    /// recompiled grid already spans `chips × T`) this divides the layer
    /// grid; for LayerPipeline the layer grid IS one chip's slots and
    /// the physical flat space is `chips ×` wider.
    per_chip_xpes: usize,
    /// Stage chip per layer (LayerPipeline shards; empty otherwise).
    chip_of_layer: Vec<usize>,
    /// The inter-chip activation channel (None when `chips == 1`).
    link: Option<ChipLink>,
}

impl<'a> FramePlan<'a> {
    /// Lay `frames` back-to-back frames over `plan` with the exact
    /// receptive-field admission rule.
    pub fn new(plan: &'a ExecutionPlan, frames: usize) -> FramePlan<'a> {
        FramePlan::with_admission(plan, frames, AdmissionMode::Exact)
    }

    /// [`FramePlan::new`] with an explicit [`AdmissionMode`] — the
    /// non-default modes exist for the differential test/bench suite.
    pub fn with_admission(
        plan: &'a ExecutionPlan,
        frames: usize,
        admission: AdmissionMode,
    ) -> FramePlan<'a> {
        assert!(frames > 0, "a frame plan needs at least one frame");
        let mut layer_vdp_base = Vec::with_capacity(plan.layers.len());
        let mut acc = 0usize;
        for lp in &plan.layers {
            layer_vdp_base.push(acc);
            acc += lp.vdp_count();
        }
        let grid = plan.layers.first().map(|l| l.total_xpes()).unwrap_or(0);
        FramePlan {
            plan,
            frames,
            admission,
            layer_vdp_base,
            frame_vdps: acc,
            chips: 1,
            per_chip_xpes: grid,
            chip_of_layer: Vec::new(),
            link: None,
        }
    }

    /// Lay `frames` frames over a [`ShardPlan`]: the unit table spans the
    /// whole K-chip group's XPEs, cross-chip edges route their
    /// activations through the shared link, and admission for those
    /// edges counts *arrived* (not merely drained) activations against
    /// the same exact thresholds.
    pub fn for_shard(
        shard: &'a ShardPlan,
        frames: usize,
        admission: AdmissionMode,
    ) -> FramePlan<'a> {
        let mut fp = FramePlan::with_admission(&shard.plan, frames, admission);
        fp.chips = shard.chips();
        fp.per_chip_xpes = shard.per_chip_xpes();
        fp.chip_of_layer = shard.chip_of_layer.clone();
        if fp.chips > 1 {
            fp.link = Some(shard.link.clone());
        }
        fp
    }

    pub fn admission(&self) -> AdmissionMode {
        self.admission
    }

    pub fn plan(&self) -> &'a ExecutionPlan {
        self.plan
    }

    pub fn frames(&self) -> usize {
        self.frames
    }

    pub fn layers(&self) -> usize {
        self.plan.layers.len()
    }

    /// Units in the batch (`frames · layers`).
    pub fn units(&self) -> usize {
        self.frames * self.layers()
    }

    pub fn unit_frame(&self, unit: usize) -> usize {
        unit / self.layers()
    }

    pub fn unit_layer(&self, unit: usize) -> usize {
        unit % self.layers()
    }

    /// The unit that produces this unit's input feature map (same frame,
    /// previous layer), or `None` for first layers.
    pub fn producer(&self, unit: usize) -> Option<usize> {
        (self.unit_layer(unit) > 0).then(|| unit - 1)
    }

    pub fn layer_plan(&self, unit: usize) -> &'a LayerPlan {
        &self.plan.layers[self.unit_layer(unit)]
    }

    /// XPE slots the batch runs on: the whole shard group's flat space
    /// (`chips × per-chip slots`; one chip's grid when unsharded).
    pub fn total_xpes(&self) -> usize {
        if self.chip_of_layer.is_empty() {
            // Single chip, or VdpSplit whose recompiled layer grid
            // already spans the whole group.
            self.plan.layers.first().map(|l| l.total_xpes()).unwrap_or(0)
        } else {
            self.chips * self.per_chip_xpes
        }
    }

    /// Chips in the shard group (1 = unsharded).
    pub fn chips(&self) -> usize {
        self.chips
    }

    /// XPE slots per member chip.
    pub fn per_chip_xpes(&self) -> usize {
        self.per_chip_xpes
    }

    /// The chip owning the XPE at flat slot `flat`.
    pub fn xpe_chip(&self, flat: usize) -> usize {
        if self.per_chip_xpes == 0 {
            0
        } else {
            flat / self.per_chip_xpes
        }
    }

    /// The chip a unit's operand fetch is charged to (LayerPipeline: the
    /// stage chip; otherwise chip 0 — VdpSplit fetches are split across
    /// every chip, see [`Self::fetch_split`]).
    pub fn unit_chip(&self, unit: usize) -> usize {
        self.chip_of_layer.get(self.unit_layer(unit)).copied().unwrap_or(0)
    }

    /// Chips an operand fetch is split across in parallel (VdpSplit:
    /// every chip stages its own VDP share; otherwise 1).
    pub fn fetch_split(&self) -> usize {
        if self.chips > 1 && self.chip_of_layer.is_empty() {
            self.chips
        } else {
            1
        }
    }

    /// May the XPE at flat slot `flat` service `unit`? Under
    /// LayerPipeline sharding a chip only runs its own stage's layers;
    /// everywhere else every XPE services every unit.
    pub fn eligible(&self, unit: usize, flat: usize) -> bool {
        match self.chip_of_layer.get(self.unit_layer(unit)) {
            Some(&chip) => self.xpe_chip(flat) == chip,
            None => true,
        }
    }

    /// Translate a group-wide flat slot to the layer-grid slot the
    /// unit's pass map is indexed by (identity except under
    /// LayerPipeline sharding, whose layer grids span one chip).
    pub fn local_flat(&self, unit: usize, flat: usize) -> usize {
        if self.chip_of_layer.get(self.unit_layer(unit)).is_some() {
            flat % self.per_chip_xpes
        } else {
            flat
        }
    }

    /// Does the edge feeding `unit` cross chips (so its activations
    /// traverse the inter-chip link and admission counts *arrivals*)?
    pub fn edge_crosses(&self, unit: usize) -> bool {
        if self.chips == 1 {
            return false;
        }
        let layer = self.unit_layer(unit);
        if layer == 0 {
            return false;
        }
        match (self.chip_of_layer.get(layer - 1), self.chip_of_layer.get(layer)) {
            (Some(a), Some(b)) => a != b,
            _ => true, // VdpSplit: every edge is all-to-all
        }
    }

    /// The shared inter-chip activation channel (None when unsharded).
    pub fn link(&self) -> Option<&ChipLink> {
        self.link.as_ref()
    }

    /// First global VDP id of `unit`.
    pub fn base_vdp(&self, unit: usize) -> usize {
        self.unit_frame(unit) * self.frame_vdps
            + self.layer_vdp_base[self.unit_layer(unit)]
    }

    /// Global VDP id of `unit`'s local VDP `v`.
    pub fn global_vdp(&self, unit: usize, v: usize) -> usize {
        self.base_vdp(unit) + v
    }

    /// Map a global VDP id back to `(unit, local vdp)`.
    pub fn unit_of_vdp(&self, global: usize) -> (usize, usize) {
        let frame = global / self.frame_vdps;
        let rem = global % self.frame_vdps;
        let layer = self.layer_vdp_base.partition_point(|&b| b <= rem) - 1;
        (frame * self.layers() + layer, rem - self.layer_vdp_base[layer])
    }

    /// Producer activations that must have drained before `unit`'s local
    /// VDP `v` may be admitted. 0 for first layers (no producer).
    ///
    /// Under [`AdmissionMode::Exact`] the threshold is closed-form from
    /// the consumer's [`crate::mapping::layer::ConvGeom`]: VDP `v` covers
    /// output raster position `v / channels_per_position`; its k×k window
    /// reaches the input map no further than raster position `(r_last,
    /// c_last)` ([`ConvGeom::last_input_rc`]), so the threshold is that
    /// raster prefix times the producer's activations-per-position — the
    /// LAST producer activation feeding the window, not one more. A 2×2
    /// pooling on the producer maps input position `(r, c)` to producer
    /// rows/cols `≤ (2r+1, 2c+1)`. FC consumers, consumers without
    /// geometry, and geometries that do not chain onto the producer's map
    /// (branchy flattenings) wait for the whole map — the sound fallback.
    ///
    /// [`ConvGeom::last_input_rc`]: crate::mapping::layer::ConvGeom::last_input_rc
    pub fn need_acts(&self, unit: usize, v: usize) -> usize {
        let Some(prev) = self.producer(unit) else {
            return 0;
        };
        let consumer = &self.layer_plan(unit).layer;
        let producer = &self.layer_plan(prev).layer;
        let produced = self.layer_plan(prev).vdp_count();
        match self.admission {
            AdmissionMode::Exact => exact_need(consumer, producer, produced, v),
            AdmissionMode::RasterHalo(halo) => {
                if consumer.h == 1 {
                    return produced; // FC: reads the whole flattened map
                }
                let position = v / consumer.k;
                let frac = (position + 1) as f64 / consumer.h as f64;
                (((frac + halo).min(1.0) * produced as f64).ceil() as usize)
                    .min(produced)
            }
        }
    }

    /// Total passes across the whole batch.
    pub fn total_passes(&self) -> usize {
        self.frames * self.plan.total_passes()
    }

    /// Event budget generous enough for any well-formed run of the batch.
    pub fn event_budget(&self) -> u64 {
        self.plan
            .layers
            .iter()
            .map(|l| l.event_budget())
            .sum::<u64>()
            .saturating_mul(self.frames as u64)
            + 10_000
    }
}

/// The receptive-field-exact threshold: the raster prefix of producer
/// activations the consumer's VDP `v` reads, in activations. Whole-map
/// (`produced`) whenever the window structure is unknown or the two
/// flattenings do not chain onto one raster — the sound fallback.
fn exact_need(
    consumer: &GemmLayer,
    producer: &GemmLayer,
    produced: usize,
    v: usize,
) -> usize {
    let Some(geom) = consumer.geom else {
        return produced; // FC, or a flattening with no raster order
    };
    let out_hw = geom.out_hw();
    let positions = out_hw * out_hw;
    if positions == 0 || consumer.vdp_count() % positions != 0 {
        return produced;
    }
    // Spatial-major VDP order: position = v / channels-per-position
    // (regular conv: per_pos = K; depthwise: per_pos = C, K = 1).
    let per_pos = consumer.vdp_count() / positions;
    let pos = (v / per_pos).min(positions - 1);
    let (mut r, mut c) = geom.last_input_rc(pos / out_hw, pos % out_hw);
    // Producer-side raster: spatial positions and activations per position.
    // A producer with geometry knows its output map; one without is taken
    // as the regular flattening of one position per H row (FC producers,
    // h == 1, have no raster and fall through the alignment check).
    let prod_positions = match producer.geom {
        Some(g) => g.out_hw() * g.out_hw(),
        None => producer.h,
    };
    if prod_positions == 0 || produced % prod_positions != 0 {
        return produced;
    }
    let per_pos_acts = produced / prod_positions;
    let Some(prod_hw) = int_sqrt(prod_positions) else {
        return produced;
    };
    if producer.pool {
        // 2×2 pooling: input position (r, c) draws from producer rows and
        // cols {2r, 2r+1} × {2c, 2c+1}; the raster-maximal element is at
        // (2r+1, 2c+1).
        if geom.in_hw * 2 != prod_hw {
            return produced;
        }
        r = 2 * r + 1;
        c = 2 * c + 1;
    } else if geom.in_hw != prod_hw {
        return produced;
    }
    ((r * prod_hw + c + 1) * per_pos_acts).min(produced)
}

fn int_sqrt(n: usize) -> Option<usize> {
    let r = (n as f64).sqrt().round() as usize;
    (r * r == n).then_some(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::layer::GemmLayer;

    #[test]
    fn compile_covers_every_layer() {
        let cfg = AcceleratorConfig::oxbnn_5();
        let wl = Workload::new(
            "t",
            vec![GemmLayer::new("a", 4, 120, 3), GemmLayer::fc("b", 64, 10)],
        );
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
        assert_eq!(plan.layers.len(), 2);
        assert_eq!(
            plan.total_passes(),
            wl.layers.iter().map(|l| l.total_passes(cfg.n)).sum::<usize>()
        );
        assert!(plan.max_queue_len() > 0);
        assert!(plan.streamed_state_bytes() > 0);
        assert!(plan.materialized_bytes() >= plan.streamed_state_bytes());
    }

    fn frame_plan_fixture() -> ExecutionPlan {
        let cfg = AcceleratorConfig::oxbnn_5();
        let wl = Workload::new(
            "fp",
            vec![
                GemmLayer::new("c1", 6, 40, 4),  // 24 VDPs
                GemmLayer::new("c2", 4, 30, 3),  // 12 VDPs
                GemmLayer::fc("fc", 64, 10),     // 10 VDPs
            ],
        );
        ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal)
    }

    #[test]
    fn frame_plan_vdp_ids_roundtrip() {
        let plan = frame_plan_fixture();
        let fp = FramePlan::new(&plan, 3);
        assert_eq!(fp.units(), 9);
        assert_eq!(fp.total_passes(), 3 * plan.total_passes());
        for unit in 0..fp.units() {
            let vdps = fp.layer_plan(unit).vdp_count();
            for v in [0, vdps / 2, vdps - 1] {
                let g = fp.global_vdp(unit, v);
                assert_eq!(fp.unit_of_vdp(g), (unit, v), "unit {} vdp {}", unit, v);
            }
        }
        // Frame-major unit order: frame 1's first layer follows frame 0's
        // last layer.
        assert_eq!(fp.unit_frame(3), 1);
        assert_eq!(fp.unit_layer(3), 0);
        assert_eq!(fp.producer(3), None);
        assert_eq!(fp.producer(4), Some(3));
    }

    #[test]
    fn frame_plan_admission_thresholds() {
        let plan = frame_plan_fixture();
        let fp = FramePlan::new(&plan, 2);
        // First layers need nothing.
        assert_eq!(fp.need_acts(0, 0), 0);
        assert_eq!(fp.need_acts(3, 0), 0);
        // The fixture's layers carry no ConvGeom, so exact admission takes
        // the sound whole-map fallback for every consumer VDP.
        let produced = fp.layer_plan(0).vdp_count();
        for v in 0..fp.layer_plan(1).vdp_count() {
            assert_eq!(fp.need_acts(1, v), produced);
        }
        // FC consumer reads the whole input map.
        let c2_vdps = fp.layer_plan(1).vdp_count();
        assert_eq!(fp.need_acts(2, 0), c2_vdps);
    }

    #[test]
    fn exact_admission_follows_the_window_structure() {
        // A chain whose geometry lines up: 8×8 map same-conv (3×3 s1 p1)
        // into a strided 3×3 s2 p1 conv (8 → 4 map), then FC.
        let cfg = AcceleratorConfig::oxbnn_5();
        let wl = Workload::new(
            "geom",
            vec![
                GemmLayer::conv("c1", 8, 2, 3, 4), // 64 positions × 4 ch
                GemmLayer::new("c2", 16, 36, 2)
                    .with_geom(crate::mapping::layer::ConvGeom::new(3, 2, 1, 8)),
                GemmLayer::fc("fc", 32, 10),
            ],
        );
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
        let fp = FramePlan::new(&plan, 1);
        let produced = fp.layer_plan(0).vdp_count(); // 64 · 4 = 256
        // c2 VDP 0 covers output (0,0): window rows/cols {0,1} of the 8
        // map → raster prefix through (1,1) = 10 positions × 4 acts.
        assert_eq!(fp.need_acts(1, 0), 10 * 4);
        // Output (0,1) (VDPs 2..4): cols {1,2,3} → prefix through (1,3).
        assert_eq!(fp.need_acts(1, 2), (8 + 3 + 1) * 4);
        // Last output position needs exactly the whole map — not less.
        let c2_vdps = fp.layer_plan(1).vdp_count();
        assert_eq!(fp.need_acts(1, c2_vdps - 1), produced);
        // FC keeps the whole-map wait.
        assert_eq!(fp.need_acts(2, 0), c2_vdps);
        // The legacy halo mode still computes the PR-4 rule for the
        // differential suite. The fixed-fraction guess misses the true
        // window: here it over-waits ((1/16 + 0.125)·256 = 48 vs the exact
        // 40); on large stride-1 maps it under-waits (the admission-oracle
        // suite and prop_invariants pin the differential).
        let halo = FramePlan::with_admission(&plan, 1, AdmissionMode::RasterHalo(0.125));
        assert_eq!(halo.need_acts(1, 0), 48);
        assert_ne!(halo.need_acts(1, 0), fp.need_acts(1, 0));
    }

    #[test]
    fn exact_admission_sees_through_producer_pooling() {
        // Producer 8×8 map, 2×2 pooled → consumer same-conv on the 4 map.
        let cfg = AcceleratorConfig::oxbnn_5();
        let wl = Workload::new(
            "pooled",
            vec![
                GemmLayer::conv("p", 8, 2, 3, 4).with_pool(),
                GemmLayer::conv("c", 4, 4, 3, 2),
            ],
        );
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
        let fp = FramePlan::new(&plan, 1);
        // Consumer output (0,0): pooled input rows/cols {0,1} → producer
        // rows/cols up to (2·1+1, 2·1+1) = (3,3) → prefix 3·8+3+1 = 28
        // positions × 4 channels.
        assert_eq!(fp.need_acts(1, 0), 28 * 4);
        // Pool misalignment (consumer claims the unpooled map) falls back
        // to the whole map.
        let wl_bad = Workload::new(
            "misaligned",
            vec![
                GemmLayer::conv("p", 8, 2, 3, 4).with_pool(),
                GemmLayer::conv("c", 8, 4, 3, 2),
            ],
        );
        let plan_bad = ExecutionPlan::compile(&cfg, &wl_bad, MappingPolicy::PcaLocal);
        let fp_bad = FramePlan::new(&plan_bad, 1);
        assert_eq!(fp_bad.need_acts(1, 0), fp_bad.layer_plan(0).vdp_count());
    }

    #[test]
    fn wake_index_pops_only_met_thresholds() {
        let plan = frame_plan_fixture();
        let fp = FramePlan::new(&plan, 1);
        let mut fs = FrameStream::new(&fp);
        assert_eq!(fs.waiting_on(0), None);
        fs.register_waiter(1, 10, 0);
        fs.register_waiter(1, 4, 3);
        fs.register_waiter(2, 7, 5);
        assert_eq!(fs.waiting_count(), 3);
        // Nothing met yet.
        assert!(fs.pop_admitted(1, 3).is_empty());
        // Pops in threshold order, not registration order; unit 2 untouched.
        assert_eq!(fs.pop_admitted(1, 4), vec![3]);
        assert_eq!(fs.waiting_on(3), None);
        assert_eq!(fs.pop_admitted(1, 64), vec![0]);
        assert_eq!(fs.waiting_count(), 1);
        assert_eq!(fs.pop_admitted(2, 7), vec![5]);
        assert_eq!(fs.waiting_count(), 0);
    }

    #[test]
    fn frame_stream_carries_frame_indexed_cursors() {
        let plan = frame_plan_fixture();
        let fp = FramePlan::new(&plan, 2);
        let mut fs = FrameStream::new(&fp);
        // Same layer, different frames: independent cursors.
        let a = fs.next_for(&fp, 0, 0).unwrap();
        let b = fs.next_for(&fp, 3, 0).unwrap();
        assert_eq!(a, b, "frame 1 re-streams the same compiled layer");
        assert_eq!(fs.issued(0), 1);
        assert_eq!(fs.issued(3), 1);
        assert_eq!(fs.peek_for(&fp, 0, 0), fs.peek_for(&fp, 3, 0));
        // Draining unit 0 on one XPE advances first_open past it.
        let flat = 0;
        while fs.next_for(&fp, 0, flat).is_some() {}
        assert!(fs.exhausted_for(&fp, 0, flat));
        fs.advance_first_open(&fp, flat);
        assert!(fs.first_open(flat) >= 1);
    }
}
