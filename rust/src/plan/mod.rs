//! # Compiled execution plans
//!
//! The event-driven simulator used to rebuild — and materialize — the
//! full VDP-to-XPE schedule for every layer of every run: one heap
//! struct per PASS (~millions for a real VGG conv layer), cloned again
//! into the per-XPE queues. This module replaces that with a compile →
//! cache → stream lifecycle:
//!
//! 1. **Compile** ([`ExecutionPlan::compile`]): resolve the mapping of a
//!    whole workload onto an accelerator once. Both mapping policies are
//!    pure index maps, so a [`LayerPlan`] stores only the geometry and
//!    slice table — O(slices) per layer, no per-pass state.
//! 2. **Cache** ([`PlanCache`]): plans are memoized by
//!    `(accelerator, workload, policy)` and shared via `Arc` across
//!    [`crate::api::Session`]s, parallel sweep cells, and the serving
//!    coordinator's replicas.
//! 3. **Stream** ([`PassStream`]): during simulation each XPE pulls its
//!    next [`crate::mapping::scheduler::ScheduledPass`] in O(1); total
//!    live state is one cursor per XPE.
//!
//! The legacy materializer `Schedule::plan` remains as the independent
//! reference implementation — [`LayerPlan::materialize`] exposes it for
//! the property tests that prove stream/materialized equivalence.

pub mod cache;
pub mod stream;

pub use cache::PlanCache;
pub use stream::{FrameStream, LayerPlan, PassStream};

use crate::arch::accelerator::AcceleratorConfig;
use crate::mapping::scheduler::MappingPolicy;
use crate::workloads::Workload;

/// A whole workload compiled onto one accelerator under one mapping
/// policy: the unit the event backend simulates and the [`PlanCache`]
/// shares.
///
/// Invariant: `layers[i].layer` is a copy of `workload.layers[i]` — the
/// frame chain reads `workload`, the per-layer simulation reads
/// `layers[i]`, and [`ExecutionPlan::compile`] (the only intended
/// constructor) keeps the two views identical. Don't assemble one by
/// hand from mismatched parts.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    /// The accelerator the plan was compiled for (timing + energy come
    /// from here; the mapping uses its N / M / XPC geometry).
    pub accelerator: AcceleratorConfig,
    /// The workload's layer geometry (layer order defines frame order).
    pub workload: Workload,
    pub policy: MappingPolicy,
    /// One compiled pass map per workload layer, in frame order.
    pub layers: Vec<LayerPlan>,
}

impl ExecutionPlan {
    /// Compile `workload` onto `cfg` under `policy`. Cheap: O(layers ·
    /// slices), no per-pass allocation.
    pub fn compile(
        cfg: &AcceleratorConfig,
        workload: &Workload,
        policy: MappingPolicy,
    ) -> ExecutionPlan {
        let (n, m, xpcs) = (cfg.n, cfg.m(), cfg.xpc_count());
        let layers = workload
            .layers
            .iter()
            .map(|l| LayerPlan::compile(l, policy, n, m, xpcs))
            .collect();
        ExecutionPlan {
            accelerator: cfg.clone(),
            workload: workload.clone(),
            policy,
            layers,
        }
    }

    /// Total passes across the frame.
    pub fn total_passes(&self) -> usize {
        self.layers.iter().map(|l| l.total_passes()).sum()
    }

    /// Longest per-XPE queue across all layers (peak queue length).
    pub fn max_queue_len(&self) -> usize {
        self.layers.iter().map(|l| l.max_queue_len()).max().unwrap_or(0)
    }

    /// Peak live simulator state under streaming (layers run one at a
    /// time, so the peak is the largest layer's state).
    pub fn streamed_state_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.streamed_state_bytes())
            .max()
            .unwrap_or(0)
    }

    /// Peak live state the old materialized path held (largest layer's
    /// schedule + cloned queues).
    pub fn materialized_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.materialized_bytes())
            .max()
            .unwrap_or(0)
    }
}

/// Receptive-field lookahead for cross-layer pass admission, as a fraction
/// of the producer layer's output feature map: a consumer VDP at spatial
/// fraction `f` of its own map may start once the producer has drained
/// activations up to fraction `min(1, f + HALO)`. The halo stands in for
/// the kernel rows a conv window reaches beyond its own raster position
/// (the flattened [`crate::mapping::layer::GemmLayer`] geometry no longer
/// knows the kernel extent, so the plan uses a conservative fixed
/// fraction).
pub const RECEPTIVE_HALO: f64 = 0.125;

/// A whole *batch of frames* laid over one [`ExecutionPlan`]: the unit
/// table the frame-scoped event world simulates in a single event space.
///
/// Each `(frame, layer)` pair is one **unit**, numbered frame-major
/// (`u = frame · layers + layer`) — the order XPEs prefer work in, so an
/// earlier frame's tail is never starved by a later frame. Units share one
/// global VDP id space (unit `u`'s VDPs occupy `[base_vdp(u),
/// base_vdp(u) + vdps)`), which lets every existing event variant carry
/// frame/layer identity through its `VdpId` untouched.
///
/// The plan also owns the **cross-layer admission rule** ([`Self::need_acts`]):
/// how many of the producer layer's activations must have drained before a
/// given consumer VDP's passes may be admitted. VDP indices are spatial-major
/// (`vdp / K` = output raster position), so admission thresholds are
/// monotone along every XPE's queue under both mapping policies.
#[derive(Debug, Clone)]
pub struct FramePlan<'a> {
    plan: &'a ExecutionPlan,
    frames: usize,
    /// Per-layer VDP base within one frame (prefix sums), plus the total.
    layer_vdp_base: Vec<usize>,
    frame_vdps: usize,
}

impl<'a> FramePlan<'a> {
    /// Lay `frames` back-to-back frames over `plan`.
    pub fn new(plan: &'a ExecutionPlan, frames: usize) -> FramePlan<'a> {
        assert!(frames > 0, "a frame plan needs at least one frame");
        let mut layer_vdp_base = Vec::with_capacity(plan.layers.len());
        let mut acc = 0usize;
        for lp in &plan.layers {
            layer_vdp_base.push(acc);
            acc += lp.vdp_count();
        }
        FramePlan { plan, frames, layer_vdp_base, frame_vdps: acc }
    }

    pub fn plan(&self) -> &'a ExecutionPlan {
        self.plan
    }

    pub fn frames(&self) -> usize {
        self.frames
    }

    pub fn layers(&self) -> usize {
        self.plan.layers.len()
    }

    /// Units in the batch (`frames · layers`).
    pub fn units(&self) -> usize {
        self.frames * self.layers()
    }

    pub fn unit_frame(&self, unit: usize) -> usize {
        unit / self.layers()
    }

    pub fn unit_layer(&self, unit: usize) -> usize {
        unit % self.layers()
    }

    /// The unit that produces this unit's input feature map (same frame,
    /// previous layer), or `None` for first layers.
    pub fn producer(&self, unit: usize) -> Option<usize> {
        (self.unit_layer(unit) > 0).then(|| unit - 1)
    }

    pub fn layer_plan(&self, unit: usize) -> &'a LayerPlan {
        &self.plan.layers[self.unit_layer(unit)]
    }

    /// XPE slots the batch runs on (same physical grid for every unit).
    pub fn total_xpes(&self) -> usize {
        self.plan.layers.first().map(|l| l.total_xpes()).unwrap_or(0)
    }

    /// First global VDP id of `unit`.
    pub fn base_vdp(&self, unit: usize) -> usize {
        self.unit_frame(unit) * self.frame_vdps
            + self.layer_vdp_base[self.unit_layer(unit)]
    }

    /// Global VDP id of `unit`'s local VDP `v`.
    pub fn global_vdp(&self, unit: usize, v: usize) -> usize {
        self.base_vdp(unit) + v
    }

    /// Map a global VDP id back to `(unit, local vdp)`.
    pub fn unit_of_vdp(&self, global: usize) -> (usize, usize) {
        let frame = global / self.frame_vdps;
        let rem = global % self.frame_vdps;
        let layer = self.layer_vdp_base.partition_point(|&b| b <= rem) - 1;
        (frame * self.layers() + layer, rem - self.layer_vdp_base[layer])
    }

    /// Producer activations that must have drained before `unit`'s local
    /// VDP `v` may be admitted. 0 for first layers (no producer). FC
    /// consumers (`H == 1`) need the whole input map; conv consumers need
    /// the raster prefix up to their own spatial fraction plus
    /// [`RECEPTIVE_HALO`]. Monotone in `v`, so per-XPE queues under both
    /// mapping policies block and unblock in order.
    pub fn need_acts(&self, unit: usize, v: usize) -> usize {
        let Some(prev) = self.producer(unit) else {
            return 0;
        };
        let consumer = &self.layer_plan(unit).layer;
        let produced = self.layer_plan(prev).vdp_count();
        if consumer.h == 1 {
            return produced; // FC: every VDP reads the whole flattened map
        }
        let position = v / consumer.k;
        let frac = (position + 1) as f64 / consumer.h as f64;
        (((frac + RECEPTIVE_HALO).min(1.0) * produced as f64).ceil() as usize)
            .min(produced)
    }

    /// Total passes across the whole batch.
    pub fn total_passes(&self) -> usize {
        self.frames * self.plan.total_passes()
    }

    /// Event budget generous enough for any well-formed run of the batch.
    pub fn event_budget(&self) -> u64 {
        self.plan
            .layers
            .iter()
            .map(|l| l.event_budget())
            .sum::<u64>()
            .saturating_mul(self.frames as u64)
            + 10_000
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::layer::GemmLayer;

    #[test]
    fn compile_covers_every_layer() {
        let cfg = AcceleratorConfig::oxbnn_5();
        let wl = Workload::new(
            "t",
            vec![GemmLayer::new("a", 4, 120, 3), GemmLayer::fc("b", 64, 10)],
        );
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
        assert_eq!(plan.layers.len(), 2);
        assert_eq!(
            plan.total_passes(),
            wl.layers.iter().map(|l| l.total_passes(cfg.n)).sum::<usize>()
        );
        assert!(plan.max_queue_len() > 0);
        assert!(plan.streamed_state_bytes() > 0);
        assert!(plan.materialized_bytes() >= plan.streamed_state_bytes());
    }

    fn frame_plan_fixture() -> ExecutionPlan {
        let cfg = AcceleratorConfig::oxbnn_5();
        let wl = Workload::new(
            "fp",
            vec![
                GemmLayer::new("c1", 6, 40, 4),  // 24 VDPs
                GemmLayer::new("c2", 4, 30, 3),  // 12 VDPs
                GemmLayer::fc("fc", 64, 10),     // 10 VDPs
            ],
        );
        ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal)
    }

    #[test]
    fn frame_plan_vdp_ids_roundtrip() {
        let plan = frame_plan_fixture();
        let fp = FramePlan::new(&plan, 3);
        assert_eq!(fp.units(), 9);
        assert_eq!(fp.total_passes(), 3 * plan.total_passes());
        for unit in 0..fp.units() {
            let vdps = fp.layer_plan(unit).vdp_count();
            for v in [0, vdps / 2, vdps - 1] {
                let g = fp.global_vdp(unit, v);
                assert_eq!(fp.unit_of_vdp(g), (unit, v), "unit {} vdp {}", unit, v);
            }
        }
        // Frame-major unit order: frame 1's first layer follows frame 0's
        // last layer.
        assert_eq!(fp.unit_frame(3), 1);
        assert_eq!(fp.unit_layer(3), 0);
        assert_eq!(fp.producer(3), None);
        assert_eq!(fp.producer(4), Some(3));
    }

    #[test]
    fn frame_plan_admission_thresholds() {
        let plan = frame_plan_fixture();
        let fp = FramePlan::new(&plan, 2);
        // First layers need nothing.
        assert_eq!(fp.need_acts(0, 0), 0);
        assert_eq!(fp.need_acts(3, 0), 0);
        // Conv consumer: monotone in VDP index, never above the producer's
        // activation count, and strictly positive (can't start on nothing).
        let produced = fp.layer_plan(0).vdp_count();
        let mut last = 0;
        for v in 0..fp.layer_plan(1).vdp_count() {
            let need = fp.need_acts(1, v);
            assert!(need >= last, "admission must be monotone");
            assert!(need >= 1 && need <= produced);
            last = need;
        }
        assert_eq!(last, produced, "last raster position drains the map");
        // FC consumer reads the whole input map.
        let c2_vdps = fp.layer_plan(1).vdp_count();
        assert_eq!(fp.need_acts(2, 0), c2_vdps);
    }

    #[test]
    fn frame_stream_carries_frame_indexed_cursors() {
        let plan = frame_plan_fixture();
        let fp = FramePlan::new(&plan, 2);
        let mut fs = FrameStream::new(&fp);
        // Same layer, different frames: independent cursors.
        let a = fs.next_for(&fp, 0, 0).unwrap();
        let b = fs.next_for(&fp, 3, 0).unwrap();
        assert_eq!(a, b, "frame 1 re-streams the same compiled layer");
        assert_eq!(fs.issued(0), 1);
        assert_eq!(fs.issued(3), 1);
        assert_eq!(fs.peek_for(&fp, 0, 0), fs.peek_for(&fp, 3, 0));
        // Draining unit 0 on one XPE advances first_open past it.
        let flat = 0;
        while fs.next_for(&fp, 0, flat).is_some() {}
        assert!(fs.exhausted_for(&fp, 0, flat));
        fs.advance_first_open(&fp, flat);
        assert!(fs.first_open(flat) >= 1);
    }
}
