//! # Compiled execution plans
//!
//! The event-driven simulator used to rebuild — and materialize — the
//! full VDP-to-XPE schedule for every layer of every run: one heap
//! struct per PASS (~millions for a real VGG conv layer), cloned again
//! into the per-XPE queues. This module replaces that with a compile →
//! cache → stream lifecycle:
//!
//! 1. **Compile** ([`ExecutionPlan::compile`]): resolve the mapping of a
//!    whole workload onto an accelerator once. Both mapping policies are
//!    pure index maps, so a [`LayerPlan`] stores only the geometry and
//!    slice table — O(slices) per layer, no per-pass state.
//! 2. **Cache** ([`PlanCache`]): plans are memoized by
//!    `(accelerator, workload, policy)` and shared via `Arc` across
//!    [`crate::api::Session`]s, parallel sweep cells, and the serving
//!    coordinator's replicas.
//! 3. **Stream** ([`PassStream`]): during simulation each XPE pulls its
//!    next [`crate::mapping::scheduler::ScheduledPass`] in O(1); total
//!    live state is one cursor per XPE.
//!
//! The legacy materializer `Schedule::plan` remains as the independent
//! reference implementation — [`LayerPlan::materialize`] exposes it for
//! the property tests that prove stream/materialized equivalence.

pub mod cache;
pub mod stream;

pub use cache::PlanCache;
pub use stream::{LayerPlan, PassStream};

use crate::arch::accelerator::AcceleratorConfig;
use crate::mapping::scheduler::MappingPolicy;
use crate::workloads::Workload;

/// A whole workload compiled onto one accelerator under one mapping
/// policy: the unit the event backend simulates and the [`PlanCache`]
/// shares.
///
/// Invariant: `layers[i].layer` is a copy of `workload.layers[i]` — the
/// frame chain reads `workload`, the per-layer simulation reads
/// `layers[i]`, and [`ExecutionPlan::compile`] (the only intended
/// constructor) keeps the two views identical. Don't assemble one by
/// hand from mismatched parts.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    /// The accelerator the plan was compiled for (timing + energy come
    /// from here; the mapping uses its N / M / XPC geometry).
    pub accelerator: AcceleratorConfig,
    /// The workload's layer geometry (layer order defines frame order).
    pub workload: Workload,
    pub policy: MappingPolicy,
    /// One compiled pass map per workload layer, in frame order.
    pub layers: Vec<LayerPlan>,
}

impl ExecutionPlan {
    /// Compile `workload` onto `cfg` under `policy`. Cheap: O(layers ·
    /// slices), no per-pass allocation.
    pub fn compile(
        cfg: &AcceleratorConfig,
        workload: &Workload,
        policy: MappingPolicy,
    ) -> ExecutionPlan {
        let (n, m, xpcs) = (cfg.n, cfg.m(), cfg.xpc_count());
        let layers = workload
            .layers
            .iter()
            .map(|l| LayerPlan::compile(l, policy, n, m, xpcs))
            .collect();
        ExecutionPlan {
            accelerator: cfg.clone(),
            workload: workload.clone(),
            policy,
            layers,
        }
    }

    /// Total passes across the frame.
    pub fn total_passes(&self) -> usize {
        self.layers.iter().map(|l| l.total_passes()).sum()
    }

    /// Longest per-XPE queue across all layers (peak queue length).
    pub fn max_queue_len(&self) -> usize {
        self.layers.iter().map(|l| l.max_queue_len()).max().unwrap_or(0)
    }

    /// Peak live simulator state under streaming (layers run one at a
    /// time, so the peak is the largest layer's state).
    pub fn streamed_state_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.streamed_state_bytes())
            .max()
            .unwrap_or(0)
    }

    /// Peak live state the old materialized path held (largest layer's
    /// schedule + cloned queues).
    pub fn materialized_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.materialized_bytes())
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::layer::GemmLayer;

    #[test]
    fn compile_covers_every_layer() {
        let cfg = AcceleratorConfig::oxbnn_5();
        let wl = Workload::new(
            "t",
            vec![GemmLayer::new("a", 4, 120, 3), GemmLayer::fc("b", 64, 10)],
        );
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
        assert_eq!(plan.layers.len(), 2);
        assert_eq!(
            plan.total_passes(),
            wl.layers.iter().map(|l| l.total_passes(cfg.n)).sum::<usize>()
        );
        assert!(plan.max_queue_len() > 0);
        assert!(plan.streamed_state_bytes() > 0);
        assert!(plan.materialized_bytes() >= plan.streamed_state_bytes());
    }
}
