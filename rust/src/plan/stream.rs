//! Per-layer compiled pass maps and the streaming cursor over them.
//!
//! Both mapping policies are pure index maps (paper Fig. 5):
//!
//! * [`MappingPolicy::PcaLocal`] — VDP `v` lives on XPE `v % T`; its
//!   slices run back-to-back, so the k-th pass on XPE `x` is slice
//!   `k % slices` of VDP `x + (k / slices)·T`.
//! * [`MappingPolicy::SlicedSpread`] — global slice id `g = v·slices + j`
//!   lives on XPE `g % T`, so the k-th pass on XPE `x` is global slice
//!   `x + k·T`.
//!
//! Nothing therefore needs materializing: [`LayerPlan::pass_at`] computes
//! any XPE's next pass in O(1), and [`PassStream`] keeps only one cursor
//! per XPE — O(#XPEs) state for a layer of millions of passes, where the
//! old `Schedule::plan` heap-allocated one `ScheduledPass` per pass (and
//! `LayerWorld` then *cloned* every queue). `Schedule::plan` survives as
//! the independently-written materialized reference that the property
//! tests check this module against.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::mapping::layer::GemmLayer;
use crate::mapping::scheduler::{MappingPolicy, Schedule, ScheduledPass};
use crate::sim::event::{VdpId, XpeId};

/// One layer's compiled mapping onto an accelerator's XPE grid: geometry
/// plus the closed-form pass map. Cheap to build (O(slices) for the slice
/// length table) and cheap to hold.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    /// The GEMM geometry this plan maps (kept for names/operand sizes).
    pub layer: GemmLayer,
    pub policy: MappingPolicy,
    /// XPE size N the slicing was computed for.
    pub n: usize,
    /// XPEs per XPC (M).
    pub m: usize,
    /// XPC count; the pass map spans `m * xpc_count` XPE slots (the last
    /// XPC may be partially populated, matching `Schedule::plan`).
    pub xpc_count: usize,
    /// Slice lengths per VDP: all N except a possibly-smaller tail.
    slice_lens: Vec<usize>,
}

impl LayerPlan {
    /// Compile the pass map for `layer` on an accelerator with
    /// `xpc_count` XPCs of `m` XPEs each, XPE size `n`.
    pub fn compile(
        layer: &GemmLayer,
        policy: MappingPolicy,
        n: usize,
        m: usize,
        xpc_count: usize,
    ) -> LayerPlan {
        assert!(n > 0 && m > 0 && xpc_count > 0);
        LayerPlan {
            layer: layer.clone(),
            policy,
            n,
            m,
            xpc_count,
            slice_lens: crate::mapping::slicing::slice_sizes(layer.s, n),
        }
    }

    /// XPE slots the pass map spans (`m * xpc_count`).
    pub fn total_xpes(&self) -> usize {
        self.m * self.xpc_count
    }

    /// Slices per VDP (`ceil(S/N)`).
    pub fn slices(&self) -> usize {
        self.slice_lens.len()
    }

    /// VDPs in the layer.
    pub fn vdp_count(&self) -> usize {
        self.layer.vdp_count()
    }

    /// Total passes across all XPEs (`VDPs · slices`).
    pub fn total_passes(&self) -> usize {
        self.vdp_count() * self.slices()
    }

    /// Flat index of an XPE id.
    pub fn flat(&self, id: XpeId) -> usize {
        id.xpc * self.m + id.xpe
    }

    /// XPE id of a flat index.
    pub fn xpe_id(&self, flat: usize) -> XpeId {
        XpeId { xpc: flat / self.m, xpe: flat % self.m }
    }

    /// Number of passes queued on the XPE at `flat` — O(1).
    pub fn queue_len(&self, flat: usize) -> usize {
        let t = self.total_xpes();
        match self.policy {
            MappingPolicy::PcaLocal => {
                // VDPs v ≡ flat (mod T), each contributing all slices.
                let v = self.vdp_count();
                if flat >= v {
                    0
                } else {
                    (v - flat).div_ceil(t) * self.slices()
                }
            }
            MappingPolicy::SlicedSpread => {
                // Global slice ids g ≡ flat (mod T).
                let g = self.total_passes();
                if flat >= g {
                    0
                } else {
                    (g - flat).div_ceil(t)
                }
            }
        }
    }

    /// Longest single-XPE queue — the critical path in PASS counts. XPE 0
    /// always has the (possibly tied) longest queue under both modular
    /// assignments.
    pub fn max_queue_len(&self) -> usize {
        self.queue_len(0)
    }

    /// The k-th pass on the XPE at `flat`, or `None` past the end of its
    /// queue — O(1), allocation-free.
    pub fn pass_at(&self, flat: usize, k: usize) -> Option<ScheduledPass> {
        if k >= self.queue_len(flat) {
            return None;
        }
        let t = self.total_xpes();
        let slices = self.slices();
        let (vdp, slice_idx) = match self.policy {
            MappingPolicy::PcaLocal => (flat + (k / slices) * t, k % slices),
            MappingPolicy::SlicedSpread => {
                let g = flat + k * t;
                (g / slices, g % slices)
            }
        };
        Some(ScheduledPass {
            vdp: VdpId(vdp),
            slice_idx,
            slice_len: self.slice_lens[slice_idx],
        })
    }

    /// Event budget generous enough for any well-formed run of this layer
    /// (each pass triggers at most a handful of follow-up events).
    pub fn event_budget(&self) -> u64 {
        self.total_passes() as u64 * 8 + 10_000
    }

    /// Materialize the full per-XPE queues via the legacy
    /// [`Schedule::plan`] — test/debug only; this allocates one struct
    /// per pass, which is exactly what the streaming path avoids.
    pub fn materialize(&self) -> Schedule {
        Schedule::plan(&self.layer, self.policy, self.n, self.m, self.xpc_count)
    }

    /// Heap bytes the old materialized path held live for this layer
    /// (the `Schedule` plus `LayerWorld`'s clone of every queue).
    pub fn materialized_bytes(&self) -> usize {
        2 * self.total_passes() * std::mem::size_of::<ScheduledPass>()
    }

    /// Heap bytes the streaming path holds live for this layer: one
    /// cursor per XPE, one completion counter per VDP, the slice table.
    pub fn streamed_state_bytes(&self) -> usize {
        (self.total_xpes() + self.vdp_count() + self.slices())
            * std::mem::size_of::<usize>()
    }
}

/// Streaming cursor over a [`LayerPlan`]: yields each XPE's next pass in
/// O(1) and tracks global completion in O(1). Total state: one `usize`
/// per XPE.
#[derive(Debug, Clone)]
pub struct PassStream {
    cursor: Vec<usize>,
    issued: usize,
    total: usize,
}

impl PassStream {
    pub fn new(plan: &LayerPlan) -> PassStream {
        PassStream {
            cursor: vec![0; plan.total_xpes()],
            issued: 0,
            total: plan.total_passes(),
        }
    }

    /// The next pass for the XPE at `flat`, advancing its cursor.
    pub fn next_for(&mut self, plan: &LayerPlan, flat: usize) -> Option<ScheduledPass> {
        let k = self.cursor[flat];
        let pass = plan.pass_at(flat, k)?;
        self.cursor[flat] = k + 1;
        self.issued += 1;
        Some(pass)
    }

    /// The next pass for the XPE at `flat` WITHOUT advancing its cursor —
    /// the frame-scoped world peeks to decide admission (is this pass's
    /// input feature-map prefix drained yet?) before committing the XPE.
    pub fn peek_for(&self, plan: &LayerPlan, flat: usize) -> Option<ScheduledPass> {
        plan.pass_at(flat, self.cursor[flat])
    }

    /// True once the XPE at `flat` has drained its whole queue.
    pub fn exhausted_for(&self, plan: &LayerPlan, flat: usize) -> bool {
        self.cursor[flat] >= plan.queue_len(flat)
    }

    /// Passes still queued for the XPE at `flat` — the closed-form
    /// remaining cost a work-stealing scheduler compares against an
    /// expected stall, O(1) off the compiled pass map.
    pub fn remaining_for(&self, plan: &LayerPlan, flat: usize) -> usize {
        plan.queue_len(flat).saturating_sub(self.cursor[flat])
    }

    /// Passes handed out so far.
    pub fn issued(&self) -> usize {
        self.issued
    }

    /// True once every XPE's queue is exhausted — O(1) (the old
    /// materialized world scanned every XPE per psum event).
    pub fn all_issued(&self) -> bool {
        self.issued >= self.total
    }
}

/// Streaming cursors over a whole [`super::FramePlan`]: one [`PassStream`]
/// per `(frame, layer)` unit — the cursor set therefore carries a frame
/// index, which is what lets frame `f+1`'s early layers stream into XPEs
/// idled by frame `f`'s tail — plus the per-XPE scheduling residue the
/// frame-scoped event world needs:
///
/// * `locked[x]` — the unit whose VDP is mid-flight on XPE `x`. Under
///   [`MappingPolicy::PcaLocal`] an XPE must finish all slices of a VDP
///   back-to-back (the PCA accumulates them in the analog domain), so the
///   XPE may not switch units between slices.
/// * `first_open[x]` — the earliest unit (in frame-major order) that still
///   has passes queued for XPE `x`; units fully drained on an XPE are
///   skipped permanently, keeping the per-dispatch unit scan short.
/// * the **wake index** — per unit, a min-heap of `(admission threshold,
///   XPE)` for XPEs whose head pass is blocked on the producer's
///   activation drain ([`super::FramePlan::need_acts`]). An activation
///   drain pops exactly the waiters whose threshold is now met — O(woken
///   · log waiters) instead of re-dispatching every idle XPE. An idle XPE
///   waiting on admission has a *stable* head pass (only the XPE itself
///   advances its cursors), so an enqueued threshold can never go stale.
///
/// Total state: `O(units · XPEs)` cursors — still no per-pass allocation.
#[derive(Debug, Clone)]
pub struct FrameStream {
    streams: Vec<PassStream>,
    locked: Vec<Option<usize>>,
    first_open: Vec<usize>,
    /// Per consumer unit: blocked XPEs keyed by their head-pass admission
    /// threshold (min-heap).
    waiters: Vec<BinaryHeap<Reverse<(usize, usize)>>>,
    /// The unit each XPE is parked under, if any — guards against double
    /// registration when unrelated events re-dispatch idle XPEs.
    waiting_on: Vec<Option<usize>>,
}

impl FrameStream {
    /// One cursor set per unit of `fp`, all XPEs unlocked.
    pub fn new(fp: &super::FramePlan<'_>) -> FrameStream {
        let xpes = fp.total_xpes();
        FrameStream {
            streams: (0..fp.units()).map(|u| PassStream::new(fp.layer_plan(u))).collect(),
            locked: vec![None; xpes],
            first_open: vec![0; xpes],
            waiters: (0..fp.units()).map(|_| BinaryHeap::new()).collect(),
            waiting_on: vec![None; xpes],
        }
    }

    /// The next pass of `unit` on XPE `flat`, advancing that unit's
    /// cursor. `flat` indexes the whole shard group's slot space; the
    /// unit's pass map is indexed by its chip-local slot.
    pub fn next_for(
        &mut self,
        fp: &super::FramePlan<'_>,
        unit: usize,
        flat: usize,
    ) -> Option<ScheduledPass> {
        self.streams[unit].next_for(fp.layer_plan(unit), fp.local_flat(unit, flat))
    }

    /// Peek the next pass of `unit` on XPE `flat` without advancing.
    pub fn peek_for(
        &self,
        fp: &super::FramePlan<'_>,
        unit: usize,
        flat: usize,
    ) -> Option<ScheduledPass> {
        self.streams[unit].peek_for(fp.layer_plan(unit), fp.local_flat(unit, flat))
    }

    /// True once `unit` has no passes left for XPE `flat`.
    pub fn exhausted_for(&self, fp: &super::FramePlan<'_>, unit: usize, flat: usize) -> bool {
        self.streams[unit].exhausted_for(fp.layer_plan(unit), fp.local_flat(unit, flat))
    }

    /// Passes `unit` still has queued for XPE `flat` — closed-form, O(1).
    pub fn remaining_for(&self, fp: &super::FramePlan<'_>, unit: usize, flat: usize) -> usize {
        self.streams[unit].remaining_for(fp.layer_plan(unit), fp.local_flat(unit, flat))
    }

    /// Passes issued so far by `unit` (all XPEs).
    pub fn issued(&self, unit: usize) -> usize {
        self.streams[unit].issued()
    }

    /// True once every pass of `unit` has been issued.
    pub fn all_issued(&self, unit: usize) -> bool {
        self.streams[unit].all_issued()
    }

    /// The unit XPE `flat` must keep servicing (a VDP is mid-flight).
    pub fn locked(&self, flat: usize) -> Option<usize> {
        self.locked[flat]
    }

    pub fn set_locked(&mut self, flat: usize, unit: Option<usize>) {
        self.locked[flat] = unit;
    }

    /// Earliest unit that may still have passes for XPE `flat`.
    pub fn first_open(&self, flat: usize) -> usize {
        self.first_open[flat]
    }

    /// Permanently skip leading units XPE `flat` will never service:
    /// drained units, and (under LayerPipeline sharding) units staged on
    /// a different chip.
    pub fn advance_first_open(&mut self, fp: &super::FramePlan<'_>, flat: usize) {
        while self.first_open[flat] < self.streams.len()
            && (!fp.eligible(self.first_open[flat], flat)
                || self.exhausted_for(fp, self.first_open[flat], flat))
        {
            self.first_open[flat] += 1;
        }
    }

    /// Park XPE `flat` on consumer `unit` until the producer has drained
    /// `need` activations. The caller must not register an XPE twice.
    pub fn register_waiter(&mut self, unit: usize, need: usize, flat: usize) {
        debug_assert!(
            self.waiting_on[flat].is_none(),
            "XPE {} registered twice (already on unit {:?})",
            flat,
            self.waiting_on[flat]
        );
        self.waiters[unit].push(Reverse((need, flat)));
        self.waiting_on[flat] = Some(unit);
    }

    /// The consumer unit XPE `flat` is parked on, if any.
    pub fn waiting_on(&self, flat: usize) -> Option<usize> {
        self.waiting_on[flat]
    }

    /// Pop every XPE parked on `unit` whose admission threshold is covered
    /// by `acts_done` producer activations, unparking them. Returns the
    /// woken XPEs (the whole point: O(woken), not O(idle)).
    pub fn pop_admitted(&mut self, unit: usize, acts_done: usize) -> Vec<usize> {
        let mut woken = Vec::new();
        while let Some(&Reverse((need, flat))) = self.waiters[unit].peek() {
            if need > acts_done {
                break;
            }
            self.waiters[unit].pop();
            self.waiting_on[flat] = None;
            woken.push(flat);
        }
        woken
    }

    /// XPEs currently parked on admission thresholds (diagnostics).
    pub fn waiting_count(&self) -> usize {
        self.waiting_on.iter().filter(|w| w.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(plan: &LayerPlan, flat: usize) -> Vec<ScheduledPass> {
        let mut out = Vec::new();
        let mut k = 0;
        while let Some(p) = plan.pass_at(flat, k) {
            out.push(p);
            k += 1;
        }
        out
    }

    #[test]
    fn fig5b_pca_local_matches_materialized() {
        // Fig. 5(b): M=2, H=2, N=9, S=15 — both slices of each VDP stay
        // on one XPE, identically to Schedule::plan.
        let layer = GemmLayer::new("fig5", 2, 15, 1);
        let plan = LayerPlan::compile(&layer, MappingPolicy::PcaLocal, 9, 2, 1);
        let sched = plan.materialize();
        assert_eq!(drain(&plan, 0), sched.queues[0][0]);
        assert_eq!(drain(&plan, 1), sched.queues[0][1]);
        assert_eq!(plan.queue_len(0), 2);
        assert_eq!(plan.total_passes(), 4);
    }

    #[test]
    fn fig5a_sliced_spread_matches_materialized() {
        let layer = GemmLayer::new("fig5", 2, 15, 1);
        let plan = LayerPlan::compile(&layer, MappingPolicy::SlicedSpread, 9, 2, 1);
        let sched = plan.materialize();
        for (id, q) in sched.iter_queues() {
            assert_eq!(&drain(&plan, plan.flat(id)), q);
        }
    }

    #[test]
    fn queue_lens_sum_to_total_passes() {
        for policy in [MappingPolicy::PcaLocal, MappingPolicy::SlicedSpread] {
            let layer = GemmLayer::new("t", 13, 200, 7);
            let plan = LayerPlan::compile(&layer, policy, 9, 4, 3);
            let sum: usize = (0..plan.total_xpes()).map(|x| plan.queue_len(x)).sum();
            assert_eq!(sum, plan.total_passes(), "{:?}", policy);
            assert_eq!(plan.max_queue_len(), plan.queue_len(0));
            assert!((0..plan.total_xpes())
                .all(|x| plan.queue_len(x) <= plan.max_queue_len()));
        }
    }

    #[test]
    fn stream_drains_exactly_once() {
        let layer = GemmLayer::new("t", 5, 40, 3);
        let plan = LayerPlan::compile(&layer, MappingPolicy::PcaLocal, 9, 3, 2);
        let mut stream = PassStream::new(&plan);
        let mut n = 0;
        // Round-robin over XPEs, as the event loop effectively does.
        loop {
            let before = n;
            for x in 0..plan.total_xpes() {
                if stream.next_for(&plan, x).is_some() {
                    n += 1;
                }
            }
            if n == before {
                break;
            }
        }
        assert_eq!(n, plan.total_passes());
        assert!(stream.all_issued());
        assert!(stream.next_for(&plan, 0).is_none());
    }

    #[test]
    fn vgg_scale_plan_is_small() {
        // The motivating case: a VGG conv layer that used to cost ~2.9M
        // heap structs (×2 for the cloned queues) now costs ~1 MB of
        // cursors + VDP counters.
        let layer = GemmLayer::new("vgg_conv2", 1024, 1152, 128);
        let plan = LayerPlan::compile(&layer, MappingPolicy::PcaLocal, 53, 53, 2);
        assert_eq!(plan.total_passes(), 1024 * 128 * 22);
        assert!(plan.materialized_bytes() / plan.streamed_state_bytes() >= 10);
        // Spot-check a deep pass without materializing anything.
        let p = plan.pass_at(0, 22 * 100 + 7).unwrap();
        assert_eq!(p.vdp, VdpId(100 * plan.total_xpes()));
        assert_eq!(p.slice_idx, 7);
    }
}
