//! MobileNetV2 (Sandler et al., CVPR 2018) on 224×224×3, binarized.
//! Inverted-residual bottlenecks: 1×1 expand (×t), 3×3 depthwise, 1×1
//! project. Depthwise convs map to per-channel VDPs of size 9
//! (`GemmLayer::depthwise`); they are the reason MobileNet stresses
//! accelerators with many tiny-S slices.

use super::Workload;
use crate::mapping::layer::{ConvGeom, GemmLayer};

/// Standard MobileNetV2 bottleneck table: (expansion t, out channels c,
/// repeats n, first-stride s).
const BOTTLENECKS: [(usize, usize, usize, usize); 7] = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

pub fn mobilenet_v2() -> Workload {
    let mut layers = Vec::new();
    // Stem: 3×3/2, 3→32, output 112².
    layers.push(
        GemmLayer::new("conv1", 112 * 112, 27, 32)
            .with_geom(ConvGeom::new(3, 2, 1, 224)),
    );
    let mut hw = 112usize;
    let mut cin = 32usize;
    let mut block = 0usize;
    for (t, c, n, first_stride) in BOTTLENECKS {
        for rep in 0..n {
            let stride = if rep == 0 { first_stride } else { 1 };
            let out_hw = hw / stride;
            let expanded = cin * t;
            block += 1;
            if t != 1 {
                layers.push(
                    GemmLayer::new(format!("b{}.expand", block), hw * hw, cin, expanded)
                        .with_geom(ConvGeom::new(1, 1, 0, hw)),
                );
            }
            layers.push(
                GemmLayer::depthwise(format!("b{}.dw", block), out_hw, expanded, 3)
                    .with_geom(ConvGeom::new(3, stride, 1, hw)),
            );
            layers.push(
                GemmLayer::new(format!("b{}.project", block), out_hw * out_hw, expanded, c)
                    .with_geom(ConvGeom::new(1, 1, 0, out_hw)),
            );
            hw = out_hw;
            cin = c;
        }
    }
    // Head: 1×1 to 1280, global pool, FC-1000.
    layers.push(
        GemmLayer::new("conv_last", 7 * 7, 320, 1280)
            .with_geom(ConvGeom::new(1, 1, 0, 7)),
    );
    layers.push(GemmLayer::fc("fc", 1280, 1000));
    Workload::new("mobilenet_v2", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_structure() {
        let w = mobilenet_v2();
        // 17 bottlenecks; the first (t=1) has 2 layers, the rest 3.
        // 1 stem + (2 + 16·3) + conv_last + fc = 53 layers.
        assert_eq!(w.layers.len(), 1 + 2 + 16 * 3 + 1 + 1);
    }

    #[test]
    fn total_macs_published() {
        // Published: ≈ 0.30 GMACs.
        let g = mobilenet_v2().total_bitops() as f64;
        assert!((g - 0.30e9).abs() / 0.30e9 < 0.15, "bitops = {}", g);
    }

    #[test]
    fn depthwise_layers_have_s9() {
        let w = mobilenet_v2();
        let dw: Vec<&GemmLayer> =
            w.layers.iter().filter(|l| l.name.ends_with(".dw")).collect();
        assert_eq!(dw.len(), 17);
        assert!(dw.iter().all(|l| l.s == 9 && l.k == 1));
    }

    #[test]
    fn max_conv_s_under_paper_bound() {
        assert!(mobilenet_v2().max_conv_s() <= 4608);
    }

    #[test]
    fn conv_geometry_carried_and_consistent() {
        let w = mobilenet_v2();
        for l in &w.layers {
            if l.h == 1 {
                assert!(l.geom.is_none(), "{}: FC carries no window", l.name);
                continue;
            }
            let g = l.geom.expect("every conv/depthwise layer carries its window");
            let out = g.out_hw();
            // Regular convs raster one VDP set per position; depthwise
            // flattens (position, channel) pairs position-major.
            assert_eq!(l.vdp_count() % (out * out), 0, "{}", l.name);
            if l.name.ends_with(".dw") {
                assert_eq!((g.kernel, g.padding), (3, 1), "{}", l.name);
            } else {
                assert_eq!(l.h, out * out, "{}", l.name);
            }
        }
        // The stride-2 depthwise windows exist (blocks 2, 4, 8, 14).
        let strided = w
            .layers
            .iter()
            .filter(|l| l.name.ends_with(".dw") && l.geom.unwrap().stride == 2)
            .count();
        assert_eq!(strided, 4);
    }
}
