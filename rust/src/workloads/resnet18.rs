//! ResNet18 (He et al., CVPR 2016) on 224×224×3 ImageNet input,
//! binarized. Standard geometry: 7×7/2 stem, four stages of two basic
//! blocks (64, 128, 256, 512 channels; stages 2–4 downsample with
//! stride-2 first conv + 1×1 shortcut projection), global pool, FC-1000.

use super::Workload;
use crate::mapping::layer::{ConvGeom, GemmLayer};

pub fn resnet18() -> Workload {
    let mut layers = Vec::new();
    // Stem: 7×7/2, 3→64, output 112×112, then 3×3/2 max pool → 56×56.
    layers.push(
        GemmLayer::new("conv1", 112 * 112, 7 * 7 * 3, 64)
            .with_geom(ConvGeom::new(7, 2, 3, 224))
            .with_pool(),
    );

    // (stage, out_hw, in_c, out_c, downsample?)
    let stages = [
        (1, 56usize, 64usize, 64usize, false),
        (2, 28, 64, 128, true),
        (3, 14, 128, 256, true),
        (4, 7, 256, 512, true),
    ];
    for (si, hw, cin, cout, down) in stages {
        let h = hw * hw;
        // Downsampling stages halve the map in block 1's first conv
        // (3×3 stride 2 from the previous stage's 2·hw map).
        let in_hw1 = if down { hw * 2 } else { hw };
        let stride1 = if down { 2 } else { 1 };
        // Block 1.
        layers.push(
            GemmLayer::new(format!("stage{}.b1.conv1", si), h, 3 * 3 * cin, cout)
                .with_geom(ConvGeom::new(3, stride1, 1, in_hw1)),
        );
        layers.push(
            GemmLayer::new(format!("stage{}.b1.conv2", si), h, 3 * 3 * cout, cout)
                .with_geom(ConvGeom::new(3, 1, 1, hw)),
        );
        if down {
            // 1×1 stride-2 projection shortcut. Its true input is the
            // stage input (the 2·hw map), which is NOT its predecessor in
            // this flattened chain — the pipelined admission rule detects
            // the mismatch and falls back to the whole-map wait.
            layers.push(
                GemmLayer::new(format!("stage{}.b1.down", si), h, cin, cout)
                    .with_geom(ConvGeom::new(1, 2, 0, hw * 2)),
            );
        }
        // Block 2.
        layers.push(
            GemmLayer::new(format!("stage{}.b2.conv1", si), h, 3 * 3 * cout, cout)
                .with_geom(ConvGeom::new(3, 1, 1, hw)),
        );
        layers.push(
            GemmLayer::new(format!("stage{}.b2.conv2", si), h, 3 * 3 * cout, cout)
                .with_geom(ConvGeom::new(3, 1, 1, hw)),
        );
    }
    layers.push(GemmLayer::fc("fc", 512, 1000));
    Workload::new("resnet18", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        // 1 stem + 4 stages × (4 convs + downsample for 3 stages) + fc
        // = 1 + (4 + 5 + 5 + 5) + 1 + ... : stage1 has 4, stages 2-4 have 5.
        assert_eq!(resnet18().layers.len(), 1 + 4 + 5 + 5 + 5 + 1);
    }

    #[test]
    fn total_macs_published() {
        // Published: ≈ 1.82 GMACs for ResNet18 at 224².
        let g = resnet18().total_bitops() as f64;
        assert!((g - 1.82e9).abs() / 1.82e9 < 0.1, "bitops = {}", g);
    }

    #[test]
    fn max_conv_s_is_4608() {
        // Stage 4's 3×3×512 convs: S = 4608 — the paper's cited maximum.
        assert_eq!(resnet18().max_conv_s(), 4608);
    }

    #[test]
    fn stem_dominates_h() {
        let w = resnet18();
        assert_eq!(w.layers[0].h, 12544);
        assert!(w.layers.iter().all(|l| l.h <= 12544));
    }

    #[test]
    fn conv_geometry_carried_and_consistent() {
        let w = resnet18();
        for l in &w.layers {
            if l.h == 1 {
                assert!(l.geom.is_none(), "{}: FC carries no window", l.name);
            } else {
                let g = l.geom.expect("every conv layer carries its window");
                let out = g.out_hw();
                assert_eq!(l.h, out * out, "{}: H must raster the output map", l.name);
            }
        }
        // The stem's strided 7×7 window.
        let g = w.layers[0].geom.unwrap();
        assert_eq!((g.kernel, g.stride, g.padding, g.in_hw), (7, 2, 3, 224));
    }
}
