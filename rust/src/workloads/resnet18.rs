//! ResNet18 (He et al., CVPR 2016) on 224×224×3 ImageNet input,
//! binarized. Standard geometry: 7×7/2 stem, four stages of two basic
//! blocks (64, 128, 256, 512 channels; stages 2–4 downsample with
//! stride-2 first conv + 1×1 shortcut projection), global pool, FC-1000.

use super::Workload;
use crate::mapping::layer::GemmLayer;

pub fn resnet18() -> Workload {
    let mut layers = Vec::new();
    // Stem: 7×7/2, 3→64, output 112×112, then 3×3/2 max pool → 56×56.
    layers.push(GemmLayer::new("conv1", 112 * 112, 7 * 7 * 3, 64).with_pool());

    // (stage, out_hw, in_c, out_c, downsample?)
    let stages = [
        (1, 56usize, 64usize, 64usize, false),
        (2, 28, 64, 128, true),
        (3, 14, 128, 256, true),
        (4, 7, 256, 512, true),
    ];
    for (si, hw, cin, cout, down) in stages {
        let h = hw * hw;
        // Block 1.
        layers.push(GemmLayer::new(
            format!("stage{}.b1.conv1", si),
            h,
            3 * 3 * cin,
            cout,
        ));
        layers.push(GemmLayer::new(
            format!("stage{}.b1.conv2", si),
            h,
            3 * 3 * cout,
            cout,
        ));
        if down {
            // 1×1 stride-2 projection shortcut.
            layers.push(GemmLayer::new(format!("stage{}.b1.down", si), h, cin, cout));
        }
        // Block 2.
        layers.push(GemmLayer::new(
            format!("stage{}.b2.conv1", si),
            h,
            3 * 3 * cout,
            cout,
        ));
        layers.push(GemmLayer::new(
            format!("stage{}.b2.conv2", si),
            h,
            3 * 3 * cout,
            cout,
        ));
    }
    layers.push(GemmLayer::fc("fc", 512, 1000));
    Workload::new("resnet18", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        // 1 stem + 4 stages × (4 convs + downsample for 3 stages) + fc
        // = 1 + (4 + 5 + 5 + 5) + 1 + ... : stage1 has 4, stages 2-4 have 5.
        assert_eq!(resnet18().layers.len(), 1 + 4 + 5 + 5 + 5 + 1);
    }

    #[test]
    fn total_macs_published() {
        // Published: ≈ 1.82 GMACs for ResNet18 at 224².
        let g = resnet18().total_bitops() as f64;
        assert!((g - 1.82e9).abs() / 1.82e9 < 0.1, "bitops = {}", g);
    }

    #[test]
    fn max_conv_s_is_4608() {
        // Stage 4's 3×3×512 convs: S = 4608 — the paper's cited maximum.
        assert_eq!(resnet18().max_conv_s(), 4608);
    }

    #[test]
    fn stem_dominates_h() {
        let w = resnet18();
        assert_eq!(w.layers[0].h, 12544);
        assert!(w.layers.iter().all(|l| l.h <= 12544));
    }
}
