//! Workload zoo: the four BNNs of the paper's evaluation (Section V-B),
//! binarized with LQ-Nets — VGG-small, ResNet18, MobileNetV2 and
//! ShuffleNetV2 — expressed as flattened GEMM-layer geometry.
//!
//! FPS/FPS-per-W depend only on layer geometry (H, S, K per layer), not on
//! trained weight values (DESIGN.md substitution table), so the builders
//! here encode the architectures' shapes. Structural tests pin total
//! MAC counts against the published numbers.

pub mod mobilenet_v2;
pub mod resnet18;
pub mod shufflenet_v2;
pub mod vgg_small;
pub mod zoo;

use crate::mapping::layer::GemmLayer;

/// A BNN inference workload: ordered layers of one frame (batch = 1).
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub layers: Vec<GemmLayer>,
}

impl Workload {
    pub fn new(name: impl Into<String>, layers: Vec<GemmLayer>) -> Workload {
        let w = Workload { name: name.into(), layers };
        assert!(!w.layers.is_empty(), "empty workload");
        w
    }

    /// Total 1-bit XNOR ops (== MACs of the float model).
    pub fn total_bitops(&self) -> u64 {
        self.layers.iter().map(|l| l.bitops()).sum()
    }

    /// Largest flattened vector size across layers.
    pub fn max_s(&self) -> usize {
        self.layers.iter().map(|l| l.s).max().unwrap()
    }

    /// Largest *conv* vector size (the paper's §IV-C claim concerns convs).
    pub fn max_conv_s(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| l.h > 1)
            .map(|l| l.s)
            .max()
            .unwrap_or(0)
    }

    /// The four evaluation workloads in paper order.
    pub fn evaluation_set() -> Vec<Workload> {
        vec![
            vgg_small::vgg_small(),
            resnet18::resnet18(),
            mobilenet_v2::mobilenet_v2(),
            shufflenet_v2::shufflenet_v2(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evaluation_set_has_four_bnns() {
        let set = Workload::evaluation_set();
        let names: Vec<&str> = set.iter().map(|w| w.name.as_str()).collect();
        assert_eq!(names, vec!["vgg_small", "resnet18", "mobilenet_v2", "shufflenet_v2"]);
    }

    #[test]
    fn paper_claim_max_conv_s_at_most_4608() {
        // §IV-C: max XNOR vector size observed across modern CNNs is 4608
        // — every conv layer must fit under γ(50 GS/s) = 8503.
        for w in Workload::evaluation_set() {
            assert!(
                w.max_conv_s() <= 4608,
                "{}: max conv S = {}",
                w.name,
                w.max_conv_s()
            );
            assert!(w.max_conv_s() < 8503);
        }
    }

    #[test]
    fn published_mac_counts_within_tolerance() {
        // Published multiply-accumulate counts (ops per frame):
        //   VGG-small (CIFAR-10) ≈ 0.57 G, ResNet18 (224²) ≈ 1.82 G,
        //   MobileNetV2 ≈ 0.30 G, ShuffleNetV2 1x ≈ 0.146 G.
        let expect = [
            ("vgg_small", 0.57e9, 0.15),
            ("resnet18", 1.82e9, 0.15),
            ("mobilenet_v2", 0.30e9, 0.25),
            ("shufflenet_v2", 0.146e9, 0.30),
        ];
        let set = Workload::evaluation_set();
        for (name, macs, tol) in expect {
            let w = set.iter().find(|w| w.name == name).unwrap();
            let got = w.total_bitops() as f64;
            let rel = (got - macs).abs() / macs;
            assert!(
                rel < tol,
                "{}: {} bitops vs published {} MACs (rel err {:.2})",
                name,
                got,
                macs,
                rel
            );
        }
    }

    #[test]
    fn all_layers_valid() {
        for w in Workload::evaluation_set() {
            for l in &w.layers {
                l.validate();
            }
            assert!(w.layers.len() >= 5, "{} too shallow", w.name);
        }
    }
}
