//! VGG-small (LQ-Nets variant for CIFAR-10, 32×32×3 input): six 3×3 convs
//! (128,128,256,256,512,512) with 2×2 pooling after every pair, then a
//! 10-way linear classifier. Geometry matches `python/compile/model.py`'s
//! `vgg_small` ModelSpec exactly (pinned by `test_model.py` on the python
//! side and the tests below on this side).

use super::Workload;
use crate::mapping::layer::GemmLayer;

pub fn vgg_small() -> Workload {
    let mut layers = Vec::new();
    // (out_hw, in_c, out_c, pool) per conv.
    let specs = [
        (32, 3, 128, false),
        (32, 128, 128, true),
        (16, 128, 256, false),
        (16, 256, 256, true),
        (8, 256, 512, false),
        (8, 512, 512, true),
    ];
    for (i, (hw, cin, cout, pool)) in specs.into_iter().enumerate() {
        let mut l = GemmLayer::conv(format!("conv{}", i + 1), hw, cin, 3, cout);
        if pool {
            l = l.with_pool();
        }
        layers.push(l);
    }
    // After three pools: 4×4×512 = 8192 features.
    layers.push(GemmLayer::fc("fc", 4 * 4 * 512, 10));
    Workload::new("vgg_small", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_matches_python_modelspec() {
        let w = vgg_small();
        let dims: Vec<(usize, usize, usize)> =
            w.layers.iter().map(|l| (l.h, l.s, l.k)).collect();
        assert_eq!(
            dims,
            vec![
                (1024, 27, 128),
                (1024, 1152, 128),
                (256, 1152, 256),
                (256, 2304, 256),
                (64, 2304, 512),
                (64, 4608, 512),
                (1, 8192, 10),
            ]
        );
    }

    #[test]
    fn max_conv_s_is_4608() {
        // This workload realizes the paper's §IV-C extreme: S = 4608.
        assert_eq!(vgg_small().max_conv_s(), 4608);
    }

    #[test]
    fn total_macs_published() {
        let g = vgg_small().total_bitops() as f64;
        assert!((g - 0.57e9).abs() / 0.57e9 < 0.1, "bitops = {}", g);
    }

    #[test]
    fn conv_geometry_chains_through_the_pools() {
        let w = vgg_small();
        for pair in w.layers.windows(2) {
            let (p, c) = (&pair[0], &pair[1]);
            if c.h == 1 {
                assert!(c.geom.is_none());
                continue;
            }
            let (pg, cg) = (p.geom.unwrap(), c.geom.unwrap());
            assert_eq!((cg.kernel, cg.stride, cg.padding), (3, 1, 1), "{}", c.name);
            // Consumer reads the producer's map, halved when pooled.
            let expect_in = if p.pool { pg.out_hw() / 2 } else { pg.out_hw() };
            assert_eq!(cg.in_hw, expect_in, "{} after {}", c.name, p.name);
        }
    }
}
