//! ShuffleNetV2 1× (Ma et al., ECCV 2018) on 224×224×3, binarized.
//! Channel-split units: the right branch runs 1×1 → 3×3 depthwise → 1×1 on
//! half the channels; stride-2 units process both branches and double the
//! channels. Stages: 116/232/464 channels with 4/8/4 units; 1×1 conv5 to
//! 1024; FC-1000.

use super::Workload;
use crate::mapping::layer::{ConvGeom, GemmLayer};

/// (stage index, out channels, units, out_hw).
const STAGES: [(usize, usize, usize, usize); 3] =
    [(2, 116, 4, 28), (3, 232, 8, 14), (4, 464, 4, 7)];

pub fn shufflenet_v2() -> Workload {
    let mut layers = Vec::new();
    // Stem: 3×3/2 conv to 24 channels (112²), then 3×3/2 max pool → 56².
    layers.push(
        GemmLayer::new("conv1", 112 * 112, 27, 24)
            .with_geom(ConvGeom::new(3, 2, 1, 224))
            .with_pool(),
    );
    let mut cin = 24usize;
    for (si, cout, units, out_hw) in STAGES {
        for u in 0..units {
            let half = cout / 2;
            if u == 0 {
                // Stride-2 unit: input hw = 2·out_hw, both branches run.
                let in_hw = out_hw * 2;
                let h_out = out_hw * out_hw;
                // Left branch: depthwise (on cin) + 1×1 → half.
                layers.push(
                    GemmLayer::depthwise(format!("s{}.u{}.l.dw", si, u), out_hw, cin, 3)
                        .with_geom(ConvGeom::new(3, 2, 1, in_hw)),
                );
                layers.push(
                    GemmLayer::new(format!("s{}.u{}.l.pw", si, u), h_out, cin, half)
                        .with_geom(ConvGeom::new(1, 1, 0, out_hw)),
                );
                // Right branch: 1×1 → dw/2 → 1×1. The 1×1's true input is
                // the unit input, not the left branch it follows in this
                // flattened chain; its honest geometry (the 2·out_hw map)
                // will not chain onto the left pw's map, so admission
                // falls back to the whole-map wait there.
                layers.push(
                    GemmLayer::new(
                        format!("s{}.u{}.r.pw1", si, u),
                        in_hw * in_hw,
                        cin,
                        half,
                    )
                    .with_geom(ConvGeom::new(1, 1, 0, in_hw)),
                );
                layers.push(
                    GemmLayer::depthwise(format!("s{}.u{}.r.dw", si, u), out_hw, half, 3)
                        .with_geom(ConvGeom::new(3, 2, 1, in_hw)),
                );
                layers.push(
                    GemmLayer::new(format!("s{}.u{}.r.pw2", si, u), h_out, half, half)
                        .with_geom(ConvGeom::new(1, 1, 0, out_hw)),
                );
            } else {
                // Stride-1 unit: split; only the right half (c/2) computes.
                let h = out_hw * out_hw;
                layers.push(
                    GemmLayer::new(format!("s{}.u{}.pw1", si, u), h, half, half)
                        .with_geom(ConvGeom::new(1, 1, 0, out_hw)),
                );
                layers.push(
                    GemmLayer::depthwise(format!("s{}.u{}.dw", si, u), out_hw, half, 3)
                        .with_geom(ConvGeom::new(3, 1, 1, out_hw)),
                );
                layers.push(
                    GemmLayer::new(format!("s{}.u{}.pw2", si, u), h, half, half)
                        .with_geom(ConvGeom::new(1, 1, 0, out_hw)),
                );
            }
        }
        cin = cout;
    }
    layers.push(
        GemmLayer::new("conv5", 7 * 7, 464, 1024).with_geom(ConvGeom::new(1, 1, 0, 7)),
    );
    layers.push(GemmLayer::fc("fc", 1024, 1000));
    Workload::new("shufflenet_v2", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_counts() {
        let w = shufflenet_v2();
        // stem + stage2 (5 + 3·3) + stage3 (5 + 7·3) + stage4 (5 + 3·3)
        // + conv5 + fc.
        let expect = 1 + (5 + 9) + (5 + 21) + (5 + 9) + 1 + 1;
        assert_eq!(w.layers.len(), expect);
    }

    #[test]
    fn total_macs_published() {
        // Published: ≈ 146 MMACs for ShuffleNetV2 1×.
        let g = shufflenet_v2().total_bitops() as f64;
        assert!((g - 0.146e9).abs() / 0.146e9 < 0.2, "bitops = {}", g);
    }

    #[test]
    fn conv_geometry_carried_and_consistent() {
        let w = shufflenet_v2();
        for l in &w.layers {
            if l.h == 1 {
                assert!(l.geom.is_none(), "{}: FC carries no window", l.name);
                continue;
            }
            let g = l.geom.expect("every conv/depthwise layer carries its window");
            let out = g.out_hw();
            assert_eq!(l.vdp_count() % (out * out), 0, "{}", l.name);
            if !l.name.contains(".dw") {
                assert_eq!(l.h, out * out, "{}", l.name);
            }
        }
    }

    #[test]
    fn lightest_of_the_four() {
        let all = Workload::evaluation_set();
        let shuffle = all.iter().find(|w| w.name == "shufflenet_v2").unwrap();
        for other in &all {
            assert!(shuffle.total_bitops() <= other.total_bitops());
        }
    }
}
