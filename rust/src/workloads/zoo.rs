//! Extended CNN zoo for the paper's §IV-C claim.
//!
//! Section IV-C: "the maximum XNOR vector size is observed to be S = 4608
//! across all major modern CNNs (e.g., ResNet18, ResNet50, DenseNet121,
//! VGG16, VGG19, GoogleNet, ...)". The four evaluation BNNs live in their
//! own modules; this zoo adds VGG16/VGG19 and ResNet50 geometry so the
//! claim is checked over a broader population (E8).

use super::Workload;
use crate::mapping::layer::{ConvGeom, GemmLayer};

/// VGG16 (224×224×3): thirteen 3×3 convs in five pooled stages + 3 FC.
pub fn vgg16() -> Workload {
    vgg(&[2, 2, 3, 3, 3], "vgg16")
}

/// VGG19: same stages with (2,2,4,4,4) convs.
pub fn vgg19() -> Workload {
    vgg(&[2, 2, 4, 4, 4], "vgg19")
}

fn vgg(stage_convs: &[usize], name: &str) -> Workload {
    let widths = [64usize, 128, 256, 512, 512];
    let mut layers = Vec::new();
    let mut hw = 224usize;
    let mut cin = 3usize;
    for (si, (&n_convs, &width)) in stage_convs.iter().zip(&widths).enumerate() {
        for ci in 0..n_convs {
            let mut l = GemmLayer::conv(
                format!("s{}.conv{}", si + 1, ci + 1),
                hw,
                cin,
                3,
                width,
            );
            if ci == n_convs - 1 {
                l = l.with_pool();
            }
            layers.push(l);
            cin = width;
        }
        hw /= 2;
    }
    // Classifier: 7·7·512 → 4096 → 4096 → 1000.
    layers.push(GemmLayer::fc("fc1", 7 * 7 * 512, 4096));
    layers.push(GemmLayer::fc("fc2", 4096, 4096));
    layers.push(GemmLayer::fc("fc3", 4096, 1000));
    Workload::new(name, layers)
}

/// ResNet50 (224×224×3): bottleneck blocks (1×1 reduce, 3×3, 1×1 expand)
/// with stage widths (256, 512, 1024, 2048) and (3, 4, 6, 3) blocks.
pub fn resnet50() -> Workload {
    let mut layers = Vec::new();
    layers.push(
        GemmLayer::new("conv1", 112 * 112, 7 * 7 * 3, 64)
            .with_geom(ConvGeom::new(7, 2, 3, 224))
            .with_pool(),
    );
    let stages: [(usize, usize, usize, usize); 4] = [
        (56, 64, 256, 3),
        (28, 128, 512, 4),
        (14, 256, 1024, 6),
        (7, 512, 2048, 3),
    ];
    let mut cin = 64usize;
    for (si, (hw, mid, cout, blocks)) in stages.into_iter().enumerate() {
        let h = hw * hw;
        // Stages past the first downsample in their first block's 1×1
        // (stride 2 from the previous stage's 2·hw map); stage 2 reads the
        // pooled stem at the same 56 resolution.
        let entry_hw = if si == 0 { hw } else { hw * 2 };
        for b in 0..blocks {
            let block_in = if b == 0 { cin } else { cout };
            let (in_a, stride_a) =
                if b == 0 { (entry_hw, entry_hw / hw) } else { (hw, 1) };
            layers.push(
                GemmLayer::new(format!("s{}.b{}.conv1x1a", si + 2, b + 1), h, block_in, mid)
                    .with_geom(ConvGeom::new(1, stride_a, 0, in_a)),
            );
            layers.push(
                GemmLayer::new(format!("s{}.b{}.conv3x3", si + 2, b + 1), h, 3 * 3 * mid, mid)
                    .with_geom(ConvGeom::new(3, 1, 1, hw)),
            );
            layers.push(
                GemmLayer::new(format!("s{}.b{}.conv1x1b", si + 2, b + 1), h, mid, cout)
                    .with_geom(ConvGeom::new(1, 1, 0, hw)),
            );
            if b == 0 {
                // Projection shortcut: reads the stage input, which is NOT
                // its predecessor in this flattened chain. It carries no
                // window on purpose — in stage 2 an honest (1×1, stride 1,
                // 56-map) window would *accidentally* chain onto
                // conv1x1b's same-sized map and fabricate an admission
                // dependency; no geometry forces the sound whole-map wait
                // in every stage.
                layers.push(GemmLayer::new(
                    format!("s{}.b{}.down", si + 2, b + 1),
                    h,
                    block_in,
                    cout,
                ));
            }
        }
        cin = cout;
    }
    layers.push(GemmLayer::fc("fc", 2048, 1000));
    Workload::new("resnet50", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_macs_published() {
        // Published: ≈ 15.5 GMACs.
        let g = vgg16().total_bitops() as f64;
        assert!((g - 15.5e9).abs() / 15.5e9 < 0.05, "bitops = {}", g);
    }

    #[test]
    fn vgg19_macs_published() {
        // Published: ≈ 19.6 GMACs.
        let g = vgg19().total_bitops() as f64;
        assert!((g - 19.6e9).abs() / 19.6e9 < 0.05, "bitops = {}", g);
    }

    #[test]
    fn resnet50_macs_published() {
        // Published: ≈ 4.1 GMACs.
        let g = resnet50().total_bitops() as f64;
        assert!((g - 4.1e9).abs() / 4.1e9 < 0.10, "bitops = {}", g);
    }

    #[test]
    fn paper_s_max_claim_holds_across_zoo() {
        // §IV-C: max conv S is exactly 4608 (3·3·512) across the zoo,
        // below γ(50 GS/s) = 8503 — VGG16/19 and ResNet50 all peak there.
        for w in [vgg16(), vgg19(), resnet50()] {
            assert_eq!(w.max_conv_s(), 4608, "{}", w.name);
            assert!(w.max_conv_s() < 8503);
        }
    }

    #[test]
    fn layer_counts() {
        assert_eq!(vgg16().layers.len(), 13 + 3);
        assert_eq!(vgg19().layers.len(), 16 + 3);
        // 1 stem + (3+4+6+3) blocks × 3 convs + 4 downsamples + fc.
        assert_eq!(resnet50().layers.len(), 1 + 16 * 3 + 4 + 1);
    }

    #[test]
    fn conv_geometry_carried_and_consistent() {
        for w in [vgg16(), vgg19(), resnet50()] {
            for l in &w.layers {
                if l.h == 1 {
                    assert!(l.geom.is_none(), "{}/{}: FC has no window", w.name, l.name);
                    continue;
                }
                if l.name.ends_with(".down") {
                    // Residual projections read the stage input, not their
                    // chain predecessor — no window, whole-map admission.
                    assert!(l.geom.is_none(), "{}/{}", w.name, l.name);
                    continue;
                }
                let g = l
                    .geom
                    .unwrap_or_else(|| panic!("{}/{}: conv without window", w.name, l.name));
                let out = g.out_hw();
                assert_eq!(l.h, out * out, "{}/{}", w.name, l.name);
            }
        }
        // VGG same-convs: every conv window is 3×3 stride 1 pad 1.
        for l in vgg16().layers.iter().filter(|l| l.h > 1) {
            let g = l.geom.unwrap();
            assert_eq!((g.kernel, g.stride, g.padding), (3, 1, 1), "{}", l.name);
        }
    }
}
