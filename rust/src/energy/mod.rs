//! Power/energy accounting (paper Table III + photonic device energies).

pub mod power;

pub use power::{EnergyModel, Peripheral, Peripherals, PERIPHERAL_CLOCK_HZ};
