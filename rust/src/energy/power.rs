//! Power/energy model parameters.
//!
//! Two layers of constants:
//! * [`Peripherals`] — paper Table III verbatim (power, latency, area of
//!   the shared accelerator peripherals).
//! * [`EnergyModel`] — per-event device energies for the photonic parts.
//!   The paper gives only aggregate statements here (single-MRR OXGs use
//!   less energy than the two-MRR/microdisk gates of ROBIN/LIGHTBULB; PCA
//!   avoids ADC + psum-network energy), so the per-bit numbers below are
//!   standard silicon-photonics figures chosen to respect those orderings;
//!   DESIGN.md lists them as calibration constants.

/// One Table III row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Peripheral {
    pub power_w: f64,
    pub latency_s: f64,
    pub area_mm2: f64,
}

/// Paper Table III: accelerator peripherals and XPE parameters.
#[derive(Debug, Clone)]
pub struct Peripherals {
    pub reduction_network: Peripheral,
    pub activation_unit: Peripheral,
    pub io_interface: Peripheral,
    pub pooling_unit: Peripheral,
    pub edram: Peripheral,
    pub bus: Peripheral,
    pub router: Peripheral,
    /// EO tuning: 80 µW per FSR of shift (power), 20 ns lock time.
    pub eo_tuning_w_per_fsr: f64,
    pub eo_tuning_latency_s: f64,
    /// TO tuning: 275 mW per FSR of shift, 4 µs lock time.
    pub to_tuning_w_per_fsr: f64,
    pub to_tuning_latency_s: f64,
}

/// Peripheral clock used to convert Table III "cycles" rows (bus: 5
/// cycles, router: 2 cycles) into seconds. The table's nanosecond entries
/// (activation 0.78 ns ≈ 1/1.28 GHz; reduction 3.125 ns ≈ 1/0.32 GHz)
/// suggest a ~1 GHz peripheral domain.
pub const PERIPHERAL_CLOCK_HZ: f64 = 1.0e9;

impl Default for Peripherals {
    fn default() -> Self {
        let cyc = 1.0 / PERIPHERAL_CLOCK_HZ;
        Peripherals {
            reduction_network: Peripheral { power_w: 0.050e-3, latency_s: 3.125e-9, area_mm2: 3.00e-5 },
            activation_unit: Peripheral { power_w: 0.52e-3, latency_s: 0.78e-9, area_mm2: 6.00e-5 },
            io_interface: Peripheral { power_w: 140.18e-3, latency_s: 0.78e-9, area_mm2: 2.44e-2 },
            pooling_unit: Peripheral { power_w: 0.4e-3, latency_s: 3.125e-9, area_mm2: 2.40e-4 },
            edram: Peripheral { power_w: 41.1e-3, latency_s: 1.56e-9, area_mm2: 1.66e-1 },
            bus: Peripheral { power_w: 7e-3, latency_s: 5.0 * cyc, area_mm2: 9.00e-3 },
            router: Peripheral { power_w: 42e-3, latency_s: 2.0 * cyc, area_mm2: 1.50e-2 },
            eo_tuning_w_per_fsr: 80e-6,
            eo_tuning_latency_s: 20e-9,
            to_tuning_w_per_fsr: 275e-3,
            to_tuning_latency_s: 4e-6,
        }
    }
}

/// Per-event photonic/analog energies (J) and per-device static power (W).
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// Energy per 1-bit XNOR at the gate (modulator drive of all MRRs
    /// involved). OXBNN drives one MRR (two junctions); ROBIN two MRRs;
    /// LIGHTBULB a microdisk pair.
    pub xnor_j_per_bit: f64,
    /// Receiver (PD + TIR integration) energy per PASS per XPE.
    pub receiver_j_per_pass: f64,
    /// PCA readout + comparator energy per VDP result (OXBNN only).
    pub pca_readout_j: f64,
    /// ADC conversion energy per psum (prior-work bitcount circuits).
    pub adc_j_per_psum: f64,
    /// Reduction-network energy per psum combined.
    pub reduction_j_per_psum: f64,
    /// SRAM/buffer energy per bit moved (operands and psums).
    pub sram_j_per_bit: f64,
    /// Static thermal-tuning hold power per MRR (W). Average lock shift
    /// of a few % of FSR.
    pub tuning_w_per_mrr: f64,
    /// MRRs (or microdisks) per 1-bit XNOR gate: OXBNN = 1 (the paper's
    /// headline device win), ROBIN/LIGHTBULB = 2.
    pub mrrs_per_gate: f64,
}

impl EnergyModel {
    /// OXBNN: single-MRR OXG + PCA (no ADC, no reduction traffic).
    pub fn oxbnn() -> EnergyModel {
        EnergyModel {
            xnor_j_per_bit: 50e-15,
            receiver_j_per_pass: 100e-15,
            pca_readout_j: 500e-15,
            adc_j_per_psum: 0.0,
            reduction_j_per_psum: 0.0,
            sram_j_per_bit: 20e-15,
            tuning_w_per_mrr: 0.275e-3,
            mrrs_per_gate: 1.0,
        }
    }

    /// ROBIN: two-MRR XNOR gates, electrical ADC per psum + reduction.
    pub fn robin() -> EnergyModel {
        EnergyModel {
            xnor_j_per_bit: 100e-15,
            receiver_j_per_pass: 100e-15,
            pca_readout_j: 0.0,
            adc_j_per_psum: 1e-12,
            reduction_j_per_psum: 200e-15,
            sram_j_per_bit: 20e-15,
            tuning_w_per_mrr: 0.275e-3,
            mrrs_per_gate: 2.0,
        }
    }

    /// LIGHTBULB: microdisk pairs + high-rate optical ADC per psum; PCM
    /// racetrack weights are non-volatile (no weight-tuning hold power),
    /// modeled as half the tuning population needing holds.
    pub fn lightbulb() -> EnergyModel {
        EnergyModel {
            xnor_j_per_bit: 120e-15,
            receiver_j_per_pass: 100e-15,
            pca_readout_j: 0.0,
            adc_j_per_psum: 2e-12,
            reduction_j_per_psum: 200e-15,
            sram_j_per_bit: 20e-15,
            tuning_w_per_mrr: 0.5 * 0.275e-3,
            mrrs_per_gate: 2.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_values_verbatim() {
        let p = Peripherals::default();
        assert_eq!(p.reduction_network.power_w, 0.050e-3);
        assert_eq!(p.reduction_network.latency_s, 3.125e-9);
        assert_eq!(p.reduction_network.area_mm2, 3.00e-5);
        assert_eq!(p.activation_unit.power_w, 0.52e-3);
        assert_eq!(p.activation_unit.latency_s, 0.78e-9);
        assert_eq!(p.io_interface.power_w, 140.18e-3);
        assert_eq!(p.io_interface.area_mm2, 2.44e-2);
        assert_eq!(p.pooling_unit.power_w, 0.4e-3);
        assert_eq!(p.edram.power_w, 41.1e-3);
        assert_eq!(p.edram.latency_s, 1.56e-9);
        assert_eq!(p.bus.power_w, 7e-3);
        assert_eq!(p.router.power_w, 42e-3);
        assert_eq!(p.eo_tuning_w_per_fsr, 80e-6);
        assert_eq!(p.eo_tuning_latency_s, 20e-9);
        assert_eq!(p.to_tuning_w_per_fsr, 275e-3);
        assert_eq!(p.to_tuning_latency_s, 4e-6);
    }

    #[test]
    fn cycle_rows_use_peripheral_clock() {
        let p = Peripherals::default();
        assert!((p.bus.latency_s - 5e-9).abs() < 1e-15);
        assert!((p.router.latency_s - 2e-9).abs() < 1e-15);
    }

    #[test]
    fn oxbnn_gate_cheaper_than_baselines() {
        // The paper's stated reason for OXBNN's energy edge: one MRR per
        // gate instead of two.
        let ox = EnergyModel::oxbnn();
        let ro = EnergyModel::robin();
        let lb = EnergyModel::lightbulb();
        assert!(ox.xnor_j_per_bit < ro.xnor_j_per_bit);
        assert!(ox.xnor_j_per_bit < lb.xnor_j_per_bit);
        assert_eq!(ox.mrrs_per_gate, 1.0);
        assert_eq!(ro.mrrs_per_gate, 2.0);
    }

    #[test]
    fn oxbnn_has_no_psum_costs() {
        let ox = EnergyModel::oxbnn();
        assert_eq!(ox.adc_j_per_psum, 0.0);
        assert_eq!(ox.reduction_j_per_psum, 0.0);
        assert!(EnergyModel::robin().adc_j_per_psum > 0.0);
        assert!(EnergyModel::lightbulb().adc_j_per_psum > 0.0);
    }
}
