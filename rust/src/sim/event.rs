//! Event types for the transaction-level, event-driven simulator.
//!
//! Granularity follows the paper's definition of a PASS (Section III-B):
//! one bit-parallel application of an N-bit slice pair to an XPE's OXG
//! array plus the PCA/bitcount action. Peripheral transactions (psum
//! reduction, activation, pooling, memory, NoC) are the Table III events.

/// Identifies an XPE within an accelerator: (xpc index, xpe index in XPC).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct XpeId {
    pub xpc: usize,
    pub xpe: usize,
}

/// A vector-dot-product job: one output element of a GEMM layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VdpId(pub usize);

/// Domain events.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// An XPE finished one PASS (slice `slice_idx` of VDP `vdp`).
    PassComplete { xpe: XpeId, vdp: VdpId, slice_idx: usize, ones: u64 },
    /// A PCA readout fired (VDP complete on an OXBNN-style XPE).
    PcaReadout { xpe: XpeId, vdp: VdpId },
    /// A psum was produced by a bitcount circuit (prior-work XPE) and
    /// enqueued for the reduction network.
    PsumReady { xpe: XpeId, vdp: VdpId, slice_idx: usize },
    /// The reduction network finished combining all psums of `vdp`.
    ReductionDone { vdp: VdpId },
    /// Activation unit applied the comparator/sign for `vdp`.
    ActivationDone { vdp: VdpId },
    /// A memory fetch completed (operand staging for a pass group).
    MemFetchDone { bytes: usize },
    /// A `(frame, layer)` unit's operand staging (eDRAM fetch + tile
    /// buffer write) completed — the whole-frame pipelined world's
    /// admission trigger for that unit's first passes.
    FetchDone { unit: usize },
    /// One of producer `unit`'s activations finished crossing the
    /// inter-chip link of a sharded group — the consumer chip's
    /// cross-chip admission trigger (consumers admit on *arrivals*, not
    /// on the producer chip's drains).
    LinkArrived { unit: usize },
    /// Generic scheduler wakeup.
    Wakeup,
}

/// A timestamped event. Ordering: earliest time first; ties broken by
/// insertion sequence for determinism.
#[derive(Debug, Clone)]
pub struct Event {
    pub time_s: f64,
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time_s == other.time_s && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .time_s
            .partial_cmp(&self.time_s)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BinaryHeap;

    #[test]
    fn heap_orders_by_time_then_seq() {
        let mut h = BinaryHeap::new();
        h.push(Event { time_s: 2.0, seq: 0, kind: EventKind::Wakeup });
        h.push(Event { time_s: 1.0, seq: 2, kind: EventKind::Wakeup });
        h.push(Event { time_s: 1.0, seq: 1, kind: EventKind::Wakeup });
        let order: Vec<(f64, u64)> = std::iter::from_fn(|| h.pop())
            .map(|e| (e.time_s, e.seq))
            .collect();
        assert_eq!(order, vec![(1.0, 1), (1.0, 2), (2.0, 0)]);
    }

    #[test]
    fn xpe_id_ordering() {
        let a = XpeId { xpc: 0, xpe: 5 };
        let b = XpeId { xpc: 1, xpe: 0 };
        assert!(a < b);
    }
}
