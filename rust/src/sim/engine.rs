//! Discrete-event simulation engine.
//!
//! A deterministic min-time event queue plus a `World` trait that reacts
//! to events and schedules new ones. The accelerator models in
//! `crate::arch::event_sim` implement `World`; the engine itself is
//! domain-agnostic and unit-tested standalone.

use std::collections::BinaryHeap;

use super::event::{Event, EventKind};
use super::stats::SimStats;

/// Scheduling interface handed to the world on every event.
pub struct Scheduler {
    heap: BinaryHeap<Event>,
    seq: u64,
    now: f64,
}

impl Scheduler {
    fn new() -> Scheduler {
        Scheduler { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }

    /// Current simulation time (s).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `kind` at absolute time `at_s` (must not be in the past).
    pub fn at(&mut self, at_s: f64, kind: EventKind) {
        debug_assert!(at_s >= self.now, "scheduling into the past");
        let e = Event { time_s: at_s.max(self.now), seq: self.seq, kind };
        self.seq += 1;
        self.heap.push(e);
    }

    /// Schedule `kind` after a relative delay.
    pub fn after(&mut self, delay_s: f64, kind: EventKind) {
        self.at(self.now + delay_s, kind);
    }

    pub fn pending(&self) -> usize {
        self.heap.len()
    }
}

/// A simulated system reacting to events.
pub trait World {
    /// Handle one event; schedule follow-ups through `sched`, account
    /// metrics in `stats`.
    fn handle(&mut self, event: &EventKind, sched: &mut Scheduler, stats: &mut SimStats);

    /// Called once before the run to seed initial events.
    fn init(&mut self, sched: &mut Scheduler, stats: &mut SimStats);

    /// Completion predicate (checked after each event).
    fn done(&self) -> bool;

    /// Called once after the run completes — the place to flush locally
    /// accumulated counters/energy into `stats` (keeps per-event string
    /// lookups off the hot loop; see EXPERIMENTS.md §Perf).
    fn finalize(&mut self, _stats: &mut SimStats) {}
}

/// Run `world` to completion (or until `max_events`). Returns final stats
/// with `end_time_s` set to the time of the last processed event.
pub fn run<W: World>(world: &mut W, max_events: u64) -> SimStats {
    let mut sched = Scheduler::new();
    let mut stats = SimStats::default();
    world.init(&mut sched, &mut stats);
    let mut processed = 0u64;
    while let Some(event) = sched.heap.pop() {
        sched.now = event.time_s;
        world.handle(&event.kind, &mut sched, &mut stats);
        processed += 1;
        stats.events_processed = processed;
        stats.end_time_s = sched.now;
        if world.done() {
            break;
        }
        if processed >= max_events {
            panic!(
                "event budget exhausted ({} events, t = {} s) — likely a scheduling livelock",
                processed, sched.now
            );
        }
    }
    assert!(world.done(), "event queue drained before completion");
    world.finalize(&mut stats);
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy world: a chain of N wakeups 1 µs apart.
    struct Chain {
        remaining: usize,
    }

    impl World for Chain {
        fn init(&mut self, sched: &mut Scheduler, _stats: &mut SimStats) {
            sched.at(0.0, EventKind::Wakeup);
        }

        fn handle(&mut self, event: &EventKind, sched: &mut Scheduler, stats: &mut SimStats) {
            assert!(matches!(event, EventKind::Wakeup));
            stats.count("wakeups", 1);
            self.remaining -= 1;
            if self.remaining > 0 {
                sched.after(1e-6, EventKind::Wakeup);
            }
        }

        fn done(&self) -> bool {
            self.remaining == 0
        }
    }

    #[test]
    fn chain_advances_time() {
        let mut w = Chain { remaining: 10 };
        let stats = run(&mut w, 1000);
        assert_eq!(stats.events_processed, 10);
        assert!((stats.end_time_s - 9e-6).abs() < 1e-12);
        assert_eq!(stats.counter("wakeups"), 10);
    }

    #[test]
    #[should_panic(expected = "event budget exhausted")]
    fn livelock_detected() {
        struct Forever;
        impl World for Forever {
            fn init(&mut self, sched: &mut Scheduler, _s: &mut SimStats) {
                sched.at(0.0, EventKind::Wakeup);
            }
            fn handle(&mut self, _e: &EventKind, sched: &mut Scheduler, _s: &mut SimStats) {
                sched.after(1e-9, EventKind::Wakeup);
            }
            fn done(&self) -> bool {
                false
            }
        }
        run(&mut Forever, 100);
    }

    #[test]
    fn ties_processed_in_schedule_order() {
        struct Ties {
            seen: Vec<u64>,
            total: usize,
        }
        impl World for Ties {
            fn init(&mut self, sched: &mut Scheduler, _s: &mut SimStats) {
                for i in 0..5 {
                    sched.at(1e-6, EventKind::MemFetchDone { bytes: i });
                }
            }
            fn handle(&mut self, e: &EventKind, _sched: &mut Scheduler, _s: &mut SimStats) {
                if let EventKind::MemFetchDone { bytes } = e {
                    self.seen.push(*bytes as u64);
                }
            }
            fn done(&self) -> bool {
                self.seen.len() == self.total
            }
        }
        let mut w = Ties { seen: vec![], total: 5 };
        run(&mut w, 100);
        assert_eq!(w.seen, vec![0, 1, 2, 3, 4]);
    }
}
