//! Discrete-event simulation engine.
//!
//! A deterministic min-time event queue plus a `World` trait that reacts
//! to events and schedules new ones. The accelerator models in
//! `crate::arch::event_sim` implement `World`; the engine itself is
//! domain-agnostic and unit-tested standalone.

use std::collections::BinaryHeap;

use super::event::{Event, EventKind};
use super::stats::SimStats;

/// Scheduling interface handed to the world on every event.
pub struct Scheduler {
    heap: BinaryHeap<Event>,
    seq: u64,
    now: f64,
    clamped: u64,
    peak_pending: usize,
}

impl Scheduler {
    fn new() -> Scheduler {
        Scheduler {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
            clamped: 0,
            peak_pending: 0,
        }
    }

    /// Current simulation time (s).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `kind` at absolute time `at_s`. Scheduling into the past
    /// is a modeling error; the event is clamped to `now` and counted —
    /// the engine surfaces the count as the `clamped_events` stat so the
    /// error is visible in release-mode sweeps too (a `debug_assert`
    /// alone was silent there).
    pub fn at(&mut self, at_s: f64, kind: EventKind) {
        if at_s < self.now {
            self.clamped += 1;
        }
        let e = Event { time_s: at_s.max(self.now), seq: self.seq, kind };
        self.seq += 1;
        self.heap.push(e);
        self.peak_pending = self.peak_pending.max(self.heap.len());
    }

    /// Schedule `kind` after a relative delay.
    pub fn after(&mut self, delay_s: f64, kind: EventKind) {
        self.at(self.now + delay_s, kind);
    }

    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Events clamped by past-time scheduling so far.
    pub fn clamped(&self) -> u64 {
        self.clamped
    }

    /// Peak simultaneous pending events over the run so far — the live
    /// event-queue footprint. The whole-frame pipelined world keeps many
    /// `(frame, layer)` units in one event space; this stat (surfaced as
    /// the `peak_pending_events` counter) shows the single shared queue
    /// stays O(#XPEs), not O(units · XPEs).
    pub fn peak_pending(&self) -> usize {
        self.peak_pending
    }
}

/// A simulated system reacting to events.
pub trait World {
    /// Handle one event; schedule follow-ups through `sched`, account
    /// metrics in `stats`.
    fn handle(&mut self, event: &EventKind, sched: &mut Scheduler, stats: &mut SimStats);

    /// Called once before the run to seed initial events.
    fn init(&mut self, sched: &mut Scheduler, stats: &mut SimStats);

    /// Completion predicate (checked after each event).
    fn done(&self) -> bool;

    /// Called once after the run completes — the place to flush locally
    /// accumulated counters/energy into `stats` (keeps per-event string
    /// lookups off the hot loop; see EXPERIMENTS.md §Perf).
    fn finalize(&mut self, _stats: &mut SimStats) {}
}

/// Result of an engine run. `completed == false` means the stats are
/// TRUNCATED — either the event budget ran out (likely a scheduling
/// livelock) or the queue drained before the world reached its
/// completion predicate. Truncated stats must never be reported as a
/// latency; callers either check the flag or use
/// [`RunOutcome::expect_complete`].
#[derive(Debug, Clone)]
#[must_use = "a truncated run reports a bogus shorter latency — check `completed`"]
pub struct RunOutcome {
    pub stats: SimStats,
    pub completed: bool,
}

impl RunOutcome {
    /// Unwrap the stats, panicking with `context` if the run truncated.
    pub fn expect_complete(self, context: &str) -> SimStats {
        assert!(
            self.completed,
            "event simulation truncated ({}): {} events processed, t = {} s — \
             budget exhausted or queue drained early; the partial latency \
             would be bogus",
            context, self.stats.events_processed, self.stats.end_time_s
        );
        self.stats
    }
}

/// Run `world` until its completion predicate holds, the event queue
/// drains, or `max_events` events have been processed. The outcome's
/// `completed` flag distinguishes a finished run from a truncated one;
/// `finalize` runs either way so partial counters are still real.
pub fn run<W: World>(world: &mut W, max_events: u64) -> RunOutcome {
    let mut sched = Scheduler::new();
    let mut stats = SimStats::default();
    world.init(&mut sched, &mut stats);
    let mut processed = 0u64;
    let mut truncated = false;
    while let Some(event) = sched.heap.pop() {
        sched.now = event.time_s;
        world.handle(&event.kind, &mut sched, &mut stats);
        processed += 1;
        stats.events_processed = processed;
        stats.end_time_s = sched.now;
        if world.done() {
            break;
        }
        if processed >= max_events {
            truncated = true;
            break;
        }
    }
    stats.count("peak_pending_events", sched.peak_pending as u64);
    if sched.clamped > 0 {
        stats.count("clamped_events", sched.clamped);
        // Loud in every build: a clamp is a modeling error distorting
        // latencies. It does not abort the run (the clamped time is a
        // defensible approximation), but it must never pass unnoticed —
        // the scale tests also assert the counter is zero.
        crate::log_warn!(
            "{} event(s) scheduled into the past were clamped to sim-time — \
             modeling error; latencies are approximate",
            sched.clamped
        );
    }
    let completed = !truncated && world.done();
    world.finalize(&mut stats);
    RunOutcome { stats, completed }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy world: a chain of N wakeups 1 µs apart.
    struct Chain {
        remaining: usize,
    }

    impl World for Chain {
        fn init(&mut self, sched: &mut Scheduler, _stats: &mut SimStats) {
            sched.at(0.0, EventKind::Wakeup);
        }

        fn handle(&mut self, event: &EventKind, sched: &mut Scheduler, stats: &mut SimStats) {
            assert!(matches!(event, EventKind::Wakeup));
            stats.count("wakeups", 1);
            self.remaining -= 1;
            if self.remaining > 0 {
                sched.after(1e-6, EventKind::Wakeup);
            }
        }

        fn done(&self) -> bool {
            self.remaining == 0
        }
    }

    #[test]
    fn chain_advances_time() {
        let mut w = Chain { remaining: 10 };
        let out = run(&mut w, 1000);
        assert!(out.completed);
        let stats = out.expect_complete("chain");
        assert_eq!(stats.events_processed, 10);
        assert!((stats.end_time_s - 9e-6).abs() < 1e-12);
        assert_eq!(stats.counter("wakeups"), 10);
        assert_eq!(stats.counter("clamped_events"), 0);
    }

    #[test]
    fn livelock_is_reported_as_truncation() {
        struct Forever;
        impl World for Forever {
            fn init(&mut self, sched: &mut Scheduler, _s: &mut SimStats) {
                sched.at(0.0, EventKind::Wakeup);
            }
            fn handle(&mut self, _e: &EventKind, sched: &mut Scheduler, _s: &mut SimStats) {
                sched.after(1e-9, EventKind::Wakeup);
            }
            fn done(&self) -> bool {
                false
            }
        }
        let out = run(&mut Forever, 100);
        assert!(!out.completed, "budget exhaustion must not look finished");
        assert_eq!(out.stats.events_processed, 100);
    }

    #[test]
    #[should_panic(expected = "event simulation truncated")]
    fn expect_complete_panics_on_truncation() {
        let mut w = Chain { remaining: 10 };
        // Budget of 3 cannot finish a 10-event chain.
        let _ = run(&mut w, 3).expect_complete("short budget");
    }

    #[test]
    fn drained_queue_before_done_is_incomplete() {
        // A world that expects two events but only schedules one.
        struct Starved {
            seen: usize,
        }
        impl World for Starved {
            fn init(&mut self, sched: &mut Scheduler, _s: &mut SimStats) {
                sched.at(0.0, EventKind::Wakeup);
            }
            fn handle(&mut self, _e: &EventKind, _sched: &mut Scheduler, _s: &mut SimStats) {
                self.seen += 1;
            }
            fn done(&self) -> bool {
                self.seen >= 2
            }
        }
        let out = run(&mut Starved { seen: 0 }, 100);
        assert!(!out.completed);
        assert_eq!(out.stats.events_processed, 1);
    }

    #[test]
    fn past_scheduling_is_clamped_and_counted() {
        // First event at t = 1 µs; its handler schedules "at 0" — a
        // modeling error that must clamp to now and be counted.
        struct Rewind {
            fired: usize,
        }
        impl World for Rewind {
            fn init(&mut self, sched: &mut Scheduler, _s: &mut SimStats) {
                sched.at(1e-6, EventKind::Wakeup);
            }
            fn handle(&mut self, _e: &EventKind, sched: &mut Scheduler, _s: &mut SimStats) {
                self.fired += 1;
                if self.fired == 1 {
                    sched.at(0.0, EventKind::Wakeup); // into the past
                }
            }
            fn done(&self) -> bool {
                self.fired >= 2
            }
        }
        let out = run(&mut Rewind { fired: 0 }, 10);
        assert!(out.completed);
        assert_eq!(out.stats.counter("clamped_events"), 1);
        // The clamped event ran at `now`, not before it.
        assert!((out.stats.end_time_s - 1e-6).abs() < 1e-15);
    }

    #[test]
    fn ties_processed_in_schedule_order() {
        struct Ties {
            seen: Vec<u64>,
            total: usize,
        }
        impl World for Ties {
            fn init(&mut self, sched: &mut Scheduler, _s: &mut SimStats) {
                for i in 0..5 {
                    sched.at(1e-6, EventKind::MemFetchDone { bytes: i });
                }
            }
            fn handle(&mut self, e: &EventKind, _sched: &mut Scheduler, _s: &mut SimStats) {
                if let EventKind::MemFetchDone { bytes } = e {
                    self.seen.push(*bytes as u64);
                }
            }
            fn done(&self) -> bool {
                self.seen.len() == self.total
            }
        }
        let mut w = Ties { seen: vec![], total: 5 };
        let out = run(&mut w, 100);
        assert!(out.completed);
        assert_eq!(w.seen, vec![0, 1, 2, 3, 4]);
    }
}
