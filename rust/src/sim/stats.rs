//! Simulation statistics: named counters, energy ledger by category, and
//! latency tracking. Shared by the event-driven and analytic paths so the
//! two can be cross-validated on identical metrics.

use std::collections::BTreeMap;

/// Accumulated metrics of a simulation run.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    /// Events processed by the engine.
    pub events_processed: u64,
    /// Simulation time of the last event (s).
    pub end_time_s: f64,
    counters: BTreeMap<String, u64>,
    energy_j: BTreeMap<String, f64>,
}

impl SimStats {
    /// Increment a named counter.
    pub fn count(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Raise a named counter to at least `value` (for peak-style stats
    /// that must not add when merging runs).
    pub fn set_counter_max(&mut self, name: &str, value: u64) {
        let e = self.counters.entry(name.to_string()).or_insert(0);
        *e = (*e).max(value);
    }

    /// Add energy (J) in a named category.
    pub fn energy(&mut self, category: &str, joules: f64) {
        *self.energy_j.entry(category.to_string()).or_insert(0.0) += joules;
    }

    pub fn energy_of(&self, category: &str) -> f64 {
        self.energy_j.get(category).copied().unwrap_or(0.0)
    }

    pub fn total_energy_j(&self) -> f64 {
        self.energy_j.values().sum()
    }

    pub fn energy_breakdown(&self) -> &BTreeMap<String, f64> {
        &self.energy_j
    }

    pub fn counters(&self) -> &BTreeMap<String, u64> {
        &self.counters
    }

    /// Render as JSON for result dumps.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let counters = self
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
            .collect();
        let energy = self
            .energy_j
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v)))
            .collect();
        Json::Obj(
            [
                ("events".to_string(), Json::Num(self.events_processed as f64)),
                ("end_time_s".to_string(), Json::Num(self.end_time_s)),
                ("counters".to_string(), Json::Obj(counters)),
                ("energy_j".to_string(), Json::Obj(energy)),
            ]
            .into_iter()
            .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut s = SimStats::default();
        s.count("passes", 3);
        s.count("passes", 4);
        assert_eq!(s.counter("passes"), 7);
        assert_eq!(s.counter("missing"), 0);
    }

    #[test]
    fn energy_ledger() {
        let mut s = SimStats::default();
        s.energy("laser", 1e-9);
        s.energy("oxg", 2e-9);
        s.energy("laser", 1e-9);
        assert!((s.energy_of("laser") - 2e-9).abs() < 1e-18);
        assert!((s.total_energy_j() - 4e-9).abs() < 1e-18);
    }

    #[test]
    fn json_dump_parses() {
        let mut s = SimStats::default();
        s.count("vdp", 10);
        s.energy("pca", 5e-12);
        let j = s.to_json();
        let text = j.to_string();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(back.path(&["counters", "vdp"]).unwrap().as_usize(), Some(10));
    }
}
