//! Transaction-level, event-driven simulation engine (the rust counterpart
//! of the paper's python B_ONN_SIM).

pub mod engine;
pub mod event;
pub mod stats;

pub use engine::{run, RunOutcome, Scheduler, World};
pub use event::{Event, EventKind, VdpId, XpeId};
pub use stats::SimStats;
