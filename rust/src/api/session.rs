//! The [`Session`] facade: one builder-configured object that runs a
//! workload on an accelerator through any [`Backend`] and returns the
//! unified [`Report`].
//!
//! ```no_run
//! use oxbnn::api::{BackendKind, Session};
//!
//! let report = Session::builder()
//!     .accelerator_named("OXBNN_50")
//!     .workload_named("vgg_small")
//!     .backend(BackendKind::Event)
//!     .batch(4)
//!     .build()
//!     .unwrap()
//!     .run();
//! println!("{:.1} FPS, {:.2} FPS/W", report.fps, report.fps_per_w);
//! ```

use std::sync::Arc;

use super::backend::{default_policy, Backend, BackendKind};
use super::report::{LayerReport, Report};
use crate::arch::accelerator::AcceleratorConfig;
use crate::mapping::layer::GemmLayer;
use crate::mapping::scheduler::MappingPolicy;
use crate::plan::{ExecutionPlan, PlanCache, ShardPlan, ShardPolicy};
use crate::workloads::Workload;

/// Errors from building a [`Session`].
#[derive(Debug, thiserror::Error)]
pub enum ApiError {
    #[error("session needs an accelerator: call .accelerator(..) or .accelerator_named(..)")]
    MissingAccelerator,
    #[error("session needs a workload: call .workload(..) or .workload_named(..)")]
    MissingWorkload,
    #[error("unknown accelerator '{0}' (see `oxbnn info` for the built-ins)")]
    UnknownAccelerator(String),
    #[error("unknown workload '{0}' (built-ins: vgg_small|resnet18|mobilenet_v2|shufflenet_v2)")]
    UnknownWorkload(String),
    #[error("workload '{0}' has no layers")]
    EmptyWorkload(String),
    #[error("unknown backend '{0}' (expected analytic|event|functional)")]
    UnknownBackend(String),
    #[error("batch must be >= 1")]
    ZeroBatch,
    #[error("chips must be >= 1")]
    ZeroChips,
    #[error("unknown shard policy '{0}' (expected layer|vdp)")]
    UnknownShardPolicy(String),
    #[error(transparent)]
    Config(#[from] crate::config::ConfigError),
}

enum BackendChoice {
    Kind(BackendKind),
    Custom(Box<dyn Backend + Send>),
}

/// Builder for [`Session`]; see the module docs for the usual call chain.
pub struct SessionBuilder {
    accelerator: Option<AcceleratorConfig>,
    accelerator_name: Option<String>,
    workload: Option<Workload>,
    workload_name: Option<String>,
    backend: BackendChoice,
    policy: Option<MappingPolicy>,
    batch: usize,
    pipeline: Option<bool>,
    steal: Option<bool>,
    chips: usize,
    shard_policy: Option<ShardPolicy>,
    plan_cache: Option<Arc<PlanCache>>,
}

impl SessionBuilder {
    /// Use this accelerator configuration (takes precedence over
    /// [`SessionBuilder::accelerator_named`]).
    pub fn accelerator(mut self, cfg: AcceleratorConfig) -> Self {
        self.accelerator = Some(cfg);
        self
    }

    /// Use a built-in accelerator by name (resolved at `build`):
    /// `OXBNN_5|OXBNN_50|ROBIN_EO|ROBIN_PO|LIGHTBULB`.
    pub fn accelerator_named(mut self, name: impl Into<String>) -> Self {
        self.accelerator_name = Some(name.into());
        self
    }

    /// Use this workload (takes precedence over
    /// [`SessionBuilder::workload_named`]).
    pub fn workload(mut self, workload: Workload) -> Self {
        self.workload = Some(workload);
        self
    }

    /// Use a built-in evaluation workload by name (resolved at `build`).
    pub fn workload_named(mut self, name: impl Into<String>) -> Self {
        self.workload_name = Some(name.into());
        self
    }

    /// Select the execution model (default: [`BackendKind::Analytic`]).
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.backend = BackendChoice::Kind(kind);
        self
    }

    /// Inject a custom [`Backend`] implementation (future accelerator
    /// models plug in here without touching the consumers).
    pub fn backend_impl(mut self, backend: Box<dyn Backend + Send>) -> Self {
        self.backend = BackendChoice::Custom(backend);
        self
    }

    /// Override the VDP-to-XPE mapping policy (default: implied by the
    /// accelerator's bitcount mode — see [`default_policy`]).
    pub fn policy(mut self, policy: MappingPolicy) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Frames to evaluate back-to-back (default 1).
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Run the batch through the whole-frame pipelined event space
    /// (cross-layer + multi-frame overlap) instead of multiplying one
    /// frame's latency. Honored by the event backend (exact
    /// receptive-field admission) and the analytic backend (closed-form
    /// overlap estimate from the same exact thresholds); backends without
    /// a frame-overlap model fall back to the sequential multiply.
    ///
    /// **Default: pipelined whenever `batch > 1`** (single frames have
    /// nothing to overlap with, and the cross-layer path is covered by the
    /// conformance suite). Call `.pipeline(false)` to opt out; the
    /// `OXBNN_PIPELINE` environment variable pins the unset *batched*
    /// default (`1` = pipelined, `0` = sequential multiply; batch-1
    /// sessions stay sequential either way) — the CI admission matrix
    /// runs both modes through it.
    pub fn pipeline(mut self, pipeline: bool) -> Self {
        self.pipeline = Some(pipeline);
        self
    }

    /// Enable bounded work-stealing in the pipelined event space: an XPE
    /// parked on an admission threshold may run an already-admitted VDP
    /// from a later unit when its closed-form cost fits inside a lower
    /// bound on the stall, shrinking parked time without ever delaying
    /// the blocked unit past its wake (the "pipelined ≤ sequential"
    /// guarantee is property-tested with stealing on).
    ///
    /// **Default: on.** Call `.steal(false)` for the strict frame-major
    /// frontier; the `OXBNN_STEAL` environment variable pins the unset
    /// default (`1` = stealing, `0` = strict). No effect outside the
    /// pipelined event path.
    pub fn steal(mut self, steal: bool) -> Self {
        self.steal = Some(steal);
        self
    }

    /// Shard the model across `chips` accelerators of the configured
    /// geometry (default 1 — no sharding). With `chips > 1` the session
    /// compiles a [`ShardPlan`] and routes through
    /// [`Backend::run_planned_sharded`]: the report charges K chips'
    /// static power and carries a per-chip idle / inter-chip transfer
    /// breakdown ([`super::report::ShardBreakdown`]).
    pub fn chips(mut self, chips: usize) -> Self {
        self.chips = chips;
        self
    }

    /// How a multi-chip group splits the model (default
    /// [`ShardPolicy::VdpSplit`]): `VdpSplit` spreads every layer's VDPs
    /// over all chips; `LayerPipeline` gives each chip a contiguous layer
    /// range and streams frames through the chip pipeline. Ignored when
    /// `chips == 1`.
    pub fn shard_policy(mut self, policy: ShardPolicy) -> Self {
        self.shard_policy = Some(policy);
        self
    }

    /// Share a [`PlanCache`] with other sessions (parallel sweep cells,
    /// serving replicas): the `(accelerator, workload, policy)` mapping
    /// is compiled once and streamed by every session that hits the same
    /// key. Default: a private cache per session.
    pub fn plan_cache(mut self, cache: Arc<PlanCache>) -> Self {
        self.plan_cache = Some(cache);
        self
    }

    /// Resolve names and assemble the session.
    pub fn build(self) -> Result<Session, ApiError> {
        if self.batch == 0 {
            return Err(ApiError::ZeroBatch);
        }
        if self.chips == 0 {
            return Err(ApiError::ZeroChips);
        }
        let accelerator = match (self.accelerator, self.accelerator_name) {
            (Some(cfg), _) => cfg,
            (None, Some(name)) => crate::config::builtin(&name)
                .ok_or(ApiError::UnknownAccelerator(name))?,
            (None, None) => return Err(ApiError::MissingAccelerator),
        };
        let workload = match (self.workload, self.workload_name) {
            (Some(w), _) => w,
            (None, Some(name)) => Workload::evaluation_set()
                .into_iter()
                .find(|w| w.name == name)
                .ok_or(ApiError::UnknownWorkload(name))?,
            (None, None) => return Err(ApiError::MissingWorkload),
        };
        // `Workload::new` asserts this, but the struct's fields are public;
        // guard here so the library API errors instead of panicking (or
        // dividing by an empty frame) later.
        if workload.layers.is_empty() {
            return Err(ApiError::EmptyWorkload(workload.name));
        }
        let policy = self.policy.unwrap_or_else(|| default_policy(&accelerator));
        let backend = match self.backend {
            BackendChoice::Kind(kind) => kind.create(),
            BackendChoice::Custom(b) => b,
        };
        let plan_cache = self
            .plan_cache
            .unwrap_or_else(|| Arc::new(PlanCache::default()));
        let pipeline = self
            .pipeline
            .unwrap_or_else(|| default_pipeline(self.batch));
        let steal = self.steal.unwrap_or_else(default_steal);
        Ok(Session {
            accelerator,
            workload,
            backend,
            policy,
            batch: self.batch,
            pipeline,
            steal,
            chips: self.chips,
            shard_policy: self.shard_policy.unwrap_or(ShardPolicy::VdpSplit),
            plan_cache,
        })
    }
}

/// The pipelined-by-default rule for batches: pipelined whenever the
/// session evaluates more than one frame. `OXBNN_PIPELINE` pins the
/// *batched* default for the CI admission matrix (`1` = the pipelined
/// default, `0` = the sequential multiply); single frames stay
/// sequential either way — there is nothing to overlap, and the override
/// must not change batch-1 semantics between matrix legs.
fn default_pipeline(batch: usize) -> bool {
    match std::env::var("OXBNN_PIPELINE").ok().as_deref() {
        Some("1") | Some("true") | Some("on") | None => batch > 1,
        Some("0") | Some("false") | Some("off") => false,
        // A misspelt override silently collapsing both CI matrix legs onto
        // the same default would defeat the matrix — fail loudly instead.
        Some(other) => panic!(
            "OXBNN_PIPELINE must be 1/true/on or 0/false/off, got '{}'",
            other
        ),
    }
}

/// The work-stealing default for sessions that did not call
/// [`SessionBuilder::steal`]: on, unless `OXBNN_STEAL` pins it off —
/// the same env-pinned-default pattern as [`default_pipeline`], so the
/// CI matrix can run both scheduler frontiers without code changes.
fn default_steal() -> bool {
    match std::env::var("OXBNN_STEAL").ok().as_deref() {
        Some("1") | Some("true") | Some("on") | Some("auto") | None => true,
        Some("0") | Some("false") | Some("off") => false,
        Some(other) => panic!(
            "OXBNN_STEAL must be 1/true/on/auto or 0/false/off, got '{}'",
            other
        ),
    }
}

/// A configured accelerator × workload × backend evaluation.
pub struct Session {
    accelerator: AcceleratorConfig,
    workload: Workload,
    backend: Box<dyn Backend + Send>,
    policy: MappingPolicy,
    batch: usize,
    pipeline: bool,
    steal: bool,
    chips: usize,
    shard_policy: ShardPolicy,
    plan_cache: Arc<PlanCache>,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder {
            accelerator: None,
            accelerator_name: None,
            workload: None,
            workload_name: None,
            backend: BackendChoice::Kind(BackendKind::Analytic),
            policy: None,
            batch: 1,
            pipeline: None,
            steal: None,
            chips: 1,
            shard_policy: None,
            plan_cache: None,
        }
    }

    /// Run the configured workload and return the unified report. The
    /// execution plan is fetched from (or compiled into) the session's
    /// [`PlanCache`], so repeated runs — and other sessions sharing the
    /// cache — never recompile the mapping. With
    /// [`SessionBuilder::pipeline`] set, the event backend runs the batch
    /// through one whole-frame pipelined event space.
    pub fn run(&mut self) -> Report {
        if self.chips > 1 {
            let shard = self.shard_plan();
            return self
                .backend
                .run_planned_sharded(&shard, self.batch, self.pipeline, self.steal);
        }
        let plan = self.plan();
        self.backend
            .run_planned_batched(&plan, self.batch, self.pipeline, self.steal)
    }

    /// The compiled execution plan for this session's triple (cached).
    pub fn plan(&self) -> Arc<ExecutionPlan> {
        self.plan_cache
            .get_or_compile(&self.accelerator, &self.workload, self.policy)
    }

    /// The compiled K-chip shard plan for this session's group geometry
    /// (fresh per call — [`ShardPlan::compile`] is cheap; the plan cache
    /// keys single-accelerator triples only).
    pub fn shard_plan(&self) -> ShardPlan {
        ShardPlan::compile(
            &self.accelerator,
            &self.workload,
            self.policy,
            self.chips,
            self.shard_policy,
        )
    }

    /// Run a single layer (not necessarily from the configured workload)
    /// on the session's accelerator and backend.
    pub fn run_layer(&mut self, layer: &GemmLayer) -> LayerReport {
        self.backend.run_layer(&self.accelerator, layer, self.policy)
    }

    pub fn accelerator(&self) -> &AcceleratorConfig {
        &self.accelerator
    }

    pub fn workload(&self) -> &Workload {
        &self.workload
    }

    pub fn backend_kind(&self) -> BackendKind {
        self.backend.kind()
    }

    pub fn policy(&self) -> MappingPolicy {
        self.policy
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Whether batches run through the pipelined whole-frame event space.
    pub fn pipelined(&self) -> bool {
        self.pipeline
    }

    /// Whether the pipelined scheduler may steal boundedly past
    /// admission-blocked units.
    pub fn steal(&self) -> bool {
        self.steal
    }

    /// Accelerators in the session's shard group (1 = unsharded).
    pub fn chips(&self) -> usize {
        self.chips
    }

    /// How a multi-chip group splits the model.
    pub fn shard_policy(&self) -> ShardPolicy {
        self.shard_policy
    }

    /// The session's plan cache (shared when built with
    /// [`SessionBuilder::plan_cache`]).
    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plan_cache
    }
}
