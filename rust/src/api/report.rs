//! The unified result type every [`super::Backend`] produces.
//!
//! A [`Report`] carries the full Fig. 7 metric set (FPS, FPS/W, energy
//! breakdown) together with the transaction counts (PASSes, psums) that
//! the event-driven simulator and the analytic model are cross-validated
//! on — one shape regardless of which execution model produced it.

use std::collections::BTreeMap;

use super::backend::BackendKind;
use crate::arch::accelerator::AcceleratorConfig;
use crate::util::json::Json;

/// Per-layer slice of a [`Report`].
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub name: String,
    /// Layer latency (s). Analytic: closed-form estimate; event: simulated
    /// end time; functional: the analytic estimate (the functional engine
    /// models arithmetic, not time).
    pub latency_s: f64,
    pub dynamic_energy_j: f64,
    /// XPE PASS transactions in this layer.
    pub passes: u64,
    /// Electrical psums emitted (0 in PCA mode — the paper's headline).
    pub psums: u64,
    /// Latency decomposition (keys like `compute_s`, `memory_s`,
    /// `reduce_s`, `fixed_s`); backends fill what they can attribute.
    pub timing: BTreeMap<String, f64>,
    /// Named transaction counters (event backend: the full SimStats
    /// counter set; functional backend: `checked_vdps`, `mismatches`, ...).
    pub counters: BTreeMap<String, u64>,
    /// Dynamic-energy ledger by category (event backend only; the
    /// analytic model attributes energy at layer granularity).
    pub energy_breakdown: BTreeMap<String, f64>,
}

impl LayerReport {
    /// Named counter, 0 when the backend did not record it.
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }
}

/// Functional-backend correctness summary.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Correctness {
    /// VDPs whose XNOR-bitcount arithmetic was recomputed bit-exactly.
    pub vdps_checked: u64,
    /// Sliced-accumulation vs whole-vector bitcount disagreements
    /// (must be 0 — the invariant that makes the PCA mapping valid).
    pub mismatches: u64,
    /// VDPs whose bitcount exceeded the PCA capacity γ (would saturate
    /// the TIR mid-VDP on real hardware).
    pub pca_clamped: u64,
}

impl Correctness {
    pub fn is_clean(&self) -> bool {
        self.mismatches == 0
    }
}

/// Per-chip breakdown of a multi-accelerator (sharded) run — present iff
/// the report came through [`super::Backend::run_planned_sharded`] with
/// more than one chip.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ShardBreakdown {
    /// Accelerators in the shard group.
    pub chips: usize,
    /// Shard policy name (`layer` or `vdp`).
    pub policy: String,
    /// Fraction of the makespan each chip's XPEs sat idle (len = chips;
    /// event backend only — the analytic estimate leaves it empty).
    pub chip_idle_fraction: Vec<f64>,
    /// Total busy time of the serialized inter-chip transfer channel (s).
    pub link_busy_s: f64,
    /// Activations that crossed the inter-chip channel.
    pub link_transfers: u64,
}

/// Unified whole-workload result (one frame unless `batch > 1`).
#[derive(Debug, Clone)]
pub struct Report {
    pub backend: BackendKind,
    pub accelerator: String,
    pub workload: String,
    /// Frames evaluated back-to-back by the session.
    pub batch: usize,
    /// True when the batch ran through the whole-frame pipelined event
    /// space (cross-layer + multi-frame overlap) instead of the
    /// sequential frame-latency multiply.
    pub pipelined: bool,
    /// Latency of one inference frame (s). Pipelined: the first frame's
    /// completion time (cross-layer overlap included).
    pub frame_latency_s: f64,
    /// Latency of the whole batch. Sequential: `batch · frame_latency_s`;
    /// pipelined: the simulated makespan of the shared event space
    /// (strictly less when frames overlap).
    pub batch_latency_s: f64,
    /// Throughput. Sequential: `1 / frame_latency_s`; pipelined:
    /// `batch / batch_latency_s` (the honest batched FPS).
    pub fps: f64,
    pub dynamic_energy_per_frame_j: f64,
    pub static_power_w: f64,
    pub avg_power_w: f64,
    pub fps_per_w: f64,
    /// Total XPE PASS transactions per frame.
    pub passes: u64,
    /// Total electrical psums per frame (0 in PCA mode).
    pub psums: u64,
    /// Dynamic-energy ledger by category, summed over layers (may be
    /// empty for backends that only attribute per-layer totals).
    pub energy_breakdown: BTreeMap<String, f64>,
    /// Present iff the backend carries correctness (functional).
    pub correctness: Option<Correctness>,
    /// Present iff this run sharded the model across `chips > 1`
    /// accelerators (per-chip idle + inter-chip transfer breakdown).
    pub shard: Option<ShardBreakdown>,
    pub layers: Vec<LayerReport>,
}

impl Report {
    /// Assemble a report from per-layer results plus the frame latency the
    /// backend attributes to the whole frame (which may be less than the
    /// layer sum when fetch/compute overlap is modeled).
    pub(crate) fn from_layers(
        backend: BackendKind,
        cfg: &AcceleratorConfig,
        workload_name: &str,
        layers: Vec<LayerReport>,
        frame_latency_s: f64,
    ) -> Report {
        let dynamic: f64 = layers.iter().map(|l| l.dynamic_energy_j).sum();
        let passes: u64 = layers.iter().map(|l| l.passes).sum();
        let psums: u64 = layers.iter().map(|l| l.psums).sum();
        let mut energy_breakdown: BTreeMap<String, f64> = BTreeMap::new();
        for l in &layers {
            for (k, v) in &l.energy_breakdown {
                *energy_breakdown.entry(k.clone()).or_insert(0.0) += *v;
            }
        }
        let correctness = if backend == BackendKind::Functional {
            Some(Correctness {
                vdps_checked: layers.iter().map(|l| l.counter("checked_vdps")).sum(),
                mismatches: layers.iter().map(|l| l.counter("mismatches")).sum(),
                pca_clamped: layers.iter().map(|l| l.counter("pca_clamped")).sum(),
            })
        } else {
            None
        };
        let static_power_w = cfg.static_power_w();
        let frame_energy = static_power_w * frame_latency_s + dynamic;
        Report {
            backend,
            accelerator: cfg.name.clone(),
            workload: workload_name.to_string(),
            batch: 1,
            pipelined: false,
            frame_latency_s,
            batch_latency_s: frame_latency_s,
            fps: 1.0 / frame_latency_s,
            dynamic_energy_per_frame_j: dynamic,
            static_power_w,
            avg_power_w: frame_energy / frame_latency_s,
            fps_per_w: 1.0 / frame_energy,
            passes,
            psums,
            energy_breakdown,
            correctness,
            shard: None,
            layers,
        }
    }

    /// Stamp the session's batch size (frames run back-to-back).
    pub(crate) fn with_batch(mut self, batch: usize) -> Report {
        self.batch = batch;
        self.batch_latency_s = self.frame_latency_s * batch as f64;
        self
    }

    /// Stamp a whole-frame pipelined batch: `frame_latency_s` becomes the
    /// first frame's completion time, `batch_latency_s` the simulated
    /// makespan, and the throughput metrics (`fps`, `avg_power_w`,
    /// `fps_per_w`) are recomputed from the makespan — static power is
    /// burnt for the makespan, not for `batch` serial frames.
    pub(crate) fn with_pipelined_batch(
        mut self,
        batch: usize,
        frame_latency_s: f64,
        batch_latency_s: f64,
    ) -> Report {
        self.batch = batch;
        self.pipelined = true;
        self.frame_latency_s = frame_latency_s;
        self.batch_latency_s = batch_latency_s;
        self.fps = batch as f64 / batch_latency_s;
        let frame_energy = self.static_power_w * batch_latency_s / batch as f64
            + self.dynamic_energy_per_frame_j;
        self.avg_power_w = frame_energy * batch as f64 / batch_latency_s;
        self.fps_per_w = 1.0 / frame_energy;
        self
    }

    /// Stamp a multi-chip sharded run: attach the per-chip breakdown and
    /// re-account static power for `chips` accelerators burning
    /// `per_chip_static_w` each — a K-chip group pays K× the wall-plug
    /// static power for the same makespan, so `fps_per_w` is the honest
    /// group efficiency, not a single chip's.
    pub(crate) fn with_shard(
        mut self,
        breakdown: ShardBreakdown,
        per_chip_static_w: f64,
    ) -> Report {
        self.static_power_w = per_chip_static_w * breakdown.chips as f64;
        let frame_static_s = if self.pipelined {
            self.batch_latency_s / self.batch as f64
        } else {
            self.frame_latency_s
        };
        let frame_energy = self.static_power_w * frame_static_s
            + self.dynamic_energy_per_frame_j;
        self.avg_power_w = frame_energy / frame_static_s;
        self.fps_per_w = 1.0 / frame_energy;
        self.shard = Some(breakdown);
        self
    }

    /// Batched throughput: frames per batch latency. Equals `fps` for
    /// pipelined reports and `1 / frame_latency_s` for sequential ones —
    /// the apples-to-apples number the pipeline bench gates on.
    pub fn batched_fps(&self) -> f64 {
        self.batch as f64 / self.batch_latency_s
    }

    /// Total wall-plug energy of one frame (static + dynamic), J. For
    /// pipelined batches the static share is amortized over the makespan.
    pub fn total_energy_per_frame_j(&self) -> f64 {
        let static_s = if self.pipelined {
            self.batch_latency_s / self.batch as f64
        } else {
            self.frame_latency_s
        };
        self.static_power_w * static_s + self.dynamic_energy_per_frame_j
    }

    /// JSON rendering for result dumps and sweep outputs.
    pub fn to_json(&self) -> Json {
        let layers = Json::Arr(
            self.layers
                .iter()
                .map(|l| {
                    Json::obj(vec![
                        ("name", Json::Str(l.name.clone())),
                        ("latency_s", Json::Num(l.latency_s)),
                        ("dynamic_energy_j", Json::Num(l.dynamic_energy_j)),
                        ("passes", Json::Num(l.passes as f64)),
                        ("psums", Json::Num(l.psums as f64)),
                    ])
                })
                .collect(),
        );
        let energy = Json::Obj(
            self.energy_breakdown
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                .collect(),
        );
        let mut fields = vec![
            ("backend", Json::Str(self.backend.as_str().to_string())),
            ("accelerator", Json::Str(self.accelerator.clone())),
            ("workload", Json::Str(self.workload.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("pipelined", Json::Bool(self.pipelined)),
            ("frame_latency_s", Json::Num(self.frame_latency_s)),
            ("batch_latency_s", Json::Num(self.batch_latency_s)),
            ("fps", Json::Num(self.fps)),
            ("fps_per_w", Json::Num(self.fps_per_w)),
            ("dynamic_energy_per_frame_j", Json::Num(self.dynamic_energy_per_frame_j)),
            ("static_power_w", Json::Num(self.static_power_w)),
            ("avg_power_w", Json::Num(self.avg_power_w)),
            ("passes", Json::Num(self.passes as f64)),
            ("psums", Json::Num(self.psums as f64)),
            ("energy_breakdown_j", energy),
            ("layers", layers),
        ];
        if let Some(c) = &self.correctness {
            fields.push((
                "correctness",
                Json::obj(vec![
                    ("vdps_checked", Json::Num(c.vdps_checked as f64)),
                    ("mismatches", Json::Num(c.mismatches as f64)),
                    ("pca_clamped", Json::Num(c.pca_clamped as f64)),
                ]),
            ));
        }
        if let Some(s) = &self.shard {
            fields.push((
                "shard",
                Json::obj(vec![
                    ("chips", Json::Num(s.chips as f64)),
                    ("policy", Json::Str(s.policy.clone())),
                    (
                        "chip_idle_fraction",
                        Json::Arr(
                            s.chip_idle_fraction.iter().map(|f| Json::Num(*f)).collect(),
                        ),
                    ),
                    ("link_busy_s", Json::Num(s.link_busy_s)),
                    ("link_transfers", Json::Num(s.link_transfers as f64)),
                ]),
            ));
        }
        Json::obj(fields)
    }
}
