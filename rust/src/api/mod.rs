//! # Unified execution facade: `Session` + `Backend`
//!
//! The paper's evaluation story rests on comparing the *same* workload
//! across execution models — the closed-form analytic model (Fig. 7
//! sweeps), the transaction-level event-driven simulator (Fig. 5, PCA
//! dynamics), and the integer functional reference (correctness). This
//! module is the one seam those models share:
//!
//! * [`Backend`] — the execution-model trait
//!   (`run_layer` / `run_workload`), implemented by
//!   [`AnalyticBackend`], [`EventSimBackend`] and [`FunctionalBackend`];
//! * [`Session`] — a builder-configured accelerator × workload × backend
//!   evaluation returning one unified [`Report`] (FPS, FPS/W, energy
//!   breakdown, transaction counts, optional correctness block).
//!
//! ```no_run
//! use oxbnn::api::{BackendKind, Session};
//! use oxbnn::arch::accelerator::AcceleratorConfig;
//! use oxbnn::workloads::Workload;
//!
//! let mut session = Session::builder()
//!     .accelerator(AcceleratorConfig::oxbnn_50())
//!     .workload(Workload::evaluation_set().remove(0)) // vgg_small
//!     .backend(BackendKind::Analytic)
//!     .batch(8)
//!     .build()
//!     .unwrap();
//! let report = session.run();
//! println!("{} on {}: {:.0} FPS ({} passes, {} psums)",
//!     report.accelerator, report.workload, report.fps,
//!     report.passes, report.psums);
//! ```
//!
//! Every consumer — the `oxbnn` CLI subcommands, the serving coordinator's
//! simulated-photonic-latency annotation, the Fig. 7 benches and the
//! examples — goes through this facade; nothing outside this module calls
//! `arch::perf::workload_perf` directly. New execution models (sharded
//! sweeps, remote backends) plug in via [`SessionBuilder::backend_impl`]
//! without touching those consumers.

pub mod backend;
pub mod report;
pub mod session;

pub use backend::{
    default_policy, AnalyticBackend, Backend, BackendKind, EventSimBackend,
    FunctionalBackend,
};
pub use report::{Correctness, LayerReport, Report, ShardBreakdown};
pub use session::{ApiError, Session, SessionBuilder};

/// One-call fast path for the overwhelmingly common case: evaluate
/// `workload` on `cfg` with the analytic backend and the accelerator's
/// implied mapping policy. Equivalent to the full [`Session`] builder
/// chain with [`BackendKind::Analytic`] and batch 1 — the Fig. 7 sweep
/// path the benches and baselines use.
///
/// # Panics
///
/// If `workload` has no layers (the invariant [`Workload::new`] upholds;
/// the builder path returns [`ApiError::EmptyWorkload`] instead).
///
/// [`Workload::new`]: crate::workloads::Workload::new
pub fn analytic_report(
    cfg: &crate::arch::accelerator::AcceleratorConfig,
    workload: &crate::workloads::Workload,
) -> Report {
    assert!(
        !workload.layers.is_empty(),
        "workload '{}' has no layers",
        workload.name
    );
    let mut backend = AnalyticBackend;
    backend.run_workload(cfg, workload, default_policy(cfg))
}

/// One-call form of "what frame latency would this geometry have on that
/// accelerator under this execution model?" — the annotation the serving
/// coordinator attaches to every response, and the photonic reference
/// `serve-bench` prints next to achieved serving FPS. Equivalent to the
/// full [`Session`] builder chain with batch 1, returning only
/// `frame_latency_s`.
pub fn simulated_frame_latency(
    cfg: &crate::arch::accelerator::AcceleratorConfig,
    workload: &crate::workloads::Workload,
    kind: BackendKind,
) -> Result<f64, ApiError> {
    // One-shot: a throwaway single-slot cache keeps one session-building
    // code path (the cached variant below).
    let cache = std::sync::Arc::new(crate::plan::PlanCache::with_capacity(1));
    simulated_frame_latency_cached(&cache, cfg, workload, kind)
}

/// [`simulated_frame_latency`] over a shared [`crate::plan::PlanCache`]:
/// repeat callers on the same `(accelerator, workload, policy)` triple —
/// e.g. the serving coordinator's worker replicas — reuse one compiled
/// mapping instead of recompiling it per call.
pub fn simulated_frame_latency_cached(
    cache: &std::sync::Arc<crate::plan::PlanCache>,
    cfg: &crate::arch::accelerator::AcceleratorConfig,
    workload: &crate::workloads::Workload,
    kind: BackendKind,
) -> Result<f64, ApiError> {
    Ok(Session::builder()
        .accelerator(cfg.clone())
        .workload(workload.clone())
        .backend(kind)
        .plan_cache(std::sync::Arc::clone(cache))
        .build()?
        .run()
        .frame_latency_s)
}

/// Effective per-frame latency of a `batch`-frame run: `batch_latency /
/// batch`. With `pipelined` set, frames overlap — the event backend runs
/// one whole-frame event space; the analytic backend applies its
/// threshold-driven overlap estimate — so this is *smaller* than the
/// single-frame latency: the photonic reference the serving coordinator
/// attaches when it batches requests anyway
/// ([`crate::coordinator::ServerConfig`]'s `sim_pipeline`, on by
/// default). Sequential runs, and the functional backend, return the
/// plain frame latency.
pub fn simulated_effective_latency_cached(
    cache: &std::sync::Arc<crate::plan::PlanCache>,
    cfg: &crate::arch::accelerator::AcceleratorConfig,
    workload: &crate::workloads::Workload,
    kind: BackendKind,
    batch: usize,
    pipelined: bool,
) -> Result<f64, ApiError> {
    let report = Session::builder()
        .accelerator(cfg.clone())
        .workload(workload.clone())
        .backend(kind)
        .batch(batch)
        .pipeline(pipelined)
        .plan_cache(std::sync::Arc::clone(cache))
        .build()?
        .run();
    Ok(report.batch_latency_s / report.batch as f64)
}

/// Simulated photonic throughput (frames/s) at the effective per-frame
/// latency of [`simulated_effective_latency_cached`] — the paper-model
/// reference figure the serving registry attaches to each loaded model.
pub fn simulated_photonic_fps_cached(
    cache: &std::sync::Arc<crate::plan::PlanCache>,
    cfg: &crate::arch::accelerator::AcceleratorConfig,
    workload: &crate::workloads::Workload,
    kind: BackendKind,
    batch: usize,
    pipelined: bool,
) -> Result<f64, ApiError> {
    Ok(1.0 / simulated_effective_latency_cached(cache, cfg, workload, kind, batch, pipelined)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::accelerator::{AcceleratorConfig, BitcountMode};
    use crate::arch::perf::workload_perf;
    use crate::arch::workload_sim::simulate_frame;
    use crate::mapping::layer::GemmLayer;
    use crate::mapping::scheduler::MappingPolicy;
    use crate::workloads::Workload;

    fn small_cfg() -> AcceleratorConfig {
        let mut cfg = AcceleratorConfig::oxbnn_5();
        cfg.n = 9;
        cfg.xpe_total = 18;
        cfg
    }

    fn tiny_workload() -> Workload {
        use crate::mapping::layer::ConvGeom;
        Workload::new(
            "tiny",
            vec![
                GemmLayer::new("c1", 16, 243, 8).with_geom(ConvGeom::new(3, 1, 1, 4)),
                GemmLayer::new("c2", 16, 288, 8)
                    .with_geom(ConvGeom::new(3, 1, 1, 4))
                    .with_pool(),
                GemmLayer::fc("fc", 512, 10),
            ],
        )
    }

    #[test]
    fn analytic_backend_matches_workload_perf_exactly() {
        // The planless convenience path IS the closed-form model, exactly.
        let cfg = AcceleratorConfig::oxbnn_50();
        let wl = Workload::evaluation_set().remove(0);
        let perf = workload_perf(&cfg, &wl);
        let report = analytic_report(&cfg, &wl);
        assert_eq!(report.frame_latency_s, perf.frame_latency_s);
        assert_eq!(report.fps, perf.fps);
        assert_eq!(report.fps_per_w, perf.fps_per_w);
        assert_eq!(report.avg_power_w, perf.avg_power_w);
        assert_eq!(report.static_power_w, perf.static_power_w);
        assert_eq!(
            report.dynamic_energy_per_frame_j,
            perf.dynamic_energy_per_frame_j
        );
        assert_eq!(report.layers.len(), perf.layers.len());
        let passes: u64 = perf.layers.iter().map(|l| l.passes).sum();
        assert_eq!(report.passes, passes);

        // The Session path is PLAN-AWARE: same transaction counts and
        // energy, but each layer's compute term is the compiled plan's
        // longest per-XPE queue (`max_queue_len · τ`) instead of the
        // perfect-balance `ceil(passes / xpe_total) · τ`. (The two can
        // differ in either direction: unbalanced tails lengthen the
        // critical path, while the plan's padded XPE grid — the last XPC
        // may be partially populated — can shorten it slightly.)
        let cfg2 = AcceleratorConfig::oxbnn_50();
        let wl2 = Workload::evaluation_set().remove(0);
        let session = Session::builder()
            .accelerator(cfg2.clone())
            .workload(wl2.clone())
            .backend(BackendKind::Analytic)
            .build()
            .unwrap()
            .run();
        assert_eq!(session.passes, report.passes);
        assert_eq!(session.psums, report.psums);
        assert_eq!(
            session.dynamic_energy_per_frame_j,
            report.dynamic_energy_per_frame_j
        );
        let plan = crate::plan::ExecutionPlan::compile(
            &cfg2,
            &wl2,
            default_policy(&cfg2),
        );
        let tau = cfg2.tau_s();
        for (s, lp) in session.layers.iter().zip(&plan.layers) {
            let expect = lp.max_queue_len() as f64 * tau;
            assert_eq!(
                s.timing.get("compute_s").copied(),
                Some(expect),
                "layer {} must use the plan's critical-path compute term",
                s.name
            );
        }
    }

    #[test]
    fn event_backend_matches_simulate_frame() {
        let cfg = small_cfg();
        let wl = tiny_workload();
        let trace = simulate_frame(&cfg, &wl, MappingPolicy::PcaLocal);
        let report = Session::builder()
            .accelerator(cfg)
            .workload(wl)
            .backend(BackendKind::Event)
            .build()
            .unwrap()
            .run();
        assert!(
            (report.frame_latency_s - trace.frame_latency_s).abs() < 1e-15,
            "session {} vs simulate_frame {}",
            report.frame_latency_s,
            trace.frame_latency_s
        );
        assert_eq!(report.passes, trace.stats.counter("passes"));
        assert_eq!(report.psums, trace.stats.counter("psums"));
        let energy = (report.dynamic_energy_per_frame_j
            - trace.stats.total_energy_j())
        .abs();
        assert!(energy < 1e-18, "energy ledger diverged by {} J", energy);
    }

    #[test]
    fn functional_backend_carries_clean_correctness() {
        let report = Session::builder()
            .accelerator(small_cfg())
            .workload(tiny_workload())
            .backend(BackendKind::Functional)
            .build()
            .unwrap()
            .run();
        let c = report.correctness.as_ref().expect("functional correctness");
        assert!(c.vdps_checked > 0);
        assert_eq!(c.mismatches, 0, "sliced accumulation must be exact");
        assert!(c.is_clean());
        assert_eq!(c.pca_clamped, 0, "γ=29761 cannot clamp S ≤ 512 layers");
        // Timing delegates to the analytic model.
        let analytic = Session::builder()
            .accelerator(small_cfg())
            .workload(tiny_workload())
            .backend(BackendKind::Analytic)
            .build()
            .unwrap()
            .run();
        assert_eq!(report.frame_latency_s, analytic.frame_latency_s);
        // Non-functional backends carry no correctness block.
        assert!(analytic.correctness.is_none());
    }

    #[test]
    fn functional_backend_flags_pca_clamping() {
        let mut cfg = small_cfg();
        cfg.bitcount = BitcountMode::Pca { gamma: 4 }; // absurdly small
        let report = Session::builder()
            .accelerator(cfg)
            .workload(tiny_workload())
            .backend(BackendKind::Functional)
            .build()
            .unwrap()
            .run();
        let c = report.correctness.unwrap();
        assert!(c.pca_clamped > 0, "γ=4 must clamp ~half-ones vectors");
        assert_eq!(c.mismatches, 0);
    }

    #[test]
    fn builder_resolves_names_and_reports_errors() {
        let mut s = Session::builder()
            .accelerator_named("ROBIN_EO")
            .workload_named("vgg_small")
            .build()
            .unwrap();
        assert_eq!(s.accelerator().name, "ROBIN_EO");
        assert_eq!(s.workload().name, "vgg_small");
        assert_eq!(s.backend_kind(), BackendKind::Analytic);
        assert_eq!(s.policy(), MappingPolicy::SlicedSpread); // implied
        assert!(s.run().psums > 0);

        assert!(matches!(
            Session::builder().workload(tiny_workload()).build(),
            Err(ApiError::MissingAccelerator)
        ));
        assert!(matches!(
            Session::builder().accelerator(small_cfg()).build(),
            Err(ApiError::MissingWorkload)
        ));
        assert!(matches!(
            Session::builder()
                .accelerator_named("WARP_CORE")
                .workload(tiny_workload())
                .build(),
            Err(ApiError::UnknownAccelerator(_))
        ));
        assert!(matches!(
            Session::builder()
                .accelerator(small_cfg())
                .workload_named("doom")
                .build(),
            Err(ApiError::UnknownWorkload(_))
        ));
        assert!(matches!(
            Session::builder()
                .accelerator(small_cfg())
                .workload(tiny_workload())
                .batch(0)
                .build(),
            Err(ApiError::ZeroBatch)
        ));
    }

    #[test]
    fn empty_workload_is_an_error_not_a_panic() {
        // Workload::new asserts non-empty, but the struct fields are
        // public — the facade must reject it instead of panicking (event
        // backend) or reporting fps = inf (analytic).
        let w = Workload { name: "empty".into(), layers: vec![] };
        assert!(matches!(
            Session::builder().accelerator(small_cfg()).workload(w).build(),
            Err(ApiError::EmptyWorkload(_))
        ));
    }

    #[test]
    fn simulated_frame_latency_matches_session() {
        let cfg = small_cfg();
        let wl = tiny_workload();
        for kind in [BackendKind::Analytic, BackendKind::Event] {
            let quick = simulated_frame_latency(&cfg, &wl, kind).unwrap();
            let full = Session::builder()
                .accelerator(cfg.clone())
                .workload(wl.clone())
                .backend(kind)
                .build()
                .unwrap()
                .run();
            assert_eq!(quick, full.frame_latency_s, "{}", kind);
            assert!(quick > 0.0);
        }
        let empty = Workload { name: "empty".into(), layers: vec![] };
        assert!(matches!(
            simulated_frame_latency(&cfg, &empty, BackendKind::Analytic),
            Err(ApiError::EmptyWorkload(_))
        ));
    }

    #[test]
    fn sessions_share_one_compiled_plan_through_the_cache() {
        use crate::plan::PlanCache;
        use std::sync::Arc;

        let cache = Arc::new(PlanCache::default());
        let cfg = small_cfg();
        let wl = tiny_workload();
        let mut reports = Vec::new();
        for _ in 0..2 {
            let report = Session::builder()
                .accelerator(cfg.clone())
                .workload(wl.clone())
                .backend(BackendKind::Event)
                .plan_cache(Arc::clone(&cache))
                .build()
                .unwrap()
                .run();
            reports.push(report);
        }
        // One compile, every later run a hit — and bit-identical results.
        assert_eq!(cache.misses(), 1);
        assert!(cache.hits() >= 1);
        assert_eq!(cache.len(), 1);
        assert_eq!(reports[0].frame_latency_s, reports[1].frame_latency_s);
        assert_eq!(reports[0].passes, reports[1].passes);

        // The cached latency helper shares the same entry.
        let quick =
            simulated_frame_latency_cached(&cache, &cfg, &wl, BackendKind::Event)
                .unwrap();
        assert_eq!(quick, reports[0].frame_latency_s);
        assert_eq!(cache.misses(), 1, "helper must not recompile");
    }

    #[test]
    fn analytic_report_convenience_matches_session() {
        let cfg = small_cfg();
        let wl = tiny_workload();
        let quick = analytic_report(&cfg, &wl);
        let full = Session::builder()
            .accelerator(cfg)
            .workload(wl)
            .build()
            .unwrap()
            .run();
        assert_eq!(quick.frame_latency_s, full.frame_latency_s);
        assert_eq!(quick.passes, full.passes);
        assert_eq!(quick.fps_per_w, full.fps_per_w);
    }

    #[test]
    fn backend_kind_parses_and_displays() {
        use std::str::FromStr;
        assert_eq!(BackendKind::from_str("analytic").unwrap(), BackendKind::Analytic);
        assert_eq!(BackendKind::from_str("event").unwrap(), BackendKind::Event);
        assert_eq!(
            BackendKind::from_str("event-driven").unwrap(),
            BackendKind::Event
        );
        assert_eq!(
            BackendKind::from_str("functional").unwrap(),
            BackendKind::Functional
        );
        assert!(BackendKind::from_str("quantum").is_err());
        for kind in BackendKind::all() {
            assert_eq!(BackendKind::from_str(kind.as_str()).unwrap(), kind);
        }
    }

    #[test]
    fn pipelined_event_batch_beats_sequential_multiply() {
        let run = |pipeline: bool| {
            Session::builder()
                .accelerator(small_cfg())
                .workload(tiny_workload())
                .backend(BackendKind::Event)
                .batch(4)
                .pipeline(pipeline)
                .build()
                .unwrap()
                .run()
        };
        let seq = run(false);
        let pipe = run(true);
        assert!(!seq.pipelined && pipe.pipelined);
        // Per-frame transaction counts are conserved exactly.
        assert_eq!(pipe.passes, seq.passes);
        assert_eq!(pipe.psums, seq.psums);
        let e_rel = (pipe.dynamic_energy_per_frame_j - seq.dynamic_energy_per_frame_j)
            .abs()
            / seq.dynamic_energy_per_frame_j;
        assert!(e_rel < 1e-9, "per-frame energy diverged by rel {}", e_rel);
        // Cross-layer overlap: the pipelined first frame is no slower.
        assert!(pipe.frame_latency_s <= seq.frame_latency_s * (1.0 + 1e-9));
        // Multi-frame overlap: the batch strictly beats the multiply.
        assert!(
            pipe.batch_latency_s < seq.batch_latency_s,
            "pipelined batch {} vs sequential {}",
            pipe.batch_latency_s,
            seq.batch_latency_s
        );
        assert!(pipe.batched_fps() > seq.batched_fps());
        assert!(pipe.fps > seq.fps, "pipelined fps must report the throughput win");
        assert!(pipe.fps_per_w > seq.fps_per_w, "static power amortizes over the makespan");
    }

    #[test]
    fn analytic_pipelined_estimate_reads_exact_thresholds() {
        let run = |pipeline: bool| {
            Session::builder()
                .accelerator(small_cfg())
                .workload(tiny_workload())
                .backend(BackendKind::Analytic)
                .batch(4)
                .pipeline(pipeline)
                .build()
                .unwrap()
                .run()
        };
        let plain = run(false);
        let piped = run(true);
        assert!(!plain.pipelined && piped.pipelined);
        // Same per-frame transactions and energy; overlap only moves time.
        assert_eq!(plain.passes, piped.passes);
        assert_eq!(plain.psums, piped.psums);
        assert_eq!(
            plain.dynamic_energy_per_frame_j,
            piped.dynamic_energy_per_frame_j
        );
        // The exact thresholds admit c2 after ~3/8 of c1's map (3×3 same
        // conv on the 4×4 map), so the estimated frame strictly beats the
        // serial layer sum, and the steady-state batch beats the serial
        // multiply.
        assert!(
            piped.frame_latency_s < plain.frame_latency_s,
            "pipelined frame estimate {} vs serial {}",
            piped.frame_latency_s,
            plain.frame_latency_s
        );
        assert!(
            piped.batch_latency_s < plain.batch_latency_s,
            "pipelined estimate {} vs serial {}",
            piped.batch_latency_s,
            plain.batch_latency_s
        );
        assert!(piped.batched_fps() > plain.batched_fps());
        // Sanity floor: a batch cannot beat one bottleneck layer per frame.
        let bottleneck = plain
            .layers
            .iter()
            .map(|l| l.latency_s)
            .fold(0.0_f64, f64::max);
        assert!(piped.batch_latency_s >= 4.0 * bottleneck * (1.0 - 1e-12));
    }

    #[test]
    fn pipeline_knob_is_noop_for_the_functional_backend() {
        let run = |pipeline: bool| {
            Session::builder()
                .accelerator(small_cfg())
                .workload(tiny_workload())
                .backend(BackendKind::Functional)
                .batch(4)
                .pipeline(pipeline)
                .build()
                .unwrap()
                .run()
        };
        let plain = run(false);
        let piped = run(true);
        assert!(!piped.pipelined, "functional has no frame-overlap model");
        assert_eq!(plain.frame_latency_s, piped.frame_latency_s);
        assert_eq!(plain.batch_latency_s, piped.batch_latency_s);
        assert_eq!(plain.fps, piped.fps);
    }

    #[test]
    fn batched_sessions_default_to_the_pipelined_path() {
        // ROADMAP deferral closed: `with_batch` consumers get the
        // pipelined path by default now that the conformance suite covers
        // it; `.pipeline(false)` stays as the opt-out. (The unset default
        // also honors the OXBNN_PIPELINE env override — not set here.)
        let build = |batch: usize| {
            Session::builder()
                .accelerator(small_cfg())
                .workload(tiny_workload())
                .backend(BackendKind::Event)
                .batch(batch)
                .build()
                .unwrap()
        };
        if std::env::var("OXBNN_PIPELINE").is_ok() {
            return; // the CI admission matrix pins the default externally
        }
        assert!(!build(1).pipelined(), "single frames have nothing to overlap");
        assert!(build(4).pipelined(), "batches pipeline by default");
        let mut s = build(4);
        let report = s.run();
        assert!(report.pipelined);
        assert!(report.batch_latency_s <= 4.0 * report.frame_latency_s * (1.0 + 1e-9));
    }

    #[test]
    fn effective_latency_helper_reflects_pipelining() {
        use std::sync::Arc;
        let cache = Arc::new(crate::plan::PlanCache::default());
        let cfg = small_cfg();
        let wl = tiny_workload();
        let seq = simulated_effective_latency_cached(
            &cache, &cfg, &wl, BackendKind::Event, 4, false,
        )
        .unwrap();
        let frame =
            simulated_frame_latency_cached(&cache, &cfg, &wl, BackendKind::Event)
                .unwrap();
        assert!((seq - frame).abs() < 1e-15, "sequential effective == frame latency");
        let pipe = simulated_effective_latency_cached(
            &cache, &cfg, &wl, BackendKind::Event, 4, true,
        )
        .unwrap();
        assert!(pipe < seq, "pipelined effective {} vs sequential {}", pipe, seq);
        assert_eq!(cache.misses(), 1, "all helpers share one compiled plan");
    }

    #[test]
    fn photonic_fps_is_reciprocal_effective_latency() {
        use std::sync::Arc;
        let cache = Arc::new(crate::plan::PlanCache::default());
        let cfg = small_cfg();
        let wl = tiny_workload();
        let lat = simulated_effective_latency_cached(
            &cache, &cfg, &wl, BackendKind::Event, 4, true,
        )
        .unwrap();
        let fps = simulated_photonic_fps_cached(
            &cache, &cfg, &wl, BackendKind::Event, 4, true,
        )
        .unwrap();
        assert!((fps - 1.0 / lat).abs() / fps < 1e-12);
        assert!(fps > 0.0);
    }

    #[test]
    fn batch_scales_batch_latency_only() {
        // Sequential semantics via the explicit `.pipeline(false)` opt-out
        // (batches default to the pipelined path).
        let report = Session::builder()
            .accelerator(small_cfg())
            .workload(tiny_workload())
            .batch(4)
            .pipeline(false)
            .build()
            .unwrap()
            .run();
        assert_eq!(report.batch, 4);
        assert!(
            (report.batch_latency_s - 4.0 * report.frame_latency_s).abs() < 1e-15
        );
        assert!((report.fps - 1.0 / report.frame_latency_s).abs() < 1e-9);
    }

    #[test]
    fn run_layer_works_for_all_backends() {
        let layer = GemmLayer::new("l", 16, 96, 4);
        for kind in BackendKind::all() {
            let mut s = Session::builder()
                .accelerator(small_cfg())
                .workload(tiny_workload())
                .backend(kind)
                .build()
                .unwrap();
            let lr = s.run_layer(&layer);
            assert_eq!(lr.passes, layer.total_passes(9) as u64, "{}", kind);
            assert!(lr.latency_s > 0.0);
        }
    }

    #[test]
    fn report_json_roundtrips() {
        let report = Session::builder()
            .accelerator(small_cfg())
            .workload(tiny_workload())
            .backend(BackendKind::Event)
            .build()
            .unwrap()
            .run();
        let j = report.to_json();
        let back = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            back.get("backend").and_then(crate::util::json::Json::as_str),
            Some("event")
        );
        assert_eq!(
            back.get("passes")
                .and_then(crate::util::json::Json::as_usize),
            Some(report.passes as usize)
        );
        assert_eq!(
            back.get("layers")
                .and_then(crate::util::json::Json::as_arr)
                .map(|a| a.len()),
            Some(3)
        );
    }

    #[test]
    fn custom_backend_plugs_in() {
        /// A trivial fixed-latency model, standing in for future plug-in
        /// execution models.
        struct Flat;
        impl Backend for Flat {
            fn kind(&self) -> BackendKind {
                BackendKind::Analytic
            }
            fn run_layer(
                &mut self,
                _cfg: &AcceleratorConfig,
                layer: &GemmLayer,
                _policy: MappingPolicy,
            ) -> LayerReport {
                LayerReport {
                    name: layer.name.clone(),
                    latency_s: 1e-6,
                    dynamic_energy_j: 0.0,
                    passes: 1,
                    psums: 0,
                    timing: Default::default(),
                    counters: Default::default(),
                    energy_breakdown: Default::default(),
                }
            }
        }
        let report = Session::builder()
            .accelerator(small_cfg())
            .workload(tiny_workload())
            .backend_impl(Box::new(Flat))
            .build()
            .unwrap()
            .run();
        assert_eq!(report.passes, 3);
        assert!((report.frame_latency_s - 3e-6).abs() < 1e-18);
    }
}
