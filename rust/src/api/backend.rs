//! The [`Backend`] trait — one execution model behind the [`super::Session`]
//! facade — and its three built-in implementations:
//!
//! * [`AnalyticBackend`] — the closed-form model ([`crate::arch::perf`]),
//!   fast enough for full Fig. 7 sweeps;
//! * [`EventSimBackend`] — the transaction-level event-driven simulator
//!   ([`crate::arch::event_sim`] / [`crate::arch::workload_sim`]) with real
//!   PCA saturation/discharge dynamics;
//! * [`FunctionalBackend`] — the integer XNOR-bitcount reference
//!   ([`crate::functional::bnn`]), carrying arithmetic correctness through
//!   the same report shape (timing delegated to the analytic model).
//!
//! All three consume the same `(AcceleratorConfig, GemmLayer, MappingPolicy)`
//! inputs and produce the same [`LayerReport`] / [`Report`], so any
//! accelerator — OXBNN variants and the ROBIN/LIGHTBULB baselines alike —
//! compares apples-to-apples across execution models.

use std::collections::BTreeMap;

use super::report::{LayerReport, Report};
use super::session::ApiError;
use crate::arch::accelerator::{AcceleratorConfig, BitcountMode};
use crate::mapping::layer::GemmLayer;
use crate::mapping::scheduler::MappingPolicy;
use crate::plan::ExecutionPlan;
use crate::sim::stats::SimStats;
use crate::workloads::Workload;

/// Which execution model a session runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Closed-form analytic model (default; full-sweep fast path).
    Analytic,
    /// Event-driven transaction-level simulation (detailed, slower).
    Event,
    /// Integer functional reference (correctness-carrying).
    Functional,
}

impl BackendKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Analytic => "analytic",
            BackendKind::Event => "event",
            BackendKind::Functional => "functional",
        }
    }

    /// All kinds, in documentation order.
    pub fn all() -> [BackendKind; 3] {
        [BackendKind::Analytic, BackendKind::Event, BackendKind::Functional]
    }

    /// Instantiate the built-in backend of this kind.
    pub fn create(&self) -> Box<dyn Backend + Send> {
        match self {
            BackendKind::Analytic => Box::new(AnalyticBackend),
            BackendKind::Event => Box::new(EventSimBackend),
            BackendKind::Functional => Box::new(FunctionalBackend::default()),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for BackendKind {
    type Err = ApiError;

    fn from_str(s: &str) -> Result<BackendKind, ApiError> {
        match s {
            "analytic" | "perf" => Ok(BackendKind::Analytic),
            "event" | "event-driven" | "sim" => Ok(BackendKind::Event),
            "functional" | "bnn" => Ok(BackendKind::Functional),
            other => Err(ApiError::UnknownBackend(other.to_string())),
        }
    }
}

/// The mapping policy an accelerator's bitcount hardware implies: PCA
/// designs keep every slice of a VDP on one XPE (Fig. 5(b)); psum-reduction
/// designs spread slices across the XPC (Fig. 5(a)).
pub fn default_policy(cfg: &AcceleratorConfig) -> MappingPolicy {
    match cfg.bitcount {
        BitcountMode::Pca { .. } => MappingPolicy::PcaLocal,
        BitcountMode::Reduction { .. } => MappingPolicy::SlicedSpread,
    }
}

/// One execution model. Implementations are configuration-free: the
/// accelerator under evaluation arrives with every call, which is what
/// lets one backend sweep many accelerators (and any accelerator run on
/// many backends).
pub trait Backend {
    /// Which kind this backend is (stamped into reports).
    fn kind(&self) -> BackendKind;

    /// Evaluate one GEMM layer on one accelerator.
    fn run_layer(
        &mut self,
        cfg: &AcceleratorConfig,
        layer: &GemmLayer,
        policy: MappingPolicy,
    ) -> LayerReport;

    /// Evaluate a whole workload (one inference frame). The default runs
    /// layers sequentially and sums their latencies; backends that model
    /// cross-layer effects (fetch/compute overlap) override this.
    fn run_workload(
        &mut self,
        cfg: &AcceleratorConfig,
        workload: &Workload,
        policy: MappingPolicy,
    ) -> Report {
        let layers: Vec<LayerReport> = workload
            .layers
            .iter()
            .map(|l| self.run_layer(cfg, l, policy))
            .collect();
        let frame: f64 = layers.iter().map(|l| l.latency_s).sum();
        Report::from_layers(self.kind(), cfg, &workload.name, layers, frame)
    }

    /// Evaluate a pre-compiled [`ExecutionPlan`] (the [`super::Session`]
    /// entry point — plans come from the session's
    /// [`crate::plan::PlanCache`]). The default ignores the compiled
    /// mapping and delegates to [`Backend::run_workload`]; backends that
    /// consume the mapping itself (the event simulator) override this to
    /// stream it instead of recompiling.
    fn run_planned(&mut self, plan: &ExecutionPlan) -> Report {
        self.run_workload(&plan.accelerator, &plan.workload, plan.policy)
    }
}

// ---------------------------------------------------------------------------
// Analytic
// ---------------------------------------------------------------------------

/// Closed-form analytic model (wraps [`crate::arch::perf`]). The mapping
/// policy is implied by the bitcount mode, so the `policy` argument does
/// not change the result here.
#[derive(Debug, Clone, Copy, Default)]
pub struct AnalyticBackend;

impl Backend for AnalyticBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Analytic
    }

    fn run_layer(
        &mut self,
        cfg: &AcceleratorConfig,
        layer: &GemmLayer,
        _policy: MappingPolicy,
    ) -> LayerReport {
        let p = crate::arch::perf::layer_perf(cfg, layer);
        let mut timing = BTreeMap::new();
        timing.insert("compute_s".to_string(), p.compute_s);
        timing.insert("memory_s".to_string(), p.memory_s);
        timing.insert("reduce_s".to_string(), p.reduce_s);
        timing.insert("fixed_s".to_string(), p.fixed_s);
        LayerReport {
            name: p.name,
            latency_s: p.latency_s,
            dynamic_energy_j: p.dynamic_energy_j,
            passes: p.passes,
            psums: p.psums,
            timing,
            counters: BTreeMap::new(),
            energy_breakdown: BTreeMap::new(),
        }
    }
}

// ---------------------------------------------------------------------------
// Event-driven
// ---------------------------------------------------------------------------

/// Transaction-level event-driven simulation (wraps
/// [`crate::arch::event_sim`]); whole-workload runs reproduce
/// [`crate::arch::workload_sim::simulate_frame`]'s fetch/compute overlap
/// (pinned by the `event_backend_matches_simulate_frame` test).
#[derive(Debug, Clone, Copy, Default)]
pub struct EventSimBackend;

/// Shape a finished layer's event stats into the unified report slice.
fn layer_report_from_stats(name: &str, stats: &SimStats) -> LayerReport {
    let mut counters = stats.counters().clone();
    counters.insert("events".to_string(), stats.events_processed);
    LayerReport {
        name: name.to_string(),
        latency_s: stats.end_time_s,
        dynamic_energy_j: stats.total_energy_j(),
        passes: stats.counter("passes"),
        psums: stats.counter("psums"),
        timing: BTreeMap::new(),
        counters,
        energy_breakdown: stats.energy_breakdown().clone(),
    }
}

impl Backend for EventSimBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Event
    }

    fn run_layer(
        &mut self,
        cfg: &AcceleratorConfig,
        layer: &GemmLayer,
        policy: MappingPolicy,
    ) -> LayerReport {
        let stats = crate::arch::event_sim::simulate_layer(cfg, layer, policy);
        layer_report_from_stats(&layer.name, &stats)
    }

    /// Whole frames compile (or receive) an [`ExecutionPlan`] and stream
    /// it — see [`EventSimBackend::run_planned`].
    fn run_workload(
        &mut self,
        cfg: &AcceleratorConfig,
        workload: &Workload,
        policy: MappingPolicy,
    ) -> Report {
        self.run_planned(&ExecutionPlan::compile(cfg, workload, policy))
    }

    /// The plan-driven path: every layer streams its compiled pass map
    /// (no schedule materialization, no recompilation on cache hits), and
    /// layers chain with eDRAM prefetch overlap through the same
    /// [`crate::arch::workload_sim::OverlapChain`] recurrence that
    /// [`crate::arch::workload_sim::simulate_frame`] uses (layers run in
    /// separate event spaces there too, so per-layer stats are identical).
    fn run_planned(&mut self, plan: &ExecutionPlan) -> Report {
        let cfg = &plan.accelerator;
        let workload = &plan.workload;
        let mut chain = crate::arch::workload_sim::OverlapChain::new(cfg, workload);
        let layers: Vec<LayerReport> = plan
            .layers
            .iter()
            .map(|lp| {
                let stats = crate::arch::event_sim::simulate_layer_planned(cfg, lp);
                let lr = layer_report_from_stats(&lp.layer.name, &stats);
                chain.step(lr.latency_s);
                lr
            })
            .collect();
        Report::from_layers(
            self.kind(),
            cfg,
            &workload.name,
            layers,
            chain.frame_latency_s(),
        )
    }
}

// ---------------------------------------------------------------------------
// Functional
// ---------------------------------------------------------------------------

/// Integer XNOR-bitcount reference: recomputes a deterministic sample of
/// each layer's VDPs bit-exactly two ways — whole-vector popcount vs the
/// sliced accumulation an XPE actually performs — and flags VDPs whose
/// bitcount would saturate the PCA (γ). Timing and energy are delegated to
/// the analytic model; the value carried here is the
/// [`super::Correctness`] block in the report.
#[derive(Debug, Clone)]
pub struct FunctionalBackend {
    /// Seed for the synthetic {0,1} operands (deterministic per layer).
    pub seed: u64,
    /// Cap on VDPs recomputed per layer (keeps big layers affordable).
    pub max_checked_vdps: usize,
}

impl Default for FunctionalBackend {
    fn default() -> Self {
        FunctionalBackend { seed: 0xB17C0, max_checked_vdps: 256 }
    }
}

impl Backend for FunctionalBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Functional
    }

    fn run_layer(
        &mut self,
        cfg: &AcceleratorConfig,
        layer: &GemmLayer,
        _policy: MappingPolicy,
    ) -> LayerReport {
        use crate::mapping::slicing::{slice_xnor_popcount, slices};

        let analytic = crate::arch::perf::layer_perf(cfg, layer);
        let mut rng = crate::util::rng::Rng::new(
            self.seed
                ^ (layer.h as u64).wrapping_mul(0x9E3779B9)
                ^ (layer.s as u64).wrapping_mul(0x85EBCA6B)
                ^ (layer.k as u64),
        );
        let gamma = match cfg.bitcount {
            BitcountMode::Pca { gamma } => Some(gamma),
            BitcountMode::Reduction { .. } => None,
        };
        let slice_plan = slices(layer.s, cfg.n);
        let check = layer.vdp_count().min(self.max_checked_vdps.max(1));
        let mut mismatches = 0u64;
        let mut clamped = 0u64;
        for _ in 0..check {
            let input = rng.bits(layer.s);
            let weight = rng.bits(layer.s);
            let whole = slice_xnor_popcount(&input, &weight);
            let sliced: u64 = slice_plan
                .iter()
                .map(|sl| {
                    slice_xnor_popcount(
                        &input[sl.start..sl.start + sl.len],
                        &weight[sl.start..sl.start + sl.len],
                    )
                })
                .sum();
            if sliced != whole {
                mismatches += 1;
            }
            if let Some(g) = gamma {
                if whole > g {
                    clamped += 1;
                }
            }
        }
        // `passes`/`psums` live in the dedicated LayerReport fields; the
        // counters map carries only what this backend uniquely measures.
        let mut counters = BTreeMap::new();
        counters.insert("checked_vdps".to_string(), check as u64);
        counters.insert("mismatches".to_string(), mismatches);
        counters.insert("pca_clamped".to_string(), clamped);
        LayerReport {
            name: layer.name.clone(),
            latency_s: analytic.latency_s,
            dynamic_energy_j: analytic.dynamic_energy_j,
            passes: analytic.passes,
            psums: analytic.psums,
            timing: BTreeMap::new(),
            counters,
            energy_breakdown: BTreeMap::new(),
        }
    }
}
